"""§Perf hillclimb driver: run the three chosen cells through their
iteration ladders, writing tagged JSONs to results/perf/."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
sys.path.insert(0, "/root/repo/src")

from repro.launch.dryrun import run_cell

OUT = "/root/repo/results/perf"

def show(r):
    if r["status"] != "ok":
        print("   ERROR:", r.get("error", "")[:300]); return
    roof = r["roofline"]
    print(f"   peak={r['peak_bytes_per_device']/1e9:6.2f}GB "
          f"step={roof['step_s']:8.3f}s [{roof['bottleneck']}] "
          f"comp={roof['compute_s']:.3f}s mem={roof['memory_s']:.3f}s "
          f"coll={roof['collective_s']:.4f}s "
          f"useful={roof['useful_flops_ratio']:.3f} frac={roof['roofline_fraction']:.4f}")

RUNS = [
    # Cell A: qwen1.5-110b train_4k single — representative big-model training
    ("A1_grad_shard", "qwen1.5-110b", "train_4k", "single", {}, {}),
    ("A2_bf16_grads", "qwen1.5-110b", "train_4k", "single", {}, {"grad_dtype": "bfloat16"}),
    ("A3_dots_policy", "qwen1.5-110b", "train_4k", "single", {},
     {"grad_dtype": "bfloat16", "remat_policy": "dots_with_no_batch_dims_saveable"}),
    # Cell B: qwen1.5-110b decode_32k single — serving path (paper-representative)
    ("B1_int8_kv", "qwen1.5-110b", "decode_32k", "single",
     {"kv_cache_dtype": "int8"}, {}),
    # Cell C: deepseek-moe prefill_32k multi — worst replication / collective
    ("C1_expert_cap_shard", "deepseek-moe-16b", "prefill_32k", "multi", {}, {}),
    ("C2_cap_factor1", "deepseek-moe-16b", "prefill_32k", "multi",
     {"capacity_factor": 1.0}, {}),
]

only = sys.argv[1:] or None
for tag, arch, shape, mesh, cfg_ov, ov in RUNS:
    if only and not any(tag.startswith(o) for o in only):
        continue
    print(f"== {tag}: {arch} x {shape} x {mesh} {cfg_ov} {ov}")
    r = run_cell(arch, shape, mesh, OUT, cfg_overrides=cfg_ov, tag=tag, **ov)
    show(r)

EXTRA = [
    ("A4_ce_chunk4k", "qwen1.5-110b", "train_4k", "single",
     {"ce_chunk": 4096}, {}),
    ("A5_attn_chunk2k", "qwen1.5-110b", "train_4k", "single",
     {"attn_chunk": 2048}, {}),
    ("B2_int8_kv_multi", "qwen1.5-110b", "decode_32k", "multi",
     {"kv_cache_dtype": "int8"}, {}),
    ("C3_revert_expert_shard", "deepseek-moe-16b", "prefill_32k", "multi", {}, {}),
    ("C4_cap1_and_microchunk", "deepseek-moe-16b", "prefill_32k", "multi",
     {"capacity_factor": 1.0}, {}),
]
for tag, arch, shape, mesh, cfg_ov, ov in EXTRA:
    if only and not any(tag.startswith(o) for o in only):
        continue
    print(f"== {tag}: {arch} x {shape} x {mesh} {cfg_ov} {ov}")
    r = run_cell(arch, shape, mesh, OUT, cfg_overrides=cfg_ov, tag=tag, **ov)
    show(r)
