import sys, shutil
sys.path.insert(0, "/root/repo/src")
import jax
from repro.configs import SMOKES
from repro.serving import Orchestrator
from repro.launch import steps

shutil.rmtree("/root/repo/.devstore2", ignore_errors=True)
orch = Orchestrator("/root/repo/.devstore2", mode="reap", keepalive_s=0.2)
cfg = SMOKES["qwen2-7b"]
batch = steps.make_batch(cfg, 32, 2, "train", jax.random.key(0))
orch.register("fn-qwen", cfg, seed=5, warmup_batch=batch)

# 1st invocation: cold + record
_, r1 = orch.invoke("fn-qwen", batch)
print(f"cold#1 (record): vmm={r1.load_vmm_s*1e3:.1f}ms conn={r1.connection_s*1e6:.0f}us "
      f"proc={r1.processing_s*1e3:.0f}ms faults={r1.n_faults}")
# 2nd: warm (instance kept)
_, r2 = orch.invoke("fn-qwen", batch)
print(f"warm:            proc={r2.processing_s*1e3:.1f}ms faults={r2.n_faults}")
# scale to zero, then cold with REAP prefetch
orch.scale_to_zero("fn-qwen")
_, r3 = orch.invoke("fn-qwen", batch)
print(f"cold#2 (REAP):   vmm={r3.load_vmm_s*1e3:.1f}ms prefetch={r3.prefetch_s*1e3:.1f}ms "
      f"({r3.n_prefetched_pages}p) proc={r3.processing_s*1e3:.0f}ms faults={r3.n_faults}")
# keepalive sweep
import time; time.sleep(0.3)
n = orch.reap_idle()
print("reclaimed:", n)
# vanilla orchestrator for comparison
orch2 = Orchestrator("/root/repo/.devstore2", mode="vanilla")
orch2.register("fn-qwen", cfg, seed=5, warmup_batch=batch)
_, r4 = orch2.invoke("fn-qwen", batch, force_cold=True)
print(f"cold vanilla:    proc={r4.processing_s*1e3:.0f}ms faults={r4.n_faults} fault_s={r4.fault_s*1e3:.0f}ms")
print("serving OK")
