#!/usr/bin/env python
"""Render the fleet control room: one self-contained HTML dashboard from
``results/telemetry/*.jsonl``.

The telemetry pipeline (src/repro/telemetry/) is emitters -> registry ->
snapshotter -> jsonl; this script is the consumer tier.  It parses every
snapshotter stream in the telemetry directory, precomputes the panel
series in Python, and inlines them into a single static HTML file — no
build step, no external assets, openable from a CI artifact tab.

Panels:

  * **Warm instances per node** — ``sources.cluster.nodes[id]
    .warm_instances`` summed per node over time: is the prewarm plane
    keeping pools where the load is?
  * **Cache tiers** — cumulative WS page-cache hit rate (registry
    ``ws_cache.hits`` / ``ws_cache.misses``) against the sharded store's
    L1 ``local_hit_rate``: which tier absorbs restores.
  * **Restore-stage breakdown** — cumulative mean seconds per pipeline
    stage (registry ``restore.<stage>_s`` histograms): where a cold start
    spends its time, over time.
  * **Forecast vs actual demand** — the demand plane's modeled
    per-function rates (``sources.cluster.demand.functions``) summed,
    against the observed fleet completion rate (derivative of the summed
    router ``completed`` counters).
  * **Transport** — socket-fleet page-transport per node
    (``nodes[id].transport``, repro.transport): cumulative wire bytes,
    fetch RTT p95, and the codec's compression ratio.  Streams recorded
    by an inproc fleet (or before the transport layer existed) carry no
    such block; the panel degrades to a "no transport data" note rather
    than silently vanishing.

Usage: python scripts/control_room.py [--telemetry-dir results/telemetry]
                                      [--out results/telemetry/control_room.html]
"""
from __future__ import annotations

import argparse
import glob
import html
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_DIR = os.path.join(ROOT, "results", "telemetry")

RESTORE_STAGES = ("load_vmm", "connect", "ws_fetch", "install",
                  "materialize")


def load_streams(telemetry_dir: str) -> dict[str, list[dict]]:
    """{stream name: [sample, ...]} for every ``*.jsonl`` in the dir."""
    streams: dict[str, list[dict]] = {}
    for path in sorted(glob.glob(os.path.join(telemetry_dir, "*.jsonl"))):
        name = os.path.splitext(os.path.basename(path))[0]
        samples = []
        with open(path, encoding="utf-8") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue                 # torn tail line: skip
                if isinstance(rec, dict) and "sources" in rec:
                    samples.append(rec)
        if samples:
            streams[name] = samples
    return streams


def _dig(d, *path):
    for p in path:
        if not isinstance(d, dict) or p not in d:
            return None
        d = d[p]
    return d


def _num(v):
    return v if isinstance(v, (int, float)) and not isinstance(v, bool) \
        else None


def build_panels(streams: dict[str, list[dict]]) -> list[dict]:
    """Panel series from the raw samples.  Each panel: {title, unit,
    series: [{label, points: [[t, v], ...]}]}."""
    panels = []
    for stream, samples in streams.items():
        t0 = samples[0].get("t", 0.0)

        warm: dict[str, list] = {}
        wire: dict[str, list] = {}
        rtt95: dict[str, list] = {}
        cratio: dict[str, list] = {}
        saw_fleet_nodes = False
        ws_rate, l1_rate, demand_fc, demand_actual = [], [], [], []
        stages: dict[str, list] = {s: [] for s in RESTORE_STAGES}
        prev_completed = prev_t = None
        for rec in samples:
            t = round(rec.get("t", 0.0) - t0, 3)
            cluster = _dig(rec, "sources", "cluster") or \
                _dig(rec, "sources", "node")
            reg = _dig(rec, "sources", "registry") or {}

            nodes = _dig(cluster, "nodes") if cluster else None
            if nodes is None and cluster and "warm_instances" in cluster:
                nodes = {cluster.get("node", stream): cluster}
            completed_total = 0.0
            have_completed = False
            for node_id, ns in sorted((nodes or {}).items()):
                wi = _dig(ns, "warm_instances")
                if isinstance(wi, dict):
                    warm.setdefault(node_id, []).append(
                        [t, sum(v for v in wi.values()
                                if _num(v) is not None)])
                c = _num(_dig(ns, "router", "completed"))
                if c is not None:
                    completed_total += c
                    have_completed = True
                saw_fleet_nodes = True
                tr = _dig(ns, "transport")
                if isinstance(tr, dict):
                    tx = _num(tr.get("wire_tx_bytes")) or 0
                    rx = _num(tr.get("wire_rx_bytes")) or 0
                    wire.setdefault(node_id, []).append([t, tx + rx])
                    if _num(_dig(tr, "fetch_rtt_s", "count")):
                        p95 = _num(_dig(tr, "fetch_rtt_s", "p95"))
                        if p95 is not None:
                            rtt95.setdefault(node_id, []).append([t, p95])
                    cr = _num(tr.get("compress_ratio"))
                    if cr is not None:
                        cratio.setdefault(node_id, []).append([t, cr])

            hits = _num(_dig(reg, "counters", "ws_cache.hits")) or 0
            misses = _num(_dig(reg, "counters", "ws_cache.misses")) or 0
            if hits + misses:
                ws_rate.append([t, hits / (hits + misses)])
            lhr = _num(_dig(cluster, "store", "local_hit_rate"))
            if lhr is not None:
                l1_rate.append([t, lhr])

            for stage in RESTORE_STAGES:
                h = _dig(reg, "histograms", f"restore.{stage}_s")
                if h and _num(h.get("count")):
                    stages[stage].append([t, h["sum"] / h["count"]])

            fns = _dig(cluster, "demand", "functions")
            if isinstance(fns, dict):
                rates = [_num(_dig(f, "rate")) for f in fns.values()]
                rates = [r for r in rates if r is not None]
                if rates:
                    demand_fc.append([t, sum(rates)])
            if have_completed:
                if prev_completed is not None and t > prev_t:
                    d = (completed_total - prev_completed) / (t - prev_t)
                    if d >= 0:               # counter reset between arms
                        demand_actual.append([t, d])
                prev_completed, prev_t = completed_total, t

        if warm:
            panels.append({
                "title": f"{stream}: warm instances per node",
                "unit": "instances",
                "series": [{"label": nid, "points": pts}
                           for nid, pts in sorted(warm.items())]})
        tiers = []
        if ws_rate:
            tiers.append({"label": "ws page-cache hit rate",
                          "points": ws_rate})
        if l1_rate:
            tiers.append({"label": "store L1 local hit rate",
                          "points": l1_rate})
        if tiers:
            panels.append({"title": f"{stream}: cache tiers",
                           "unit": "hit rate", "series": tiers})
        stage_series = [{"label": s, "points": pts}
                        for s, pts in stages.items() if pts]
        if stage_series:
            panels.append({
                "title": f"{stream}: restore-stage mean seconds",
                "unit": "s", "series": stage_series})
        demand_series = []
        if demand_fc:
            demand_series.append({"label": "forecast rate (demand plane)",
                                  "points": demand_fc})
        if demand_actual:
            demand_series.append({"label": "actual completion rate",
                                  "points": demand_actual})
        if demand_series:
            panels.append({
                "title": f"{stream}: forecast vs actual demand",
                "unit": "rps", "series": demand_series})
        if wire:
            panels.append({
                "title": f"{stream}: transport wire bytes per node",
                "unit": "bytes",
                "series": [{"label": nid, "points": pts}
                           for nid, pts in sorted(wire.items())]})
            if rtt95:
                panels.append({
                    "title": f"{stream}: transport fetch RTT p95 per node",
                    "unit": "s",
                    "series": [{"label": nid, "points": pts}
                               for nid, pts in sorted(rtt95.items())]})
            if cratio:
                panels.append({
                    "title": f"{stream}: transport compression ratio",
                    "unit": "logical/wire",
                    "series": [{"label": nid, "points": pts}
                               for nid, pts in sorted(cratio.items())]})
        elif saw_fleet_nodes:
            # old run or inproc fleet: keep the panel slot visible so the
            # dashboard says *why* there are no transport series
            panels.append({
                "title": f"{stream}: transport",
                "unit": "",
                "series": [],
                "note": "no transport data — inproc fleet (modeled "
                        "TransferModel network) or a run predating "
                        "repro.transport"})
    return panels


_PAGE = """<!doctype html>
<html><head><meta charset="utf-8"><title>fleet control room</title>
<style>
 body {{ font: 13px/1.4 -apple-system, 'Segoe UI', sans-serif;
        background: #0f1318; color: #d8dee6; margin: 24px; }}
 h1 {{ font-size: 18px; }} h2 {{ font-size: 14px; margin: 4px 0; }}
 .meta {{ color: #7a8699; margin-bottom: 16px; }}
 .grid {{ display: grid; grid-template-columns: repeat(auto-fill,
          minmax(460px, 1fr)); gap: 18px; }}
 .panel {{ background: #171d25; border: 1px solid #232c38;
           border-radius: 8px; padding: 12px; }}
 svg {{ width: 100%; height: 220px; }}
 .legend span {{ margin-right: 12px; white-space: nowrap; }}
 .legend i {{ display: inline-block; width: 10px; height: 10px;
              border-radius: 2px; margin-right: 4px; }}
</style></head><body>
<h1>fleet control room</h1>
<div class="meta">{meta}</div>
<div class="grid" id="grid"></div>
<script>
const PANELS = {panels_json};
const COLORS = ["#58a6ff","#3fb950","#d29922","#f85149","#bc8cff",
                "#39c5cf","#ff7b72","#7ee787","#e3b341","#79c0ff"];
function chart(panel) {{
  const W = 460, H = 220, L = 46, B = 24, T = 8, R = 8;
  let xs = [], ys = [];
  for (const s of panel.series) for (const [x, y] of s.points) {{
    xs.push(x); ys.push(y);
  }}
  if (!xs.length) return "";
  const x0 = Math.min(...xs), x1 = Math.max(...xs) || 1;
  const y0 = Math.min(0, ...ys), y1 = Math.max(...ys) || 1;
  const sx = x => L + (x - x0) / (x1 - x0 || 1) * (W - L - R);
  const sy = y => H - B - (y - y0) / (y1 - y0 || 1) * (H - B - T);
  let out = `<svg viewBox="0 0 ${{W}} ${{H}}">`;
  for (let i = 0; i <= 4; i++) {{
    const y = y0 + (y1 - y0) * i / 4, py = sy(y);
    out += `<line x1="${{L}}" y1="${{py}}" x2="${{W - R}}" y2="${{py}}"
             stroke="#232c38"/>` +
           `<text x="${{L - 4}}" y="${{py + 4}}" fill="#7a8699"
             font-size="10" text-anchor="end">${{y.toPrecision(3)}}</text>`;
  }}
  for (let i = 0; i <= 4; i++) {{
    const x = x0 + (x1 - x0) * i / 4, px = sx(x);
    out += `<text x="${{px}}" y="${{H - 8}}" fill="#7a8699" font-size="10"
             text-anchor="middle">${{x.toFixed(1)}}s</text>`;
  }}
  panel.series.forEach((s, i) => {{
    const pts = s.points.map(([x, y]) => `${{sx(x)}},${{sy(y)}}`).join(" ");
    const c = COLORS[i % COLORS.length];
    out += s.points.length > 1
      ? `<polyline points="${{pts}}" fill="none" stroke="${{c}}"
          stroke-width="1.6"/>`
      : `<circle cx="${{sx(s.points[0][0])}}" cy="${{sy(s.points[0][1])}}"
          r="3" fill="${{c}}"/>`;
  }});
  return out + "</svg>";
}}
const grid = document.getElementById("grid");
for (const panel of PANELS) {{
  const div = document.createElement("div");
  div.className = "panel";
  if (panel.note) {{
    div.innerHTML = `<h2>${{panel.title}}</h2>` +
      `<div style="color:#7a8699;padding:24px 0">${{panel.note}}</div>`;
    grid.appendChild(div);
    continue;
  }}
  const legend = panel.series.map((s, i) =>
    `<span><i style="background:${{COLORS[i % COLORS.length]}}"></i>` +
    `${{s.label}}</span>`).join("");
  div.innerHTML = `<h2>${{panel.title}} <small style="color:#7a8699">` +
    `(${{panel.unit}})</small></h2>${{chart(panel)}}` +
    `<div class="legend">${{legend}}</div>`;
  grid.appendChild(div);
}}
if (!PANELS.length)
  grid.innerHTML = "<div class='panel'>no telemetry samples found</div>";
</script></body></html>
"""


def render(streams: dict[str, list[dict]], out_path: str) -> int:
    panels = build_panels(streams)
    n = sum(len(s) for s in streams.values())
    meta = (f"{len(streams)} stream(s), {n} sample(s), "
            f"{len(panels)} panel(s) — "
            + ", ".join(f"{k} ({len(v)})" for k, v in streams.items()))
    page = _PAGE.format(meta=html.escape(meta),
                        panels_json=json.dumps(panels))
    d = os.path.dirname(out_path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(out_path, "w", encoding="utf-8") as f:
        f.write(page)
    print(f"control room: {len(panels)} panel(s) from {n} sample(s) "
          f"-> {out_path}")
    return len(panels)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--telemetry-dir", default=DEFAULT_DIR)
    ap.add_argument("--out", default=None,
                    help="output html (default <telemetry-dir>/"
                         "control_room.html)")
    args = ap.parse_args(argv)
    out = args.out or os.path.join(args.telemetry_dir, "control_room.html")
    streams = load_streams(args.telemetry_dir)
    if not streams:
        print(f"control_room: no *.jsonl under {args.telemetry_dir} — "
              "run a quick cluster benchmark first "
              "(PYTHONPATH=src python -m benchmarks.cluster --quick)",
              file=sys.stderr)
        render({}, out)                      # still emit an empty shell
        return 0
    render(streams, out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
