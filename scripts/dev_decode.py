import sys, dataclasses
import jax, jax.numpy as jnp
sys.path.insert(0, "/root/repo/src")
from repro.configs import SMOKES
from repro.launch import steps

failures = []
for name, cfg in SMOKES.items():
    try:
        if cfg.n_experts:  # no-drop capacity so teacher-forced == decode
            cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
        key = jax.random.key(1)
        params = steps.init_params(cfg, key)
        B, S, EXTRA = 2, 32, 4
        full = steps.make_batch(cfg, S + EXTRA, B, "train", key)
        fwd = steps.build_forward(cfg)
        ref_logits = fwd(params, full)
        n_img = full["patch_embeds"].shape[1] if cfg.family == "vlm" else 0
        n_txt = full["tokens"].shape[1]
        S = n_txt - EXTRA  # prompt length in *text* tokens

        max_len = n_txt + EXTRA + n_img
        cache = steps.init_cache(cfg, B, max_len)
        pre_batch = dict(full)
        pre_batch["tokens"] = full["tokens"][:, :S]
        prefill = steps.build_prefill_step(cfg)
        dec = steps.build_decode_step(cfg)
        logits, cache = prefill(params, pre_batch, cache)
        ref_pf = ref_logits[:, n_img + S - 1, :]
        err = float(jnp.max(jnp.abs(logits[:, -1, :].astype(jnp.float32) - ref_pf.astype(jnp.float32))))
        assert err < 0.15, f"prefill mismatch {err}"

        for i in range(EXTRA):
            db = {"tokens": full["tokens"][:, S + i][:, None]}
            pos = n_img + S + i
            logits, cache = dec(params, cache, db, pos)
            ref_d = ref_logits[:, n_img + S + i, :]
            err = float(jnp.max(jnp.abs(logits[:, -1, :].astype(jnp.float32) - ref_d.astype(jnp.float32))))
            assert err < 0.2, f"decode step {i} mismatch {err}"
        print(f"[OK decode] {name}")
    except Exception as e:
        import traceback; traceback.print_exc()
        failures.append(name)
        print(f"[FAIL] {name}: {e}")
print("FAILURES:", failures)
