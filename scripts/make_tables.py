"""Generate markdown tables from results artifacts.

Modes (``python scripts/make_tables.py [mode]``):

  * ``dryrun`` / ``roofline`` / ``coll`` — the §Dry-run / §Roofline tables
    from ``results/dryrun/*.json`` (the accelerator dry-run sweep).
  * ``bench`` — render the quick-benchmark artifacts
    (``BENCH_scalability.json`` / ``BENCH_cluster.json``): Fig. 9 rows,
    the burst / overlap A/Bs with their PR-6 ``stage_seconds`` breakdown,
    and the provisioning-policy A/B.
  * ``all`` (default) — dryrun + roofline + coll.

Every artifact key is fetched through :func:`req`, which raises a
``SystemExit`` *naming the missing key and the file it was missing from*.
A silently blank cell in a committed table is a schema drift bug that
nobody notices for three PRs; a named error at generation time is fixed in
one.
"""
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRY = os.path.join(ROOT, "results", "dryrun")
ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCHS = ["qwen1.5-110b", "qwen2-7b", "mistral-nemo-12b", "olmo-1b",
         "zamba2-1.2b", "deepseek-moe-16b", "llama4-maverick-400b-a17b",
         "seamless-m4t-medium", "pixtral-12b", "rwkv6-7b"]


def req(d, path, *, src):
    """Fetch ``a.b.c`` from nested dicts; exit naming the key on a miss."""
    node = d
    for part in path.split("."):
        if not isinstance(node, dict) or part not in node:
            raise SystemExit(
                f"make_tables: required key {path!r} missing from {src} "
                f"(stopped at {part!r}) — artifact schema drifted; "
                "regenerate the artifact or update this table")
        node = node[part]
    return node


def cell(arch, shape, mesh):
    fn = os.path.join(DRY, f"{arch}__{shape}__{mesh}.json")
    if not os.path.exists(fn):
        return None, fn
    with open(fn) as f:
        return json.load(f), fn


def dryrun_table(mesh):
    print(f"\n### {'Single-pod 16x16 (256 chips)' if mesh=='single' else 'Multi-pod 2x16x16 (512 chips)'}\n")
    print("| arch | shape | status | peak GB/dev | fits 16GB | micro | lower+compile s |")
    print("|---|---|---|---|---|---|---|")
    for a in ARCHS:
        for s in ORDER:
            c, fn = cell(a, s, mesh)
            if c is None:
                continue
            status = req(c, "status", src=fn)
            if status == "skipped":
                print(f"| {a} | {s} | skipped (full attention @500k) | — | — | — | — |")
                continue
            if status != "ok":
                print(f"| {a} | {s} | **ERROR** | — | — | — | — |")
                continue
            mb = req(c, "meta.microbatches", src=fn)
            print(f"| {a} | {s} | ok | {req(c, 'peak_bytes_per_device', src=fn)/1e9:.2f} | "
                  f"{'yes' if req(c, 'fits_hbm', src=fn) else 'no'} | {mb} | "
                  f"{req(c, 'lower_s', src=fn)+req(c, 'compile_s', src=fn):.0f} |")


def roofline_table(mesh):
    print(f"\n### Roofline terms — {mesh} pod mesh\n")
    print("| arch | shape | compute s | memory s | collective s | bottleneck | "
          "MODEL_FLOPs | useful ratio | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for a in ARCHS:
        for s in ORDER:
            c, fn = cell(a, s, mesh)
            if c is None or c.get("status") != "ok":
                continue
            r = req(c, "roofline", src=fn)
            print(f"| {a} | {s} | {req(r, 'compute_s', src=fn):.3f} | "
                  f"{req(r, 'memory_s', src=fn):.3f} | "
                  f"{req(r, 'collective_s', src=fn):.3f} | "
                  f"{req(r, 'bottleneck', src=fn)} | "
                  f"{req(r, 'model_flops', src=fn):.2e} | "
                  f"{req(r, 'useful_flops_ratio', src=fn):.3f} | "
                  f"{req(r, 'roofline_fraction', src=fn):.4f} |")


def coll_detail(mesh):
    print(f"\n### Collective mix — {mesh} (bytes/device/step)\n")
    print("| arch | shape | all-gather | all-reduce | reduce-scatter | all-to-all | permute |")
    print("|---|---|---|---|---|---|---|")
    for a in ARCHS:
        for s in ORDER:
            c, fn = cell(a, s, mesh)
            if c is None or c.get("status") != "ok":
                continue
            b = req(c, "collectives.bytes", src=fn)
            f = lambda k: f"{b.get(k,0)/1e9:.2f}G"
            print(f"| {a} | {s} | {f('all-gather')} | {f('all-reduce')} | "
                  f"{f('reduce-scatter')} | {f('all-to-all')} | "
                  f"{f('collective-permute')} |")


# -- bench mode: BENCH_*.json quick-benchmark artifacts --------------------

#: The PR-6 per-stage seconds schema (``summarize()['stage_seconds']``).
STAGE_KEYS = ("load_vmm_s", "connection_s", "ws_fetch_s", "install_s",
              "materialize_s", "tail_wait_s")


def _load_artifact(name):
    path = os.path.join(ROOT, name)
    if not os.path.exists(path):
        return None, path
    with open(path) as f:
        return json.load(f), path


def bench_scalability():
    art, src = _load_artifact("BENCH_scalability.json")
    if art is None:
        print(f"\n(no {os.path.basename(src)} — run "
              "`PYTHONPATH=src python -m benchmarks.scalability --quick`)")
        return
    print("\n### Fig. 9 — cold-start latency vs concurrency\n")
    print("| label | us/call | derived |")
    print("|---|---|---|")
    for row in req(art, "fig9", src=src):
        print(f"| {req(row, 'label', src=src)} | "
              f"{req(row, 'us_per_call', src=src):.0f} | "
              f"{req(row, 'derived', src=src)} |")

    print("\n### Burst-restore A/B — batched vs unbatched group cold starts\n")
    print("| depth | arm | ws_reads | ws_waits | install mean (ms) | "
          "cold e2e p95 (ms) | wall (ms) |")
    print("|---|---|---|---|---|---|---|")
    for depth, arms in sorted(req(art, "burst_ab", src=src).items()):
        for arm in ("unbatched", "batched"):
            o = req(arms, arm, src=f"{src}:burst_ab.{depth}")
            print(f"| {depth} | {arm} | {req(o, 'ws_reads', src=src)} | "
                  f"{req(o, 'ws_waits', src=src)} | "
                  f"{req(o, 'install_mean_s', src=src)*1e3:.2f} | "
                  f"{req(o, 'cold_e2e_p95_s', src=src)*1e3:.1f} | "
                  f"{req(o, 'wall_s', src=src)*1e3:.1f} |")

    print("\n### Overlapped-restore A/B — per-stage seconds (PR-6 schema)\n")
    header = "| arm | restore p95 (ms) | ttfr wall (ms) | " + \
        " | ".join(k[:-2] for k in STAGE_KEYS) + " |"
    print(header)
    print("|---" * (3 + len(STAGE_KEYS)) + "|")
    overlap = req(art, "overlap_ab", src=src)
    for arm in ("resident", "overlap"):
        o = req(overlap, arm, src=f"{src}:overlap_ab")
        stages = req(o, "stage_seconds", src=f"{src}:overlap_ab.{arm}")
        cells = " | ".join(
            f"{req(stages, k, src=f'{src}:overlap_ab.{arm}.stage_seconds')*1e3:.2f}"
            for k in STAGE_KEYS)
        print(f"| {arm} | {req(o, 'cold_restore_p95_s', src=src)*1e3:.1f} | "
              f"{req(o, 'ttfr_wall_s', src=src)*1e3:.1f} | {cells} |")

    print("\n### Provisioning-policy A/B\n")
    print("| trace | arm | cold fraction | prewarmed | e2e p95 (ms) | "
          "ws cache hit rate |")
    print("|---|---|---|---|---|---|")
    for tname, arms in sorted(req(art, "policy_ab", src=src).items()):
        for arm, o in sorted(arms.items()):
            print(f"| {tname} | {arm} | "
                  f"{req(o, 'cold_fraction', src=src):.3f} | "
                  f"{req(o, 'prewarmed_served', src=src)} | "
                  f"{req(o, 'e2e_p95_s', src=src)*1e3:.1f} | "
                  f"{req(o, 'ws_cache_hit_rate', src=src):.3f} |")


def bench_cluster():
    art, src = _load_artifact("BENCH_cluster.json")
    if art is None:
        print(f"\n(no {os.path.basename(src)} — run "
              "`PYTHONPATH=src python -m benchmarks.cluster --quick`)")
        return
    print("\n### Cluster placement A/B\n")
    print("| trace | arm | cold p95 (ms) | local hit rate |")
    print("|---|---|---|---|")

    def walk(d, prefix):
        if not isinstance(d, dict):
            return
        if "cold_p95_s" in d or "local_hit_rate" in d:
            cold = d.get("cold_p95_s")
            lhr = d.get("local_hit_rate")
            trace, _, arm = prefix.rpartition(".")
            cold_cell = f"{cold*1e3:.1f}" if cold is not None else "—"
            lhr_cell = f"{lhr:.3f}" if lhr is not None else "—"
            print(f"| {trace or '—'} | {arm} | {cold_cell} | {lhr_cell} |")
            return
        for k, v in sorted(d.items()):
            walk(v, f"{prefix}.{k}" if prefix else k)

    for section in ("placement_ab", "demand_plane"):
        walk(req(art, section, src=src), section)


def bench_tables():
    bench_scalability()
    bench_cluster()


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        dryrun_table("single")
        dryrun_table("multi")
    if which in ("all", "roofline"):
        roofline_table("single")
    if which in ("all", "coll"):
        coll_detail("single")
    if which == "bench":
        bench_tables()
