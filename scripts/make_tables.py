"""Generate the §Dry-run / §Roofline markdown tables from results/dryrun."""
import json
import os
import sys

DRY = "/root/repo/results/dryrun"
ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCHS = ["qwen1.5-110b", "qwen2-7b", "mistral-nemo-12b", "olmo-1b",
         "zamba2-1.2b", "deepseek-moe-16b", "llama4-maverick-400b-a17b",
         "seamless-m4t-medium", "pixtral-12b", "rwkv6-7b"]


def cell(arch, shape, mesh):
    fn = os.path.join(DRY, f"{arch}__{shape}__{mesh}.json")
    if not os.path.exists(fn):
        return None
    with open(fn) as f:
        return json.load(f)


def fmt(c):
    if c is None:
        return "—"
    if c["status"] == "skipped":
        return "skip"
    if c["status"] != "ok":
        return "ERR"
    r = c["roofline"]
    return (f"{r['compute_s']:.2f}/{r['memory_s']:.2f}/{r['collective_s']:.2f}s "
            f"**{r['bottleneck'][:4]}** f={r['roofline_fraction']:.3f}")


def dryrun_table(mesh):
    print(f"\n### {'Single-pod 16x16 (256 chips)' if mesh=='single' else 'Multi-pod 2x16x16 (512 chips)'}\n")
    print("| arch | shape | status | peak GB/dev | fits 16GB | micro | lower+compile s |")
    print("|---|---|---|---|---|---|---|")
    for a in ARCHS:
        for s in ORDER:
            c = cell(a, s, mesh)
            if c is None:
                continue
            if c["status"] == "skipped":
                print(f"| {a} | {s} | skipped (full attention @500k) | — | — | — | — |")
                continue
            if c["status"] != "ok":
                print(f"| {a} | {s} | **ERROR** | — | — | — | — |")
                continue
            mb = c.get("meta", {}).get("microbatches", "—")
            print(f"| {a} | {s} | ok | {c['peak_bytes_per_device']/1e9:.2f} | "
                  f"{'yes' if c['fits_hbm'] else 'no'} | {mb} | "
                  f"{c['lower_s']+c['compile_s']:.0f} |")


def roofline_table(mesh):
    print(f"\n### Roofline terms — {mesh} pod mesh\n")
    print("| arch | shape | compute s | memory s | collective s | bottleneck | "
          "MODEL_FLOPs | useful ratio | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for a in ARCHS:
        for s in ORDER:
            c = cell(a, s, mesh)
            if c is None or c["status"] != "ok":
                continue
            r = c["roofline"]
            print(f"| {a} | {s} | {r['compute_s']:.3f} | {r['memory_s']:.3f} | "
                  f"{r['collective_s']:.3f} | {r['bottleneck']} | "
                  f"{r['model_flops']:.2e} | {r['useful_flops_ratio']:.3f} | "
                  f"{r['roofline_fraction']:.4f} |")


def coll_detail(mesh):
    print(f"\n### Collective mix — {mesh} (bytes/device/step)\n")
    print("| arch | shape | all-gather | all-reduce | reduce-scatter | all-to-all | permute |")
    print("|---|---|---|---|---|---|---|")
    for a in ARCHS:
        for s in ORDER:
            c = cell(a, s, mesh)
            if c is None or c["status"] != "ok":
                continue
            b = c["collectives"]["bytes"]
            f = lambda k: f"{b.get(k,0)/1e9:.2f}G"
            print(f"| {a} | {s} | {f('all-gather')} | {f('all-reduce')} | "
                  f"{f('reduce-scatter')} | {f('all-to-all')} | "
                  f"{f('collective-permute')} |")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        dryrun_table("single")
        dryrun_table("multi")
    if which in ("all", "roofline"):
        roofline_table("single")
    if which in ("all", "coll"):
        coll_detail("single")
