#!/usr/bin/env python
"""Compare quick-mode benchmark artifacts against committed baselines.

CI runs the quick benchmarks (``benchmarks.scalability --quick``,
``benchmarks.cluster --quick``), which write ``BENCH_scalability.json`` /
``BENCH_cluster.json`` at the repo root; this script diffs the headline
metrics against the seeds committed under ``benchmarks/baselines/`` and
exits non-zero when a guarded metric regressed past the threshold
(default 25%).

Guarded metrics (chosen for run-to-run stability on shared CI runners —
percentile latencies over a fixed k-burst and cache *rates*, not wall
clocks):

  * scalability ``burst_ab``:   batched-arm cold e2e p95 (higher = worse)
  * scalability ``overlap_ab``: overlap-arm restore-path p95 (higher = worse)
  * scalability ``policy_ab``:  per-trace WS cache hit rate (lower = worse)
  * cluster per-arm:            cold p95 (higher = worse) and L1 local hit
    rate (lower = worse)

Informational deltas are printed for everything else in the baseline.
Regenerate baselines (after an intentional perf change) with::

    PYTHONPATH=src python -m benchmarks.scalability --quick
    PYTHONPATH=src python -m benchmarks.cluster --quick
    python scripts/bench_compare.py --update

Usage: python scripts/bench_compare.py [--threshold 0.25] [--update]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE_DIR = os.path.join(ROOT, "benchmarks", "baselines")
ARTIFACTS = ("BENCH_scalability.json", "BENCH_cluster.json")

#: Top-level sections each artifact must carry; a missing one is reported
#: by name (nonzero exit) instead of surfacing as a bare KeyError later.
EXPECTED_SECTIONS = {
    "BENCH_scalability.json": ("burst_ab", "overlap_ab", "policy_ab"),
    "BENCH_cluster.json": ("placement_ab", "demand_plane"),
}


def _dig(d: dict, path: str):
    """Fetch ``a.b.c`` from nested dicts; None when any hop is missing."""
    for part in path.split("."):
        if not isinstance(d, dict) or part not in d:
            return None
        d = d[part]
    return d


def _guards(name: str, artifact: dict) -> list[tuple[str, str]]:
    """(metric path, direction) pairs to guard; direction is ``up`` when an
    increase is a regression (latency) and ``down`` when a decrease is
    (hit rate)."""
    guards: list[tuple[str, str]] = []
    if name == "BENCH_scalability.json":
        for k in (artifact.get("burst_ab") or {}):
            guards.append((f"burst_ab.{k}.batched.cold_e2e_p95_s", "up"))
        if _dig(artifact, "overlap_ab.overlap.cold_restore_p95_s") is not None:
            guards.append(("overlap_ab.overlap.cold_restore_p95_s", "up"))
        for trace, arms in (artifact.get("policy_ab") or {}).items():
            if not isinstance(arms, dict):
                continue                 # malformed trace entry: no guards
            for arm in arms:
                guards.append(
                    (f"policy_ab.{trace}.{arm}.ws_cache_hit_rate", "down"))
    elif name == "BENCH_cluster.json":
        # every per-arm metric block anywhere under placement_ab /
        # demand_plane (arms nest under trace names in the former)
        def walk(d, prefix):
            if not isinstance(d, dict):
                return
            if "cold_p95_s" in d:
                guards.append((f"{prefix}.cold_p95_s", "up"))
            if "local_hit_rate" in d:
                guards.append((f"{prefix}.local_hit_rate", "down"))
            for k, v in d.items():
                walk(v, f"{prefix}.{k}")

        for section in ("placement_ab", "demand_plane"):
            walk(artifact.get(section), section)
    return guards


def _load(path: str) -> tuple[dict | None, str | None]:
    """(artifact, error): a malformed or non-object artifact is a named
    failure, never a traceback."""
    try:
        with open(path) as f:
            data = json.load(f)
    except json.JSONDecodeError as e:
        return None, f"{os.path.basename(path)}: malformed JSON ({e})"
    if not isinstance(data, dict):
        return None, (f"{os.path.basename(path)}: expected a JSON object, "
                      f"got {type(data).__name__}")
    return data, None


def compare(name: str, threshold: float) -> list[str]:
    """Returns failure strings for ``name``; empty when within budget."""
    cur_path = os.path.join(ROOT, name)
    base_path = os.path.join(BASELINE_DIR, name)
    if not os.path.exists(cur_path):
        return [f"{name}: artifact missing (run the quick benchmark first)"]
    if not os.path.exists(base_path):
        return [f"{name}: no committed baseline at {base_path}"]
    cur, err = _load(cur_path)
    if err:
        return [err]
    base, err = _load(base_path)
    if err:
        return [f"baseline {err}"]

    failures = []
    for section in EXPECTED_SECTIONS.get(name, ()):
        if section not in base:
            failures.append(f"{name}: expected key {section!r} missing "
                            "from the committed baseline")
        if section not in cur:
            failures.append(f"{name}: expected key {section!r} missing "
                            "from the artifact (benchmark ran partially?)")
    for path, direction in _guards(name, base):
        b, c = _dig(base, path), _dig(cur, path)
        if b is None or c is None:
            missing_in = "baseline" if b is None else "artifact"
            failures.append(f"{name}: guarded metric {path!r} missing from "
                            f"the {missing_in} "
                            f"(baseline={b}, current={c})")
            continue
        if not isinstance(b, (int, float)) or not isinstance(c, (int, float)):
            failures.append(f"{name}: guarded metric {path!r} is not "
                            f"numeric (baseline={b!r}, current={c!r})")
            continue
        if not b:                      # zero baseline carries no signal
            continue
        delta = (c - b) / abs(b)
        regressed = delta > threshold if direction == "up" \
            else delta < -threshold
        marker = "FAIL" if regressed else "ok"
        print(f"  [{marker:4s}] {name}:{path}  "
              f"baseline={b:.6g} current={c:.6g} delta={delta:+.1%}")
        if regressed:
            failures.append(
                f"{name}:{path} regressed {delta:+.1%} "
                f"(baseline {b:.6g} -> {c:.6g}, budget ±{threshold:.0%})")
    return failures


def update() -> None:
    os.makedirs(BASELINE_DIR, exist_ok=True)
    for name in ARTIFACTS:
        src = os.path.join(ROOT, name)
        if not os.path.exists(src):
            sys.exit(f"cannot update baseline: {src} missing "
                     f"(run the quick benchmark first)")
        shutil.copyfile(src, os.path.join(BASELINE_DIR, name))
        print(f"baseline updated: benchmarks/baselines/{name}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="relative regression budget (default 0.25 = 25%%)")
    ap.add_argument("--update", action="store_true",
                    help="copy current artifacts over the baselines")
    args = ap.parse_args(argv)
    if args.update:
        update()
        return 0
    failures: list[str] = []
    for name in ARTIFACTS:
        failures += compare(name, args.threshold)
    if failures:
        print("\nbench-compare FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("\nbench-compare: all guarded metrics within budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
