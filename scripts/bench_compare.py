#!/usr/bin/env python
"""Compare quick-mode benchmark artifacts against committed baselines.

CI runs the quick benchmarks (``benchmarks.scalability --quick``,
``benchmarks.cluster --quick``), which write ``BENCH_scalability.json`` /
``BENCH_cluster.json`` at the repo root; this script diffs the headline
metrics against the seeds committed under ``benchmarks/baselines/`` and
exits non-zero when a guarded metric regressed past the threshold
(default 25%).

Guarded metrics (chosen for run-to-run stability on shared CI runners —
percentile latencies over a fixed k-burst and cache *rates*, not wall
clocks):

  * scalability ``burst_ab``:   batched-arm cold e2e p95 (higher = worse)
  * scalability ``overlap_ab``: overlap-arm restore-path p95 (higher = worse)
  * scalability ``policy_ab``:  per-trace WS cache hit rate (lower = worse)
  * cluster per-arm:            cold p95 (higher = worse) and L1 local hit
    rate (lower = worse)
  * cluster ``dedup_scale``:    cas-arm ``transfer_bytes`` (higher = worse:
    the manifest wire started shipping chunks the requester already held)
    and ``dedup_ratio`` (lower = worse: cross-function page sharing
    regressed) — both byte/ratio counters over a deterministic record
    wave, fully stable run-to-run
  * cluster ``transport_ab``:   socket-over-inproc cold-p95 *ratio*
    (higher = worse; same machine + same run, so load cancels), the
    compressed pull arm's wire bytes (higher = worse) and its compress
    ratio (lower = worse) — the latter two over a deterministic
    fabricated record set

Informational deltas are printed for everything else in the baseline.
Regenerate baselines (after an intentional perf change) with::

    PYTHONPATH=src python -m benchmarks.scalability --quick
    PYTHONPATH=src python -m benchmarks.cluster --quick
    python scripts/bench_compare.py --update

Trend gate (``--history``): besides the absolute diff against the seed
baseline, each CI run appends the guarded metrics of the *current*
artifacts to a committed trajectory file
(``benchmarks/baselines/trajectory.jsonl``, one JSON object per run) and
fails when any guarded metric has degraded **monotonically** across the
last ``--window`` runs by more than ``--trend-threshold`` in total.  The
absolute gate catches one bad commit; the trend gate catches death by a
thousand 3% cuts that each slip under the 25% budget.

Usage: python scripts/bench_compare.py [--threshold 0.25] [--update]
       python scripts/bench_compare.py --history [--trajectory PATH]
                                       [--window 4] [--trend-threshold 0.05]
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import shutil
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE_DIR = os.path.join(ROOT, "benchmarks", "baselines")
ARTIFACTS = ("BENCH_scalability.json", "BENCH_cluster.json")
TRAJECTORY = os.path.join(BASELINE_DIR, "trajectory.jsonl")

#: Top-level sections each artifact must carry; a missing one is reported
#: by name (nonzero exit) instead of surfacing as a bare KeyError later.
EXPECTED_SECTIONS = {
    "BENCH_scalability.json": ("burst_ab", "overlap_ab", "policy_ab"),
    "BENCH_cluster.json": ("placement_ab", "demand_plane", "dedup_scale",
                           "transport_ab"),
}


def _dig(d: dict, path: str):
    """Fetch ``a.b.c`` from nested dicts; None when any hop is missing."""
    for part in path.split("."):
        if not isinstance(d, dict) or part not in d:
            return None
        d = d[part]
    return d


def _guards(name: str, artifact: dict) -> list[tuple[str, str]]:
    """(metric path, direction) pairs to guard; direction is ``up`` when an
    increase is a regression (latency) and ``down`` when a decrease is
    (hit rate)."""
    guards: list[tuple[str, str]] = []
    if name == "BENCH_scalability.json":
        for k in (artifact.get("burst_ab") or {}):
            guards.append((f"burst_ab.{k}.batched.cold_e2e_p95_s", "up"))
        if _dig(artifact, "overlap_ab.overlap.cold_restore_p95_s") is not None:
            guards.append(("overlap_ab.overlap.cold_restore_p95_s", "up"))
        for trace, arms in (artifact.get("policy_ab") or {}).items():
            if not isinstance(arms, dict):
                continue                 # malformed trace entry: no guards
            for arm in arms:
                guards.append(
                    (f"policy_ab.{trace}.{arm}.ws_cache_hit_rate", "down"))
    elif name == "BENCH_cluster.json":
        # every per-arm metric block anywhere under placement_ab /
        # demand_plane (arms nest under trace names in the former)
        def walk(d, prefix):
            if not isinstance(d, dict):
                return
            if "cold_p95_s" in d:
                guards.append((f"{prefix}.cold_p95_s", "up"))
            if "local_hit_rate" in d:
                guards.append((f"{prefix}.local_hit_rate", "down"))
            for k, v in d.items():
                walk(v, f"{prefix}.{k}")

        for section in ("placement_ab", "demand_plane"):
            walk(artifact.get(section), section)
        for path, direction in (("dedup_scale.arms.cas.transfer_bytes", "up"),
                                ("dedup_scale.arms.cas.dedup_ratio", "down"),
                                # real-transport drift gates: the codec's
                                # wire bytes / ratio over a deterministic
                                # fabricated record set (byte-stable; the
                                # noisy cold-p95 ratio is gated as an
                                # *absolute* invariant instead, see
                                # _invariants)
                                ("transport_ab.pull.socket_compress"
                                 ".wire_bytes", "up"),
                                ("transport_ab.pull.socket_compress"
                                 ".compress_ratio", "down")):
            if _dig(artifact, path) is not None:
                guards.append((path, direction))
    return guards


def _invariants(name: str, artifact: dict) -> list[str]:
    """Absolute (baseline-free) gates on the *current* artifact.

    The transport A/B's cold-p95 ratio jitters run-to-run far beyond a
    drift budget (both arms race the same cores), but the paper-level
    claims are absolute: the socket fleet stays within its 2x budget and
    the codec'd stream ships strictly fewer bytes than raw.
    """
    failures: list[str] = []
    if name != "BENCH_cluster.json":
        return failures
    ratio = _dig(artifact, "transport_ab.e2e.socket_over_inproc_p95")
    if isinstance(ratio, (int, float)) and ratio > 2.0:
        failures.append(f"{name}: socket fleet cold p95 is {ratio:.2f}x "
                        "the inproc fleet's (budget: 2.0x)")
    comp = _dig(artifact, "transport_ab.pull.socket_compress.wire_bytes")
    raw = _dig(artifact, "transport_ab.pull.socket_inline.wire_bytes")
    if isinstance(comp, (int, float)) and isinstance(raw, (int, float)) \
            and comp >= raw:
        failures.append(f"{name}: compressed pull put {comp} bytes on the "
                        f"wire, not strictly below raw's {raw}")
    return failures


def _load(path: str) -> tuple[dict | None, str | None]:
    """(artifact, error): a malformed or non-object artifact is a named
    failure, never a traceback."""
    try:
        with open(path) as f:
            data = json.load(f)
    except json.JSONDecodeError as e:
        return None, f"{os.path.basename(path)}: malformed JSON ({e})"
    if not isinstance(data, dict):
        return None, (f"{os.path.basename(path)}: expected a JSON object, "
                      f"got {type(data).__name__}")
    return data, None


def compare(name: str, threshold: float) -> list[str]:
    """Returns failure strings for ``name``; empty when within budget."""
    cur_path = os.path.join(ROOT, name)
    base_path = os.path.join(BASELINE_DIR, name)
    if not os.path.exists(cur_path):
        return [f"{name}: artifact missing (run the quick benchmark first)"]
    if not os.path.exists(base_path):
        return [f"{name}: no committed baseline at {base_path}"]
    cur, err = _load(cur_path)
    if err:
        return [err]
    base, err = _load(base_path)
    if err:
        return [f"baseline {err}"]

    failures = _invariants(name, cur)
    for section in EXPECTED_SECTIONS.get(name, ()):
        if section not in base:
            failures.append(f"{name}: expected key {section!r} missing "
                            "from the committed baseline")
        if section not in cur:
            failures.append(f"{name}: expected key {section!r} missing "
                            "from the artifact (benchmark ran partially?)")
    for path, direction in _guards(name, base):
        b, c = _dig(base, path), _dig(cur, path)
        if b is None or c is None:
            missing_in = "baseline" if b is None else "artifact"
            failures.append(f"{name}: guarded metric {path!r} missing from "
                            f"the {missing_in} "
                            f"(baseline={b}, current={c})")
            continue
        if not isinstance(b, (int, float)) or not isinstance(c, (int, float)):
            failures.append(f"{name}: guarded metric {path!r} is not "
                            f"numeric (baseline={b!r}, current={c!r})")
            continue
        if not b:                      # zero baseline carries no signal
            continue
        delta = (c - b) / abs(b)
        regressed = delta > threshold if direction == "up" \
            else delta < -threshold
        marker = "FAIL" if regressed else "ok"
        print(f"  [{marker:4s}] {name}:{path}  "
              f"baseline={b:.6g} current={c:.6g} delta={delta:+.1%}")
        if regressed:
            failures.append(
                f"{name}:{path} regressed {delta:+.1%} "
                f"(baseline {b:.6g} -> {c:.6g}, budget ±{threshold:.0%})")
    return failures


# -- trend gate (--history) ------------------------------------------------

def collect_guarded(artifacts_dir: str = ROOT) -> tuple[dict, dict]:
    """(values, directions) of every guarded metric in the current
    artifacts, keyed ``<artifact>:<metric.path>``.  Artifacts that are
    missing or malformed contribute nothing — a partial CI run appends a
    partial record rather than failing the append."""
    values: dict[str, float] = {}
    directions: dict[str, str] = {}
    for name in ARTIFACTS:
        art, err = _load_optional(os.path.join(artifacts_dir, name))
        if art is None:
            continue
        for path, direction in _guards(name, art):
            v = _dig(art, path)
            if isinstance(v, (int, float)):
                key = f"{name}:{path}"
                values[key] = float(v)
                directions[key] = direction
    return values, directions


def _load_optional(path: str) -> tuple[dict | None, str | None]:
    if not os.path.exists(path):
        return None, None
    return _load(path)


def history_append(trajectory: str = TRAJECTORY,
                   artifacts_dir: str = ROOT) -> dict | None:
    """Append one trajectory record built from the current artifacts;
    returns the record (None when no guarded metric was found)."""
    values, directions = collect_guarded(artifacts_dir)
    if not values:
        return None
    rec = {
        "time": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"),
        "metrics": values,
        "directions": directions,
    }
    d = os.path.dirname(trajectory)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(trajectory, "a", encoding="utf-8") as f:
        f.write(json.dumps(rec, sort_keys=True) + "\n")
    return rec


def load_trajectory(trajectory: str = TRAJECTORY) -> list[dict]:
    if not os.path.exists(trajectory):
        return []
    records = []
    with open(trajectory, encoding="utf-8") as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise SystemExit(f"{trajectory}:{i}: malformed trajectory "
                                 f"line ({e})")
            if isinstance(rec, dict) and isinstance(rec.get("metrics"), dict):
                records.append(rec)
    return records


def history_check(trajectory: str = TRAJECTORY, *, window: int = 4,
                  trend_threshold: float = 0.05) -> list[str]:
    """Failure strings for monotone-degrading metrics over the last
    ``window`` trajectory records.

    A metric fails when (a) it is present in every record of the window,
    (b) *every* consecutive step moves in its bad direction, and (c) the
    total relative drift across the window exceeds ``trend_threshold``.
    Fewer than ``window`` records is a pass — the gate needs history.
    """
    records = load_trajectory(trajectory)
    if len(records) < window:
        print(f"  trend gate: {len(records)}/{window} runs recorded — "
              "not enough history yet")
        return []
    tail = records[-window:]
    directions = tail[-1].get("directions") or {}
    failures: list[str] = []
    keys = set(tail[0]["metrics"])
    for rec in tail[1:]:
        keys &= set(rec["metrics"])
    for key in sorted(keys):
        series = [rec["metrics"][key] for rec in tail]
        if not all(isinstance(v, (int, float)) for v in series):
            continue
        direction = directions.get(key, "up")
        sign = 1.0 if direction == "up" else -1.0
        steps = [sign * (b - a) for a, b in zip(series, series[1:])]
        monotone = all(s > 0 for s in steps)
        first = series[0]
        drift = sign * (series[-1] - first) / abs(first) if first else 0.0
        marker = "FAIL" if monotone and drift > trend_threshold else "ok"
        print(f"  [{marker:4s}] trend {key}  "
              f"{series[0]:.6g} -> {series[-1]:.6g} over {window} runs "
              f"(drift={drift:+.1%}, monotone={monotone})")
        if marker == "FAIL":
            failures.append(
                f"{key}: degraded monotonically across the last {window} "
                f"runs ({series[0]:.6g} -> {series[-1]:.6g}, "
                f"{drift:+.1%} > {trend_threshold:.0%} budget)")
    return failures


def update() -> None:
    os.makedirs(BASELINE_DIR, exist_ok=True)
    for name in ARTIFACTS:
        src = os.path.join(ROOT, name)
        if not os.path.exists(src):
            sys.exit(f"cannot update baseline: {src} missing "
                     f"(run the quick benchmark first)")
        shutil.copyfile(src, os.path.join(BASELINE_DIR, name))
        print(f"baseline updated: benchmarks/baselines/{name}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="relative regression budget (default 0.25 = 25%%)")
    ap.add_argument("--update", action="store_true",
                    help="copy current artifacts over the baselines")
    ap.add_argument("--history", action="store_true",
                    help="append current guarded metrics to the trajectory "
                         "file and fail on monotone-degrading trends")
    ap.add_argument("--trajectory", default=TRAJECTORY,
                    help="trajectory jsonl path (default "
                         "benchmarks/baselines/trajectory.jsonl)")
    ap.add_argument("--window", type=int, default=4,
                    help="trend window in runs (default 4)")
    ap.add_argument("--trend-threshold", type=float, default=0.05,
                    help="total relative drift across the window that "
                         "fails a monotone trend (default 0.05 = 5%%)")
    args = ap.parse_args(argv)
    if args.update:
        update()
        return 0
    if args.history:
        rec = history_append(args.trajectory)
        if rec is None:
            print("trend gate: no guarded metrics in current artifacts "
                  "(nothing appended)")
        else:
            print(f"trend gate: appended {len(rec['metrics'])} metrics "
                  f"to {os.path.relpath(args.trajectory, ROOT)}")
        failures = history_check(args.trajectory, window=args.window,
                                 trend_threshold=args.trend_threshold)
        if failures:
            print("\nbench-compare trend gate FAILED:", file=sys.stderr)
            for f in failures:
                print(f"  {f}", file=sys.stderr)
            return 1
        print("\nbench-compare: no monotone-degrading trends")
        return 0
    failures: list[str] = []
    for name in ARTIFACTS:
        failures += compare(name, args.threshold)
    if failures:
        print("\nbench-compare FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("\nbench-compare: all guarded metrics within budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
