import sys, shutil, os
sys.path.insert(0, "/root/repo/src")
from repro.configs import SMOKES
from repro.training import OptConfig, SimulatedPreemption, Trainer, TrainLoopConfig
from repro.data import synthesize_corpus

wd = "/root/repo/.devtrain"
shutil.rmtree(wd, ignore_errors=True); os.makedirs(wd)
cfg = SMOKES["olmo-1b"]
corpus = synthesize_corpus(f"{wd}/corpus.bin", 200_000, cfg.vocab)

loop = TrainLoopConfig(total_steps=24, checkpoint_every=8, batch_size=4, seq_len=64)
# run 1: preempted at step 12
tr = Trainer(cfg, OptConfig(lr=1e-3, warmup_steps=4, total_steps=24), loop, corpus, f"{wd}/ckpt", preempt_at=12)
try:
    tr.run()
    raise RuntimeError("expected preemption")
except SimulatedPreemption as e:
    print("preempted:", e)
# run 2: restart from checkpoint (REAP restore), finish
tr2 = Trainer(cfg, OptConfig(lr=1e-3, warmup_steps=4, total_steps=24), loop, corpus, f"{wd}/ckpt")
out = tr2.run()
print(f"resumed->final step={out['final_step']} restore={out['restore_stats']}")
print(f"losses head={out['losses'][:2]} tail={out['losses'][-2:]}")
assert out["final_step"] == 24
# uninterrupted reference run must match the final losses (exactly-once data order)
shutil.rmtree(f"{wd}/ckpt2", ignore_errors=True)
tr3 = Trainer(cfg, OptConfig(lr=1e-3, warmup_steps=4, total_steps=24), loop, corpus, f"{wd}/ckpt2")
out3 = tr3.run()
import numpy as np
d = abs(np.array(out['losses'][-4:]) - np.array(out3['losses'][-4:]))
print("tail loss diff vs uninterrupted:", d.max())
assert d.max() < 0.05, d
print("train loop + fault tolerance OK")
