#!/usr/bin/env python
"""Run the repo's static concurrency/invariant analysis.

Usage:
    python scripts/analyze.py                 # human-readable findings
    python scripts/analyze.py --json          # machine-readable JSON
    python scripts/analyze.py --check         # CI gate: nonzero exit on
                                              # any finding not in the
                                              # baseline file
    python scripts/analyze.py --write-baseline  # accept current findings

The baseline (``analysis-baseline.json``) maps finding keys to a short
justification.  ``--check`` fails on unbaselined findings and warns (exit 0)
about stale baseline entries that no longer fire, so the file can only
shrink or be consciously grown.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

DEFAULT_TARGET = os.path.join(ROOT, "src", "repro")
DEFAULT_BASELINE = os.path.join(ROOT, "analysis-baseline.json")


def load_baseline(path: str) -> dict[str, str]:
    if not os.path.exists(path):
        return {}
    with open(path, "r", encoding="utf-8") as fh:
        data = json.load(fh)
    if not isinstance(data, dict):
        raise SystemExit(f"baseline {path} must be a JSON object of "
                         "{finding-key: justification}")
    return data


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=DEFAULT_TARGET,
                    help="package directory to analyze (default: src/repro)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline JSON of accepted findings")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as JSON")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if any finding is not baselined")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write all current findings into the baseline "
                         "(justifications default to TODO)")
    args = ap.parse_args(argv)

    from repro.analysis import run_all

    findings = run_all(args.root)
    baseline = load_baseline(args.baseline)

    if args.write_baseline:
        merged = {f.key: baseline.get(f.key, "TODO: justify")
                  for f in findings}
        with open(args.baseline, "w", encoding="utf-8") as fh:
            json.dump(merged, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"wrote {len(merged)} baseline entries to {args.baseline}")
        return 0

    fresh = [f for f in findings if f.key not in baseline]
    accepted = [f for f in findings if f.key in baseline]
    stale = sorted(set(baseline) - {f.key for f in findings})

    if args.as_json:
        print(json.dumps({
            "findings": [f.to_dict() for f in findings],
            "fresh": [f.key for f in fresh],
            "baselined": [f.key for f in accepted],
            "stale_baseline_entries": stale,
        }, indent=2))
    else:
        for f in fresh:
            print(f.render())
        if accepted:
            print(f"-- {len(accepted)} baselined finding(s) suppressed "
                  f"(see {os.path.basename(args.baseline)})")
        for key in stale:
            print(f"-- warning: stale baseline entry no longer fires: {key}")
        print(f"{len(fresh)} finding(s), {len(accepted)} baselined, "
              f"{len(stale)} stale baseline entr(ies)")

    if args.check and fresh:
        print(f"\n--check: {len(fresh)} unbaselined finding(s); fix them or "
              f"add a justified entry to {os.path.basename(args.baseline)}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
