"""Forward-pass smoke over every assigned architecture (CI smoke job).

Exits nonzero if any architecture fails, so CI can gate on it.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import SMOKES
from repro.launch import steps
from repro.training.optimizer import OptConfig

failures = []
for name, cfg in SMOKES.items():
    try:
        key = jax.random.key(0)
        params = steps.init_params(cfg, key)
        B, S = 2, 64
        batch = steps.make_batch(cfg, S, B, "train", key)
        fwd = steps.build_forward(cfg)
        logits = fwd(params, batch)
        assert not bool(jnp.any(jnp.isnan(logits))), "NaN logits"
        steps.build_train_step(cfg, OptConfig(), remat=False)
        print(f"[OK fwd] {name}: logits {logits.shape}")
    except Exception as e:
        import traceback; traceback.print_exc()
        failures.append((name, str(e)[:200]))
        print(f"[FAIL] {name}: {e}")
print("FAILURES:", [f[0] for f in failures])
sys.exit(1 if failures else 0)
