import sys
import jax, jax.numpy as jnp
import numpy as np
sys.path.insert(0, "/root/repo/src")
from repro.configs import SMOKES
from repro.launch import steps
from repro.nn import spec as nnspec

failures = []
for name, cfg in SMOKES.items():
    try:
        key = jax.random.key(0)
        params = steps.init_params(cfg, key)
        B, S = 2, 64
        batch = steps.make_batch(cfg, S, B, "train", key)
        fwd = steps.build_forward(cfg)
        logits = fwd(params, batch)
        assert not bool(jnp.any(jnp.isnan(logits))), "NaN logits"
        fam_loss = steps.build_train_step(cfg, __import__("repro.training.optimizer", fromlist=["OptConfig"]).OptConfig(), remat=False)
        print(f"[OK fwd] {name}: logits {logits.shape}")
    except Exception as e:
        import traceback; traceback.print_exc()
        failures.append((name, str(e)[:200]))
        print(f"[FAIL] {name}: {e}")
print("FAILURES:", [f[0] for f in failures])
