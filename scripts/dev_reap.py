import sys, shutil, os
sys.path.insert(0, "/root/repo/src")
import jax.numpy as jnp
from repro.configs import SMOKES
from repro.core import (Monitor, ReapConfig, build_instance_snapshot,
                        run_invocation)
from repro.launch import steps
import jax

store = "/root/repo/.devstore"
shutil.rmtree(store, ignore_errors=True)
os.makedirs(store)

for name in ["qwen2-7b", "deepseek-moe-16b", "pixtral-12b"]:
    cfg = SMOKES[name]
    base = f"{store}/{name}"
    gm = build_instance_snapshot(cfg, base, seed=3)
    key = jax.random.key(3)
    batch = steps.make_batch(cfg, 32, 2, "train", key)

    # warm reference with the same (host-initialized) params
    from repro.nn import spec as nnspec
    from repro.models import get_family
    fam = get_family(cfg)
    host = nnspec.host_initialize(fam.param_specs(cfg), seed=3)
    params = nnspec.map_leaves(lambda p, s: jnp.asarray(host[p]), fam.param_specs(cfg))
    ref = fam.forward(cfg, params, batch)

    # record phase
    rc = ReapConfig()
    mon = Monitor(gm, base, rc)
    assert mon.mode == "record"
    mon.start()
    logits, secs = run_invocation(cfg, mon.arena, batch)
    err = float(jnp.max(jnp.abs(logits.astype(jnp.float32) - ref.astype(jnp.float32))))
    info = mon.finish()
    print(f"{name}: record faults={info['n_faults']} fault_s={info['fault_s']:.3f} "
          f"ws_pages={info['ws_pages']} err={err:.2e} t={secs:.3f}s")
    assert err < (0.08 if cfg.n_experts else 1e-2), err

    # prefetch phase
    mon2 = Monitor(gm, base, rc)
    assert mon2.mode == "prefetch"
    mon2.start()
    logits2, secs2 = run_invocation(cfg, mon2.arena, batch)
    err2 = float(jnp.max(jnp.abs(logits2.astype(jnp.float32) - ref.astype(jnp.float32))))
    info2 = mon2.finish()
    print(f"{name}: prefetch residual_faults={info2['n_faults']} "
          f"prefetched={info2['prefetched_pages']} prefetch_s={info2['prefetch_s']:.4f} "
          f"err={err2:.2e} t={secs2:.3f}s")
    assert err2 < (0.08 if cfg.n_experts else 1e-2)

    # different input: residual faults should be small but nonzero (unique pages)
    batch3 = steps.make_batch(cfg, 32, 2, "train", jax.random.key(99))
    mon3 = Monitor(gm, base, rc)
    mon3.start()
    logits3, secs3 = run_invocation(cfg, mon3.arena, batch3)
    info3 = mon3.finish()
    print(f"{name}: new-input residual_faults={info3['n_faults']} "
          f"ratio={info3.get('residual_ratio', 0):.3f} t={secs3:.3f}s")
print("REAP core OK")
