"""Staged restore pipeline + batched group restores (core/restore.py).

Covers: per-stage timing attribution (fakeclock-driven), fused
gather/scatter install parity vs the per-page ``install_span`` path (arena
bytes and logits), one-WS-read/k-install group semantics through the
orchestrator and the router, the drop_record-vs-cold-start race fallback,
and the shard-tier push invalidation broadcast.
"""
import threading

import jax
import numpy as np
import pytest
from fakeclock import FakeClock

from repro.configs import SMOKES
from repro.core import ReapConfig
from repro.core import reap as reap_mod
from repro.core.arena import PAGE, ArenaLayout, GuestMemoryFile, InstanceArena
from repro.core.reap import WS_CACHE, WSCache
from repro.core.restore import (RestoreBatch, RestorePipeline, fuse_ws_block)
from repro.launch import steps
from repro.serving import Orchestrator, Router, RouterConfig


@pytest.fixture(scope="module")
def served(tmp_path_factory):
    """One registered+recorded function on a module-scoped orchestrator."""
    store = str(tmp_path_factory.mktemp("batchstore"))
    cfg = SMOKES["olmo-1b"]
    batch = steps.make_batch(cfg, 32, 2, "train", jax.random.key(0))
    orch = Orchestrator(store, mode="reap", reap=ReapConfig())
    orch.register("fn", cfg, warmup_batch=batch)
    orch.invoke("fn", batch)          # record phase
    orch.scale_to_zero("fn")
    return orch, batch


@pytest.fixture()
def small_recorded(tmp_path):
    """A tiny recorded guest-memory file for arena-level parity tests."""
    tensors = [
        ("infra/tab", (3000,), "uint8", "infra"),
        ("params/w", (64, 33), "float32", "serve"),
        ("boot/opt", (64, 33), "float32", "boot"),
    ]
    layout = ArenaLayout.build(tensors)
    rng = np.random.default_rng(7)
    arrays = {
        "infra/tab": np.arange(3000, dtype=np.uint8),
        "params/w": rng.standard_normal((64, 33)).astype(np.float32),
        "boot/opt": np.ones((64, 33), np.float32),
    }
    gm = GuestMemoryFile.create(str(tmp_path / "fn"), layout, arrays)
    arena = InstanceArena(gm)
    arena.tensor("infra/tab")
    arena.tensor("params/w")
    reap_mod.write_record(gm.base, arena.stats.trace)
    arena.close()
    return gm


# -- fused install parity ----------------------------------------------


@pytest.mark.parametrize("engine", ["numpy", "pallas"])
def test_fused_block_install_matches_install_span(small_recorded, engine):
    """The fused gather + vectorized scatter must be byte-identical to the
    per-page install_span path, for both fuse engines."""
    gm = small_recorded
    pages, data = reap_mod._read_ws(gm.base, ReapConfig())

    a_span = InstanceArena(GuestMemoryFile.open(gm.base))
    a_span.install_span(pages, data)
    a_block = InstanceArena(GuestMemoryFile.open(gm.base))
    sorted_pages, block = fuse_ws_block(pages, data, engine=engine)
    installed = a_block.install_block(sorted_pages, block)

    assert installed == len(pages)
    np.testing.assert_array_equal(np.asarray(a_span.resident),
                                  np.asarray(a_block.resident))
    assert bytes(a_span.view) == bytes(a_block.view)   # full arena bytes
    a_span.close()
    a_block.close()


def test_fuse_engines_agree_and_scatter_kernel_roundtrips(small_recorded):
    """numpy and pallas fuse engines produce identical blocks, and the
    scatter_pages kernel (the install's TPU-native realization) lands the
    block on the same pages as install_block."""
    gm = small_recorded
    pages, data = reap_mod._read_ws(gm.base, ReapConfig())
    idx_np, block_np = fuse_ws_block(pages, data, engine="numpy")
    idx_pl, block_pl = fuse_ws_block(pages, data, engine="pallas")
    np.testing.assert_array_equal(idx_np, idx_pl)
    np.testing.assert_array_equal(block_np, block_pl)

    import jax.numpy as jnp
    from repro.kernels import scatter_pages
    n_pages = gm.layout.n_pages
    dest = jnp.zeros((n_pages, PAGE), jnp.uint8)
    out = np.asarray(scatter_pages(jnp.asarray(block_np),
                                   jnp.asarray(idx_np.astype(np.int32)),
                                   dest))
    arena = InstanceArena(GuestMemoryFile.open(gm.base))
    arena.install_block(idx_np, block_np)
    arena_pages = np.frombuffer(bytes(arena.view), np.uint8,
                                count=n_pages * PAGE).reshape(-1, PAGE)
    np.testing.assert_array_equal(out[idx_np], arena_pages[idx_np])
    arena.close()


def test_batched_restore_identical_logits(served):
    """A batch-restored instance computes logits identical to an unbatched
    cold instance (same params, same request)."""
    orch, batch = served
    ref, _ = orch.invoke("fn", batch, force_cold=True)
    orch.scale_to_zero("fn")
    insts = orch.spawn_batch("fn", 2)
    try:
        for inst in insts:
            assert inst.try_acquire()
            logits, _ = inst.invoke(batch)
            np.testing.assert_array_equal(np.asarray(logits), np.asarray(ref))
            inst.release()
    finally:
        for inst in insts:
            inst.try_reclaim()


# -- group restore semantics -------------------------------------------


def test_spawn_batch_one_fetch_k_installs(served):
    """k instances restored as one group: exactly one WS-cache transaction,
    one underlying read, k arena installs, per-report batch_size=k."""
    orch, _ = served
    WS_CACHE.clear()
    WS_CACHE.reset_stats()
    k = 4
    insts = orch.spawn_batch("fn", k)
    try:
        s = WS_CACHE.stats()
        assert s["reads"] == 1
        assert s["misses"] == 1 and s["hits"] == 0   # one transaction total
        assert s["group_fetches"] == 1 and s["group_instances"] == k
        ws_pages = insts[0].report.n_prefetched_pages
        assert ws_pages > 0
        for inst in insts:
            assert inst.report.batch_size == k
            assert inst.report.load_vmm_s > 0
            assert inst.report.install_s > 0
            assert inst.report.prefetch_s >= inst.report.install_s
            assert inst.report.n_prefetched_pages == ws_pages
            # each arena performed its own (single, fused) install
            assert inst.monitor.arena.stats.n_pages_installed == ws_pages
        # identical residency across the group
        r0 = np.asarray(insts[0].monitor.arena.resident)
        for inst in insts[1:]:
            np.testing.assert_array_equal(
                r0, np.asarray(inst.monitor.arena.resident))
    finally:
        for inst in insts:
            inst.try_reclaim()


def test_group_hint_invoke_parks_fresh_for_followers(served):
    """A cold invoke with group_hint=k restores k instances; the k-1 extras
    park in the fresh pool and later cold invocations consume them without
    spawning (or re-reading) anything."""
    orch, batch = served
    orch.scale_to_zero("fn")
    WS_CACHE.clear()
    WS_CACHE.reset_stats()
    rec = orch.functions["fn"]
    spawned0 = rec.n_spawned
    k = 3
    _, rep = orch.invoke("fn", batch, force_cold=True, group_hint=k)
    assert rep.batch_size == k and rep.load_vmm_s > 0
    with rec.lock:
        assert len(rec.fresh) == k - 1
    for _ in range(k - 1):
        _, rep = orch.invoke("fn", batch, force_cold=True)
        assert rep.batch_size == k          # restored by the group
        assert rep.load_vmm_s > 0           # still charged the full split
    assert rec.n_spawned - spawned0 == k    # no extra spawns
    assert WS_CACHE.stats()["reads"] == 1
    with rec.lock:
        assert not rec.fresh
    orch.scale_to_zero("fn")


def test_router_serial_worker_batches_whole_queue(served):
    """k-deep same-function cold queue, one worker: the first dispatch
    group-restores everything queued behind it — exactly one WS read and
    k installs, every report batch_size=k (deterministic: no racing
    workers)."""
    orch, batch = served
    orch.scale_to_zero("fn")
    WS_CACHE.clear()
    WS_CACHE.reset_stats()
    rec = orch.functions["fn"]
    spawned0 = rec.n_spawned
    k = 4
    router = Router(orch, RouterConfig(max_concurrency=1,
                                       max_instances_per_function=k,
                                       batch_restore_limit=k), start=False)
    invs = [router.submit("fn", batch, force_cold=True) for _ in range(k)]
    router.start()
    reports = [inv.result(timeout=300)[1] for inv in invs]
    router.close()
    assert rec.n_spawned - spawned0 == k
    assert WS_CACHE.stats()["reads"] == 1
    assert WS_CACHE.stats()["misses"] == 1       # one cache transaction
    ws_pages = reports[0].n_prefetched_pages
    for r in reports:
        assert r.batch_size == k
        assert r.load_vmm_s > 0 and r.connection_s > 0
        assert r.n_prefetched_pages == ws_pages
    orch.scale_to_zero("fn")


def test_batch_restore_limit_one_disables_grouping(served):
    orch, batch = served
    orch.scale_to_zero("fn")
    rec = orch.functions["fn"]
    router = Router(orch, RouterConfig(max_concurrency=1,
                                       max_instances_per_function=4,
                                       batch_restore_limit=1), start=False)
    invs = [router.submit("fn", batch, force_cold=True) for _ in range(3)]
    router.start()
    reports = [inv.result(timeout=300)[1] for inv in invs]
    router.close()
    assert all(r.batch_size == 1 for r in reports)
    with rec.lock:
        assert not rec.fresh
    orch.scale_to_zero("fn")


def test_failed_materialize_reclaims_whole_group(served, monkeypatch):
    """If make_warm fails mid-group (records dropped mid-spawn), every
    already-adopted arena is reclaimed — nothing leaks."""
    from repro.serving.instance import FunctionInstance, State, restore_group
    orch, _ = served
    rec = orch.functions["fn"]
    insts = [FunctionInstance("fn", rec.cfg, rec.base, orch.reap)
             for _ in range(2)]

    def boom(self):
        raise RuntimeError("materialize failed")

    monkeypatch.setattr(FunctionInstance, "make_warm", boom)
    with pytest.raises(RuntimeError):
        restore_group(insts, materialize=True)
    assert all(i.state is State.RECLAIMED for i in insts)


def test_prewarm_restores_as_one_group(served):
    """A prewarm burst is one group restore: one WS-cache transaction, and
    the instances park warm with their restore off-path."""
    orch, batch = served
    orch.scale_to_zero("fn")
    WS_CACHE.clear()
    WS_CACHE.reset_stats()
    rec = orch.functions["fn"]
    assert orch.prewarm("fn", 3, wait=True) == 3
    s = WS_CACHE.stats()
    assert s["reads"] == 1 and s["misses"] == 1
    assert s["group_fetches"] == 1 and s["group_instances"] == 3
    with rec.lock:
        assert len(rec.idle) == 3
        assert all(i.prewarmed and i.report.batch_size == 3
                   for i in rec.idle)
    _, rep = orch.invoke("fn", batch)
    assert rep.prewarmed and rep.load_vmm_s == 0.0   # restore stayed off-path
    orch.scale_to_zero("fn")


# -- stage timing attribution (fakeclock-driven) -----------------------


class _TickClock(FakeClock):
    """A fake perf counter that advances 1s per reading: each pipeline
    stage is bracketed by exactly two readings, so its timing must come
    out at exactly 1.0 — proving stages are timed separately and nothing
    else reads the clock inside a stage."""

    def __call__(self) -> float:
        t = super().__call__()
        self.advance(1.0)
        return t


def test_pipeline_stage_timings_are_attributed(small_recorded):
    gm = small_recorded
    pipe = RestorePipeline(gm.base, ReapConfig(), clock=_TickClock())
    pipe.load_vmm()
    pipe.connect()
    fetched = pipe.ws_fetch()
    pipe.install(fetched)
    t = pipe.timings
    assert t.load_vmm_s == 1.0
    assert t.connection_s == 1.0
    assert t.ws_fetch_s == 1.0
    assert t.install_s == 1.0
    assert t.prefetch_s == 2.0           # fetch + install, the §4.2 segment
    assert t.materialize_s == 0.0
    pipe.close()


def test_batch_charges_shared_fetch_to_every_member(small_recorded):
    """In a group, the single fetch + fuse pass land on every member's
    ws_fetch_s (they all waited on it), install_s stays per-member."""
    gm = small_recorded
    pipes = [RestorePipeline(gm.base, ReapConfig(), clock=_TickClock())
             for _ in range(3)]
    batch = RestoreBatch(pipes).run()
    assert batch.fuse_s > 0
    shared = pipes[0].timings.ws_fetch_s
    for p in pipes:
        assert p.timings.ws_fetch_s == shared
        assert p.timings.install_s == 1.0
        assert p.monitor.prefetched > 0
    stages = batch.stage_seconds()
    assert stages["load_vmm_s"] == 3.0 and stages["connection_s"] == 3.0
    for p in pipes:
        p.close()


# -- drop_record vs cold start race (§7.2) -----------------------------


def test_monitor_falls_back_to_record_when_record_dropped(small_recorded):
    """drop_record between mode selection and start() must not fail the
    cold start: the monitor falls back to record mode."""
    gm = small_recorded
    mon = reap_mod.Monitor(GuestMemoryFile.open(gm.base), gm.base,
                           ReapConfig())
    assert mon.mode == "prefetch"
    reap_mod.drop_record(gm.base)        # concurrent §7.2 re-record wins
    mon.start()                          # must not raise
    assert mon.mode == "record"
    assert mon.prefetched == 0
    mon.arena.close()


def test_cold_start_racing_drop_record_rerecords(served, monkeypatch):
    """End-to-end: a drop_record landing inside the WS fetch window falls
    back to record mode, the invocation succeeds, and a fresh record is
    written by finish()."""
    orch, batch = served
    orch.scale_to_zero("fn")
    base = orch.functions["fn"].base
    assert reap_mod.has_record(base)
    real_fetch = WSCache.fetch
    raced = threading.Event()

    def racing_fetch(self, b, cfg, group=1):
        if b == base and not raced.is_set():
            raced.set()
            reap_mod.drop_record(b)      # the re-record wins the race
        return real_fetch(self, b, cfg, group)

    monkeypatch.setattr(WSCache, "fetch", racing_fetch)
    _, rep = orch.invoke("fn", batch, force_cold=True)   # must not raise
    assert raced.is_set()
    assert rep.n_prefetched_pages == 0   # fell back to record mode
    assert reap_mod.has_record(base)     # finish() re-recorded
    orch.scale_to_zero("fn")
    monkeypatch.undo()
    _, rep = orch.invoke("fn", batch, force_cold=True)
    assert rep.n_prefetched_pages > 0    # prefetch engaged on the new record
    orch.scale_to_zero("fn")


def test_ws_cache_threads_group_to_source(tmp_path):
    """A group-aware miss source (the shard tier) receives the restore
    batch size; legacy two-arg sources keep working."""
    base = str(tmp_path / "f")
    with open(reap_mod.ws_path(base), "wb") as f:
        f.write(b"x")
    seen = []

    def tiered(b, cfg, group=1):
        seen.append(group)
        return [0], b"A" * PAGE

    cache = WSCache(source=tiered)
    cache.fetch(base, ReapConfig(), group=5)
    assert seen == [5]
    s = cache.stats()
    assert s["group_fetches"] == 1 and s["group_instances"] == 5

    legacy_calls = []
    legacy = WSCache(source=lambda b, cfg: (legacy_calls.append(b)
                                            or ([0], b"B" * PAGE)))
    legacy.fetch(base, ReapConfig(), group=3)
    assert legacy_calls == [base]        # called without the kwarg


# -- shard-tier push invalidation --------------------------------------


def test_rerecord_pushes_invalidation_to_peer_caches(small_recorded):
    """A re-record (write_record/drop_record) eagerly drops the stale WS
    from every attached L1 — counted in pushed_invalidations — instead of
    waiting for each node's next mtime-checked fetch."""
    from repro.cluster.shardmap import ConsistentHashRing
    from repro.cluster.snapstore import ShardedSnapshotStore, TransferModel
    gm = small_recorded
    ring = ConsistentHashRing()
    store = ShardedSnapshotStore(ring, transfer=TransferModel(latency_s=0.0),
                                 sleep=lambda s: None)
    try:
        a = store.attach("node-a")
        b = store.attach("node-b")
        a.fetch(gm.base, ReapConfig())
        b.fetch(gm.base, ReapConfig())
        assert a.contains(gm.base) and b.contains(gm.base)

        reap_mod.drop_record(gm.base)    # re-record path
        assert not a.contains(gm.base) and not b.contains(gm.base)
        assert store.stats()["pushed_invalidations"] == 2
    finally:
        store.close()

    # after close() the store must stop receiving broadcasts
    a._entries["zzz"] = (0.0, [0], b"")  # fake entry; invalidate would drop
    reap_mod._broadcast_invalidation("zzz")
    assert "zzz" in a._entries           # detached: untouched
