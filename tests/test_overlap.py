"""Overlapped restore (hot prefix + background tail install) + ServeConfig.

Covers: byte parity of fault-during-tail-install races against the
unoverlapped path (both fuse engines), fault-waits counted apart from disk
faults, the straggler-deadline demotion to the disk-fault path (and its
§7.2 residual-ratio exemption), reaper/close safety around live tails, the
ServeConfig deprecation shims, the stages-based ColdStartReport, the
serving-mode trace cap, and the recorded hot-prefix cut point.
"""
import dataclasses
import threading
import time

import numpy as np
import pytest

from repro.core import ReapConfig
from repro.core import reap as reap_mod
from repro.core.arena import ArenaLayout, GuestMemoryFile, InstanceArena
from repro.core.reap import ColdStartReport, StageTimings
from repro.core.restore import RestoreBatch, RestorePipeline, TailInstall

OVERLAP = ReapConfig(overlap_install=True, hot_prefix_frac=0.25,
                     tail_workers=2, tail_deadline_s=30.0)


@pytest.fixture()
def recorded(tmp_path):
    """A recorded guest-memory file whose WS is big enough to overlap."""
    tensors = [
        ("infra/tab", (3000,), "uint8", "infra"),
        ("params/w", (256, 256), "float32", "serve"),
        ("boot/opt", (64, 33), "float32", "boot"),
    ]
    layout = ArenaLayout.build(tensors)
    rng = np.random.default_rng(7)
    arrays = {
        "infra/tab": np.arange(3000, dtype=np.uint8),
        "params/w": rng.standard_normal((256, 256)).astype(np.float32),
        "boot/opt": np.ones((64, 33), np.float32),
    }
    gm = GuestMemoryFile.create(str(tmp_path / "fn"), layout, arrays)
    arena = InstanceArena(gm)
    arena.tensor("infra/tab")
    arena.tensor("params/w")
    reap_mod.write_record(gm.base, arena.stats.trace)
    arena.close()
    return gm


@pytest.fixture()
def slow_tail():
    """Shrink tail chunks and stall between them so tests can race faults
    against a live tail deterministically; restores the seam afterwards."""
    chunk0, throttle0 = TailInstall.CHUNK_PAGES, TailInstall.throttle
    TailInstall.CHUNK_PAGES = 8

    def set_throttle(fn):
        TailInstall.throttle = staticmethod(fn)

    yield set_throttle
    TailInstall.CHUNK_PAGES = chunk0
    TailInstall.throttle = throttle0


def _restore(gm, reap, **kw):
    pipe = RestorePipeline(gm.base, reap, **kw)
    pipe.run()
    return pipe


# -- byte parity under fault-during-tail-install races ------------------


@pytest.mark.parametrize("engine", ["numpy", "pallas"])
def test_group_overlap_parity_with_fault_race(recorded, slow_tail, engine):
    """Two group-restored overlapping arenas, faulted mid-tail-install,
    end up byte-identical to an unoverlapped restore — for both fuse
    engines."""
    gm = recorded
    slow_tail(lambda tail, i: time.sleep(0.02))
    reap = dataclasses.replace(OVERLAP, fuse_engine=engine)
    ref = _restore(gm, ReapConfig(fuse_engine=engine))
    assert ref.tail is None                     # unoverlapped: no tail

    pipes = [RestorePipeline(gm.base, reap) for _ in range(2)]
    RestoreBatch(pipes).run()
    ws_pages = [int(p) for p in np.load(reap_mod.trace_path(gm.base))]
    try:
        for p in pipes:
            assert p.tail is not None           # restore really overlapped
        # fault the *whole* WS on both arenas while their tails are still
        # installing: tail pages must block on the pending install, then
        # read installed bytes — never stale zeros
        threads = [threading.Thread(
            target=p.monitor.arena.touch_pages, args=(ws_pages,))
            for p in pipes]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for p in pipes:
            p.tail.wait(30)
            assert bytes(p.monitor.arena.view) == bytes(ref.monitor.arena.view)
            st = p.monitor.arena.stats
            assert st.tail_waits >= 1
            assert st.tail_wait_seconds > 0.0
    finally:
        for p in pipes:
            p.close()
        ref.close()


def test_single_overlap_parity_and_wait_not_a_fault(recorded, slow_tail):
    """Single-pipeline overlap: a fault on a pending tail page waits for
    the installer and is NOT counted as a disk fault (else §7.2 would
    re-record a perfectly good WS)."""
    gm = recorded
    slow_tail(lambda tail, i: time.sleep(0.002))
    ref = _restore(gm, ReapConfig())
    pipe = _restore(gm, OVERLAP)
    try:
        arena = pipe.monitor.arena
        assert pipe.tail is not None
        assert arena.pending_count > 0
        tail_page = int(pipe.tail.pages[-1])
        f0 = arena.stats.n_faults
        arena.touch_pages([tail_page])
        assert bool(arena.resident[tail_page])
        assert arena.stats.tail_waits == 1
        assert arena.stats.n_faults == f0       # waited, did not disk-fault
        pipe.tail.wait(30)
        assert bytes(arena.view) == bytes(ref.monitor.arena.view)
        assert pipe.tail.done_at is not None    # time-to-fully-resident known
    finally:
        pipe.close()
        ref.close()


def test_straggler_deadline_demotes_to_disk_faults(recorded, slow_tail):
    """A stuck tail is demoted at the deadline: pending markers drop, the
    fault path serves the pages from disk byte-correctly, and the §7.2
    residual ratio exempts the demoted faults (no re-record storm)."""
    gm = recorded
    slow_tail(lambda tail, i: time.sleep(0.05))
    reap = dataclasses.replace(OVERLAP, tail_deadline_s=0.0)
    ref = _restore(gm, ReapConfig())
    pipe = _restore(gm, reap)
    try:
        arena = pipe.monitor.arena
        pipe.tail.wait(30)
        assert pipe.tail.demoted
        assert arena.stats.tail_demoted > 0
        assert arena.pending_count == 0
        # every demoted page now serves via the normal disk-fault path
        ws_pages = [int(p) for p in np.load(reap_mod.trace_path(gm.base))]
        arena.touch_pages(ws_pages)
        assert bytes(arena.view) == bytes(ref.monitor.arena.view)
        assert arena.stats.n_faults >= arena.stats.tail_demoted
        out = pipe.monitor.finish()
        assert out["residual_ratio"] <= pipe.reap.rerecord_threshold
        assert "rerecord" not in out            # demotion must not re-record
        assert reap_mod.has_record(gm.base)
    finally:
        pipe.close()
        ref.close()


def test_split_fetch_on_cache_miss(recorded, slow_tail):
    """On a WS-cache miss the overlapped pipeline reads only the
    hot-prefix span eagerly; the background tail fetches the full WS
    (populating the shared cache) and installs it — byte-identical."""
    from repro.core.reap import WS_CACHE
    gm = recorded
    ref = _restore(gm, ReapConfig())
    WS_CACHE.clear()
    WS_CACHE.reset_stats()
    slow_tail(lambda tail, i: time.sleep(0.002))
    pipe = _restore(gm, OVERLAP)
    try:
        assert pipe._split_k is not None        # fetch really split
        assert pipe.tail is not None and pipe.tail.block is None
        pipe.tail.wait(30)
        assert pipe.tail.fetch_s > 0.0          # tail resolved the bytes
        assert WS_CACHE.stats()["reads"] == 1   # ...through the cache
        assert bytes(pipe.monitor.arena.view) == bytes(ref.monitor.arena.view)
        # the eager critical path never paid the full-file read: a second
        # (unoverlapped) restore now hits the tail-populated entry
        again = _restore(gm, ReapConfig())
        assert again.monitor.ws_cache_hit
        again.close()
    finally:
        pipe.close()
        ref.close()


def test_split_fetch_group_shares_one_read(recorded, slow_tail):
    """A group restore with a split fetch: one prefix span read on the
    critical path, ONE full-WS read shared by every member's tail."""
    from repro.core.reap import WS_CACHE
    gm = recorded
    ref = _restore(gm, ReapConfig())
    WS_CACHE.clear()
    WS_CACHE.reset_stats()
    slow_tail(lambda tail, i: time.sleep(0.002))
    pipes = [RestorePipeline(gm.base, OVERLAP) for _ in range(3)]
    RestoreBatch(pipes).run()
    try:
        assert pipes[0]._split_k is not None
        for p in pipes:
            assert p.tail is not None
            p.tail.wait(30)
            assert bytes(p.monitor.arena.view) == bytes(ref.monitor.arena.view)
        assert WS_CACHE.stats()["reads"] == 1   # tails collapsed to one read
    finally:
        for p in pipes:
            p.close()
        ref.close()


def test_pipeline_close_joins_live_tail(recorded, slow_tail):
    """close() on a pipeline with a live tail cancels + joins it before
    releasing the arena mmap (no crash, no hang)."""
    slow_tail(lambda tail, i: time.sleep(0.005))
    pipe = _restore(recorded, OVERLAP)
    assert pipe.tail is not None and not pipe.tail.done()
    pipe.close()                                # must not raise or hang
    assert pipe.tail is None


# -- serving-layer safety around live tails -----------------------------


@pytest.fixture(scope="module")
def overlap_served(tmp_path_factory):
    """Orchestrator built through ServeConfig (overlap ON) with one
    registered + recorded function."""
    import jax
    from repro.configs import SMOKES
    from repro.launch import steps
    from repro.serving import Orchestrator, ServeConfig

    store = str(tmp_path_factory.mktemp("overlapstore"))
    cfg = SMOKES["olmo-1b"]
    batch = steps.make_batch(cfg, 32, 2, "train", jax.random.key(0))
    orch = Orchestrator(store, ServeConfig(warm_limit=8))
    assert orch.reap.overlap_install
    orch.register("fn", cfg, warmup_batch=batch)
    ref, _ = orch.invoke("fn", batch)            # record phase
    orch.scale_to_zero("fn")
    yield orch, batch, np.asarray(ref)
    orch.close()


def test_reaper_skips_live_tail_and_forced_paths_cancel(
        overlap_served, slow_tail):
    """reap_idle never tears down a tail-installing instance; the forced
    paths (scale_to_zero) cancel the tail and reclaim it."""
    orch, batch, _ = overlap_served
    slow_tail(lambda tail, i: time.sleep(0.01))
    inst = orch.spawn_batch("fn", 1)[0]
    rec = orch.functions["fn"]
    try:
        assert inst._tail is not None and not inst._tail.done()
        with rec.lock:
            rec.idle.append(inst)
        orch.set_policy("fn", keepalive_s=0.0)
        assert not inst.try_reclaim()            # live tail => refuse
        orch.reap_idle()
        with rec.lock:
            assert inst in rec.idle              # the sweep kept it
    finally:
        orch.set_policy("fn", keepalive_s=None)
        orch.scale_to_zero("fn")                 # forced: cancels + reclaims
    with rec.lock:
        assert inst not in rec.idle
    from repro.serving import State
    assert inst.state is State.RECLAIMED


def test_cold_burst_with_overlap_correct_and_router_closes(
        overlap_served, slow_tail):
    """A k-deep cold burst through the router with overlap on returns
    correct logits per invocation, attributes tail-wait time in the
    summary, and router.close() with live tails neither hangs nor crashes."""
    from repro.serving import Router, RouterConfig, summarize

    orch, batch, ref = overlap_served
    slow_tail(lambda tail, i: time.sleep(0.001))
    orch.scale_to_zero("fn")
    k = 4
    router = Router(orch, RouterConfig(
        max_concurrency=k, max_instances_per_function=k,
        batch_restore_limit=k), start=False)
    invs = [router.submit("fn", batch, force_cold=True) for _ in range(k)]
    router.start()
    outs = [inv.result(timeout=120) for inv in invs]
    router.close()
    for logits, rep in outs:
        np.testing.assert_array_equal(np.asarray(logits), ref)
        assert rep.load_vmm_s > 0                # really went cold
    s = summarize([rep for _, rep in outs])
    assert set(s["stage_seconds"]) == set(StageTimings().as_dict())
    assert "tail_wait_s" in s["stage_seconds"]
    assert s["tail_waits"] >= 0
    orch.tail_quiesce(timeout=60)
    assert orch.tail_stats()["live"] == 0
    orch.scale_to_zero("fn")


# -- ServeConfig + report API redesign ----------------------------------


def test_serveconfig_resolves_overlap_knobs(tmp_path):
    from repro.serving import Orchestrator, ServeConfig
    cfg = ServeConfig(hot_prefix_frac=0.5, tail_workers=3,
                      tail_deadline_s=1.5)
    r = cfg.resolved_reap()
    assert (r.overlap_install, r.hot_prefix_frac, r.tail_workers,
            r.tail_deadline_s) == (True, 0.5, 3, 1.5)
    orch = Orchestrator(str(tmp_path / "s"), cfg)
    assert orch.reap.hot_prefix_frac == 0.5
    assert orch.config is cfg


def test_orchestrator_legacy_kwargs_shim(tmp_path):
    import warnings
    from repro.serving import Orchestrator, ServeConfig
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        orch = Orchestrator(str(tmp_path / "s"), reap=ReapConfig(),
                            mode="vanilla", keepalive_s=1.5, warm_limit=3)
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert (orch.mode, orch.keepalive_s, orch.warm_limit) == ("vanilla", 1.5, 3)
    assert not orch.reap.overlap_install         # legacy keeps PR-5 contract
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        Orchestrator(str(tmp_path / "s2"), ServeConfig())  # new path: silent


def test_workernode_legacy_kwargs_shim(tmp_path):
    import warnings
    from repro.cluster import WorkerNode
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        node = WorkerNode("n0", str(tmp_path / "s"), max_concurrency=2,
                          queue_depth=7, keepalive_s=2.0, warm_limit=5)
    assert any(issubclass(x.category, DeprecationWarning) for x in w)
    assert node.router.cfg.max_concurrency == 2
    assert node.router.cfg.queue_depth == 7
    assert node.orch.keepalive_s == 2.0 and node.orch.warm_limit == 5
    assert not node.orch.reap.overlap_install
    node.close()
    with pytest.raises(TypeError):
        WorkerNode("n1", str(tmp_path / "s"), bogus_kwarg=1)


def test_report_stages_are_source_of_truth():
    st = StageTimings(load_vmm_s=1.0, connection_s=2.0, ws_fetch_s=3.0,
                      install_s=4.0, tail_wait_s=0.5)
    rep = ColdStartReport(stages=st, processing_s=1.0)
    assert rep.load_vmm_s == 1.0 and rep.connection_s == 2.0
    assert rep.prefetch_s == 7.0 and rep.install_s == 4.0
    assert rep.tail_wait_s == 0.5
    assert rep.total_s == 1.0 + 2.0 + 7.0 + 1.0
    with pytest.raises(AttributeError):
        rep.load_vmm_s = 9.0                     # flat names are read-only
    rep2 = dataclasses.replace(rep, queue_s=0.25)  # router's compat path
    assert rep2.e2e_s == rep.total_s + 0.25


# -- trace cap + cut point ----------------------------------------------


def test_trace_capped_outside_record_mode(recorded):
    """Serving-mode (prefetch) arenas must not accumulate the fault trace;
    record mode (incl. the §7.2 fallback) must."""
    gm = recorded
    pipe = _restore(gm, ReapConfig())
    assert pipe.monitor.mode == "prefetch"
    arena = pipe.monitor.arena
    assert not arena.record_trace
    boot = sorted(gm.layout.pages_of("boot/opt"))
    arena.touch_pages(boot)                      # residual disk faults...
    assert arena.stats.trace == []               # ...don't grow the trace
    assert arena.stats.n_faults == len(boot)     # but still count
    pipe.monitor.mode = "record"                 # §7.2 fallback re-arms it
    assert arena.record_trace
    pipe.close()

    raw = InstanceArena(GuestMemoryFile.open(gm.base))
    raw.tensor("infra/tab")                      # raw arenas still record
    assert raw.stats.trace
    assert len(raw.stats.trace_t) == len(raw.stats.trace)
    raw.close()


def test_choose_hot_prefix_finds_knee_and_falls_back():
    # 30 boot-phase faults 0.1ms apart, a 0.5s knee, then 70 more
    times = [i * 1e-4 for i in range(30)]
    times += [times[-1] + 0.5 + i * 1e-4 for i in range(70)]
    assert reap_mod.choose_hot_prefix(times) == 30
    # flat spacing carries no signal: caller falls back to hot_prefix_frac
    flat = [i * 1e-4 for i in range(100)]
    assert reap_mod.choose_hot_prefix(flat) is None
    assert reap_mod.choose_hot_prefix([0.0, 1.0]) is None  # tiny trace


def test_write_record_persists_cut_point(recorded, tmp_path):
    gm = recorded
    # re-record with timestamps exhibiting a knee after 10 pages
    pages = [int(p) for p in np.load(reap_mod.trace_path(gm.base))]
    times = [i * 1e-4 for i in range(10)]
    times += [times[-1] + 1.0 + i * 1e-4 for i in range(len(pages) - 10)]
    reap_mod.write_record(gm.base, pages, times)
    assert reap_mod.read_hot_prefix(gm.base) == 10
    pipe = RestorePipeline(gm.base, OVERLAP)
    assert pipe.hot_count(len(pages)) == 10      # cut beats the blind frac
    # a knee-less re-record must drop the stale cut (back to the frac knob)
    reap_mod.write_record(gm.base, pages, [i * 1e-4 for i in range(len(pages))])
    assert reap_mod.read_hot_prefix(gm.base) is None
    pipe = RestorePipeline(gm.base, OVERLAP)
    assert pipe.hot_count(len(pages)) == max(
        1, int(round(len(pages) * OVERLAP.hot_prefix_frac)))
    reap_mod.drop_record(gm.base)
    assert reap_mod.read_hot_prefix(gm.base) is None


def test_tail_wait_stats_attributed_in_report(overlap_served, slow_tail):
    """An invocation whose faults blocked on the tail reports tail_waits
    and stages.tail_wait_s > 0."""
    orch, batch, ref = overlap_served
    slow_tail(lambda tail, i: time.sleep(0.01))
    orch.scale_to_zero("fn")
    logits, rep = orch.invoke("fn", batch, force_cold=True)
    np.testing.assert_array_equal(np.asarray(logits), ref)
    assert rep.load_vmm_s > 0
    # the cold invocation's own faults may or may not land on tail pages
    # (run_invocation touches in fault order = hot prefix first), but the
    # stats plumbing must be present either way
    assert rep.tail_waits >= 0
    assert rep.stages.tail_wait_s >= 0.0
    orch.tail_quiesce(timeout=60)
    orch.scale_to_zero("fn")
