"""Graceful degradation when ``hypothesis`` is absent (importorskip-style,
but per-test): property tests collect and SKIP instead of killing the whole
module at import time.  CI installs hypothesis, so the properties run there.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    def settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    def given(*_a, **_k):
        def deco(_fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():
                pass
            _skipped.__name__ = _fn.__name__
            _skipped.__doc__ = _fn.__doc__
            return _skipped
        return deco

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies`` *and* for any strategy
        object: attribute access yields a callable returning another
        stand-in, so module-level strategy expressions — including chained
        combinators like ``st.lists(...).filter(...)`` — construct fine
        even though the decorated tests are skipped."""

        def __getattr__(self, _name):
            def _any(*_a, **_k):
                return _AnyStrategy()
            return _any

    st = _AnyStrategy()
