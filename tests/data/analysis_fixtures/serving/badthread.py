"""Seeded REP004 violations: a spawned thread with no join path and a
bare ThreadPoolExecutor with no shutdown.  Never imported."""
import threading
from concurrent.futures import ThreadPoolExecutor

POOL = ThreadPoolExecutor(max_workers=2)    # REP004: no .shutdown anywhere


def fire_and_forget(fn):
    t = threading.Thread(target=fn, daemon=True)   # REP004: no .join anywhere
    t.start()
    return t
