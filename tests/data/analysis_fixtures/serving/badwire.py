"""REP008 fixture: raw data-plane imports outside src/repro/transport/."""
import socket                                        # REP008: fires
from multiprocessing import shared_memory            # REP008: fires


def dial(path):
    import socket.socketpair  # noqa: F401           # REP008: fires (dotted)
    s = socket.socket(socket.AF_UNIX)
    s.connect(path)
    return s


def map_segment(name):
    seg = shared_memory.SharedMemory(name=name)
    return seg.buf
