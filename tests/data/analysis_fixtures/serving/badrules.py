"""Seeded REP001/REP002/REP003/REP005/REP006 violations in a serving/
path.  Never imported — parsed by the static analyzer in
tests/test_analysis.py."""
import time


class State:
    IDLE = 1
    BUSY = 2


WS_CACHE = object()


def clock_bypass():
    return time.monotonic()     # REP001: direct call in serving/


def legal_seam(clock=time.monotonic):
    """The injected-clock seam: a default-parameter *reference* is legal."""
    return clock()


def raw_state_write(inst):
    inst.state = State.BUSY     # REP002: bypasses the state machine


def cache_poke():
    return WS_CACHE._entries    # REP003: private single-flight internals


def flat_stage_write(report):
    report.install_s = 1.0      # REP005: stage seconds outside StageTimings


def legal_stage_write(timings):
    timings.install_s = 1.0     # allowed: StageTimings receiver


class SneakyEmitter:
    def queue_stats(self):      # REP006: ad-hoc stats dict in serving/
        return {"queued": 1, "inflight": 2, "dropped": 3}

    def reset_stats(self):      # allowed: returns nothing, no dict built
        self.n = 0

    def stats_name_only(self):  # not stats-like: name doesn't match
        return {"a": 1, "b": 2}
