"""REP008 fixture: the transport/ prefix is the data plane's home turf."""
import socket                                        # legal here
from multiprocessing import shared_memory            # legal here


def serve(path):
    srv = socket.socket(socket.AF_UNIX)
    srv.bind(path)
    return srv


def carve(n):
    return shared_memory.SharedMemory(create=True, size=n)
