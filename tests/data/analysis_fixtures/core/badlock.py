"""Seeded lockgraph violations: an A->B / B->A order inversion and a
``time.sleep`` while holding a lock.  Never imported — parsed by the
static analyzer in tests/test_analysis.py."""
import threading
import time


class Alpha:
    def __init__(self):
        self._a = threading.Lock()
        self._b = threading.Lock()

    def forward(self):
        with self._a:
            with self._b:       # order edge Alpha._a -> Alpha._b
                pass

    def backward(self):
        with self._b:
            with self._a:       # order edge Alpha._b -> Alpha._a: CYCLE
                pass

    def sleepy(self):
        with self._a:
            time.sleep(0.5)     # held-across-blocking


class Chained:
    """The blocking call hides one call level down: the analyzer must
    propagate the callee's blocking op to the locked caller."""

    def __init__(self):
        self._mu = threading.Lock()

    def _slow(self):
        time.sleep(0.1)

    def entry(self):
        with self._mu:
            self._slow()        # held-across-blocking via _slow
