"""Seeded REP007 violations: raw WS byte reads outside the page store.

Parsed (never imported) by tests/test_analysis.py.  The ``.ws`` file may
be a chunk manifest, so every raw read here must be flagged; the
metadata probe and the write-mode open must stay clean.
"""
import os

import numpy as np

from repro.core.arena import PageSource
from repro.core.reap import ws_path


def sneaky_open_read(base):
    with open(ws_path(base), "rb") as f:          # REP007
        return f.read()


def sneaky_page_source(base):
    return PageSource(ws_path(base), o_direct=False)   # REP007


def sneaky_fromfile(base):
    return np.fromfile(ws_path(base), dtype=np.uint8)  # REP007


def sneaky_os_open(base):
    return os.open(ws_path(base), os.O_RDONLY)    # REP007


def legal_mtime_probe(base):
    return os.path.getmtime(ws_path(base))        # metadata, not bytes


def legal_writer(base, blob):
    with open(ws_path(base) + ".tmp", "wb") as f:  # write-mode: legal
        f.write(blob)
