"""Azure Functions 2019 invocations-per-minute CSV ingestion."""
import os

import pytest

from repro.serving import Trace, azure_trace

FIXTURE = os.path.join(os.path.dirname(__file__), "data", "azure_sample.csv")

# fixture row totals: f_hot=113, f_warm=18, f_periodic=4, f_rare=1, f_idle=0
HOT, WARM, PERIODIC, RARE = 113, 18, 4, 1


def test_parses_counts_into_events():
    tr = azure_trace(FIXTURE)
    assert isinstance(tr, Trace)
    assert len(tr.events) == HOT + WARM + PERIODIC + RARE  # f_idle drops out
    # function ids come from the hash columns
    per_fn = {}
    for e in tr.events:
        per_fn[e.function] = per_fn.get(e.function, 0) + 1
    assert per_fn["o1/appA/f_hot/http"] == HOT
    assert per_fn["o2/appB/f_rare/queue"] == RARE
    # arrivals are ordered and live inside the 10-minute span
    assert all(0 <= e.t <= 600 for e in tr.events)
    assert all(tr.events[i].t <= tr.events[i + 1].t
               for i in range(len(tr.events) - 1))


def test_maps_busiest_rows_onto_registered_functions():
    names = ["fn_a", "fn_b", "fn_c"]
    tr = azure_trace(FIXTURE, functions=names, seed=3)
    per_fn = {}
    for e in tr.events:
        per_fn[e.function] = per_fn.get(e.function, 0) + 1
    # rank order: busiest azure row -> first registered name
    assert per_fn == {"fn_a": HOT, "fn_b": WARM, "fn_c": PERIODIC}


def test_duration_rescale_and_minute_cap():
    tr = azure_trace(FIXTURE, functions=["f"], duration_s=5.0)
    assert all(0 <= e.t <= 5.0 for e in tr.events)
    assert len(tr.events) == HOT                      # top-1 row only
    tr2 = azure_trace(FIXTURE, functions=["f"], max_minutes=3)
    assert len(tr2.events) == 12 + 8 + 15              # f_hot's first 3 min
    assert all(e.t <= 180 for e in tr2.events)


def test_replayable_and_roundtrips(tmp_path):
    t1 = azure_trace(FIXTURE, functions=["a", "b"], duration_s=4.0, seed=9)
    t2 = azure_trace(FIXTURE, functions=["a", "b"], duration_s=4.0, seed=9)
    assert t1.events == t2.events                      # seeded => replayable
    p = str(tmp_path / "azure.json")
    t1.save(p)
    assert Trace.load(p).events == t1.events


def test_rejects_malformed(tmp_path):
    bad = tmp_path / "bad.csv"
    bad.write_text("HashOwner,HashApp,Trigger\n")      # no minute columns
    with pytest.raises(ValueError):
        azure_trace(str(bad))
    empty = tmp_path / "empty.csv"
    empty.write_text("HashOwner,1,2,3\n")              # header only, no rows
    with pytest.raises(ValueError):
        azure_trace(str(empty))
