"""REAP core invariants: arena layout, fault semantics, record/prefetch,
misprediction handling, re-record policy -- with hypothesis property tests
on the trace/WS machinery."""
import os

import numpy as np
import pytest
from hypo import given, settings, st

from repro.core.arena import (PAGE, ArenaLayout, GuestMemoryFile,
                              InstanceArena)
from repro.core import reap as reap_mod
from repro.core.reap import ReapConfig


@pytest.fixture()
def small_gm(tmp_path):
    tensors = [
        ("infra/tab", (3000,), "uint8", "infra"),
        ("params/w", (64, 33), "float32", "serve"),
        ("boot/opt", (64, 33), "float32", "boot"),
    ]
    layout = ArenaLayout.build(tensors)
    arrays = {
        "infra/tab": np.arange(3000, dtype=np.uint8),
        "params/w": np.random.default_rng(0).standard_normal((64, 33)).astype(np.float32),
        "boot/opt": np.ones((64, 33), np.float32),
    }
    return GuestMemoryFile.create(str(tmp_path / "fn"), layout, arrays), arrays


def test_layout_page_alignment(small_gm):
    gm, _ = small_gm
    for e in gm.layout.entries.values():
        assert e.offset % PAGE == 0
    assert gm.layout.total_bytes % PAGE == 0
    assert os.path.getsize(gm.mem_path) == gm.layout.total_bytes


def test_fault_roundtrip_and_stats(small_gm):
    gm, arrays = small_gm
    arena = InstanceArena(gm)
    w = arena.tensor("params/w")
    np.testing.assert_array_equal(w, arrays["params/w"])
    n_pages = gm.layout.entries["params/w"].n_pages
    assert arena.stats.n_faults == n_pages
    # second access: no new faults
    arena.tensor("params/w")
    assert arena.stats.n_faults == n_pages
    arena.close()


def test_row_granular_faults(small_gm):
    gm, arrays = small_gm
    arena = InstanceArena(gm)
    arena.tensor_rows("params/w", [0, 1])   # rows 0-1: first page only
    assert arena.stats.n_faults == 1
    w = arena.tensor("params/w", fault=False)
    np.testing.assert_array_equal(w[0], arrays["params/w"][0])
    arena.close()


def test_record_then_prefetch_eliminates_faults(small_gm):
    gm, arrays = small_gm
    base = gm.base
    arena = InstanceArena(gm)
    arena.tensor("infra/tab")
    arena.tensor("params/w")
    reap_mod.write_record(base, arena.stats.trace)
    arena.close()
    assert reap_mod.has_record(base)

    arena2 = InstanceArena(GuestMemoryFile.open(base))
    n, secs = reap_mod.prefetch(arena2, base, ReapConfig())
    assert n == arena2.resident.sum()
    # same access pattern: zero residual faults, identical contents
    f = arena2.touch_pages(gm.layout.pages_of("params/w"))
    assert f == 0
    np.testing.assert_array_equal(arena2.tensor("params/w", fault=False),
                                  arrays["params/w"])
    arena2.close()


def test_boot_region_not_in_working_set(small_gm):
    gm, _ = small_gm
    arena = InstanceArena(gm)
    arena.tensor("infra/tab")
    arena.tensor("params/w")
    boot_pages = gm.layout.region_pages("boot")
    assert not boot_pages & set(arena.stats.trace)
    assert arena.resident_bytes < gm.layout.total_bytes
    arena.close()


def test_rerecord_policy(small_gm):
    gm, _ = small_gm
    base = gm.base
    # record only the infra pages
    arena = InstanceArena(gm)
    arena.tensor("infra/tab")
    reap_mod.write_record(base, arena.stats.trace)
    arena.close()
    # prefetch, then touch a much larger set -> residual ratio > threshold
    mon = reap_mod.Monitor(GuestMemoryFile.open(base), base,
                           ReapConfig(rerecord_threshold=0.5))
    assert mon.mode == "prefetch"
    mon.start()
    mon.arena.tensor("params/w")
    mon.arena.tensor("boot/opt")
    out = mon.finish()
    assert out.get("rerecord") is True
    assert not reap_mod.has_record(base)  # dropped -> next start re-records
    mon.arena.close()


@settings(max_examples=25, deadline=None)
@given(trace=st.lists(st.integers(0, 63), min_size=1, max_size=200))
def test_write_record_dedup_preserves_order(tmp_path_factory, trace):
    """Trace file = first-touch order with duplicates dropped (§5.2.1)."""
    tmp = tmp_path_factory.mktemp("rec")
    layout = ArenaLayout.build([("params/big", (64 * PAGE,), "uint8", "serve")])
    arrays = {"params/big": np.arange(64 * PAGE, dtype=np.uint8)}
    gm = GuestMemoryFile.create(str(tmp / "fn"), layout, arrays)
    n, nbytes = reap_mod.write_record(gm.base, trace)
    got = np.load(reap_mod.trace_path(gm.base))
    expected = list(dict.fromkeys(trace))
    assert list(got) == expected
    assert nbytes == len(expected) * PAGE
    # reassembled WS = pages in trace order (chunk-store round trip)
    pages, ws = reap_mod._read_ws(gm.base, reap_mod.ReapConfig(o_direct=False))
    assert pages == expected
    for i, p in enumerate(expected):
        assert ws[i * PAGE:(i + 1) * PAGE] == bytes(
            arrays["params/big"][p * PAGE:(p + 1) * PAGE])
    # the legacy flat format lays the same bytes out contiguously on disk
    reap_mod.write_record(gm.base, trace, fmt="flat")
    with open(reap_mod.ws_path(gm.base), "rb") as f:
        flat = f.read()
    assert flat == ws


@settings(max_examples=10, deadline=None)
@given(rows=st.lists(st.integers(0, 63), min_size=1, max_size=64))
def test_row_pages_cover_rows(rows):
    layout = ArenaLayout.build([("t", (64, 100), "float32", "serve")])
    e = layout.entries["t"]
    pages = e.row_pages(rows)
    row_bytes = e.nbytes // 64
    for r in rows:
        lo = e.offset + r * row_bytes
        hi = lo + row_bytes - 1
        assert lo // PAGE in pages and hi // PAGE in pages


def test_parallel_faults_match_serial(small_gm):
    gm, arrays = small_gm
    a1 = InstanceArena(gm)
    a1.touch_pages(gm.layout.pages_of("params/w"))
    a2 = InstanceArena(GuestMemoryFile.open(gm.base))
    a2.touch_pages(gm.layout.pages_of("params/w"), parallel=4)
    np.testing.assert_array_equal(a1.tensor("params/w", fault=False),
                                  a2.tensor("params/w", fault=False))
    a1.close()
    a2.close()
