"""Tests for the static analyzer (lockgraph + lint) and the runtime lock
sanitizer — PR 7's machine-checked concurrency invariants.

The seeded-violation fixtures live in ``tests/data/analysis_fixtures/``
(a miniature ``src/repro``-shaped tree that is parsed, never imported);
each rule must fire there, and the real tree must be clean modulo the
checked-in baseline.
"""
import json
import os
import subprocess
import sys
import threading

import pytest

from repro.analysis import analyze_lint, analyze_lockgraph, run_all
from repro.analysis.sanitizer import (
    HeldAcrossBlocking, LockOrderViolation, SanitizedCondition,
    SanitizedLock, SanitizedRLock, SanitizerState, render_violation)

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
FIXTURES = os.path.join(HERE, "data", "analysis_fixtures")
SRC_REPRO = os.path.join(ROOT, "src", "repro")


# -------------------------------------------------------------------------
# lockgraph on the seeded fixtures
# -------------------------------------------------------------------------

def test_lockgraph_detects_order_cycle():
    findings = analyze_lockgraph(FIXTURES)
    cycles = [f for f in findings if f.rule == "LOCK-ORDER"]
    assert cycles, "seeded A->B/B->A inversion not detected"
    msg = cycles[0].message
    assert "Alpha._a" in msg and "Alpha._b" in msg
    # witnesses carry file:line sites for both directions
    assert "core/badlock.py" in msg


def test_lockgraph_detects_sleep_under_lock():
    findings = analyze_lockgraph(FIXTURES)
    sleeps = [f for f in findings
              if f.rule == "LOCK-BLOCKING" and "time.sleep" in f.message]
    assert any(f.symbol == "Alpha.sleepy" for f in sleeps)


def test_lockgraph_propagates_blocking_through_calls():
    findings = analyze_lockgraph(FIXTURES)
    via = [f for f in findings
           if f.rule == "LOCK-BLOCKING" and f.symbol == "Chained.entry"]
    assert via, "blocking op one call level down not propagated"
    assert "Chained._slow" in via[0].message
    assert "Chained._mu" in via[0].message


# -------------------------------------------------------------------------
# lint rules on the seeded fixtures
# -------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lint_findings():
    return analyze_lint(FIXTURES)


def test_rep001_clock_bypass_fires(lint_findings):
    hits = [f for f in lint_findings if f.rule == "REP001"]
    assert any(f.symbol == "clock_bypass" for f in hits)
    # the injected-clock seam (default parameter value) must NOT fire
    assert not any(f.symbol == "legal_seam" for f in hits)


def test_rep002_raw_state_write_fires(lint_findings):
    hits = [f for f in lint_findings if f.rule == "REP002"]
    assert any(f.symbol == "raw_state_write" for f in hits)


def test_rep003_ws_cache_poke_fires(lint_findings):
    hits = [f for f in lint_findings if f.rule == "REP003"]
    assert any(f.symbol == "cache_poke" for f in hits)


def test_rep004_thread_without_join_fires(lint_findings):
    details = {f.detail for f in lint_findings if f.rule == "REP004"}
    assert "thread-without-join" in details
    assert "pool-without-shutdown" in details


def test_rep005_flat_stage_write_fires(lint_findings):
    hits = [f for f in lint_findings if f.rule == "REP005"]
    assert any(f.symbol == "flat_stage_write" for f in hits)
    assert not any(f.symbol == "legal_stage_write" for f in hits)


def test_rep006_adhoc_stats_dict_fires(lint_findings):
    hits = [f for f in lint_findings if f.rule == "REP006"]
    assert any(f.symbol == "SneakyEmitter.queue_stats" for f in hits)
    # no dict built / name not stats-like: both stay legal
    assert not any(f.symbol.endswith("reset_stats") for f in hits)
    assert not any(f.symbol.endswith("stats_name_only") for f in hits)


def test_rep007_ws_byte_reads_fire(lint_findings):
    hits = [f for f in lint_findings if f.rule == "REP007"]
    flagged = {f.symbol for f in hits}
    assert {"sneaky_open_read", "sneaky_page_source",
            "sneaky_fromfile", "sneaky_os_open"} <= flagged
    # metadata probes and write-mode opens are not byte reads
    assert "legal_mtime_probe" not in flagged
    assert "legal_writer" not in flagged


def test_rep008_data_plane_imports_fire(lint_findings):
    hits = [f for f in lint_findings if f.rule == "REP008"]
    details = {f.detail for f in hits}
    assert "data-plane-import:socket" in details
    assert "data-plane-import:multiprocessing.shared_memory" in details
    # every hit sits outside the transport/ prefix …
    assert all(f.path.startswith("serving/") for f in hits)
    # … and the identical imports inside transport/ stay legal
    assert not any(f.path.startswith("transport/") for f in hits)


# -------------------------------------------------------------------------
# the real tree: clean modulo the checked-in baseline
# -------------------------------------------------------------------------

def test_real_tree_clean_with_baseline():
    findings = run_all(SRC_REPRO)
    with open(os.path.join(ROOT, "analysis-baseline.json")) as f:
        baseline = json.load(f)
    fresh = [f for f in findings if f.key not in baseline]
    assert not fresh, "unbaselined findings:\n" + "\n".join(
        f.render() for f in fresh)
    # and the baseline carries no stale (never-firing) entries
    live = {f.key for f in findings}
    stale = set(baseline) - live
    assert not stale, f"stale baseline entries: {stale}"


def test_analyze_cli_check_green():
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "scripts", "analyze.py"),
         "--check"],
        capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr


def test_analyze_cli_check_fails_on_fixtures(tmp_path):
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "scripts", "analyze.py"),
         "--check", "--root", FIXTURES,
         "--baseline", str(tmp_path / "empty.json")],
        capture_output=True, text=True)
    assert r.returncode == 1
    assert "unbaselined" in r.stderr


# -------------------------------------------------------------------------
# runtime sanitizer
# -------------------------------------------------------------------------

def test_sanitizer_detects_order_cycle():
    st = SanitizerState()
    a = SanitizedLock(state=st, site="fixture.py:1")
    b = SanitizedLock(state=st, site="fixture.py:2")
    with a:
        with b:
            pass
    with pytest.raises(LockOrderViolation) as ei:
        with b:
            with a:
                pass
    msg = str(ei.value)
    assert "fixture.py:1" in msg and "fixture.py:2" in msg
    assert "cycle" in msg
    # the state also records the violation for deferred reporting
    assert st.violations and st.violations[0]["kind"] == "lock-order-cycle"


def test_sanitizer_witness_trace_content():
    st = SanitizerState(raise_on_violation=False)
    a = SanitizedLock(state=st, site="w.py:10")
    b = SanitizedLock(state=st, site="w.py:20")
    with a:
        with b:
            pass
    with b:
        with a:
            pass
    assert len(st.violations) == 1
    v = st.violations[0]
    # witness carries real stack frames from THIS test
    assert "test_sanitizer_witness_trace_content" in v["witness_new"]
    rendered = render_violation(v)
    assert "w.py:10" in rendered and "w.py:20" in rendered
    assert "acquisition trace" in rendered


def test_sanitizer_rlock_reentry_is_not_a_cycle():
    st = SanitizerState()
    a = SanitizedRLock(state=st, site="r.py:1")
    b = SanitizedRLock(state=st, site="r.py:2")
    with a:
        with a:          # reentry: no self-edge
            with b:
                pass
    assert not st.violations


def test_sanitizer_held_across_condition_wait():
    st = SanitizerState()
    other = SanitizedLock(state=st, site="c.py:1")
    cv = SanitizedCondition(state=st, site="c.py:2")
    with other:
        with cv:
            with pytest.raises(HeldAcrossBlocking) as ei:
                cv.wait(timeout=0.01)
    assert "c.py:1" in str(ei.value)


def test_sanitizer_condition_wait_own_lock_ok():
    st = SanitizerState()
    cv = SanitizedCondition(state=st, site="c.py:9")
    with cv:
        assert cv.wait(timeout=0.01) is False    # timed out, no violation
    assert not st.violations


def test_sanitizer_condition_wraps_real_wakeup():
    st = SanitizerState()
    cv = SanitizedCondition(state=st)
    hits = []

    def waiter():
        with cv:
            hits.append(cv.wait(timeout=5.0))

    t = threading.Thread(target=waiter)
    t.start()
    # notify until the waiter wakes (early notifies are lost if it has
    # not reached wait() yet)
    import time
    for _ in range(1000):
        if not t.is_alive():
            break
        with cv:
            cv.notify_all()
        time.sleep(0.001)
    t.join(timeout=5.0)
    assert hits == [True]
    assert not st.violations


def test_sanitizer_enable_scopes_to_repro_modules():
    from repro.analysis import sanitizer
    was_enabled = sanitizer.enabled()
    sanitizer.enable()
    try:
        # a lock created from a repro.* module gets wrapped
        ns_repro = {"__name__": "repro.fake_module"}
        exec("import threading\nL = threading.Lock()", ns_repro)
        assert isinstance(ns_repro["L"], SanitizedLock)
        # anyone else gets the real primitive
        ns_other = {"__name__": "some.other.module"}
        exec("import threading\nL = threading.Lock()", ns_other)
        assert not isinstance(ns_other["L"], SanitizedLock)
        # stdlib machinery built on threading stays real (Event -> Condition)
        ev = threading.Event()
        assert not isinstance(ev._cond, SanitizedCondition)
    finally:
        if not was_enabled:
            sanitizer.disable()


def test_sanitizer_sleep_under_lock():
    from repro.analysis import sanitizer
    was_enabled = sanitizer.enabled()
    was_raising = sanitizer.STATE.raise_on_violation
    sanitizer.enable()
    sanitizer.STATE.raise_on_violation = True   # conftest may defer
    try:
        sanitizer.STATE.reset()
        ns = {"__name__": "repro.fake_sleepy"}
        exec("import threading\nL = threading.Lock()", ns)
        with pytest.raises(HeldAcrossBlocking):
            with ns["L"]:
                import time
                time.sleep(0.001)
    finally:
        sanitizer.STATE.reset()
        sanitizer.STATE.raise_on_violation = was_raising
        if not was_enabled:
            sanitizer.disable()
