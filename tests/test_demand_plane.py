"""Fleet demand plane: arrival merge, owner-shard forecast routing,
re-targeting on membership change, and end-to-end prewarm-before-spillover.

The aggregator unit tests run on stubs + the fake clock (no models, no
sleeps).  The integration tests drive a real 3-node fleet but step every
control loop *by hand*, so actuation is deterministic."""
import time

import pytest
from fakeclock import FakeClock

from repro.cluster import ConsistentHashRing
from repro.cluster.demand import FLEET_TAP, DemandAggregator, DemandConfig
from repro.serving import PolicyConfig

# -- stubs ---------------------------------------------------------------


class StubPolicy:
    def __init__(self):
        self.hints = {}

    def push_forecast(self, name, rate, expires_at):
        self.hints[name] = (rate, expires_at)

    def clear_forecast(self, name):
        self.hints.pop(name, None)


class StubOrch:
    functions: dict = {}


class StubRouter:
    def __init__(self):
        self.taps = {}

    def open_tap(self, tap):
        self.taps.setdefault(tap, {})
        return tap

    def load_arrivals(self, tap, arrivals):
        for name, ts in arrivals.items():
            self.taps.setdefault(tap, {}).setdefault(name, []).extend(ts)

    def drain_arrivals(self, tap="policy"):
        out = self.taps.get(tap, {})
        self.taps[tap] = {}
        return {n: ts for n, ts in out.items() if ts}


class StubNode:
    def __init__(self, node_id):
        self.node_id = node_id
        self.alive = True
        self.router = StubRouter()
        self.policy = StubPolicy()
        self.orch = StubOrch()

    def push_forecast(self, name, rate, expires_at):
        self.policy.push_forecast(name, rate, expires_at)

    def clear_forecast(self, name):
        self.policy.clear_forecast(name)


class StubStore:
    def __init__(self, ring, replication=1):
        self.ring = ring
        self.replication = replication

    def owners(self, name):
        return self.ring.lookup(name, self.replication)


class StubCluster:
    def __init__(self, node_ids, replication=1):
        self.nodes = {i: StubNode(i) for i in node_ids}
        self.store = StubStore(ConsistentHashRing(node_ids, vnodes=16),
                               replication)

    def alive_nodes(self):
        return [n for n in self.nodes.values() if n.alive]


def steady(now, rate, dur=3.0):
    n = int(rate * dur)
    return [now - dur + i * (dur / n) for i in range(n)]


# -- aggregator unit (stubs + fake clock) --------------------------------

def test_aggregator_pushes_rate_shares_to_owner_shards_only():
    cluster = StubCluster(["na", "nb", "nc"], replication=2)
    clock = FakeClock()
    agg = DemandAggregator(cluster, DemandConfig(
        headroom=1.5, hint_ttl_s=2.0), clock=clock)
    now = clock.now
    # arrivals live on nc's router; forecasts must go to the *owners*
    cluster.nodes["nc"].router.open_tap(FLEET_TAP)
    cluster.nodes["nc"].router.load_arrivals(
        FLEET_TAP, {"f": steady(now, rate=10.0)})
    pushed = agg.step()
    owners = cluster.store.owners("f")
    assert len(owners) == 2
    assert pushed["f"] == pytest.approx(10.0 * 1.5, rel=0.2)
    for node_id, node in cluster.nodes.items():
        if node_id in owners:
            rate, expires = node.policy.hints["f"]
            assert rate == pytest.approx(pushed["f"] / 2)
            assert expires == pytest.approx(now + 2.0)
        else:
            assert "f" not in node.policy.hints
    assert agg.pushed["f"] == set(owners)


def test_aggregator_retargets_when_owner_dies():
    cluster = StubCluster(["na", "nb", "nc"], replication=1)
    clock = FakeClock()
    agg = DemandAggregator(cluster, DemandConfig(hint_ttl_s=5.0),
                           clock=clock)
    agg.ingest({"f": steady(clock.now, rate=10.0)})
    agg.step()
    [owner] = cluster.store.owners("f")
    # the owner dies and leaves the ring (what ClusterRouter.kill_node does)
    cluster.nodes[owner].alive = False
    cluster.store.ring.remove(owner)
    agg.retarget()
    clock.advance(0.1)
    agg.ingest({"f": steady(clock.now, rate=10.0)})
    agg.step()
    [successor] = cluster.store.owners("f")
    assert successor != owner
    assert "f" in cluster.nodes[successor].policy.hints
    assert agg.pushed["f"] == {successor}


def test_aggregator_withdraws_hints_when_demand_stops():
    cluster = StubCluster(["na", "nb"], replication=1)
    clock = FakeClock()
    # short history so the learned model is dropped quickly once quiet
    from repro.serving import ForecastConfig
    agg = DemandAggregator(cluster, DemandConfig(
        forecast=ForecastConfig(history_s=20.0)), clock=clock)
    agg.ingest({"f": steady(clock.now, rate=10.0)})
    agg.step()
    [owner] = cluster.store.owners("f")
    assert "f" in cluster.nodes[owner].policy.hints
    clock.advance(30.0)               # past window, keepalive, and history
    agg.step()
    assert "f" not in cluster.nodes[owner].policy.hints
    assert "f" not in agg.demand      # model forgotten once history is quiet
    assert agg.pushed == {}


def test_aggregator_ignores_sub_threshold_trickle():
    cluster = StubCluster(["na", "nb"], replication=1)
    clock = FakeClock()
    agg = DemandAggregator(cluster, DemandConfig(min_push_rate=5.0),
                           clock=clock)
    agg.ingest({"f": steady(clock.now, rate=1.0)})
    assert agg.step() == {}           # 1 rps < threshold: no hint pushed
    assert all(not n.policy.hints for n in cluster.nodes.values())


# -- real fleet integration ---------------------------------------------

@pytest.fixture(scope="module")
def fleet(tmp_path_factory):
    import jax
    from repro.cluster import ScheduleConfig, TransferModel, build_fleet
    from repro.core import ReapConfig
    from repro.configs import SMOKES
    from repro.launch import steps
    from repro.serving import PrewarmPolicy

    store_dir = str(tmp_path_factory.mktemp("dstore"))
    cfg = SMOKES["olmo-1b"]
    batch = steps.make_batch(cfg, 16, 1, "train", jax.random.key(0))
    cluster = build_fleet(
        3, store_dir, cfg=ScheduleConfig(placement="locality"),
        transfer=TransferModel(latency_s=1e-4, gbps=10.0),
        max_concurrency=2, max_instances_per_function=2, mode="reap",
        reap=ReapConfig(o_direct=False))
    # hand-stepped policies (not started): actuation is deterministic
    for node in cluster.nodes.values():
        node.policy = PrewarmPolicy(node.orch, node.router,
                                    PolicyConfig(sweep=False))
    cluster.register("dfn", cfg, seed=0, warmup_batch=batch)
    _, rep = cluster.invoke("dfn", batch)      # record phase
    assert rep.processing_s > 0
    yield cluster, batch
    for node in cluster.nodes.values():
        if node.policy is not None:
            node.policy.stop()
    cluster.close()


def test_fleet_arrivals_reach_owner_policies_and_prewarm(fleet):
    """The tentpole property end-to-end: traffic served anywhere in the
    fleet makes the *owner shards* prewarm — before any spillover
    placement lands on them."""
    cluster, batch = fleet
    agg = DemandAggregator(cluster, DemandConfig(hint_ttl_s=10.0,
                                                 headroom=2.0))
    for node in cluster.nodes.values():
        agg.attach_node(node)
    for _ in range(8):                # sustained traffic, wherever it lands
        cluster.invoke("dfn", batch)
    pushed = agg.step()
    assert pushed["dfn"] > 0
    owners = [o for o in cluster.store.owners("dfn")
              if cluster.nodes[o].alive]
    assert owners
    for node_id in owners:
        node = cluster.nodes[node_id]
        assert node.policy.fleet["dfn"][0] > 0   # hint arrived
        node.policy.step()
        node.orch.prewarm_quiesce()
        assert node.orch.idle_count("dfn") >= 1  # replica is warm
        # and a placement landing there now serves without restore cost
        _, rep = node.submit("dfn", batch).result(120)
        assert rep.load_vmm_s == 0.0


def test_cluster_router_runs_demand_plane_lifecycle(tmp_path_factory):
    """build_fleet(demand=...) wires the aggregator: taps open on every
    node, stats expose it, close() stops the loop thread."""
    import jax
    from repro.cluster import ScheduleConfig, TransferModel, build_fleet
    from repro.core import ReapConfig
    from repro.configs import SMOKES
    from repro.launch import steps

    store_dir = str(tmp_path_factory.mktemp("lstore"))
    cfg = SMOKES["olmo-1b"]
    batch = steps.make_batch(cfg, 16, 1, "train", jax.random.key(2))
    cluster = build_fleet(
        2, store_dir, cfg=ScheduleConfig(placement="locality"),
        demand=DemandConfig(interval_s=0.02),
        transfer=TransferModel(latency_s=1e-4, gbps=10.0),
        max_concurrency=2, mode="reap", reap=ReapConfig(o_direct=False),
        policy=PolicyConfig(interval_s=0.02, sweep=False))
    try:
        assert cluster.demand_plane is not None
        for node in cluster.nodes.values():
            assert FLEET_TAP in node.router._taps
        cluster.register("lfn", cfg, seed=0, warmup_batch=batch)
        _, rep = cluster.invoke("lfn", batch)
        assert rep.processing_s > 0
        deadline = time.monotonic() + 5.0
        while (cluster.demand_plane.n_steps == 0
               and time.monotonic() < deadline):
            time.sleep(0.01)
        stats = cluster.stats()
        assert stats["demand"]["steps"] > 0
        assert stats["demand"]["errors"] == 0
    finally:
        cluster.close()
    assert cluster.demand_plane._thread is None  # loop joined on close


def test_aggregator_loop_survives_errors():
    """A node dying mid-step must not kill the fleet control loop."""
    cluster = StubCluster(["na"])
    agg = DemandAggregator(cluster, DemandConfig(interval_s=0.005))
    boom = {"n": 0}

    def bad_drain():
        boom["n"] += 1
        if boom["n"] == 1:
            raise RuntimeError("node died mid-drain")

    agg._drain_nodes = bad_drain
    agg.start()
    deadline = time.monotonic() + 5.0
    while boom["n"] < 3 and time.monotonic() < deadline:
        time.sleep(0.005)
    agg.stop()
    assert boom["n"] >= 3
    assert agg.n_errors >= 1


def test_double_start_and_stop_are_idempotent():
    cluster = StubCluster(["na"])
    agg = DemandAggregator(cluster, DemandConfig(interval_s=0.01))
    agg.start()
    t = agg._thread
    assert agg.start()._thread is t
    agg.stop()
    agg.stop()
    assert agg._thread is None
