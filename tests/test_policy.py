"""Adaptive prewarming control plane: demand model, prewarm API,
per-function warm limits, reaper floor, and the policy loop.

Timing-sensitive tests run on the deterministic fake clock
(tests/fakeclock.py) injected via the ``clock=`` hooks — no real
``time.sleep`` on those paths, so they finish in milliseconds and never
flake.  Only the background-thread integration tests (marked ``slow``)
pace themselves against the wall clock.
"""
import threading
import time

import jax
import pytest
from fakeclock import FakeClock

from repro.configs import SMOKES
from repro.core import ReapConfig
from repro.launch import steps
from repro.serving import (FunctionDemand, Orchestrator, PolicyConfig,
                           PrewarmPolicy, Router, RouterConfig)


@pytest.fixture(scope="module")
def served(tmp_path_factory):
    """One registered+recorded function on a module-scoped orchestrator."""
    store = str(tmp_path_factory.mktemp("pstore"))
    cfg = SMOKES["olmo-1b"]
    batch = steps.make_batch(cfg, 32, 2, "train", jax.random.key(0))
    orch = Orchestrator(store, mode="reap", reap=ReapConfig())
    orch.register("fn", cfg, warmup_batch=batch)
    orch.invoke("fn", batch)          # record phase
    orch.scale_to_zero("fn")
    yield orch, batch
    orch.close()


def _reset(orch, name="fn"):
    orch.set_policy(name, warm_limit=None, keepalive_s=None, min_warm=0)
    orch.scale_to_zero(name)


# -- demand model (pure, synthetic clocks) -----------------------------

def test_demand_rate_and_keepalive():
    cfg = PolicyConfig(window_s=5.0, keepalive_horizons=8.0,
                       min_keepalive_s=0.5, max_keepalive_s=60.0)
    d = FunctionDemand(cfg)
    now = 1000.0
    d.observe([now - 2.0 + 0.1 * i for i in range(20)])   # 10 rps for 2s
    # max(windowed 20/5s, EWMA 1/0.1s) = 10 rps
    assert d.rate(now) == pytest.approx(10.0, rel=0.05)
    assert d.active(now)
    # EWMA tracks the 100ms gap; keepalive = 8 horizons, clamped below 60
    assert 0.5 <= d.keepalive(now) <= 60.0
    # demand goes stale once the gap since the last arrival exceeds keepalive
    assert not d.active(now + 100.0)


def test_demand_burst_width_and_robust_keepalive():
    cfg = PolicyConfig(window_s=5.0, keepalive_horizons=8.0,
                       min_keepalive_s=0.1)
    d = FunctionDemand(cfg)
    now = 50.0
    # two 4-wide simultaneous bursts 1.5s apart
    d.observe([now - 1.5] * 4 + [now] * 4)
    assert d.peak_concurrency(0.05, now) == 4
    # intra-burst gaps drive the EWMA to ~0, but the windowed mean keeps
    # the keepalive spanning the burst period (no collapse between bursts)
    assert d.ewma_interarrival < 0.3
    assert d.keepalive(now) >= 8.0 * (5.0 / 8) * 0.99
    assert d.active(now + 1.4)        # still live when the next burst lands


# -- orchestrator prewarm + limits + reaper floor ----------------------

def test_prewarm_serves_arrivals_without_restore_cost(served):
    """The acceptance property: a prewarmed instance's restore (load VMM,
    connection, WS prefetch) never lands on an invocation's critical path."""
    orch, batch = served
    _reset(orch)
    rec = orch.functions["fn"]
    n = orch.prewarm("fn", 2, wait=True)
    assert n == 2
    with rec.lock:
        assert len(rec.idle) == 2
    assert rec.n_prewarmed >= 2

    router = Router(orch, RouterConfig(max_concurrency=2,
                                       max_instances_per_function=2))
    results = router.map([("fn", batch)] * 2)
    router.close()
    for _, rep in results:
        assert rep.prewarmed
        assert rep.load_vmm_s == 0.0       # paid off-path by the pool thread
        assert rep.prefetch_s == 0.0
        assert rep.connection_s == 0.0
        assert rep.processing_s > 0
    _reset(orch)


def test_prewarm_respects_per_function_warm_limit(served):
    orch, batch = served
    _reset(orch)
    orch.set_policy("fn", warm_limit=1)
    rec = orch.functions["fn"]
    scheduled = orch.prewarm("fn", 3, wait=True)
    assert scheduled <= 1
    with rec.lock:
        assert len(rec.idle) <= 1
    _reset(orch)


def test_reaper_never_reclaims_below_policy_floor(served):
    """keepalive=-1 makes every instance strictly past its deadline the
    moment it parks — the reap outcome is deterministic with no sleep."""
    orch, batch = served
    _reset(orch)
    orch.set_policy("fn", warm_limit=3, keepalive_s=-1.0, min_warm=2)
    orch.prewarm("fn", 3, wait=True)
    rec = orch.functions["fn"]
    with rec.lock:
        assert len(rec.idle) == 3
    orch.reap_idle()
    with rec.lock:
        assert len(rec.idle) == 2     # the min_warm floor held
    orch.set_policy("fn", warm_limit=3, keepalive_s=-1.0, min_warm=0)
    orch.reap_idle()
    with rec.lock:
        assert len(rec.idle) == 0     # floor lifted => scale to zero
    _reset(orch)


# -- policy loop --------------------------------------------------------

def test_policy_step_prewarms_and_sets_knobs(served):
    orch, batch = served
    _reset(orch)
    rec = orch.functions["fn"]
    clock = FakeClock(start=1000.0)
    policy = PrewarmPolicy(orch, router=None, cfg=PolicyConfig(
        window_s=5.0, headroom=2.0, max_warm=4, sweep=False), clock=clock)
    now = clock.now
    # a steady 20 rps history, including pairs inside a restore horizon
    policy.ingest({"fn": [now - 1.0 + 0.05 * i for i in range(20)]})
    applied = policy.step()           # "now" comes from the injected clock
    assert applied["fn"] >= 1
    orch.prewarm_quiesce()
    with rec.lock:
        assert len(rec.idle) >= 1     # prewarm happened off-path
        assert rec.min_warm == applied["fn"]
        # the cap only ever rises above the orchestrator default
        assert rec.warm_limit == max(applied["fn"], orch.warm_limit)
        assert rec.keepalive_s is not None
    out, rep = orch.invoke("fn", batch)
    assert rep.prewarmed and rep.load_vmm_s == 0.0
    _reset(orch)


def test_policy_target_zero_when_demand_stops(served):
    orch, batch = served
    _reset(orch)
    clock = FakeClock()
    policy = PrewarmPolicy(orch, router=None, cfg=PolicyConfig(sweep=False),
                           clock=clock)
    now = clock.now
    policy.ingest({"fn": [now - 0.2, now - 0.1, now]})
    assert policy.step()["fn"] >= 1
    orch.prewarm_quiesce()
    # long after the last arrival the forecast goes to zero and the floor
    # drops, so a sweep can reclaim everything
    clock.advance(10_000.0)
    applied = policy.step()
    assert applied["fn"] == 0
    rec = orch.functions["fn"]
    assert rec.min_warm == 0
    assert "fn" not in policy.demand  # reactive history forgotten when stale
    orch.set_policy("fn", keepalive_s=-1.0, min_warm=0)
    orch.reap_idle()
    with rec.lock:
        assert len(rec.idle) == 0
    _reset(orch)


def test_policy_fleet_hint_prewarms_without_local_arrivals(served):
    """The cluster demand plane's push path: a fleet-forecast hint alone
    (no local history at all) raises the warm target, and the hint's
    expiry returns the function to scale-to-zero."""
    orch, batch = served
    _reset(orch)
    clock = FakeClock()
    policy = PrewarmPolicy(orch, router=None, cfg=PolicyConfig(
        headroom=2.0, max_warm=4, sweep=False), clock=clock)
    # 40 rps share x service estimate (~recorded) => >= 1 warm (the rate
    # arrives pre-headroomed by the aggregator; no local multiply)
    policy.push_forecast("fn", 40.0, expires_at=clock.now + 5.0)
    applied = policy.step()
    assert applied["fn"] >= 1
    orch.prewarm_quiesce()
    rec = orch.functions["fn"]
    with rec.lock:
        assert len(rec.idle) >= 1     # prewarmed purely off the fleet hint
        assert rec.min_warm == applied["fn"]
    _, rep = orch.invoke("fn", batch)
    assert rep.prewarmed and rep.load_vmm_s == 0.0
    # past the hint's TTL the floor drops and the hint is pruned
    clock.advance(10.0)
    applied = policy.step()
    assert applied.get("fn", 0) == 0
    assert policy.fleet == {}
    assert rec.min_warm == 0
    _reset(orch)


def test_policy_fleet_hint_withdrawn_on_clear(served):
    orch, batch = served
    _reset(orch)
    clock = FakeClock()
    policy = PrewarmPolicy(orch, router=None,
                           cfg=PolicyConfig(sweep=False), clock=clock)
    policy.push_forecast("fn", 40.0, expires_at=clock.now + 60.0)
    assert policy.step()["fn"] >= 1
    policy.clear_forecast("fn")       # aggregator re-targeted the hint away
    applied = policy.step()
    assert applied.get("fn", 0) == 0
    assert orch.functions["fn"].min_warm == 0
    orch.prewarm_quiesce()
    _reset(orch)


@pytest.mark.slow
def test_policy_loop_with_router_end_to_end(served):
    """Background loop + router: arrivals feed the policy, later arrivals
    are served by prewarmed instances."""
    orch, batch = served
    _reset(orch)
    router = Router(orch, RouterConfig(max_concurrency=4,
                                       max_instances_per_function=4))
    with PrewarmPolicy(orch, router, PolicyConfig(
            interval_s=0.02, window_s=5.0, max_warm=4)) as policy:
        reports = []
        for _ in range(4):            # spaced arrivals let the loop react
            _, rep = router.invoke("fn", batch, timeout=120)
            reports.append(rep)
            time.sleep(0.08)
        deadline = time.monotonic() + 5.0
        while not policy.targets.get("fn") and time.monotonic() < deadline:
            time.sleep(0.02)
        assert policy.targets.get("fn", 0) >= 1
        assert policy.n_steps > 0
    router.close()
    assert any(r.prewarmed for r in reports[1:]) or orch.functions[
        "fn"].n_prewarmed > 0
    _reset(orch)


@pytest.mark.slow
def test_policy_loop_survives_errors(served):
    """A mid-step exception (e.g. racing deregistration) must not kill the
    control loop thread."""
    orch, batch = served
    policy = PrewarmPolicy(orch, router=None,
                           cfg=PolicyConfig(interval_s=0.01, sweep=False))
    boom = {"n": 0}

    def bad_step(now=None):
        boom["n"] += 1
        if boom["n"] == 1:
            raise RuntimeError("transient")
        return PrewarmPolicy.step(policy, now)

    policy.step = bad_step
    policy.start()
    deadline = time.monotonic() + 5.0
    while boom["n"] < 3 and time.monotonic() < deadline:
        time.sleep(0.01)
    policy.stop()
    assert boom["n"] >= 3             # kept stepping after the error


def test_prewarm_of_recordless_function_writes_record(served):
    """Prewarming a function that was never cold-invoked must still persist
    a WS record, so REAP prefetch engages on later true cold starts instead
    of the function staying recordless behind warm pools."""
    from repro.core.reap import has_record
    orch, batch = served
    cfg = SMOKES["olmo-1b"]
    rec = orch.register("fn_rless", cfg, seed=3)
    assert not has_record(rec.base)
    orch.prewarm("fn_rless", 1, wait=True)
    assert has_record(rec.base)          # record written off-path
    _, rep = orch.invoke("fn_rless", batch)
    assert rep.prewarmed and rep.load_vmm_s == 0.0
    orch.scale_to_zero("fn_rless")
    _, rep = orch.invoke("fn_rless", batch, force_cold=True)
    assert rep.n_prefetched_pages > 0    # next cold start prefetches
    orch.scale_to_zero("fn_rless")


def test_prewarm_unknown_function_raises(served):
    orch, _ = served
    with pytest.raises(KeyError):
        orch.prewarm("nope", 1)


@pytest.mark.slow
def test_concurrent_prewarm_and_invocations(served):
    """Prewarming races the data plane: limits hold and nothing deadlocks."""
    orch, batch = served
    _reset(orch)
    orch.set_policy("fn", warm_limit=3)
    router = Router(orch, RouterConfig(max_concurrency=4,
                                       max_instances_per_function=4))
    stop = threading.Event()

    def prewarmer():
        while not stop.is_set():
            orch.prewarm("fn", 2)
            time.sleep(0.005)

    t = threading.Thread(target=prewarmer, daemon=True)
    t.start()
    try:
        results = router.map([("fn", batch)] * 10)
    finally:
        stop.set()
        t.join(timeout=5)
    router.close()
    orch.prewarm_quiesce()
    assert len(results) == 10
    assert all(rep.processing_s > 0 for _, rep in results)
    rec = orch.functions["fn"]
    with rec.lock:
        assert len(rec.idle) <= 3     # per-function limit held under the race
    _reset(orch)


def test_close_makes_prewarm_noop(served):
    """Runs last in this module: close() is permanent — a policy loop still
    winding down must not resurrect the prewarm pool."""
    orch, batch = served
    orch.close()
    assert orch.prewarm("fn", 2, wait=True) == 0
    rec = orch.functions["fn"]
    with rec.lock:
        assert len(rec.idle) == 0
