"""Cluster layer: consistent-hash ring, sharded two-tier snapshot store,
locality-aware scheduling, node-failure rerouting, ring rebalance."""
import os
import threading

import numpy as np
import pytest

from repro.cluster import (ClusterRouter, ConsistentHashRing, ScheduleConfig,
                           ShardedSnapshotStore, TransferModel, WorkerNode,
                           build_fleet)
from repro.core.arena import PAGE
from repro.core.reap import ReapConfig, trace_path, ws_path


# -- consistent-hash ring -------------------------------------------------

KEYS = [f"fn-{i}" for i in range(2000)]


def owners_of(ring, keys):
    return {k: ring.owner(k) for k in keys}


def test_ring_balance_across_nodes():
    """Virtual nodes spread 2000 keys over 8 hosts without hot-spotting:
    every host owns a share within 3x of fair."""
    ring = ConsistentHashRing([f"node-{i}" for i in range(8)], vnodes=64)
    counts = {}
    for k in KEYS:
        counts[ring.owner(k)] = counts.get(ring.owner(k), 0) + 1
    assert len(counts) == 8                      # every node owns keys
    fair = len(KEYS) / 8
    for n, c in counts.items():
        assert fair / 3 <= c <= fair * 3, (n, c)


def test_ring_lookup_is_stable_and_distinct():
    ring = ConsistentHashRing(["a", "b", "c", "d"], vnodes=32)
    for k in KEYS[:50]:
        owners = ring.lookup(k, 3)
        assert len(owners) == len(set(owners)) == 3
        assert owners == ring.lookup(k, 3)       # deterministic
        assert owners[0] == ring.owner(k)
    # insertion order must not matter
    ring2 = ConsistentHashRing(["d", "b", "a", "c"], vnodes=32)
    assert owners_of(ring, KEYS[:200]) == owners_of(ring2, KEYS[:200])


def test_ring_join_moves_minimal_keys_to_the_joiner():
    ring = ConsistentHashRing([f"node-{i}" for i in range(5)], vnodes=64)
    before = owners_of(ring, KEYS)
    ring.add("node-5")
    after = owners_of(ring, KEYS)
    moved = [k for k in KEYS if before[k] != after[k]]
    # every moved key moved *to* the joiner, never between old nodes
    assert all(after[k] == "node-5" for k in moved)
    # ~1/6 of the keyspace expected; far below a full rehash
    assert 0 < len(moved) / len(KEYS) < 0.45


def test_ring_leave_moves_only_the_victims_keys():
    ring = ConsistentHashRing([f"node-{i}" for i in range(5)], vnodes=64)
    before = owners_of(ring, KEYS)
    ring.remove("node-2")
    after = owners_of(ring, KEYS)
    for k in KEYS:
        if before[k] == "node-2":
            assert after[k] != "node-2"          # redistributed
        else:
            assert after[k] == before[k]         # untouched
    assert "node-2" not in ring and len(ring) == 4


def test_ring_replicas_promote_on_primary_death():
    """lookup(k, r)[1:] are the fallback owners: removing the primary makes
    exactly them the new owner list."""
    ring = ConsistentHashRing(["a", "b", "c", "d"], vnodes=64)
    for k in KEYS[:100]:
        first, rest = ring.lookup(k, 3)[0], ring.lookup(k, 3)[1:]
        ring.remove(first)
        assert ring.lookup(k, 2) == rest
        ring.add(first)


def test_ring_empty_and_small():
    ring = ConsistentHashRing(vnodes=8)
    assert ring.lookup("x", 2) == [] and ring.owner("x") is None
    ring.add("only")
    assert ring.lookup("x", 3) == ["only"]       # n capped at ring size


# -- sharded snapshot store (no models: fabricated WS records) ------------

def make_record(tmp_path, name: str, n_pages: int = 4) -> str:
    """Write a fake legacy flat WS record (trace + ws file) for ``name``.

    Page contents are distinct per page (and salted by name) so the
    shard tier's content-hash wire dedup doesn't collapse the transfer —
    tests asserting full-WS ``transfer_bytes`` stay meaningful."""
    base = str(tmp_path / name)
    pages = np.arange(n_pages, dtype=np.int64)
    np.save(trace_path(base), pages)
    salt = sum(name.encode())
    with open(ws_path(base), "wb") as f:
        for i in range(n_pages):
            f.write(bytes([(salt + i) % 256]) * PAGE)
    return base


@pytest.fixture()
def store2(tmp_path):
    """Two-node store with a no-op sleep (costs recorded, not paid)."""
    ring = ConsistentHashRing(vnodes=32)
    slept = []
    store = ShardedSnapshotStore(ring, transfer=TransferModel(1e-3, 1.0),
                                 reap=ReapConfig(o_direct=False),
                                 sleep=slept.append)
    caches = {n: store.attach(n) for n in ("na", "nb")}
    return store, caches, slept, tmp_path


def test_two_tier_fetch_local_remote_origin(store2):
    store, caches, slept, tmp = store2
    base = make_record(tmp, "fn", n_pages=3)
    owner = store.owners("fn")[0]
    other = "nb" if owner == "na" else "na"
    cfg = ReapConfig(o_direct=False)

    assert store.warm_owners(base) == 1       # owner shard reads origin once
    assert store.stats()["origin_reads"] == 1

    # non-owner miss: remote fetch from the warm owner shard
    pages, data, hit = caches[other].fetch(base, cfg)
    assert not hit and len(data) == 3 * PAGE and pages == [0, 1, 2]
    s = store.stats()
    assert s["remote_fetches"] == 1 and s["origin_reads"] == 1
    assert s["transfer_bytes"] == 3 * PAGE
    assert slept == [store.transfer.cost_s(3 * PAGE)]  # modeled cost charged
    assert store.resident(other, base)        # installed locally

    # second fetch on the non-owner: pure local hit, no new traffic
    _, _, hit = caches[other].fetch(base, cfg)
    assert hit
    s = store.stats()
    assert s["remote_fetches"] == 1 and s["origin_reads"] == 1
    assert s["local_hit_rate"] > 0


def test_cold_owner_does_not_serve_remote(store2):
    """An owner whose cache is cold cannot serve a peer: the requester
    reads origin itself (counted remote_misses) and the owner's cache is
    NOT populated on its behalf — peeks never join or trigger reads on
    another node's cache, which is what makes cross-cache deadlock
    impossible."""
    store, caches, slept, tmp = store2
    base = make_record(tmp, "fncold", n_pages=2)
    owner = store.owners("fncold")[0]
    other = "nb" if owner == "na" else "na"
    _, data, hit = caches[other].fetch(base, ReapConfig(o_direct=False))
    assert not hit and len(data) == 2 * PAGE
    s = store.stats()
    assert s["remote_fetches"] == 0 and s["remote_misses"] == 1
    assert s["origin_reads"] == 1 and slept == []
    assert store.resident(other, base)
    assert not store.resident(owner, base)


def test_owner_fetch_goes_straight_to_origin(store2):
    store, caches, slept, tmp = store2
    base = make_record(tmp, "fn2", n_pages=2)
    owner = store.owners("fn2")[0]
    _, _, hit = caches[owner].fetch(base, ReapConfig(o_direct=False))
    assert not hit
    s = store.stats()
    assert s["origin_reads"] == 1 and s["remote_fetches"] == 0
    assert slept == []                         # no network modeled


def test_dead_owner_falls_back_to_origin(store2):
    store, caches, slept, tmp = store2
    base = make_record(tmp, "fn3", n_pages=2)
    owner = store.owners("fn3")[0]
    other = "nb" if owner == "na" else "na"
    store.set_alive(owner, False)
    # the ring dropped the dead node, so the survivor is now the owner and
    # reads origin; either way the fetch succeeds without the dead host
    _, data, _ = caches[other].fetch(base, ReapConfig(o_direct=False))
    assert len(data) == 2 * PAGE
    s = store.stats()
    assert s["origin_reads"] == 1 and s["remote_fetches"] == 0
    assert s["alive"] == [other]


def test_dead_owner_fallback_counts_when_ring_keeps_owner(store2):
    """If the owner is marked dead in the store but still on the ring (a
    failure window before membership converges), the fetch falls back to
    origin and counts it."""
    store, caches, slept, tmp = store2
    base = make_record(tmp, "fn4", n_pages=2)
    owner = store.owners("fn4")[0]
    other = "nb" if owner == "na" else "na"
    with store._mu:
        store._alive[owner] = False            # dead, but ring unchanged
    _, data, _ = caches[other].fetch(base, ReapConfig(o_direct=False))
    assert len(data) == 2 * PAGE
    s = store.stats()
    assert s["dead_owner_fallbacks"] == 1 and s["origin_reads"] == 1


def _names_owned_by(store, owner: str, prefix: str, k: int = 1) -> list:
    """First ``k`` generated names whose primary shard is ``owner``."""
    names, i = [], 0
    while len(names) < k:
        name = f"{prefix}{i}"
        if store.owners(name)[0] == owner:
            names.append(name)
        i += 1
    return names


def test_cold_owner_consults_alive_peer_replica_before_origin(store2):
    """Regression: a replica owner whose own L1 is cold must peek its
    alive co-owners before paying the origin read — the owner-path early
    exit used to skip the peer tier entirely."""
    store, caches, slept, tmp = store2
    store.set_replication("fnrep", 2)
    primary, secondary = store.owners("fnrep")   # both of na/nb own it
    base = make_record(tmp, "fnrep", n_pages=3)
    cfg = ReapConfig(o_direct=False)
    caches[secondary].fetch(base, cfg)           # co-owner warms at origin
    store.reset_stats()
    _, data, hit = caches[primary].fetch(base, cfg)
    assert not hit and len(data) == 3 * PAGE
    s = store.stats()
    assert s["remote_fetches"] == 1 and s["origin_reads"] == 0
    assert s["transfer_bytes"] == 3 * PAGE
    assert slept == [store.transfer.cost_s(3 * PAGE)]


def test_never_alive_ring_owner_counts_remote_miss(store2):
    """Regression: a ring entry that never came up is not a *dead* owner —
    nothing failed, the owner tier simply has no replica yet.  It used to
    count ``dead_owner_fallbacks`` and pollute the failure drill's
    headline counter."""
    store, caches, slept, tmp = store2
    store.ring.add("ghost")                      # on the ring, never attached
    name = _names_owned_by(store, "ghost", "gfn")[0]
    base = make_record(tmp, name, n_pages=2)
    requester = "na" if store.owners(name) == ["ghost"] else None
    assert requester is not None                 # replication=1: sole owner
    _, data, _ = caches[requester].fetch(base, ReapConfig(o_direct=False))
    assert len(data) == 2 * PAGE
    s = store.stats()
    assert s["remote_misses"] == 1 and s["origin_reads"] == 1
    assert s["dead_owner_fallbacks"] == 0


def test_wire_ships_only_chunks_the_requester_is_missing(store2):
    """Cross-function wire dedup: a fetch is charged only for chunks the
    requester's L1 doesn't already hold from *any* function."""
    store, caches, slept, tmp = store2
    name_a, name_b = _names_owned_by(store, "na", "wfn", k=2)
    shared = bytes([7]) * PAGE                   # one page common to both
    base_a, base_b = str(tmp / name_a), str(tmp / name_b)
    for base, contents in ((base_a, [bytes([1]) * PAGE, shared]),
                           (base_b, [shared, bytes([2]) * PAGE])):
        np.save(trace_path(base), np.arange(len(contents), dtype=np.int64))
        with open(ws_path(base), "wb") as f:
            for blk in contents:
                f.write(blk)
    cfg = ReapConfig(o_direct=False)
    assert store.warm_owners(base_a) == 1 and store.warm_owners(base_b) == 1
    store.reset_stats()
    caches["nb"].fetch(base_a, cfg)              # cold requester: all ships
    s = store.stats()
    assert s["transfer_bytes"] == 2 * PAGE and s["dedup_bytes_saved"] == 0
    caches["nb"].fetch(base_b, cfg)              # shared page already held
    s = store.stats()
    assert s["remote_fetches"] == 2
    assert s["transfer_bytes"] == 3 * PAGE       # only the missing chunk
    assert s["dedup_bytes_saved"] == PAGE
    assert slept[-1] == store.transfer.cost_s(PAGE)


def test_replication_factor_for_hot_functions(store2):
    store, caches, slept, tmp = store2
    assert len(store.owners("hot")) == 1
    store.set_replication("hot", 2)
    owners = store.owners("hot")
    assert len(owners) == 2 == len(set(owners))
    with pytest.raises(ValueError):
        store.set_replication("hot", 0)


def test_warm_owners_installs_into_owner_caches(store2):
    store, caches, slept, tmp = store2
    base = make_record(tmp, "fn5", n_pages=2)
    store.set_replication("fn5", 2)
    assert store.warm_owners(base) == 2
    for owner in store.owners("fn5"):
        assert store.resident(owner, base)
    assert store.warm_owners(str(tmp / "no_record")) == 0


def test_transfer_model_cost():
    tm = TransferModel(latency_s=1e-3, gbps=8.0)
    assert tm.cost_s(0) == pytest.approx(1e-3)
    # 1 GB at 8 Gb/s = 1 s + latency
    assert tm.cost_s(10 ** 9) == pytest.approx(1.0 + 1e-3)
    assert tm.cost_pages(2) == pytest.approx(tm.cost_s(2 * PAGE))


def test_concurrent_nonowner_misses_single_flight(store2):
    """Concurrent misses on one node issue one remote fetch."""
    store, caches, slept, tmp = store2
    base = make_record(tmp, "fn6", n_pages=2)
    owner = store.owners("fn6")[0]
    other = "nb" if owner == "na" else "na"
    cfg = ReapConfig(o_direct=False)
    store.warm_owners(base)                   # owner shard can serve
    results = []
    threads = [threading.Thread(
        target=lambda: results.append(caches[other].fetch(base, cfg)))
        for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(10)
    assert len(results) == 4
    assert store.stats()["remote_fetches"] == 1   # single-flight held
    assert sum(1 for _, _, hit in results if hit) == 3


def test_ring_flip_mid_fetch_does_not_deadlock(store2, monkeypatch):
    """Ownership flipping while a shard fetch is in flight must not create
    a wait cycle.  The remote tier peeks completed entries only — it never
    joins another cache's in-flight read — so whichever way the ring flips
    mid-fetch, the requester resolves at origin instead of blocking."""
    store, caches, slept, tmp = store2
    base = make_record(tmp, "fnx", n_pages=2)
    owner = store.owners("fnx")[0]
    other = "nb" if owner == "na" else "na"
    calls = []

    def flipping(name):                     # owner -> requester mid-chain
        calls.append(name)
        return [owner] if len(calls) == 1 else [other]

    monkeypatch.setattr(store, "owners", flipping)
    out = {}
    t = threading.Thread(
        target=lambda: out.update(
            r=caches[other].fetch(base, ReapConfig(o_direct=False))),
        daemon=True)
    t.start()
    t.join(10)
    assert not t.is_alive(), "shard fetch deadlocked on its own event"
    pages, data, hit = out["r"]
    assert len(data) == 2 * PAGE and not hit
    assert store.stats()["origin_reads"] >= 1


# -- fleet integration (real serving stack, smoke-sized model) -------------

@pytest.fixture(scope="module")
def fleet(tmp_path_factory):
    import jax
    from repro.configs import SMOKES
    from repro.launch import steps

    store_dir = str(tmp_path_factory.mktemp("cstore"))
    cfg = SMOKES["olmo-1b"]
    batch = steps.make_batch(cfg, 16, 1, "train", jax.random.key(0))
    cluster = build_fleet(
        3, store_dir, cfg=ScheduleConfig(placement="locality"),
        transfer=TransferModel(latency_s=1e-4, gbps=10.0),
        max_concurrency=2, max_instances_per_function=2, mode="reap",
        reap=ReapConfig(o_direct=False))
    cluster.register("cfn", cfg, seed=0, warmup_batch=batch)
    cluster.register("cfn2", cfg, seed=1)
    # record phase: one cold invocation each writes the WS record
    for name in ("cfn", "cfn2"):
        _, rep = cluster.invoke(name, batch)
        assert rep.processing_s > 0
    yield cluster, batch
    cluster.close()


def test_locality_placement_sticks_to_the_warm_node(fleet):
    cluster, batch = fleet
    _, rep = cluster.invoke("cfn", batch)
    warm_node = max(cluster.nodes.values(),
                    key=lambda n: n.warm_count("cfn")).node_id
    for _ in range(3):
        cinv = cluster.submit("cfn", batch)
        out, rep = cinv.result(timeout=120)
        assert cinv.node_id == warm_node        # warm signal dominates
        assert rep.load_vmm_s == 0              # served warm, no restore


def test_nonowner_cold_start_remote_fetches_then_is_resident(fleet):
    cluster, batch = fleet
    name = "cfn2"
    cluster.rebalance()                       # owner shards hold the WS
    owners = cluster.store.owners(name)
    non_owner = next(n for n in cluster.nodes.values()
                     if n.node_id not in owners)
    before = cluster.store.stats()["remote_fetches"]
    inv = non_owner.submit(name, batch, force_cold=True)
    _, rep = inv.result(120)
    assert rep.n_prefetched_pages > 0           # REAP prefetch engaged
    assert cluster.store.stats()["remote_fetches"] >= before + 1
    assert non_owner.ws_resident(name)          # L1 installed for next time
    # and the next cold start on the same node is a pure local hit
    before = cluster.store.stats()["remote_fetches"]
    _, rep2 = non_owner.submit(name, batch, force_cold=True).result(120)
    assert rep2.ws_cache_hit
    assert cluster.store.stats()["remote_fetches"] == before


def test_node_kill_reroutes_queued_invocations(tmp_path_factory):
    """Kill the node holding a queue mid-burst: every future resolves, the
    queued remainder reroutes to survivors, nothing hangs."""
    import jax
    from repro.configs import SMOKES
    from repro.launch import steps

    store_dir = str(tmp_path_factory.mktemp("kstore"))
    cfg = SMOKES["olmo-1b"]
    batch = steps.make_batch(cfg, 16, 1, "train", jax.random.key(1))
    # w_load=0 keeps the queue pinned to one node so the kill has a backlog
    cluster = build_fleet(
        3, store_dir, cfg=ScheduleConfig(placement="locality", w_load=0.0),
        transfer=TransferModel(latency_s=1e-4, gbps=10.0),
        max_concurrency=1, max_instances_per_function=1, mode="reap",
        reap=ReapConfig(o_direct=False))
    cluster.register("kfn", cfg, seed=0, warmup_batch=batch)
    _, _ = cluster.invoke("kfn", batch)          # record + warm one node
    victim = max(cluster.nodes.values(),
                 key=lambda n: n.warm_count("kfn")).node_id

    # force_cold serializes real restore work behind one worker: the burst
    # is still queued on the victim when the kill lands
    invs = [cluster.submit("kfn", batch, force_cold=True) for _ in range(8)]
    assert all(inv.node_id == victim for inv in invs)   # locality pinned
    cluster.kill_node(victim)
    placements_at_kill = dict(cluster.stats()["placements"])
    reports = []
    for inv in invs:
        out, rep = inv.result(timeout=120)       # resolves: served or rerouted
        reports.append(rep)
    assert len(reports) == 8
    assert all(r.processing_s > 0 for r in reports)
    assert cluster.n_rerouted >= 1
    rerouted = [inv for inv in invs if len(inv.node_ids) > 1]
    assert rerouted and all(inv.node_ids[0] == victim
                            and inv.node_ids[-1] != victim
                            for inv in rerouted)
    # the dead node took no further placements
    assert not cluster.nodes[victim].alive
    _, rep = cluster.invoke("kfn", batch)
    assert (cluster.stats()["placements"][victim]
            == placements_at_kill[victim])
    cluster.close()


def test_rebalance_warms_new_owners(fleet):
    cluster, batch = fleet
    warmed = cluster.rebalance()
    assert set(warmed) == {"cfn", "cfn2"}
    for name in warmed:
        owners = [o for o in cluster.store.owners(name)
                  if cluster.store.is_alive(o)]
        assert warmed[name] == len(owners)
        for o in owners:
            assert cluster.store.resident(
                o, os.path.join(cluster.nodes[o].orch.store_dir, name))


def test_join_registers_functions_and_rebalances(fleet):
    cluster, batch = fleet
    node_id = "node-late"
    node = WorkerNode(node_id, cluster.nodes["node-0"].orch.store_dir,
                      max_concurrency=2, reap=ReapConfig(o_direct=False))
    cluster.add_node(node)                    # attaches the L1 cache itself
    assert node.ws_cache is cluster.store.caches[node_id]
    assert node.orch.ws_cache is node.ws_cache
    assert node_id in cluster.store.ring
    assert set(node.orch.functions) == {"cfn", "cfn2"}  # catalog replayed
    # the joiner serves traffic placed on it directly
    _, rep = node.submit("cfn", batch).result(120)
    assert rep.processing_s > 0


def test_cluster_admission_error_only_when_every_node_full(tmp_path):
    """Fleet-wide admission: one full queue falls through to other nodes."""
    from repro.cluster.scheduler import ClusterRouter
    from repro.serving import AdmissionError

    class StubRouter:
        def __init__(self, depth):
            self.depth = depth
            self.n = 0

        def submit(self, name, batch, force_cold=False):
            if self.n >= self.depth:
                raise AdmissionError("full")
            self.n += 1
            return f"inv-{self.n}"

        def stats(self):
            return {"queued": {}, "inflight": {}}

    class StubNode:
        def __init__(self, node_id, depth):
            self.node_id = node_id
            self.alive = True
            self.capacity = 1
            self.router = StubRouter(depth)

        def register(self, *a, **k):
            pass

        def submit(self, name, batch, force_cold=False):
            return self.router.submit(name, batch, force_cold)

        def load(self):
            return self.router.n

        def warm_count(self, name):
            return 0

        def ws_resident(self, name):
            return False

    a, b = StubNode("a", 1), StubNode("b", 1)
    cluster = ClusterRouter([a, b], cfg=ScheduleConfig(placement="locality"))
    assert cluster.submit("f", {}) is not None
    assert cluster.submit("f", {}) is not None   # second lands on the other
    assert a.router.n == b.router.n == 1
    with pytest.raises(AdmissionError):
        cluster.submit("f", {})                  # now every queue is full
    assert cluster.n_rejected == 1
