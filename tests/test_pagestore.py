"""Content-addressed page store (core/pagestore.py) + CAS WS records.

Covers: chunk round-trip byte parity against the flat format under both
fuse engines, delta re-records appending only changed chunks, refcount GC
never dropping chunks shared across manifests (plus compaction), the
legacy flat-WS fallback seam, concurrent readers sharing one store read
per unique chunk, crash-leftover tmp sweeping, and the hot-prefix knee
detector's winner-excluded baseline.

Records are fabricated at the ``write_record`` level: a ``.mem`` file is
just page-granular bytes, so tests control sharing exactly (same page
bytes => same chunk hash) without arena machinery.
"""
import os
import threading

import numpy as np
import pytest

from repro.core import pagestore
from repro.core import reap as reap_mod
from repro.core.reap import (PAGE, ReapConfig, choose_hot_prefix,
                             cut_path, drop_record, has_record, trace_path,
                             write_record, ws_path)

CFG = ReapConfig(o_direct=False)


def page(tag: int) -> bytes:
    """A full page of deterministic, tag-unique bytes."""
    return bytes([tag % 256]) * (PAGE // 2) + bytes([(tag * 7 + 1) % 256]) \
        * (PAGE // 2)


def make_mem(tmp_path, name: str, pages: list[bytes]) -> str:
    base = str(tmp_path / name)
    with open(base + ".mem", "wb") as f:
        for b in pages:
            f.write(b)
    return base


def store_of(base: str) -> pagestore.PageStore:
    return pagestore.get_store(os.path.dirname(base))


# -- round-trip parity -------------------------------------------------


@pytest.mark.parametrize("engine", ["numpy", "pallas"])
def test_cas_roundtrip_matches_flat_both_engines(tmp_path, engine):
    """A CAS record reassembles byte-identically to the flat format, and
    both fuse engines produce the same install block from it."""
    from repro.core.restore import fuse_ws_block
    contents = [page(3), page(5), page(3), page(9)]   # one intra-WS dup
    trace = [2, 0, 3, 1]
    cas = make_mem(tmp_path, "cas_fn", contents)
    flat = make_mem(tmp_path, "flat_fn", contents)
    write_record(cas, trace, fmt="cas")
    write_record(flat, trace, fmt="flat")

    pages_c, data_c = reap_mod._read_ws(cas, CFG)
    pages_f, data_f = reap_mod._read_ws(flat, CFG)
    assert pages_c == pages_f == trace
    assert data_c == data_f                      # full byte parity
    for j, p in enumerate(trace):
        assert data_c[j * PAGE:(j + 1) * PAGE] == contents[p]

    idx_c, block_c = fuse_ws_block(pages_c, data_c, engine=engine)
    idx_f, block_f = fuse_ws_block(pages_f, data_f, engine=engine)
    np.testing.assert_array_equal(idx_c, idx_f)
    np.testing.assert_array_equal(block_c, block_f)


def test_prefix_read_matches_flat(tmp_path):
    contents = [page(i) for i in range(6)]
    trace = [4, 1, 5, 0, 2, 3]
    cas = make_mem(tmp_path, "pcas", contents)
    flat = make_mem(tmp_path, "pflat", contents)
    write_record(cas, trace, fmt="cas")
    write_record(flat, trace, fmt="flat")
    pages_c, head_c = reap_mod._read_ws_prefix(cas, CFG, 3)
    pages_f, head_f = reap_mod._read_ws_prefix(flat, CFG, 3)
    assert pages_c == pages_f == trace           # full index list either way
    assert head_c == head_f
    assert len(head_c) == 3 * PAGE
    assert head_c == contents[4] + contents[1] + contents[5]


# -- delta re-records --------------------------------------------------


def test_delta_rerecord_appends_only_changed_chunks(tmp_path):
    contents = [page(10), page(11), page(12), page(13)]
    base = make_mem(tmp_path, "delta_fn", contents)
    write_record(base, [0, 1, 2, 3], fmt="cas")
    store = store_of(base)
    before = store.stats()
    # change exactly one page's bytes, then re-record the same trace
    with open(base + ".mem", "r+b") as f:
        f.seek(2 * PAGE)
        f.write(page(99))
    write_record(base, [0, 1, 2, 3], fmt="cas")
    after = store.stats()
    assert after["delta_chunks"] - before["delta_chunks"] == 1
    assert after["chunk_writes"] - before["chunk_writes"] == 1
    _, data = reap_mod._read_ws(base, CFG)
    assert data[2 * PAGE:3 * PAGE] == page(99)   # new bytes are served
    assert data[:PAGE] == page(10)               # untouched pages survive


def test_unchanged_rerecord_writes_nothing(tmp_path):
    base = make_mem(tmp_path, "same_fn", [page(1), page(2)])
    write_record(base, [0, 1], fmt="cas")
    store = store_of(base)
    before = store.stats()["chunk_writes"]
    write_record(base, [0, 1], fmt="cas")
    assert store.stats()["chunk_writes"] == before


def test_o_direct_read_does_not_poison_the_write_fd(tmp_path):
    """Regression: the O_DIRECT read path must use its own fd — flipping
    the flag on a dup of the write fd poisons the shared open file
    description, and every later (unaligned) chunk append fails EINVAL."""
    base = make_mem(tmp_path, "od_fn", [page(14), page(15)])
    write_record(base, [0, 1], fmt="cas")
    pages, data = reap_mod._read_ws(base, ReapConfig(o_direct=True))
    assert data == page(14) + page(15)
    with open(base + ".mem", "r+b") as f:
        f.write(page(16))
    write_record(base, [0, 1], fmt="cas")    # append after a direct read
    _, data = reap_mod._read_ws(base, ReapConfig(o_direct=True))
    assert data == page(16) + page(15)


# -- refcount GC -------------------------------------------------------


def test_gc_never_drops_shared_chunks(tmp_path):
    """Dropping one manifest frees only its private chunks; chunks shared
    with a surviving manifest keep serving correct bytes."""
    a = make_mem(tmp_path, "a_fn", [page(20), page(21), page(22)])
    b = make_mem(tmp_path, "b_fn", [page(21), page(22), page(23)])
    write_record(a, [0, 1, 2], fmt="cas")
    write_record(b, [0, 1, 2], fmt="cas")
    store = store_of(a)
    assert store.stats()["chunks"] == 4          # 20..23 stored once
    drop_record(a)
    st = store.stats()
    assert st["gc_freed"] == 1                   # only page(20) was private
    assert st["chunks"] == 3
    _, data = reap_mod._read_ws(b, CFG)
    assert data == page(21) + page(22) + page(23)
    drop_record(b)
    assert store.stats()["chunks"] == 0


def test_flat_rerecord_releases_prior_manifest_refs(tmp_path):
    """A format downgrade (cas -> flat) must not pin chunk bytes forever."""
    base = make_mem(tmp_path, "down_fn", [page(30), page(31)])
    write_record(base, [0, 1], fmt="cas")
    store = store_of(base)
    assert store.stats()["chunks"] == 2
    write_record(base, [0, 1], fmt="flat")
    assert store.stats()["chunks"] == 0          # refs released, GC'd
    _, data = reap_mod._read_ws(base, CFG)       # flat seam still serves
    assert data == page(30) + page(31)


def test_compaction_reclaims_dead_bytes_and_preserves_reads(tmp_path):
    store = pagestore.PageStore(str(tmp_path / "ps"),
                                compact_min_bytes=PAGE)
    try:
        keep = [pagestore.chunk_hash(page(t)) for t in (40, 41)]
        dead = [pagestore.chunk_hash(page(t)) for t in (50, 51, 52)]
        store.commit_manifest(keep, {h: page(t) for h, t
                                     in zip(keep, (40, 41))})
        store.commit_manifest(dead, {h: page(t) for h, t
                                     in zip(dead, (50, 51, 52))})
        store.release_manifest(dead)
        st = store.stats()
        assert st["compactions"] >= 1
        assert st["data_bytes"] == st["store_bytes"] == 2 * PAGE
        # survivors still serve correct bytes from the rewritten file
        assert store.read_chunks(keep) == page(40) + page(41)
    finally:
        store.close()


# -- legacy flat fallback ----------------------------------------------


def test_legacy_pre_manifest_ws_file_reads(tmp_path):
    """A WS file written before manifests existed (raw concatenated page
    bytes, no magic) must keep serving through the fallback seam."""
    base = str(tmp_path / "legacy_fn")
    contents = [page(60), page(61), page(62)]
    trace = [5, 0, 9]
    with open(ws_path(base), "wb") as f:         # hand-rolled legacy file
        for b in contents:
            f.write(b)
    np.save(trace_path(base) + ".tmp.npy", np.asarray(trace, np.int64))
    os.replace(trace_path(base) + ".tmp.npy", trace_path(base))
    assert has_record(base)
    assert pagestore.read_manifest(ws_path(base)) is None
    pages, data = reap_mod._read_ws(base, CFG)
    assert pages == trace
    assert data == b"".join(contents)
    pages, head = reap_mod._read_ws_prefix(base, CFG, 2)
    assert pages == trace and head == contents[0] + contents[1]


# -- concurrent readers ------------------------------------------------


def test_concurrent_readers_share_one_read_per_unique_chunk(tmp_path):
    """Two cold-starts whose manifests overlap perform exactly one store
    read per unique chunk between them (cache + per-chunk single-flight)."""
    a = make_mem(tmp_path, "ca_fn", [page(70), page(71), page(72)])
    b = make_mem(tmp_path, "cb_fn", [page(71), page(72), page(73)])
    write_record(a, [0, 1, 2], fmt="cas")
    write_record(b, [0, 1, 2], fmt="cas")
    # a fresh store instance on the same directory: cold read cache, same
    # persisted index/chunk file (the registry instance's writes are
    # durable at commit time)
    cold = pagestore.PageStore(str(tmp_path))
    try:
        man_a = pagestore.read_manifest(ws_path(a))
        man_b = pagestore.read_manifest(ws_path(b))
        union = set(man_a["chunks"]) | set(man_b["chunks"])
        barrier = threading.Barrier(2)
        out: dict[str, bytes] = {}
        errs: list[BaseException] = []

        def reader(key, chunks):
            try:
                barrier.wait()
                out[key] = cold.read_chunks(chunks)
            except BaseException as e:           # surfaced by the assert
                errs.append(e)

        ts = [threading.Thread(target=reader, args=("a", man_a["chunks"])),
              threading.Thread(target=reader, args=("b", man_b["chunks"]))]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert not errs
        assert out["a"] == page(70) + page(71) + page(72)
        assert out["b"] == page(71) + page(72) + page(73)
        assert cold.stats()["chunk_reads"] == len(union) == 4
    finally:
        cold.close()


def test_dropped_chunks_surface_as_missing_record(tmp_path):
    """A §7.2 drop racing a cold start must look like a vanished record
    (FileNotFoundError), not a KeyError from store internals."""
    base = make_mem(tmp_path, "race_fn", [page(80)])
    write_record(base, [0], fmt="cas")
    man = pagestore.read_manifest(ws_path(base))
    store_of(base).release_manifest(man["chunks"])   # chunks GC'd under us
    with pytest.raises(FileNotFoundError):
        reap_mod._read_ws(base, CFG)


# -- crash-leftover hygiene --------------------------------------------


def _strand_tmps(base: str) -> list[str]:
    tmps = [ws_path(base) + ".tmp", trace_path(base) + ".tmp.npy",
            cut_path(base) + ".tmp"]
    for p in tmps:
        with open(p, "wb") as f:
            f.write(b"stranded")
    return tmps


def test_write_record_sweeps_stale_tmps(tmp_path):
    base = make_mem(tmp_path, "sweep_fn", [page(90)])
    tmps = _strand_tmps(base)
    write_record(base, [0], fmt="cas")
    for p in tmps:
        assert not os.path.exists(p)
    assert has_record(base)                      # the sweep spared the record


def test_drop_record_sweeps_stale_tmps(tmp_path):
    base = make_mem(tmp_path, "dsweep_fn", [page(91)])
    write_record(base, [0], fmt="cas")
    tmps = _strand_tmps(base)
    drop_record(base)
    for p in tmps:
        assert not os.path.exists(p)
    assert not has_record(base)
    assert reap_mod._sweep_tmp(base) == 0        # idempotent when clean


# -- hot-prefix knee baseline ------------------------------------------


def test_choose_hot_prefix_excludes_winner_from_baseline():
    """The knee gap must not inflate its own median baseline: on a short
    trace the winner shifting the median suppressed legitimate cuts."""
    # 8 samples -> window gaps at i=1..6: [.01, .01, .04, .1, .01, .04].
    # Median WITH the winner is .04 (8x bar = .32 > .1 -> no cut, the old
    # bug); median of the OTHERS is .01 (bar = .08 < .1 -> knee at i=4).
    times = [0.0, 0.01, 0.02, 0.06, 0.16, 0.17, 0.21, 0.215]
    assert choose_hot_prefix(times) == 4


def test_choose_hot_prefix_absolute_floor_still_holds():
    # same shape shrunk 50x: the "knee" is scheduler noise (< min_gap_s)
    times = [t / 50 for t in
             [0.0, 0.01, 0.02, 0.06, 0.16, 0.17, 0.21, 0.215]]
    assert choose_hot_prefix(times) is None
