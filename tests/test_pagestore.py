"""Content-addressed page store (core/pagestore.py) + CAS WS records.

Covers: chunk round-trip byte parity against the flat format under both
fuse engines, delta re-records appending only changed chunks, refcount GC
never dropping chunks shared across manifests (plus compaction), the
legacy flat-WS fallback seam, concurrent readers sharing one store read
per unique chunk, crash-leftover tmp sweeping, and the hot-prefix knee
detector's winner-excluded baseline.

Records are fabricated at the ``write_record`` level: a ``.mem`` file is
just page-granular bytes, so tests control sharing exactly (same page
bytes => same chunk hash) without arena machinery.
"""
import os
import threading

import numpy as np
import pytest

from repro.core import pagestore
from repro.core import reap as reap_mod
from repro.core.reap import (PAGE, ReapConfig, choose_hot_prefix,
                             cut_path, drop_record, has_record, trace_path,
                             write_record, ws_path)

CFG = ReapConfig(o_direct=False)


def page(tag: int) -> bytes:
    """A full page of deterministic, tag-unique bytes."""
    return bytes([tag % 256]) * (PAGE // 2) + bytes([(tag * 7 + 1) % 256]) \
        * (PAGE // 2)


def make_mem(tmp_path, name: str, pages: list[bytes]) -> str:
    base = str(tmp_path / name)
    with open(base + ".mem", "wb") as f:
        for b in pages:
            f.write(b)
    return base


def store_of(base: str) -> pagestore.PageStore:
    return pagestore.get_store(os.path.dirname(base))


# -- round-trip parity -------------------------------------------------


@pytest.mark.parametrize("engine", ["numpy", "pallas"])
def test_cas_roundtrip_matches_flat_both_engines(tmp_path, engine):
    """A CAS record reassembles byte-identically to the flat format, and
    both fuse engines produce the same install block from it."""
    from repro.core.restore import fuse_ws_block
    contents = [page(3), page(5), page(3), page(9)]   # one intra-WS dup
    trace = [2, 0, 3, 1]
    cas = make_mem(tmp_path, "cas_fn", contents)
    flat = make_mem(tmp_path, "flat_fn", contents)
    write_record(cas, trace, fmt="cas")
    write_record(flat, trace, fmt="flat")

    pages_c, data_c = reap_mod._read_ws(cas, CFG)
    pages_f, data_f = reap_mod._read_ws(flat, CFG)
    assert pages_c == pages_f == trace
    assert data_c == data_f                      # full byte parity
    for j, p in enumerate(trace):
        assert data_c[j * PAGE:(j + 1) * PAGE] == contents[p]

    idx_c, block_c = fuse_ws_block(pages_c, data_c, engine=engine)
    idx_f, block_f = fuse_ws_block(pages_f, data_f, engine=engine)
    np.testing.assert_array_equal(idx_c, idx_f)
    np.testing.assert_array_equal(block_c, block_f)


def test_prefix_read_matches_flat(tmp_path):
    contents = [page(i) for i in range(6)]
    trace = [4, 1, 5, 0, 2, 3]
    cas = make_mem(tmp_path, "pcas", contents)
    flat = make_mem(tmp_path, "pflat", contents)
    write_record(cas, trace, fmt="cas")
    write_record(flat, trace, fmt="flat")
    pages_c, head_c = reap_mod._read_ws_prefix(cas, CFG, 3)
    pages_f, head_f = reap_mod._read_ws_prefix(flat, CFG, 3)
    assert pages_c == pages_f == trace           # full index list either way
    assert head_c == head_f
    assert len(head_c) == 3 * PAGE
    assert head_c == contents[4] + contents[1] + contents[5]


# -- delta re-records --------------------------------------------------


def test_delta_rerecord_appends_only_changed_chunks(tmp_path):
    contents = [page(10), page(11), page(12), page(13)]
    base = make_mem(tmp_path, "delta_fn", contents)
    write_record(base, [0, 1, 2, 3], fmt="cas")
    store = store_of(base)
    before = store.stats()
    # change exactly one page's bytes, then re-record the same trace
    with open(base + ".mem", "r+b") as f:
        f.seek(2 * PAGE)
        f.write(page(99))
    write_record(base, [0, 1, 2, 3], fmt="cas")
    after = store.stats()
    assert after["delta_chunks"] - before["delta_chunks"] == 1
    assert after["chunk_writes"] - before["chunk_writes"] == 1
    _, data = reap_mod._read_ws(base, CFG)
    assert data[2 * PAGE:3 * PAGE] == page(99)   # new bytes are served
    assert data[:PAGE] == page(10)               # untouched pages survive


def test_unchanged_rerecord_writes_nothing(tmp_path):
    base = make_mem(tmp_path, "same_fn", [page(1), page(2)])
    write_record(base, [0, 1], fmt="cas")
    store = store_of(base)
    before = store.stats()["chunk_writes"]
    write_record(base, [0, 1], fmt="cas")
    assert store.stats()["chunk_writes"] == before


def test_o_direct_read_does_not_poison_the_write_fd(tmp_path):
    """Regression: the O_DIRECT read path must use its own fd — flipping
    the flag on a dup of the write fd poisons the shared open file
    description, and every later (unaligned) chunk append fails EINVAL."""
    base = make_mem(tmp_path, "od_fn", [page(14), page(15)])
    write_record(base, [0, 1], fmt="cas")
    pages, data = reap_mod._read_ws(base, ReapConfig(o_direct=True))
    assert data == page(14) + page(15)
    with open(base + ".mem", "r+b") as f:
        f.write(page(16))
    write_record(base, [0, 1], fmt="cas")    # append after a direct read
    _, data = reap_mod._read_ws(base, ReapConfig(o_direct=True))
    assert data == page(16) + page(15)


# -- refcount GC -------------------------------------------------------


def test_gc_never_drops_shared_chunks(tmp_path):
    """Dropping one manifest frees only its private chunks; chunks shared
    with a surviving manifest keep serving correct bytes."""
    a = make_mem(tmp_path, "a_fn", [page(20), page(21), page(22)])
    b = make_mem(tmp_path, "b_fn", [page(21), page(22), page(23)])
    write_record(a, [0, 1, 2], fmt="cas")
    write_record(b, [0, 1, 2], fmt="cas")
    store = store_of(a)
    assert store.stats()["chunks"] == 4          # 20..23 stored once
    drop_record(a)
    st = store.stats()
    assert st["gc_freed"] == 1                   # only page(20) was private
    assert st["chunks"] == 3
    _, data = reap_mod._read_ws(b, CFG)
    assert data == page(21) + page(22) + page(23)
    drop_record(b)
    assert store.stats()["chunks"] == 0


def test_flat_rerecord_releases_prior_manifest_refs(tmp_path):
    """A format downgrade (cas -> flat) must not pin chunk bytes forever."""
    base = make_mem(tmp_path, "down_fn", [page(30), page(31)])
    write_record(base, [0, 1], fmt="cas")
    store = store_of(base)
    assert store.stats()["chunks"] == 2
    write_record(base, [0, 1], fmt="flat")
    assert store.stats()["chunks"] == 0          # refs released, GC'd
    _, data = reap_mod._read_ws(base, CFG)       # flat seam still serves
    assert data == page(30) + page(31)


def test_compaction_reclaims_dead_bytes_and_preserves_reads(tmp_path):
    store = pagestore.PageStore(str(tmp_path / "ps"),
                                compact_min_bytes=PAGE)
    try:
        keep = [pagestore.chunk_hash(page(t)) for t in (40, 41)]
        dead = [pagestore.chunk_hash(page(t)) for t in (50, 51, 52)]
        store.commit_manifest(keep, {h: page(t) for h, t
                                     in zip(keep, (40, 41))})
        store.commit_manifest(dead, {h: page(t) for h, t
                                     in zip(dead, (50, 51, 52))})
        store.release_manifest(dead)
        st = store.stats()
        assert st["compactions"] >= 1
        assert st["data_bytes"] == st["store_bytes"] == 2 * PAGE
        # survivors still serve correct bytes from the rewritten file
        assert store.read_chunks(keep) == page(40) + page(41)
    finally:
        store.close()


# -- legacy flat fallback ----------------------------------------------


def test_legacy_pre_manifest_ws_file_reads(tmp_path):
    """A WS file written before manifests existed (raw concatenated page
    bytes, no magic) must keep serving through the fallback seam."""
    base = str(tmp_path / "legacy_fn")
    contents = [page(60), page(61), page(62)]
    trace = [5, 0, 9]
    with open(ws_path(base), "wb") as f:         # hand-rolled legacy file
        for b in contents:
            f.write(b)
    np.save(trace_path(base) + ".tmp.npy", np.asarray(trace, np.int64))
    os.replace(trace_path(base) + ".tmp.npy", trace_path(base))
    assert has_record(base)
    assert pagestore.read_manifest(ws_path(base)) is None
    pages, data = reap_mod._read_ws(base, CFG)
    assert pages == trace
    assert data == b"".join(contents)
    pages, head = reap_mod._read_ws_prefix(base, CFG, 2)
    assert pages == trace and head == contents[0] + contents[1]


# -- concurrent readers ------------------------------------------------


def test_concurrent_readers_share_one_read_per_unique_chunk(tmp_path):
    """Two cold-starts whose manifests overlap perform exactly one store
    read per unique chunk between them (cache + per-chunk single-flight)."""
    a = make_mem(tmp_path, "ca_fn", [page(70), page(71), page(72)])
    b = make_mem(tmp_path, "cb_fn", [page(71), page(72), page(73)])
    write_record(a, [0, 1, 2], fmt="cas")
    write_record(b, [0, 1, 2], fmt="cas")
    # a fresh store instance on the same directory: cold read cache, same
    # persisted index/chunk file (the registry instance's writes are
    # durable at commit time)
    cold = pagestore.PageStore(str(tmp_path))
    try:
        man_a = pagestore.read_manifest(ws_path(a))
        man_b = pagestore.read_manifest(ws_path(b))
        union = set(man_a["chunks"]) | set(man_b["chunks"])
        barrier = threading.Barrier(2)
        out: dict[str, bytes] = {}
        errs: list[BaseException] = []

        def reader(key, chunks):
            try:
                barrier.wait()
                out[key] = cold.read_chunks(chunks)
            except BaseException as e:           # surfaced by the assert
                errs.append(e)

        ts = [threading.Thread(target=reader, args=("a", man_a["chunks"])),
              threading.Thread(target=reader, args=("b", man_b["chunks"]))]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert not errs
        assert out["a"] == page(70) + page(71) + page(72)
        assert out["b"] == page(71) + page(72) + page(73)
        assert cold.stats()["chunk_reads"] == len(union) == 4
    finally:
        cold.close()


def test_missing_chunk_never_strands_inflight_claims(tmp_path):
    """Regression: a KeyError mid-claim used to leave the pass's earlier
    claims registered as in-flight Events that nothing would ever set, so
    any later reader of those chunks blocked forever in ev.wait()."""
    base = make_mem(tmp_path, "strand_fn", [page(75), page(76)])
    write_record(base, [0, 1], fmt="cas")
    man = pagestore.read_manifest(ws_path(base))
    store = store_of(base)
    # claim order == hash order: present chunks are claimed before the
    # missing one raises
    with pytest.raises(KeyError):
        store.read_chunks(man["chunks"] + ["0" * 32])
    assert store._inflight == {}                 # nothing left claimed
    done: list[bytes] = []
    t = threading.Thread(
        target=lambda: done.append(store.read_chunks(man["chunks"])))
    t.start()
    t.join(5)
    assert not t.is_alive()                      # no wedged follower
    assert done == [page(75) + page(76)]


def test_short_read_raises_instead_of_zero_filling(tmp_path):
    """Regression: a truncated/corrupt chunks.data used to be served as
    zero-filled pages (the untouched tail of the anonymous mmap buffer)
    instead of failing the restore."""
    base = make_mem(tmp_path, "trunc_fn", [page(77), page(78)])
    write_record(base, [0, 1], fmt="cas")
    store = store_of(base)
    with open(store.data_path, "r+b") as f:
        f.truncate(PAGE)                         # second chunk now EOF
    man = pagestore.read_manifest(ws_path(base))
    with pytest.raises(OSError, match="short read"):
        store.read_chunks(man["chunks"])
    assert store._inflight == {}                 # error path cleaned up


def test_compaction_closes_retired_fds(tmp_path):
    """Regression: every compaction appended a fresh data fd (plus an
    O_DIRECT one) and kept the retired generation open until close() —
    unbounded fd growth in a long-lived process."""
    store = pagestore.PageStore(str(tmp_path / "fdps"),
                                compact_min_bytes=PAGE)
    try:
        def churn(t1, t2):
            """Commit a 2-chunk manifest and release it: dead (2 pages)
            outweighs live (1 page), so the release compacts."""
            dead = [pagestore.chunk_hash(page(t)) for t in (t1, t2)]
            store.commit_manifest(dead, {h: page(t) for h, t
                                         in zip(dead, (t1, t2))})
            store.release_manifest(dead)

        keep = [pagestore.chunk_hash(page(85))]
        store.commit_manifest(keep, {keep[0]: page(85)})
        for t in range(100, 112, 2):             # 6 compaction cycles
            churn(t, t + 1)
        assert store.stats()["compactions"] >= 6
        assert len(store._fds) <= 2              # current fd + dfd only
        assert store.read_chunks(keep) == page(85)

        # a pinned reader defers the close to its own release
        with store._mu:
            fd, _dfd, gen = store._acquire_read_locked()
        churn(120, 121)                          # compacts under the pin
        assert store.stats()["compactions"] >= 7
        assert fd in store._fds                  # still open for the reader
        store._release_read(gen)
        assert fd not in store._fds              # last release closed it
    finally:
        store.close()


def test_dropped_chunks_surface_as_missing_record(tmp_path):
    """A §7.2 drop racing a cold start must look like a vanished record
    (FileNotFoundError), not a KeyError from store internals."""
    base = make_mem(tmp_path, "race_fn", [page(80)])
    write_record(base, [0], fmt="cas")
    man = pagestore.read_manifest(ws_path(base))
    store_of(base).release_manifest(man["chunks"])   # chunks GC'd under us
    with pytest.raises(FileNotFoundError):
        reap_mod._read_ws(base, CFG)


# -- re-record crash ordering / serialization --------------------------


def test_failed_manifest_write_leaves_old_record_readable(tmp_path,
                                                          monkeypatch):
    """Regression: a re-record used to release the prior manifest's chunk
    refs before f.ws pointed at the new manifest — a crash in between
    left the on-disk record referencing GC'd chunks.  Now the old record
    must survive a failure at the manifest-write step."""
    base = make_mem(tmp_path, "crash_fn", [page(130), page(131)])
    write_record(base, [0, 1], fmt="cas")
    with open(base + ".mem", "r+b") as f:        # new content for the redo
        f.write(page(132))

    def boom(path, pages, chunks, **kw):
        raise RuntimeError("crash between commit and manifest write")

    monkeypatch.setattr(pagestore, "write_manifest", boom)
    with pytest.raises(RuntimeError):
        write_record(base, [0, 1], fmt="cas")
    monkeypatch.undo()
    # f.ws still names the prior manifest and its chunks are still alive
    _, data = reap_mod._read_ws(base, CFG)
    assert data == page(130) + page(131)


def test_concurrent_rerecord_and_drop_serialize(tmp_path, monkeypatch):
    """Regression: record mutations for one base are serialized by a
    per-base lock — a drop overlapping a re-record used to release the
    same prior manifest twice, GC'ing chunks a third function still
    referenced."""
    shared = [page(140), page(141)]
    a = make_mem(tmp_path, "ser_a", shared)
    b = make_mem(tmp_path, "ser_b", shared)
    write_record(a, [0, 1], fmt="cas")
    write_record(b, [0, 1], fmt="cas")
    store = store_of(a)

    entered = threading.Event()
    release = threading.Event()
    real = pagestore.write_manifest

    def slow_write(path, pages, chunks, **kw):
        entered.set()
        release.wait(10)
        return real(path, pages, chunks, **kw)

    monkeypatch.setattr(pagestore, "write_manifest", slow_write)
    t1 = threading.Thread(target=write_record, args=(a, [0, 1]),
                          kwargs={"fmt": "cas"})
    t1.start()
    assert entered.wait(10)                      # t1 holds a's record lock
    t2 = threading.Thread(target=drop_record, args=(a,))
    t2.start()
    t2.join(0.3)
    assert t2.is_alive()                         # drop queued behind it
    assert has_record(a)                         # nothing yanked mid-write
    release.set()
    t1.join(10)
    t2.join(10)
    assert not t1.is_alive() and not t2.is_alive()
    assert not has_record(a)                     # drop won in the end
    # b's WS shares every chunk with a's dropped record: exactly one
    # release of a's refs must have reached them, never two
    _, data = reap_mod._read_ws(b, CFG)
    assert data == shared[0] + shared[1]
    man_b = pagestore.read_manifest(ws_path(b))
    assert all(store._index[h][1] == 1 for h in man_b["chunks"])


# -- crash-leftover hygiene --------------------------------------------


def _strand_tmps(base: str) -> list[str]:
    tmps = [ws_path(base) + ".tmp", trace_path(base) + ".tmp.npy",
            cut_path(base) + ".tmp"]
    for p in tmps:
        with open(p, "wb") as f:
            f.write(b"stranded")
    return tmps


def test_write_record_sweeps_stale_tmps(tmp_path):
    base = make_mem(tmp_path, "sweep_fn", [page(90)])
    tmps = _strand_tmps(base)
    write_record(base, [0], fmt="cas")
    for p in tmps:
        assert not os.path.exists(p)
    assert has_record(base)                      # the sweep spared the record


def test_drop_record_sweeps_stale_tmps(tmp_path):
    base = make_mem(tmp_path, "dsweep_fn", [page(91)])
    write_record(base, [0], fmt="cas")
    tmps = _strand_tmps(base)
    drop_record(base)
    for p in tmps:
        assert not os.path.exists(p)
    assert not has_record(base)
    assert reap_mod._sweep_tmp(base) == 0        # idempotent when clean


# -- hot-prefix knee baseline ------------------------------------------


def test_choose_hot_prefix_excludes_winner_from_baseline():
    """The knee gap must not inflate its own median baseline: on a short
    trace the winner shifting the median suppressed legitimate cuts."""
    # 8 samples -> window gaps at i=1..6: [.01, .01, .04, .1, .01, .04].
    # Median WITH the winner is .04 (8x bar = .32 > .1 -> no cut, the old
    # bug); median of the OTHERS is .01 (bar = .08 < .1 -> knee at i=4).
    times = [0.0, 0.01, 0.02, 0.06, 0.16, 0.17, 0.21, 0.215]
    assert choose_hot_prefix(times) == 4


def test_choose_hot_prefix_absolute_floor_still_holds():
    # same shape shrunk 50x: the "knee" is scheduler noise (< min_gap_s)
    times = [t / 50 for t in
             [0.0, 0.01, 0.02, 0.06, 0.16, 0.17, 0.21, 0.215]]
    assert choose_hot_prefix(times) is None
