"""Concurrent data plane: router, shared WS cache, loadgen, reaper races."""
import threading

import jax
import pytest

from repro.configs import SMOKES
from repro.core import ReapConfig
from repro.core.reap import WS_CACHE, ColdStartReport
from repro.launch import steps
from repro.serving import (AdmissionError, Orchestrator, Router, RouterConfig,
                           RouterClosedError, State, Trace,
                           ClosedLoopGenerator, OpenLoopGenerator,
                           diurnal_trace, poisson_trace, uniform_trace)


@pytest.fixture(scope="module")
def served(tmp_path_factory):
    """One registered+recorded function on a module-scoped orchestrator."""
    store = str(tmp_path_factory.mktemp("rstore"))
    cfg = SMOKES["olmo-1b"]
    batch = steps.make_batch(cfg, 32, 2, "train", jax.random.key(0))
    orch = Orchestrator(store, mode="reap", reap=ReapConfig())
    orch.register("fn", cfg, warmup_batch=batch)
    orch.invoke("fn", batch)          # record phase
    orch.scale_to_zero("fn")
    return orch, batch


def test_concurrent_cold_starts_share_one_ws_read(served):
    """N concurrent unbatched cold-starts => N distinct instances, one
    WS-file read (the single-flight leader/follower property)."""
    orch, batch = served
    WS_CACHE.clear()
    WS_CACHE.reset_stats()
    n = 6
    spawned0 = orch.functions["fn"].n_spawned
    router = Router(orch, RouterConfig(max_concurrency=n,
                                       max_instances_per_function=n,
                                       batch_restore_limit=1))
    results = router.map([("fn", batch)] * n, force_cold=True)
    router.close()

    reports = [r for _, r in results]
    assert len(reports) == n
    assert orch.functions["fn"].n_spawned - spawned0 == n  # distinct instances
    for r in reports:
        assert r.load_vmm_s > 0          # all cold
        assert r.n_prefetched_pages > 0  # all took the REAP prefetch path
        assert r.queue_s >= 0
        assert r.batch_size == 1         # batching disabled
    # the headline property: one underlying read, everyone else hits
    s = WS_CACHE.stats()
    assert s["reads"] == 1
    assert s["hits"] == n - 1
    assert sum(r.ws_cache_hit for r in reports) == n - 1
    orch.scale_to_zero("fn")


def test_concurrent_cold_starts_batch_into_group_restores(served):
    """With batching on, a same-function cold burst restores as group(s):
    still one underlying WS read, but via far fewer cache transactions
    than invocations (the leader+followers pattern collapses), and every
    report still carries the full §4.2 split."""
    orch, batch = served
    WS_CACHE.clear()
    WS_CACHE.reset_stats()
    n = 6
    spawned0 = orch.functions["fn"].n_spawned
    router = Router(orch, RouterConfig(max_concurrency=n,
                                       max_instances_per_function=n,
                                       batch_restore_limit=n))
    results = router.map([("fn", batch)] * n, force_cold=True)
    router.close()

    reports = [r for _, r in results]
    assert len(reports) == n
    assert orch.functions["fn"].n_spawned - spawned0 == n
    for r in reports:
        assert r.load_vmm_s > 0 and r.connection_s > 0   # all cold, full split
        assert r.n_prefetched_pages > 0
        assert r.prefetch_s >= r.install_s >= 0
    s = WS_CACHE.stats()
    assert s["reads"] == 1               # the invariant batching preserves
    assert s["hits"] + s["misses"] <= n  # ...with fewer cache transactions
    orch.scale_to_zero("fn")


def test_rerecord_invalidates_ws_cache(served):
    orch, batch = served
    WS_CACHE.clear()
    WS_CACHE.reset_stats()
    _, r1 = orch.invoke("fn", batch, force_cold=True)   # populates cache
    assert WS_CACHE.stats()["reads"] == 1
    _, r2 = orch.invoke("fn", batch, force_cold=True)   # served from cache
    assert r2.ws_cache_hit and WS_CACHE.stats()["reads"] == 1

    orch.reset_records("fn")                             # drop_record
    assert WS_CACHE.stats()["entries"] == 0
    _, r3 = orch.invoke("fn", batch, force_cold=True)   # re-records
    assert r3.n_prefetched_pages == 0                    # record phase again
    _, r4 = orch.invoke("fn", batch, force_cold=True)   # fresh WS, fresh read
    assert r4.n_prefetched_pages > 0 and not r4.ws_cache_hit
    assert WS_CACHE.stats()["reads"] == 2
    orch.scale_to_zero("fn")


def test_reaper_never_reclaims_busy_instance(served):
    """A keepalive sweep racing in-flight invocations must only ever
    reclaim IDLE instances, and every invocation must still succeed."""
    orch, batch = served
    orch_keepalive = orch.keepalive_s
    orch.keepalive_s = 0.0               # everything idle is reclaimable
    stop = threading.Event()
    reaped = []

    def reaper():
        while not stop.is_set():
            reaped.append(orch.reap_idle())

    t = threading.Thread(target=reaper, daemon=True)
    t.start()
    try:
        router = Router(orch, RouterConfig(max_concurrency=4,
                                           max_instances_per_function=4))
        results = router.map([("fn", batch)] * 12)
        router.close()
    finally:
        stop.set()
        t.join(timeout=5)
        orch.keepalive_s = orch_keepalive
    assert len(results) == 12            # no invocation died under the race
    assert all(rep.processing_s > 0 for _, rep in results)
    orch.scale_to_zero("fn")


def test_try_reclaim_refuses_busy():
    """Direct state-machine check, no snapshot I/O needed."""
    from repro.serving import FunctionInstance
    inst = FunctionInstance.__new__(FunctionInstance)
    inst._state_lock = threading.Lock()
    inst.state = State.IDLE
    inst.last_used = 0.0
    assert inst.try_acquire()            # IDLE -> BUSY
    assert not inst.try_acquire()        # BUSY is exclusive
    assert not inst.try_reclaim()        # never reclaim a BUSY instance
    inst.release()
    assert inst.state is State.IDLE


def test_admission_control_and_queueing_delay(served):
    orch, batch = served
    router = Router(orch, RouterConfig(max_concurrency=1,
                                       max_instances_per_function=1,
                                       queue_depth=2), start=False)
    accepted = [router.submit("fn", batch) for _ in range(2)]
    with pytest.raises(AdmissionError):
        router.submit("fn", batch)       # backlog full => throttled
    assert router.stats()["rejected"] == 1

    router.start()                        # drain the staged burst
    reports = [inv.result(timeout=120)[1] for inv in accepted]
    router.close()
    # serial worker => the second invocation observed real queueing delay
    assert reports[1].queue_s > 0
    assert reports[1].e2e_s >= reports[1].total_s
    orch.scale_to_zero("fn")


def test_close_fails_pending_invocations(served):
    """close(drain=False) must fail still-queued invocations instead of
    leaving their waiters hanging in result() forever."""
    orch, batch = served
    router = Router(orch, RouterConfig(), start=False)   # no workers yet
    invs = [router.submit("fn", batch) for _ in range(3)]
    router.close(drain=False)
    for inv in invs:
        with pytest.raises(RouterClosedError):
            inv.result(timeout=5)                        # resolves, not hangs
        assert inv.done()
    with pytest.raises(RouterClosedError):
        router.submit("fn", batch)                       # closed => rejected


def test_close_drain_still_serves_accepted_work(served):
    orch, batch = served
    router = Router(orch, RouterConfig(max_concurrency=2,
                                       max_instances_per_function=2))
    invs = [router.submit("fn", batch) for _ in range(4)]
    router.close()                                       # drain=True default
    for inv in invs:
        _, rep = inv.result(timeout=120)
        assert rep.processing_s > 0
    orch.scale_to_zero("fn")


def test_router_exposes_arrival_timestamps(served):
    orch, batch = served
    router = Router(orch, RouterConfig(max_concurrency=2,
                                       max_instances_per_function=2))
    router.map([("fn", batch)] * 3)
    arr = router.drain_arrivals()
    assert len(arr["fn"]) == 3
    assert arr["fn"] == sorted(arr["fn"])
    assert router.drain_arrivals() == {}                 # drained
    router.close()
    orch.scale_to_zero("fn")


class _ThrottlingRouter:
    """Stand-in router: throttles odd-seed events, serves the rest."""

    def __init__(self, fail_on: BaseException | None = None):
        self.fail_on = fail_on
        self.n_throttled = 0

    def invoke(self, name, batch, **kw):
        ev_seed = batch["seed"]
        if self.fail_on is not None and ev_seed == 2:
            raise self.fail_on
        if ev_seed % 2 == 1:
            self.n_throttled += 1
            raise AdmissionError("backlog full")
        return None, ColdStartReport(processing_s=1e-4)


def test_closed_loop_records_throttles_as_rejections():
    """AdmissionError must not abort the run: throttled submits are recorded
    as rejections (report None), parity with OpenLoopGenerator."""
    trace = uniform_trace(8, 0.0, ["fn"])                # seeds 0..7
    router = _ThrottlingRouter()
    results = ClosedLoopGenerator(router, trace,
                                  make_batch=lambda ev: {"seed": ev.seed},
                                  n_clients=3).run()
    assert len(results) == 8                             # every event accounted
    rejected = [ev for ev, rep in results if rep is None]
    served = [rep for _, rep in results if rep is not None]
    assert len(rejected) == 4 and router.n_throttled == 4
    assert all(rep.processing_s > 0 for rep in served)


def test_closed_loop_still_raises_on_real_failures():
    trace = uniform_trace(8, 0.0, ["fn"])
    router = _ThrottlingRouter(fail_on=ValueError("instance died"))
    with pytest.raises(ValueError):
        ClosedLoopGenerator(router, trace,
                            make_batch=lambda ev: {"seed": ev.seed},
                            n_clients=2).run()


def test_ws_cache_invalidate_during_read_is_not_resurrected(
        tmp_path, monkeypatch):
    """A leader mid-read must not re-insert its entry after an invalidation
    (drop_record/write_record) — that would resurrect dropped WS data."""
    from repro.core import reap as reap_mod
    cache = reap_mod.WSCache()
    base = str(tmp_path / "f")
    with open(reap_mod.ws_path(base), "wb") as f:
        f.write(b"x")                                    # only mtime matters
    started, release = threading.Event(), threading.Event()

    def slow_read(b, cfg):
        started.set()
        assert release.wait(5)
        return [0], b"A" * 4096

    monkeypatch.setattr(reap_mod, "_read_ws", slow_read)
    out = {}
    t = threading.Thread(
        target=lambda: out.update(r=cache.fetch(base, ReapConfig())),
        daemon=True)
    t.start()
    assert started.wait(5)
    cache.invalidate(base)            # drop/re-record while the read is out
    release.set()
    t.join(5)
    pages, data, hit = out["r"]
    assert not hit and data == b"A" * 4096   # the leader still got its data
    s = cache.stats()
    assert s["entries"] == 0          # ...but the stale entry was discarded
    assert s["discarded"] == 1
    # a later fetch must do a fresh read, never serve the pre-invalidate data
    reads0 = cache.stats()["reads"]
    _, _, hit = cache.fetch(base, ReapConfig())
    assert not hit and cache.stats()["reads"] == reads0 + 1


def test_ws_cache_insert_survives_unrelated_invalidation(tmp_path,
                                                         monkeypatch):
    """The generation counter is per-base: invalidating another function
    must not discard this leader's insert."""
    from repro.core import reap as reap_mod
    cache = reap_mod.WSCache()
    base, other = str(tmp_path / "f"), str(tmp_path / "g")
    for b in (base, other):
        with open(reap_mod.ws_path(b), "wb") as f:
            f.write(b"x")
    monkeypatch.setattr(reap_mod, "_read_ws",
                        lambda b, cfg: ([0], b"B" * 4096))
    cache.invalidate(other)
    pages, data, hit = cache.fetch(base, ReapConfig())
    assert not hit and cache.stats()["entries"] == 1
    _, _, hit = cache.fetch(base, ReapConfig())
    assert hit                        # entry survived, second fetch is a hit


def test_ws_cache_capacity_evicts_lru(tmp_path, monkeypatch):
    """The cache is bounded: inserts beyond capacity evict oldest-first and
    count into the ``evicted`` stat, so a long fleet run over many
    functions cannot grow it without bound."""
    from repro.core import reap as reap_mod
    cache = reap_mod.WSCache(capacity_bytes=2 * 4096)
    bases = [str(tmp_path / f"f{i}") for i in range(3)]
    for b in bases:
        with open(reap_mod.ws_path(b), "wb") as f:
            f.write(b"x")                                # only mtime matters
    monkeypatch.setattr(reap_mod, "_read_ws",
                        lambda b, cfg: ([0], b"D" * 4096))
    for b in bases:
        cache.fetch(b, ReapConfig())
    s = cache.stats()
    assert s["evicted"] == 1 and s["entries"] == 2
    assert s["bytes"] <= 2 * 4096
    # LRU: the first-inserted base was the victim; the newest two still hit
    reads0 = s["reads"]
    assert cache.fetch(bases[1], ReapConfig())[2]
    assert cache.fetch(bases[2], ReapConfig())[2]
    assert not cache.fetch(bases[0], ReapConfig())[2]    # evicted => re-read
    assert cache.stats()["reads"] == reads0 + 1
    cache.reset_stats()
    assert cache.stats()["evicted"] == 0


def test_ws_cache_source_hook_overrides_origin_read(tmp_path):
    """The tiering hook: a cache built with ``source=`` resolves misses
    through it (single-flight) instead of the origin-disk read."""
    from repro.core import reap as reap_mod
    calls = []

    def source(base, cfg):
        calls.append(base)
        return [0, 1], b"S" * 8192

    cache = reap_mod.WSCache(source=source)
    base = str(tmp_path / "f")
    with open(reap_mod.ws_path(base), "wb") as f:
        f.write(b"x")
    pages, data, hit = cache.fetch(base, ReapConfig())
    assert not hit and pages == [0, 1] and data == b"S" * 8192
    _, _, hit = cache.fetch(base, ReapConfig())
    assert hit and calls == [base]                       # one source call
    assert cache.contains(base) and not cache.contains(base + "2")


def test_trace_roundtrip_and_determinism(tmp_path):
    tr1 = poisson_trace(rate_rps=50, duration_s=2.0,
                        functions=["a", "b"], mix={"a": 3, "b": 1},
                        modality_mix={"text": 1, "vision": 1}, seed=42)
    tr2 = poisson_trace(rate_rps=50, duration_s=2.0,
                        functions=["a", "b"], mix={"a": 3, "b": 1},
                        modality_mix={"text": 1, "vision": 1}, seed=42)
    assert tr1.events == tr2.events      # replayable: same seed, same trace
    assert len(tr1.events) > 10
    assert set(e.function for e in tr1.events) == {"a", "b"}
    assert all(tr1.events[i].t <= tr1.events[i + 1].t
               for i in range(len(tr1.events) - 1))

    p = str(tmp_path / "trace.json")
    tr1.save(p)
    tr3 = Trace.load(p)
    assert tr3.events == tr1.events      # save/load is lossless

    burst = uniform_trace(8, 0.0, ["f1", "f2"])
    assert burst.duration_s == 0.0 and len(burst.events) == 8

    d1 = diurnal_trace(1.0, 30.0, 4.0, 4.0, ["a", "b"],
                       burst_rps=40.0, burst_every_s=1.5, seed=5)
    d2 = diurnal_trace(1.0, 30.0, 4.0, 4.0, ["a", "b"],
                       burst_rps=40.0, burst_every_s=1.5, seed=5)
    assert d1.events == d2.events and len(d1.events) > 10   # replayable
    assert all(0 <= e.t <= 4.0 for e in d1.events)
    assert all(d1.events[i].t <= d1.events[i + 1].t
               for i in range(len(d1.events) - 1))
    # sinusoidal profile: the middle half carries most of the arrivals
    mid = sum(1 for e in d1.events if 1.0 <= e.t <= 3.0)
    assert mid > len(d1.events) / 2
    with pytest.raises(ValueError):
        diurnal_trace(5.0, 1.0, 4.0, 4.0, ["a"])            # peak < base


def test_open_and_closed_loop_generators(served):
    orch, batch = served
    router = Router(orch, RouterConfig(max_concurrency=4,
                                       max_instances_per_function=4))
    trace = uniform_trace(6, 0.01, ["fn"])
    results = OpenLoopGenerator(router, trace,
                                make_batch=lambda ev: batch).run()
    assert len(results) == 6 and all(rep is not None for _, rep in results)

    results = ClosedLoopGenerator(router, uniform_trace(6, 0.0, ["fn"]),
                                  make_batch=lambda ev: batch,
                                  n_clients=3).run()
    router.close()
    assert len(results) == 6
    assert all(rep.processing_s > 0 for _, rep in results)
    orch.scale_to_zero("fn")


def test_router_multi_function_fairness(served):
    """Two functions behind one router: both make progress, reports are
    per-function consistent."""
    orch, batch = served
    cfg = SMOKES["olmo-1b"]
    orch.register("fn_b", cfg, seed=9)
    router = Router(orch, RouterConfig(max_concurrency=2,
                                       max_instances_per_function=1))
    invs = ([router.submit("fn", batch) for _ in range(3)]
            + [router.submit("fn_b", batch) for _ in range(3)])
    reports = [inv.result(timeout=300)[1] for inv in invs]
    router.close()
    assert len(reports) == 6
    assert orch.functions["fn_b"].n_invocations >= 3
    orch.scale_to_zero("fn")
    orch.scale_to_zero("fn_b")
