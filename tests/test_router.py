"""Concurrent data plane: router, shared WS cache, loadgen, reaper races."""
import threading

import jax
import pytest

from repro.configs import SMOKES
from repro.core import ReapConfig
from repro.core.reap import WS_CACHE
from repro.launch import steps
from repro.serving import (AdmissionError, Orchestrator, Router, RouterConfig,
                           State, Trace, ClosedLoopGenerator,
                           OpenLoopGenerator, poisson_trace, uniform_trace)


@pytest.fixture(scope="module")
def served(tmp_path_factory):
    """One registered+recorded function on a module-scoped orchestrator."""
    store = str(tmp_path_factory.mktemp("rstore"))
    cfg = SMOKES["olmo-1b"]
    batch = steps.make_batch(cfg, 32, 2, "train", jax.random.key(0))
    orch = Orchestrator(store, mode="reap", reap=ReapConfig())
    orch.register("fn", cfg, warmup_batch=batch)
    orch.invoke("fn", batch)          # record phase
    orch.scale_to_zero("fn")
    return orch, batch


def test_concurrent_cold_starts_share_one_ws_read(served):
    """N concurrent cold-starts => N distinct instances, one WS-file read."""
    orch, batch = served
    WS_CACHE.clear()
    WS_CACHE.reset_stats()
    n = 6
    spawned0 = orch.functions["fn"].n_spawned
    router = Router(orch, RouterConfig(max_concurrency=n,
                                       max_instances_per_function=n))
    results = router.map([("fn", batch)] * n, force_cold=True)
    router.close()

    reports = [r for _, r in results]
    assert len(reports) == n
    assert orch.functions["fn"].n_spawned - spawned0 == n  # distinct instances
    for r in reports:
        assert r.load_vmm_s > 0          # all cold
        assert r.n_prefetched_pages > 0  # all took the REAP prefetch path
        assert r.queue_s >= 0
    # the headline property: one underlying read, everyone else hits
    s = WS_CACHE.stats()
    assert s["reads"] == 1
    assert s["hits"] == n - 1
    assert sum(r.ws_cache_hit for r in reports) == n - 1
    orch.scale_to_zero("fn")


def test_rerecord_invalidates_ws_cache(served):
    orch, batch = served
    WS_CACHE.clear()
    WS_CACHE.reset_stats()
    _, r1 = orch.invoke("fn", batch, force_cold=True)   # populates cache
    assert WS_CACHE.stats()["reads"] == 1
    _, r2 = orch.invoke("fn", batch, force_cold=True)   # served from cache
    assert r2.ws_cache_hit and WS_CACHE.stats()["reads"] == 1

    orch.reset_records("fn")                             # drop_record
    assert WS_CACHE.stats()["entries"] == 0
    _, r3 = orch.invoke("fn", batch, force_cold=True)   # re-records
    assert r3.n_prefetched_pages == 0                    # record phase again
    _, r4 = orch.invoke("fn", batch, force_cold=True)   # fresh WS, fresh read
    assert r4.n_prefetched_pages > 0 and not r4.ws_cache_hit
    assert WS_CACHE.stats()["reads"] == 2
    orch.scale_to_zero("fn")


def test_reaper_never_reclaims_busy_instance(served):
    """A keepalive sweep racing in-flight invocations must only ever
    reclaim IDLE instances, and every invocation must still succeed."""
    orch, batch = served
    orch_keepalive = orch.keepalive_s
    orch.keepalive_s = 0.0               # everything idle is reclaimable
    stop = threading.Event()
    reaped = []

    def reaper():
        while not stop.is_set():
            reaped.append(orch.reap_idle())

    t = threading.Thread(target=reaper, daemon=True)
    t.start()
    try:
        router = Router(orch, RouterConfig(max_concurrency=4,
                                           max_instances_per_function=4))
        results = router.map([("fn", batch)] * 12)
        router.close()
    finally:
        stop.set()
        t.join(timeout=5)
        orch.keepalive_s = orch_keepalive
    assert len(results) == 12            # no invocation died under the race
    assert all(rep.processing_s > 0 for _, rep in results)
    orch.scale_to_zero("fn")


def test_try_reclaim_refuses_busy():
    """Direct state-machine check, no snapshot I/O needed."""
    from repro.serving import FunctionInstance
    inst = FunctionInstance.__new__(FunctionInstance)
    inst._state_lock = threading.Lock()
    inst.state = State.IDLE
    inst.last_used = 0.0
    assert inst.try_acquire()            # IDLE -> BUSY
    assert not inst.try_acquire()        # BUSY is exclusive
    assert not inst.try_reclaim()        # never reclaim a BUSY instance
    inst.release()
    assert inst.state is State.IDLE


def test_admission_control_and_queueing_delay(served):
    orch, batch = served
    router = Router(orch, RouterConfig(max_concurrency=1,
                                       max_instances_per_function=1,
                                       queue_depth=2), start=False)
    accepted = [router.submit("fn", batch) for _ in range(2)]
    with pytest.raises(AdmissionError):
        router.submit("fn", batch)       # backlog full => throttled
    assert router.stats()["rejected"] == 1

    router.start()                        # drain the staged burst
    reports = [inv.result(timeout=120)[1] for inv in accepted]
    router.close()
    # serial worker => the second invocation observed real queueing delay
    assert reports[1].queue_s > 0
    assert reports[1].e2e_s >= reports[1].total_s
    orch.scale_to_zero("fn")


def test_trace_roundtrip_and_determinism(tmp_path):
    tr1 = poisson_trace(rate_rps=50, duration_s=2.0,
                        functions=["a", "b"], mix={"a": 3, "b": 1},
                        modality_mix={"text": 1, "vision": 1}, seed=42)
    tr2 = poisson_trace(rate_rps=50, duration_s=2.0,
                        functions=["a", "b"], mix={"a": 3, "b": 1},
                        modality_mix={"text": 1, "vision": 1}, seed=42)
    assert tr1.events == tr2.events      # replayable: same seed, same trace
    assert len(tr1.events) > 10
    assert set(e.function for e in tr1.events) == {"a", "b"}
    assert all(tr1.events[i].t <= tr1.events[i + 1].t
               for i in range(len(tr1.events) - 1))

    p = str(tmp_path / "trace.json")
    tr1.save(p)
    tr3 = Trace.load(p)
    assert tr3.events == tr1.events      # save/load is lossless

    burst = uniform_trace(8, 0.0, ["f1", "f2"])
    assert burst.duration_s == 0.0 and len(burst.events) == 8


def test_open_and_closed_loop_generators(served):
    orch, batch = served
    router = Router(orch, RouterConfig(max_concurrency=4,
                                       max_instances_per_function=4))
    trace = uniform_trace(6, 0.01, ["fn"])
    results = OpenLoopGenerator(router, trace,
                                make_batch=lambda ev: batch).run()
    assert len(results) == 6 and all(rep is not None for _, rep in results)

    results = ClosedLoopGenerator(router, uniform_trace(6, 0.0, ["fn"]),
                                  make_batch=lambda ev: batch,
                                  n_clients=3).run()
    router.close()
    assert len(results) == 6
    assert all(rep.processing_s > 0 for _, rep in results)
    orch.scale_to_zero("fn")


def test_router_multi_function_fairness(served):
    """Two functions behind one router: both make progress, reports are
    per-function consistent."""
    orch, batch = served
    cfg = SMOKES["olmo-1b"]
    orch.register("fn_b", cfg, seed=9)
    router = Router(orch, RouterConfig(max_concurrency=2,
                                       max_instances_per_function=1))
    invs = ([router.submit("fn", batch) for _ in range(3)]
            + [router.submit("fn_b", batch) for _ in range(3)])
    reports = [inv.result(timeout=300)[1] for inv in invs]
    router.close()
    assert len(reports) == 6
    assert orch.functions["fn_b"].n_invocations >= 3
    orch.scale_to_zero("fn")
    orch.scale_to_zero("fn_b")
