"""End-to-end behaviour tests for the paper's system (REAP on serverless
ML functions): the full cold -> record -> warm -> scale-to-zero ->
prefetch-cold lifecycle, plus the paper's three key observations at test
scale."""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import reduce_for_bench
from repro.core import (GuestMemoryFile, InstanceArena, ReapConfig,
                        run_invocation)
from repro.core import reap as reap_mod
from repro.core.snapshot import booted_footprint_bytes, build_instance_snapshot
from repro.launch import steps


@pytest.fixture(scope="module")
def fn(tmp_path_factory):
    # bench-scale (not smoke-scale) so the fixed infra region does not
    # dominate the footprint ratio the way it never would in production
    cfg = reduce_for_bench(ARCHS["qwen2-7b"])
    base = str(tmp_path_factory.mktemp("sys") / "fn")
    build_instance_snapshot(cfg, base, seed=9)
    return cfg, base


def test_observation1_working_set_much_smaller_than_boot(fn):
    """Paper Fig. 4: snapshot-restored working set << booted footprint."""
    cfg, base = fn
    arena = InstanceArena(GuestMemoryFile.open(base))
    batch = steps.make_batch(cfg, 32, 2, "train", jax.random.key(0))
    run_invocation(cfg, arena, batch)
    booted = booted_footprint_bytes(cfg)
    assert arena.resident_bytes < 0.5 * booted   # paper: 61-96% reduction
    arena.close()


def test_observation2_faults_serial_on_critical_path(fn):
    """Paper §4.2: cold processing is dominated by serial page faults."""
    cfg, base = fn
    arena = InstanceArena(GuestMemoryFile.open(base))
    batch = steps.make_batch(cfg, 32, 2, "train", jax.random.key(0))
    _, secs = run_invocation(cfg, arena, batch)
    assert arena.stats.n_faults > 100
    assert arena.stats.fault_seconds > 0
    arena.close()


def test_observation3_stable_working_set(fn):
    """Paper Fig. 5: page set is ~stable across different inputs."""
    cfg, base = fn
    sets = []
    for seed in (1, 2):
        arena = InstanceArena(GuestMemoryFile.open(base))
        run_invocation(cfg, arena,
                       steps.make_batch(cfg, 32, 2, "train", jax.random.key(seed)))
        sets.append(set(arena.stats.trace))
        arena.close()
    same = len(sets[0] & sets[1]) / len(sets[1])
    assert same > 0.9    # paper: >=97% for 7/10, >=76% for all


def test_reap_end_to_end_speedup_and_correctness(fn):
    """REAP invocation returns identical logits with ~no faults."""
    cfg, base = fn
    batch = steps.make_batch(cfg, 32, 2, "train", jax.random.key(3))
    a1 = InstanceArena(GuestMemoryFile.open(base))
    logits1, _ = run_invocation(cfg, a1, batch)
    reap_mod.write_record(base, a1.stats.trace)
    a1.close()

    a2 = InstanceArena(GuestMemoryFile.open(base))
    n, _ = reap_mod.prefetch(a2, base, ReapConfig())
    logits2, _ = run_invocation(cfg, a2, batch)
    assert a2.stats.n_faults == 0
    np.testing.assert_array_equal(np.asarray(logits1), np.asarray(logits2))
    a2.close()
    reap_mod.drop_record(base)


def test_moe_expert_working_set_input_dependent(tmp_path):
    """MoE functions touch only routed experts; different inputs shift the
    expert working set (the paper's 'unique pages')."""
    cfg = reduce_for_bench(ARCHS["deepseek-moe-16b"])
    base = str(tmp_path / "moe")
    build_instance_snapshot(cfg, base)
    traces = []
    for seed in (1, 999):
        arena = InstanceArena(GuestMemoryFile.open(base))
        run_invocation(cfg, arena,
                       steps.make_batch(cfg, 16, 1, "train", jax.random.key(seed)))
        traces.append(set(arena.stats.trace))
        arena.close()
    expert_pages = set()
    gm = GuestMemoryFile.open(base)
    for p, e in gm.layout.entries.items():
        if "/moe/wi" in p or "/moe/wo" in p:
            expert_pages |= set(e.pages())
    used0 = traces[0] & expert_pages
    used1 = traces[1] & expert_pages
    assert used0 and used1
    assert used0 != used1 or len(used0) < len(expert_pages)
