"""Periodicity detection + forecast-blended demand (serving/forecast.py).

All pure-model: synthetic arrival streams on the deterministic fake clock,
no orchestrator, no sleeps.
"""
import numpy as np
import pytest
from fakeclock import FakeClock

from repro.serving import (ForecastConfig, ForecastDemand,
                           PeriodicityDetector, PolicyConfig)

CFG = ForecastConfig(bin_s=0.5, history_s=80.0, min_period_s=2.0,
                     max_period_s=30.0, min_cycles=2.0, lookahead_s=2.0)


def periodic_stream(t0: float, period: float, cycles: int, *,
                    busy_at: float = 2.0, busy_len: float = 1.5,
                    n_busy: int = 12, seed: int = 0) -> list[float]:
    """Arrivals bunched into one busy phase per cycle (a daily ramp)."""
    rng = np.random.default_rng(seed)
    ts: list[float] = []
    for c in range(cycles):
        base = t0 + c * period + busy_at
        ts += list(base + rng.uniform(0, busy_len, size=n_busy))
    return sorted(ts)


# -- period detection ---------------------------------------------------

def test_detects_period_of_synthetic_periodic_stream():
    clock = FakeClock(160.0)
    det = PeriodicityDetector(CFG, clock=clock)
    det.observe(periodic_stream(100.0, period=10.0, cycles=6))
    found = det.detect()
    assert found is not None
    period, conf = found
    assert period == pytest.approx(10.0, abs=CFG.bin_s)
    assert conf >= CFG.min_confidence
    # the profile's peak phase carries the busy window's rate
    prof = det.profile()
    assert prof.max() >= 4.0                  # 12 arrivals / 1.5 s spread
    # deterministic: same history, same answer
    assert det.detect() == found


def test_phase_shifted_stream_same_period_shifted_profile():
    """Detection is phase-blind; the profile carries the phase."""
    clock = FakeClock(160.0)
    a = PeriodicityDetector(CFG, clock=clock)
    b = PeriodicityDetector(CFG, clock=clock)
    a.observe(periodic_stream(100.0, period=10.0, cycles=6, busy_at=2.0))
    b.observe(periodic_stream(100.0, period=10.0, cycles=6, busy_at=6.0))
    pa, _ = a.detect()
    pb, _ = b.detect()
    assert pa == pytest.approx(pb, abs=CFG.bin_s)
    # each forecasts high exactly at its own busy phase of the next cycle
    assert a.forecast_rate(162.5, 1.0) > 2.0      # 162.5 % 10 = busy for a
    assert b.forecast_rate(166.5, 1.0) > 2.0      # busy for b
    assert a.forecast_rate(166.5, 1.0) < 1.0      # a's trough
    assert b.forecast_rate(162.5, 1.0) < 1.0      # b's trough


def test_aperiodic_stream_detects_nothing():
    clock = FakeClock(160.0)
    det = PeriodicityDetector(CFG, clock=clock)
    rng = np.random.default_rng(3)
    det.observe(sorted(100.0 + rng.exponential(0.7, size=100).cumsum()))
    assert det.detect() is None
    assert det.forecast_rate(161.0, 1.0) is None


def test_too_little_history_detects_nothing():
    clock = FakeClock(160.0)
    det = PeriodicityDetector(CFG, clock=clock)
    det.observe(periodic_stream(150.0, period=10.0, cycles=1))
    assert det.detect() is None               # < min_cycles of history


def test_period_hint_skips_search_and_min_cycles():
    """A trace-supplied hint is trusted after one full cycle — the blind
    search would still be waiting for min_cycles."""
    clock = FakeClock(160.0)
    hinted = ForecastConfig(**{**CFG.__dict__, "period_hint_s": 10.0})
    det = PeriodicityDetector(hinted, clock=clock)
    det.observe(periodic_stream(145.0, period=10.0, cycles=1,
                                busy_at=2.0))  # busy 147-148.5 only
    assert det.detect() is None               # < one full cycle of span
    det.observe(periodic_stream(155.0, period=10.0, cycles=1, busy_at=2.0))
    period, conf = det.detect()
    assert period == 10.0 and conf == 1.0
    assert det.forecast_rate(167.3, 1.0) > 2.0    # next cycle's busy phase


# -- forecast-blended demand -------------------------------------------

def test_forecast_demand_prewarms_ahead_of_the_ramp():
    """The acceptance property: *before* the next cycle's busy phase the
    blended rate (and liveness) rise, while the purely reactive model
    still reads zero — this is what turns the daily ramp warm."""
    clock = FakeClock(161.0)
    pcfg = PolicyConfig(window_s=5.0)
    d = ForecastDemand(pcfg, CFG, clock=clock)
    d.observe(periodic_stream(100.0, period=10.0, cycles=6))
    # now=161: last busy window ended at ~153.5; next starts at 162.
    now = clock.now
    from repro.serving import FunctionDemand
    reactive = FunctionDemand(pcfg, clock=clock)
    reactive.observe(periodic_stream(100.0, period=10.0, cycles=6))
    assert reactive.rate(now) == 0.0          # window empty, EWMA stale
    assert not reactive.active(now)
    assert d.rate(now) > 2.0                  # profile sees the ramp coming
    assert d.active(now)                      # => targets rise *now*
    # deep in the trough (ramp > lookahead away) it scales down like the
    # reactive model ...
    assert d.rate(166.0) < 1.0
    assert not d.active(166.0)
    # ... but the learned period is not forgotten until history goes quiet
    assert not d.forgettable(166.0)
    assert d.forgettable(166.0 + CFG.history_s + 60.0)


def test_forecast_demand_falls_back_to_reactive_on_aperiodic_traffic():
    clock = FakeClock(130.0)
    pcfg = PolicyConfig(window_s=5.0)
    d = ForecastDemand(pcfg, CFG, clock=clock)
    rng = np.random.default_rng(9)
    ts = sorted(100.0 + rng.exponential(0.25, size=120).cumsum())
    d.observe(ts)
    now = max(ts)
    from repro.serving import FunctionDemand
    reactive = FunctionDemand(pcfg, clock=clock)
    reactive.observe(ts)
    # no period detected => identical to the reactive model
    assert d.detector.detect(now) is None
    assert d.rate(now) == pytest.approx(reactive.rate(now))
    assert d.active(now) == reactive.active(now)
