"""Deterministic monotonic clock for policy/forecast timing tests.

Timing behaviour in the control plane (PrewarmPolicy, FunctionDemand,
ForecastDemand, PeriodicityDetector, DemandAggregator) is a pure function
of ingested timestamps and "now" — every class takes a ``clock=`` hook.
Injecting a :class:`FakeClock` turns sleep-based timing tests into
arithmetic: ``clock.advance(3600)`` is an hour of keepalive expiry in zero
wall time, with zero flake.

The clock is callable (drop-in for ``time.monotonic``) and its ``sleep``
is a no-op that *advances* fake time instead of pausing the test.
"""
from __future__ import annotations

import threading


class FakeClock:
    """Monotonic fake clock: call it for "now", advance it explicitly.

    Starts at an arbitrary non-zero epoch (like ``time.monotonic``, the
    absolute value is meaningless — only differences matter).  Thread-safe
    so a policy loop thread may read it while the test advances it.
    """

    def __init__(self, start: float = 1000.0):
        self._t = float(start)
        self._mu = threading.Lock()

    def __call__(self) -> float:
        with self._mu:
            return self._t

    @property
    def now(self) -> float:
        return self()

    def advance(self, dt: float) -> float:
        """Move time forward by ``dt`` seconds; returns the new "now"."""
        if dt < 0:
            raise ValueError("monotonic clocks do not rewind")
        with self._mu:
            self._t += dt
            return self._t

    def sleep(self, dt: float) -> None:
        """No-op sleep: advances fake time, costs no wall time."""
        self.advance(dt)
