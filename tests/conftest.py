import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (os.path.join(ROOT, "src"), ROOT):
    if p not in sys.path:
        sys.path.insert(0, p)

_SANITIZE = os.environ.get("REPRO_LOCK_SANITIZER", "") not in ("", "0")
if _SANITIZE:
    # Must run before anything imports repro so module-level locks (e.g.
    # core.restore's tail-pool lock) are created through the wrappers.
    # Deferred mode: violations are collected and fail the session at the
    # end instead of raising inside arbitrary worker threads.
    from repro.analysis import sanitizer
    sanitizer.STATE.raise_on_violation = False
    sanitizer.enable()


def pytest_sessionfinish(session, exitstatus):
    if not _SANITIZE:
        return
    from repro.analysis import sanitizer
    if sanitizer.STATE.violations:
        rep = session.config.pluginmanager.get_plugin("terminalreporter")
        for v in sanitizer.STATE.violations:
            msg = sanitizer.render_violation(v)
            if rep is not None:
                rep.write_line(msg, red=True)
            else:
                print(msg, file=sys.stderr)
        session.exitstatus = 1
