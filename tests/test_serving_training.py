"""Serving runtime + training substrate integration tests."""
import jax
import numpy as np
import pytest

from repro.configs import SMOKES
from repro.core import ReapConfig
from repro.launch import steps
from repro.serving import Orchestrator


@pytest.fixture(scope="module")
def store(tmp_path_factory):
    return str(tmp_path_factory.mktemp("store"))


def test_orchestrator_cold_warm_reap(store):
    cfg = SMOKES["olmo-1b"]
    batch = steps.make_batch(cfg, 32, 2, "train", jax.random.key(0))
    orch = Orchestrator(store, mode="reap", reap=ReapConfig())
    orch.register("fn", cfg, warmup_batch=batch)

    _, cold1 = orch.invoke("fn", batch)           # record phase
    assert cold1.n_faults > 0
    _, warm = orch.invoke("fn", batch)            # warm
    assert warm.n_faults == 0
    assert warm.processing_s < cold1.processing_s

    orch.scale_to_zero("fn")
    _, cold2 = orch.invoke("fn", batch)           # prefetch phase
    assert cold2.n_prefetched_pages > 0
    assert cold2.n_faults <= cold1.n_faults * 0.1  # >=90% faults eliminated
    # wall-clock comparison: take the best of two prefetch cold starts so a
    # single CPU-contention spike can't flake the paper's speedup claim
    orch.scale_to_zero("fn")
    _, cold2b = orch.invoke("fn", batch)
    assert min(cold2.total_s, cold2b.total_s) < cold1.total_s


def test_vanilla_vs_reap_speedup(store):
    cfg = SMOKES["qwen2-7b"]
    batch = steps.make_batch(cfg, 32, 1, "train", jax.random.key(1))
    van = Orchestrator(store, mode="vanilla", reap=ReapConfig())
    van.register("fn2", cfg, warmup_batch=batch)
    _, base = van.invoke("fn2", batch, force_cold=True)

    rp = Orchestrator(store, mode="reap", reap=ReapConfig())
    rp.register("fn2", cfg)
    rp.reset_records("fn2")
    rp.invoke("fn2", batch, force_cold=True)       # record
    _, fast = rp.invoke("fn2", batch, force_cold=True)
    assert fast.n_faults < base.n_faults * 0.1
    assert fast.fault_s < base.fault_s


def test_keepalive_reclaims(store):
    import time
    cfg = SMOKES["olmo-1b"]
    batch = steps.make_batch(cfg, 16, 1, "train", jax.random.key(2))
    orch = Orchestrator(store, mode="reap", keepalive_s=0.05)
    orch.register("fn3", cfg, warmup_batch=batch)
    orch.invoke("fn3", batch)
    time.sleep(0.1)
    assert orch.reap_idle() == 1
    assert not orch.functions["fn3"].idle


def test_train_preempt_restart_deterministic(tmp_path):
    from repro.data import synthesize_corpus
    from repro.training import (OptConfig, SimulatedPreemption, Trainer,
                                TrainLoopConfig)
    cfg = SMOKES["olmo-1b"]
    corpus = synthesize_corpus(str(tmp_path / "c.bin"), 100_000, cfg.vocab)
    loop = TrainLoopConfig(total_steps=12, checkpoint_every=4, batch_size=2,
                           seq_len=32)
    opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=12)

    tr = Trainer(cfg, opt, loop, corpus, str(tmp_path / "ck"), preempt_at=6)
    with pytest.raises(SimulatedPreemption):
        tr.run()
    out = Trainer(cfg, opt, loop, corpus, str(tmp_path / "ck")).run()
    assert out["final_step"] == 12
    ref = Trainer(cfg, opt, loop, corpus, str(tmp_path / "ck2")).run()
    np.testing.assert_allclose(out["losses"][-3:], ref["losses"][-3:],
                               atol=1e-2)


def test_checkpoint_reap_restore_bit_exact(tmp_path):
    from repro.training import optimizer as opt_lib
    from repro.training.checkpoint import restore_checkpoint, save_checkpoint
    cfg = SMOKES["qwen2-7b"]
    params = steps.init_params(cfg, jax.random.key(5))
    opt = opt_lib.OptConfig()
    state = opt_lib.init_state(params, opt)
    base = save_checkpoint(str(tmp_path / "ck"), params, state, 7)
    for mode in ("lazy", "reap"):
        p2, s2, step, stats = restore_checkpoint(base, params, state, mode=mode)
        assert step == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # reap restore does one large read, not page faults
    assert stats["n_faults"] == 0


def test_elastic_reshard_restore(tmp_path):
    """Restoring onto a different mesh reads per-shard byte ranges that
    reassemble to the identical tensors."""
    from types import SimpleNamespace
    from repro.models import get_family
    from repro.training import optimizer as opt_lib
    from repro.training.checkpoint import restore_for_mesh, save_checkpoint
    cfg = SMOKES["olmo-1b"]
    fam = get_family(cfg)
    params = steps.init_params(cfg, jax.random.key(6))
    state = opt_lib.init_state(params, opt_lib.OptConfig())
    base = save_checkpoint(str(tmp_path / "ck"), params, state, 1)
    fake_mesh = SimpleNamespace(shape={"data": 4}, axis_names=("data",))
    restored = restore_for_mesh(base, fam.param_specs(cfg), fake_mesh, {})
    for (_pa, a), (_pb, b) in zip(
            sorted_leaves(params), sorted_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def sorted_leaves(tree, prefix=""):
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree):
            out += sorted_leaves(tree[k], prefix + str(k) + "/")
    else:
        out.append((prefix, tree))
    return out


def test_data_pipeline_deterministic_and_prefetch(tmp_path):
    from repro.data import PrefetchLoader, TokenDataset, synthesize_corpus
    path = synthesize_corpus(str(tmp_path / "c.bin"), 50_000, 1000)
    ds = TokenDataset(path, 32)
    b1 = ds.batch(3, 4)
    b2 = ds.batch(3, 4)
    np.testing.assert_array_equal(b1, b2)
    # ranks see disjoint streams
    r0 = ds.batch(0, 4, rank=0, world=2)
    r1 = ds.batch(0, 4, rank=1, world=2)
    assert not np.array_equal(r0, r1)
    loader = PrefetchLoader(ds, 4, start_step=5)
    s, b = next(loader)
    assert s == 5
    np.testing.assert_array_equal(b, ds.batch(5, 4))
    loader.close()
