"""Trace generators must be replayable artifacts: byte-identical across
runs from the same seed, robust to dirty input, and carrying their
period metadata through save/load."""
import os

import pytest

from repro.serving import Trace, azure_trace, diurnal_trace, poisson_trace

FIXTURE = os.path.join(os.path.dirname(__file__), "data", "azure_sample.csv")
NAMES = ["fn_a", "fn_b", "fn_c"]


def _saved_bytes(trace, tmp_path, tag) -> bytes:
    p = tmp_path / f"{tag}.json"
    trace.save(str(p))
    return p.read_bytes()


# -- byte-identical replays ---------------------------------------------

def test_diurnal_trace_is_byte_identical_across_runs(tmp_path):
    kw = dict(base_rps=1.0, peak_rps=12.0, period_s=2.0, duration_s=6.0,
              functions=NAMES, burst_rps=8.0, burst_every_s=2.0, seed=5)
    t1, t2 = diurnal_trace(**kw), diurnal_trace(**kw)
    assert t1.events == t2.events
    assert _saved_bytes(t1, tmp_path, "a") == _saved_bytes(t2, tmp_path, "b")
    # a different seed really does change the sample path
    t3 = diurnal_trace(**{**kw, "seed": 6})
    assert t3.events != t1.events


def test_azure_trace_is_byte_identical_across_runs(tmp_path):
    kw = dict(functions=NAMES, duration_s=6.0, seed=7)
    t1 = azure_trace(FIXTURE, **kw)
    t2 = azure_trace(FIXTURE, **kw)
    assert t1.events == t2.events
    assert _saved_bytes(t1, tmp_path, "a") == _saved_bytes(t2, tmp_path, "b")


# -- period hints --------------------------------------------------------

def test_generators_expose_period_hints(tmp_path):
    d = diurnal_trace(base_rps=1.0, peak_rps=8.0, period_s=2.5,
                      duration_s=5.0, functions=NAMES, seed=1)
    assert d.period_hint_s == 2.5
    a = azure_trace(FIXTURE, functions=NAMES, duration_s=6.0, seed=1)
    assert a.period_hint_s == pytest.approx(6.0)   # the compressed day
    p = poisson_trace(rate_rps=5.0, duration_s=2.0, functions=NAMES, seed=1)
    assert p.period_hint_s is None                 # memoryless: no claim
    # the hint survives the JSON round-trip (and its absence does too)
    path = str(tmp_path / "d.json")
    d.save(path)
    assert Trace.load(path).period_hint_s == 2.5
    p.save(path)
    assert Trace.load(path).period_hint_s is None


# -- malformed input -----------------------------------------------------

def test_azure_trace_skips_malformed_rows(tmp_path):
    """Garbled rows are dropped, not fatal: real trace dumps carry the
    occasional truncated or corrupt line."""
    p = tmp_path / "dirty.csv"
    p.write_text(
        "HashOwner,HashApp,HashFunction,Trigger,1,2,3\n"
        "o1,a1,good,http,4,5,6\n"
        "o2,a2,garbled,http,4,notanumber,6\n"      # corrupt count cell
        "o3,a3,short,http\n"                       # truncated line
        "o4,a4,good2,queue,1,0,2\n")
    tr = azure_trace(str(p))
    fns = {e.function for e in tr.events}
    assert fns == {"o1/a1/good/http", "o4/a4/good2/queue"}
    assert len(tr.events) == 15 + 3


def test_azure_trace_all_rows_malformed_raises(tmp_path):
    p = tmp_path / "hopeless.csv"
    p.write_text("HashOwner,1,2\n"
                 "o1,x,y\n"
                 "o2,nan_ish,zz\n")
    with pytest.raises(ValueError, match="malformed"):
        azure_trace(str(p))


def test_azure_trace_empty_counts_row_yields_no_events(tmp_path):
    p = tmp_path / "quiet.csv"
    p.write_text("HashOwner,1,2\no1,0,0\n")
    assert azure_trace(str(p)).events == []
