"""Real page transport (PR 10): chunk codec, wire protocol robustness,
shm zero-copy installs, TransportSource fallbacks, and the TransferModel
zero-missing-charge regression.

No models here — every test runs over fabricated WS records, so the
whole file is jax-free and fast.  The process-per-node fleet has its own
file (test_procnode.py, marked slow)."""
import os
import socket

import numpy as np
import pytest

from repro.core import pagestore
from repro.core.arena import PAGE
from repro.core.reap import ReapConfig, trace_path, ws_path
from repro.transport import (BadMagicError, ChunkHashMismatchError,
                             PageClient, PageServer, TruncatedFrameError,
                             WireError, decode_chunk, encode_chunk,
                             shm_available)
from repro.transport.wire import (HEADER, MAGIC, T_MANIFEST, recv_frame,
                                  send_frame)


def low_entropy_page(seed: int = 0) -> bytes:
    """64-byte runs from a 4-symbol alphabet: compresses hard."""
    rng = np.random.default_rng(seed)
    return np.repeat(rng.integers(0, 4, size=64, dtype=np.uint8),
                     PAGE // 64).tobytes()


def random_page(seed: int = 0) -> bytes:
    return np.random.default_rng(seed).integers(
        0, 256, size=PAGE, dtype=np.uint8).tobytes()


# -- codec ----------------------------------------------------------------

def test_codec_roundtrips_compressible_chunks():
    block = low_entropy_page(1)
    enc, payload = encode_chunk(block)
    assert len(payload) < len(block)       # actually compressed
    assert decode_chunk(enc, payload) == block


def test_codec_ships_incompressible_chunks_raw():
    block = random_page(2)
    enc, payload = encode_chunk(block)
    assert payload == block                # entropy probe said don't bother
    assert decode_chunk(enc, payload) == block


def test_codec_compress_false_is_raw():
    block = low_entropy_page(3)
    enc, payload = encode_chunk(block, compress=False)
    assert payload == block
    assert decode_chunk(enc, payload) == block


# -- frame robustness -----------------------------------------------------

def test_recv_frame_rejects_garbage_magic():
    a, b = socket.socketpair()
    try:
        a.sendall(HEADER.pack(b"XXXX", T_MANIFEST, 0))
        with pytest.raises(BadMagicError):
            recv_frame(b)
    finally:
        a.close()
        b.close()


def test_recv_frame_raises_on_truncated_frame():
    a, b = socket.socketpair()
    try:
        # header promises 100 payload bytes, peer dies after 10
        a.sendall(HEADER.pack(MAGIC, T_MANIFEST, 100) + b"x" * 10)
        a.close()
        with pytest.raises(TruncatedFrameError):
            recv_frame(b)
    finally:
        b.close()


def test_recv_frame_rejects_oversized_length():
    a, b = socket.socketpair()
    try:
        a.sendall(HEADER.pack(MAGIC, T_MANIFEST, (1 << 28) + 1))
        with pytest.raises(WireError):
            recv_frame(b)
    finally:
        a.close()
        b.close()


def test_send_recv_frame_roundtrip():
    a, b = socket.socketpair()
    try:
        send_frame(a, T_MANIFEST, b"payload bytes")
        ftype, payload = recv_frame(b)
        assert ftype == T_MANIFEST and payload == b"payload bytes"
    finally:
        a.close()
        b.close()


# -- server/client over fabricated records --------------------------------

def make_records(n_rec: int = 2, n_pages: int = 8) -> dict:
    records = {}
    for i in range(n_rec):
        data = b"".join(low_entropy_page(100 * i + j)
                        for j in range(n_pages))
        hashes = [pagestore.chunk_hash(data[j * PAGE:(j + 1) * PAGE])
                  for j in range(n_pages)]
        records[f"rec_{i}"] = (list(range(n_pages)), data, hashes)
    return records


@pytest.fixture()
def pair(tmp_path):
    """A PageServer over in-heap records plus a connected client.  Tests
    that need different server knobs build their own (see _serve)."""
    records = make_records()
    path = str(tmp_path / "page.sock")
    server = PageServer(path, records.get, use_shm=False)
    client = PageClient(path)
    yield records, server, client
    client.close()
    server.close()


def test_fetch_reassembles_byte_identical(pair):
    records, _server, client = pair
    for base, (pages, data, hashes) in records.items():
        res = client.fetch(base)
        assert res is not None
        assert list(res.pages) == pages
        assert res.hashes == hashes
        assert res.assemble() == data


def test_fetch_unknown_base_returns_none_and_connection_survives(pair):
    records, _server, client = pair
    assert client.fetch("no_such_record") is None
    base = next(iter(records))
    assert client.fetch(base).assemble() == records[base][1]


def test_dedup_negotiation_ships_only_missing_chunks(pair):
    records, server, client = pair
    base = next(iter(records))
    _pages, data, hashes = records[base]
    have = set(hashes[::2])                  # claim every other chunk
    res = client.fetch(base, have)
    assert set(res.chunks) == set(hashes) - have
    # the held chunks come from the local lookup, and the blob still
    # reassembles exactly
    local = {h: data[j * PAGE:(j + 1) * PAGE]
             for j, h in enumerate(hashes) if h in have}
    assert res.assemble(lookup=local.get) == data
    # a fully-held fetch ships zero chunk bytes (negotiation only)
    res2 = client.fetch(base, set(hashes))
    assert res2.chunks == {}
    assert server.stats.as_dict()["chunks_shipped"] == len(hashes) - len(have)


def test_compressed_stream_is_smaller_and_verified(tmp_path):
    records = make_records(n_rec=1, n_pages=16)
    raw_rx = comp_rx = None
    for compress in (False, True):
        path = str(tmp_path / f"c{compress}.sock")
        server = PageServer(path, records.get, use_shm=False,
                            compress=compress)
        client = PageClient(path)
        try:
            res = client.fetch("rec_0")
            assert res.assemble() == records["rec_0"][1]
            rx = client.stats.as_dict()["wire_rx_bytes"]
        finally:
            client.close()
            server.close()
        if compress:
            comp_rx = rx
        else:
            raw_rx = rx
    assert comp_rx < raw_rx


def test_chunk_hash_mismatch_raises_before_surfacing(pair):
    records, _server, client = pair
    base = next(iter(records))
    pages, data, hashes = records[base]
    # corrupt the served bytes without updating the advertised hashes
    records[base] = (pages, b"\0" * len(data), hashes)
    with pytest.raises(ChunkHashMismatchError):
        client.fetch(base)


def test_responder_death_surfaces_as_wire_error(pair):
    records, server, client = pair
    base = next(iter(records))
    assert client.fetch(base) is not None
    server.close()
    with pytest.raises((WireError, OSError)):
        client.fetch(base)


# -- shared-memory data plane ---------------------------------------------

needs_shm = pytest.mark.skipif(not shm_available(),
                               reason="multiprocessing.shared_memory "
                                      "unavailable on this platform")


class CaptureArena:
    """install_block sink: copies the view out so parity survives the
    segment's release."""

    def __init__(self):
        self.pages = None
        self.block = None

    def install_block(self, pages, block):
        self.pages = np.array(pages, copy=True)
        self.block = np.array(block, copy=True)


@needs_shm
def test_shm_fetch_install_is_byte_identical(tmp_path):
    records = make_records(n_rec=1, n_pages=64)   # 256KB > inline_max=1
    path = str(tmp_path / "shm.sock")
    server = PageServer(path, records.get, use_shm=True, inline_max_bytes=1)
    client = PageClient(path)
    try:
        arena = CaptureArena()
        res = client.fetch_install("rec_0", arena)
        assert res.transport == "shm"
        assert res.shm_bytes == len(records["rec_0"][1])
        pages, data, _hashes = records["rec_0"]
        assert list(arena.pages) == pages
        assert arena.block.tobytes() == data
    finally:
        client.close()
        server.close()
    # responder released its segments: nothing leaked in /dev/shm
    assert server.stats.as_dict()["shm_responses"] == 1


@needs_shm
def test_shm_corruption_raises_and_skips_install(tmp_path):
    records = make_records(n_rec=1, n_pages=64)
    pages, data, hashes = records["rec_0"]
    records["rec_0"] = (pages, b"\0" * len(data), hashes)
    path = str(tmp_path / "shmbad.sock")
    server = PageServer(path, records.get, use_shm=True, inline_max_bytes=1)
    client = PageClient(path)
    try:
        arena = CaptureArena()
        with pytest.raises(ChunkHashMismatchError):
            client.fetch_install("rec_0", arena)
        assert arena.block is None            # verification gated install
    finally:
        client.close()
        server.close()


@needs_shm
def test_small_ws_stays_inline(tmp_path):
    records = make_records(n_rec=1, n_pages=2)    # 8KB < 64KB inline_max
    path = str(tmp_path / "small.sock")
    server = PageServer(path, records.get, use_shm=True)
    client = PageClient(path)
    try:
        res = client.fetch("rec_0")
        assert res.transport == "inline"
        assert res.assemble() == records["rec_0"][1]
    finally:
        client.close()
        server.close()


# -- TransportSource: owner sockets first, origin disk last ---------------

def write_flat_record(tmp_path, name: str, n_pages: int = 4) -> str:
    base = str(tmp_path / name)
    np.save(trace_path(base), np.arange(n_pages, dtype=np.int64))
    salt = sum(name.encode())
    with open(ws_path(base), "wb") as f:
        for i in range(n_pages):
            f.write(bytes([(salt + i) % 256]) * PAGE)
    return base


@pytest.fixture()
def source_env(tmp_path):
    from repro.cluster.shardmap import ConsistentHashRing
    from repro.transport.procnode import NodeSpec, TransportSource

    sock_dir = str(tmp_path / "socks")
    os.makedirs(sock_dir)
    node_ids = ("node-a", "node-b")
    spec = NodeSpec(node_id="node-a", store_dir=str(tmp_path),
                    sock_dir=sock_dir, node_ids=node_ids, config=None)
    ring = ConsistentHashRing(list(node_ids), vnodes=spec.vnodes)
    source = TransportSource(spec, ring)
    yield tmp_path, spec, ring, source
    source.close()


def _record_owned_by(tmp_path, ring, owner: str):
    """A flat record whose ring owner is ``owner``."""
    i = 0
    while True:
        name = f"srec_{i}"
        if ring.owner(name) == owner:
            return name, write_flat_record(tmp_path, name)
        i += 1


def test_source_pulls_from_live_owner_over_the_wire(source_env):
    tmp_path, spec, ring, source = source_env
    name, base = _record_owned_by(tmp_path, ring, "node-b")
    cfg = ReapConfig(o_direct=False)
    from repro.core.reap import _read_ws
    served = {base: None}
    p, d = _read_ws(base, cfg)
    hashes = [pagestore.chunk_hash(d[j * PAGE:(j + 1) * PAGE])
              for j in range(len(p))]
    served[base] = (p, d, hashes)
    server = PageServer(spec.sock_path("node-b"), served.get, use_shm=False)
    try:
        pages, data = source(base, cfg)
        assert data == d and pages == [int(x) for x in p]
        st = source.stats()
        assert st["remote_fetches"] == 1 and st["origin_reads"] == 0
        assert st["wire_rx_bytes"] > 0
        assert st["fetch_rtt_s"]["count"] == 1
    finally:
        server.close()


def test_source_dead_owner_falls_back_to_origin(source_env):
    """No server listening at the owner's socket: the source counts a
    dead-owner fallback and reads the origin record itself."""
    tmp_path, _spec, ring, source = source_env
    name, base = _record_owned_by(tmp_path, ring, "node-b")
    cfg = ReapConfig(o_direct=False)
    pages, data = source(base, cfg)
    assert len(data) == 4 * PAGE               # origin read served it
    st = source.stats()
    assert st["dead_owner_fallbacks"] == 1
    assert st["origin_reads"] == 1 and st["remote_fetches"] == 0


def test_source_owner_mid_fetch_death_falls_back(source_env):
    """The owner dies between fetches: the broken connection surfaces as
    a dead-owner fallback, not an exception, and the origin serves."""
    tmp_path, spec, ring, source = source_env
    name, base = _record_owned_by(tmp_path, ring, "node-b")
    cfg = ReapConfig(o_direct=False)
    from repro.core.reap import _read_ws
    p, d = _read_ws(base, cfg)
    hashes = [pagestore.chunk_hash(d[j * PAGE:(j + 1) * PAGE])
              for j in range(len(p))]
    server = PageServer(spec.sock_path("node-b"),
                        {base: (p, d, hashes)}.get, use_shm=False)
    pages, data = source(base, cfg)
    assert source.stats()["remote_fetches"] == 1
    server.close()                             # owner process "dies"
    pages, data = source(base, cfg)            # must not raise
    assert len(data) == 4 * PAGE
    st = source.stats()
    assert st["dead_owner_fallbacks"] == 1 and st["origin_reads"] == 1


def test_source_cold_owner_counts_remote_miss(source_env):
    tmp_path, spec, ring, source = source_env
    name, base = _record_owned_by(tmp_path, ring, "node-b")
    server = PageServer(spec.sock_path("node-b"), lambda b: None,
                        use_shm=False)
    try:
        pages, data = source(base, ReapConfig(o_direct=False))
        assert len(data) == 4 * PAGE
        st = source.stats()
        assert st["remote_misses"] == 1 and st["origin_reads"] == 1
        assert st["dead_owner_fallbacks"] == 0
    finally:
        server.close()


# -- S1 regression: zero-missing fetch charges zero transfer time ---------

def test_fully_deduped_fetch_charges_no_transfer_sleep(tmp_path):
    """Two functions with identical page contents: the second remote
    fetch finds every chunk already in the requester's L1, ships zero
    bytes, and must charge zero modeled transfer seconds (it used to pay
    the full per-transfer latency for a transfer that never happened)."""
    from repro.cluster.shardmap import ConsistentHashRing
    from repro.cluster.snapstore import ShardedSnapshotStore, TransferModel

    ring = ConsistentHashRing(vnodes=32)
    slept = []
    store = ShardedSnapshotStore(ring, transfer=TransferModel(1e-3, 1.0),
                                 reap=ReapConfig(o_direct=False),
                                 sleep=slept.append)
    caches = {n: store.attach(n) for n in ("na", "nb")}
    cfg = ReapConfig(o_direct=False)

    def twin_record(name: str) -> str:
        # identical page bytes across both records -> full chunk dedup
        base = str(tmp_path / name)
        np.save(trace_path(base), np.arange(3, dtype=np.int64))
        with open(ws_path(base), "wb") as f:
            for i in range(3):
                f.write(bytes([i]) * PAGE)
        return base

    # same-owner twins so one requester pays the wire once, dedups twice
    bases, i = {}, 0
    while len(bases) < 2:
        name = f"twin_{i}"
        if ring.owner(name) == "nb":
            bases[name] = twin_record(name)
        i += 1
    b1, b2 = bases.values()
    assert store.warm_owners(b1) + store.warm_owners(b2) == 2

    caches["na"].fetch(b1, cfg)                # first fetch pays the wire
    assert store.stats()["transfer_bytes"] == 3 * PAGE
    assert slept == [store.transfer.cost_s(3 * PAGE)]

    caches["na"].fetch(b2, cfg)                # twin: zero missing chunks
    s = store.stats()
    assert s["remote_fetches"] == 2
    assert s["transfer_bytes"] == 3 * PAGE     # nothing new shipped
    assert s["dedup_bytes_saved"] == 3 * PAGE
    # THE regression: the zero-byte fetch charges zero seconds
    assert slept == [store.transfer.cost_s(3 * PAGE), 0.0]
    assert s["transfer_s"] == store.transfer.cost_s(3 * PAGE)
