"""Property tests for the consistent-hash ring (hypothesis).

tests/test_cluster.py pins the ring's behaviour on fixed fleets; these
properties let hypothesis hunt the invariants over arbitrary memberships:

  * load balance within bound for any >= 2-host fleet,
  * minimal remap on join (keys move only *to* the joiner) and on leave
    (only the victim's keys move),
  * ``lookup(key, n)`` returns n distinct alive hosts, primary first,
    stable under ring-insertion order.

Runs wherever hypothesis is installed (CI always); collects and skips
gracefully elsewhere via the tests/hypo.py shim.
"""
from hypo import given, settings, st

from repro.cluster import ConsistentHashRing

KEYS = [f"fn-{i}" for i in range(400)]

node_ids = st.lists(
    st.text(alphabet="abcdefghijklmnop", min_size=1, max_size=8),
    min_size=1, max_size=12, unique=True)


def owners_of(ring, keys):
    return {k: ring.owner(k) for k in keys}


@settings(max_examples=30, deadline=None)
@given(nodes=node_ids)
def test_every_key_has_an_owner_and_order_does_not_matter(nodes):
    ring = ConsistentHashRing(nodes, vnodes=32)
    ring2 = ConsistentHashRing(list(reversed(nodes)), vnodes=32)
    for k in KEYS[:50]:
        owner = ring.owner(k)
        assert owner in nodes
        assert ring2.owner(k) == owner       # insertion order irrelevant


@settings(max_examples=20, deadline=None)
@given(nodes=node_ids.filter(lambda ns: len(ns) >= 4))
def test_load_balance_within_bound(nodes):
    """No host owns more than ~4x its fair share at 64 vnodes (the fixed
    8-host test asserts 3x; arbitrary small fleets get a looser bound —
    what matters is that no host is starved and none hot-spots)."""
    ring = ConsistentHashRing(nodes, vnodes=64)
    counts = dict.fromkeys(nodes, 0)
    for k in KEYS:
        counts[ring.owner(k)] += 1
    fair = len(KEYS) / len(nodes)
    assert all(c <= 4 * fair for c in counts.values())
    assert sum(1 for c in counts.values() if c > 0) >= len(nodes) * 0.5


@settings(max_examples=25, deadline=None)
@given(nodes=node_ids, joiner=st.text(alphabet="qrstuv", min_size=1,
                                      max_size=8))
def test_join_minimal_remap(nodes, joiner):
    ring = ConsistentHashRing(nodes, vnodes=32)
    before = owners_of(ring, KEYS)
    ring.add(joiner)
    after = owners_of(ring, KEYS)
    for k in KEYS:
        if before[k] != after[k]:
            assert after[k] == joiner        # moves go *to* the joiner only


@settings(max_examples=25, deadline=None)
@given(nodes=node_ids.filter(lambda ns: len(ns) >= 2), data=st.data())
def test_leave_moves_only_the_victims_keys(nodes, data):
    victim = data.draw(st.sampled_from(nodes))
    ring = ConsistentHashRing(nodes, vnodes=32)
    before = owners_of(ring, KEYS)
    ring.remove(victim)
    after = owners_of(ring, KEYS)
    for k in KEYS:
        if before[k] == victim:
            assert after[k] != victim
        else:
            assert after[k] == before[k]


@settings(max_examples=25, deadline=None)
@given(nodes=node_ids, n=st.integers(min_value=1, max_value=6))
def test_lookup_returns_n_distinct_alive_hosts(nodes, n):
    ring = ConsistentHashRing(nodes, vnodes=16)
    for k in KEYS[:25]:
        got = ring.lookup(k, n)
        assert len(got) == min(n, len(nodes))
        assert len(set(got)) == len(got)     # distinct
        assert set(got) <= set(nodes)        # alive members only
        assert got[0] == ring.owner(k)       # primary first
        # replica list is a prefix-stable preference order
        assert ring.lookup(k, max(n - 1, 1)) == got[:max(n - 1, 1)]
