"""Per-architecture smoke tests + prefill/decode consistency.

Every assigned arch instantiates its REDUCED same-family config and runs a
forward + train step on CPU, asserting output shapes and no NaNs (the full
configs are exercised via the dry-run only).
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SMOKES
from repro.launch import steps
from repro.training.optimizer import OptConfig

ALL_ARCHS = list(ARCHS)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_shapes(arch):
    cfg = SMOKES[arch]
    key = jax.random.key(0)
    params = steps.init_params(cfg, key)
    batch = steps.make_batch(cfg, 64, 2, "train", key)
    logits = steps.build_forward(cfg)(params, batch)
    expected_tokens = batch["tokens"].shape[1]
    if cfg.family == "vlm":
        expected_tokens += batch["patch_embeds"].shape[1]
    assert logits.shape == (2, expected_tokens, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits))), "NaN logits"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    cfg = SMOKES[arch]
    key = jax.random.key(1)
    params = steps.init_params(cfg, key)
    from repro.training import optimizer as opt_lib
    opt = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = opt_lib.init_state(params, opt)
    batch = steps.make_batch(cfg, 32, 2, "train", key)
    step = steps.build_train_step(cfg, opt, remat=False)
    new_params, new_state, metrics = step(params, state, batch)
    loss = float(metrics["loss"])
    assert 0.0 < loss < 50.0 and loss == loss, loss
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(new_params)[0]
    assert not bool(jnp.all(l0 == l1))


@pytest.mark.parametrize("arch", ["qwen2-7b", "deepseek-moe-16b",
                                  "zamba2-1.2b", "rwkv6-7b",
                                  "seamless-m4t-medium", "pixtral-12b"])
def test_prefill_decode_matches_forward(arch):
    cfg = SMOKES[arch]
    if cfg.n_experts:  # no-drop capacity: teacher-forced == decode
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    key = jax.random.key(2)
    params = steps.init_params(cfg, key)
    B, EXTRA = 2, 3
    full = steps.make_batch(cfg, 24, B, "train", key)
    ref = steps.build_forward(cfg)(params, full)
    n_img = full["patch_embeds"].shape[1] if cfg.family == "vlm" else 0
    n_txt = full["tokens"].shape[1]
    S = n_txt - EXTRA

    cache = steps.init_cache(cfg, B, n_txt + n_img)
    pre = dict(full)
    pre["tokens"] = full["tokens"][:, :S]
    logits, cache = steps.build_prefill_step(cfg)(params, pre, cache)
    err = float(jnp.max(jnp.abs(
        logits[:, -1].astype(jnp.float32)
        - ref[:, n_img + S - 1].astype(jnp.float32))))
    assert err < 0.15, f"prefill mismatch {err}"

    dec = steps.build_decode_step(cfg)
    for i in range(EXTRA):
        db = {"tokens": full["tokens"][:, S + i][:, None]}
        logits, cache = dec(params, cache, db, n_img + S + i)
        err = float(jnp.max(jnp.abs(
            logits[:, -1].astype(jnp.float32)
            - ref[:, n_img + S + i].astype(jnp.float32))))
        assert err < 0.2, f"decode step {i} mismatch {err}"


def test_microbatched_train_step_matches_single():
    cfg = SMOKES["olmo-1b"]
    key = jax.random.key(3)
    params = steps.init_params(cfg, key)
    from repro.training import optimizer as opt_lib
    opt = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    batch = steps.make_batch(cfg, 32, 4, "train", key)
    s1 = steps.build_train_step(cfg, opt, remat=False, microbatches=1)
    s4 = steps.build_train_step(cfg, opt, remat=False, microbatches=4)
    _, _, m1 = s1(params, opt_lib.init_state(params, opt), batch)
    _, _, m4 = s4(params, opt_lib.init_state(params, opt), batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 0.05


def test_remat_matches_no_remat():
    cfg = SMOKES["qwen2-7b"]
    key = jax.random.key(4)
    params = steps.init_params(cfg, key)
    batch = steps.make_batch(cfg, 32, 2, "train", key)
    from repro.models import get_family
    fam = get_family(cfg)
    g1 = jax.grad(lambda p: fam.loss(cfg, p, batch, remat=False))(params)
    g2 = jax.grad(lambda p: fam.loss(cfg, p, batch, remat=True))(params)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))), g1, g2)
    assert max(jax.tree.leaves(diffs)) < 2e-2
