"""Sharding-rule derivation + HLO analyzer + compression unit tests."""
from types import SimpleNamespace

import jax.numpy as jnp
import numpy as np
import pytest
from hypo import given, settings, st

from repro.nn.spec import _partition_spec, tensor

MESH = SimpleNamespace(shape={"pod": 2, "data": 16, "model": 16},
                       axis_names=("pod", "data", "model"))

RULES = {"heads": "model", "kv_heads": "model", "mlp": "model",
         "vocab": "model", "embed": ("pod", "data"), "batch": ("pod", "data"),
         "seq": "model", "layers": None}


def test_partition_spec_basic():
    s = tensor(8192, 64, 128, axes=("embed", "heads", "head_dim"))
    p = _partition_spec(s, RULES, MESH)
    assert p[0] == ("pod", "data") and p[1] == "model"


def test_partition_spec_divisibility_fallback():
    # kv_heads=8 cannot shard over model=16 -> replicated
    s = tensor(80, 8, 128, axes=("layers", "kv_heads", "head_dim"))
    p = _partition_spec(s, RULES, MESH)
    assert all(e is None for e in p)


def test_partition_spec_no_axis_reuse():
    s = tensor(64, 128, axes=("heads", "seq"))  # both want "model"
    p = _partition_spec(s, RULES, MESH)
    assert p[0] == "model" and (len(p) < 2 or p[1] is None)


def test_partition_spec_prefix_drop():
    # dim 2 divisible by pod(2) but not pod*data(32): keep the prefix
    s = tensor(2, 128, axes=("embed", None))
    p = _partition_spec(s, RULES, MESH)
    assert p[0] == "pod"


@settings(max_examples=50, deadline=None)
@given(dim=st.integers(1, 4096))
def test_partition_spec_always_divides(dim):
    s = tensor(dim, axes=("embed",))
    p = _partition_spec(s, RULES, MESH)
    if p and p[0] is not None:
        axes = p[0] if isinstance(p[0], tuple) else (p[0],)
        prod = 1
        for a in axes:
            prod *= MESH.shape[a]
        assert dim % prod == 0


def test_batch_axes():
    from repro.distributed.sharding import batch_axes
    assert batch_axes(MESH, 256) == ("pod", "data")
    assert batch_axes(MESH, 16) == ("data",)
    assert batch_axes(MESH, 1) is None


# ---------------------------------------------------------------------------
# HLO analyzer
# ---------------------------------------------------------------------------

FAKE_HLO = """
%body.1 (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %a = f32[8,128]{1,0} parameter(1)
  %b = f32[128,8]{1,0} parameter(2)
  %d = f32[8,8]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[64,8]{1,0} all-gather(%d), replica_groups={}
}
%cond.1 (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %c = pred[] compare(%p, %p)
}
ENTRY %main.1 (x: f32[8,8]) -> f32[8,8] {
  %x = f32[8,8]{1,0} parameter(0)
  %w = (s32[], f32[8,8]) while(%x), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
  %ar = f32[8,8]{1,0} all-reduce(%x), replica_groups={}
}
"""


def test_analyze_hlo_loop_multipliers():
    from repro.distributed.hlo_analysis import analyze_hlo
    r = analyze_hlo(FAKE_HLO)
    # dot: 2 * 64 * 128 flops, x10 trips
    assert r["dot_flops_per_device"] == 2 * 64 * 128 * 10
    # all-gather operand = 8*8*4 bytes x10; all-reduce = 8*8*4 once
    assert r["collective_bytes_per_device"]["all-gather"] == 8 * 8 * 4 * 10
    assert r["collective_bytes_per_device"]["all-reduce"] == 8 * 8 * 4
    assert r["collective_count"]["all-gather"] == 10


def test_roofline_terms():
    from repro.distributed.hlo_analysis import HBM_BW, PEAK_FLOPS, Roofline
    r = Roofline(flops=PEAK_FLOPS, hbm_bytes=HBM_BW / 2, coll_bytes=0,
                 n_chips=4, model_flops=2 * PEAK_FLOPS)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(0.5)
    assert r.bottleneck == "compute"
    assert r.roofline_fraction == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**16), scale=st.floats(1e-3, 1e3))
def test_ef_quantize_bounded_error(seed, scale):
    from repro.distributed.compress import ef_compress, dequantize_int8
    g = jnp.asarray(np.random.default_rng(seed).standard_normal(64) * scale,
                    jnp.float32)
    e0 = jnp.zeros_like(g)
    q, s, e1 = ef_compress(g, e0)
    # residual bounded by half a quantization step
    assert float(jnp.max(jnp.abs(e1))) <= float(s) / 2 + 1e-6
    np.testing.assert_allclose(np.asarray(dequantize_int8(q, s) + e1),
                               np.asarray(g), rtol=1e-5, atol=1e-5)


def test_ef_long_run_unbiased():
    """Error feedback: accumulated updates converge to the true sum."""
    from repro.distributed.compress import dequantize_int8, ef_compress
    rng_ = np.random.default_rng(0)
    g_true = jnp.asarray(rng_.standard_normal(32).astype(np.float32))
    err = jnp.zeros_like(g_true)
    acc = jnp.zeros_like(g_true)
    for _ in range(200):
        q, s, err = ef_compress(g_true, err)
        acc = acc + dequantize_int8(q, s)
    np.testing.assert_allclose(np.asarray(acc / 200), np.asarray(g_true),
                               atol=1e-2)
