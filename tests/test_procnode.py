"""Process-per-node fleet (PR 10): spawn children, move real pages.

Each WorkerNode runs in its own child process with a private WSCache and
a PageServer; the supervisor speaks the ClusterRouter scheduling
interface.  These tests build real fleets (spawn + jax init per child),
so everything fleet-shaped is marked slow; the build_fleet dispatch
checks at the top are cheap and run in the default CI matrix."""
import numpy as np
import pytest

from repro.cluster import ScheduleConfig, build_fleet


# -- build_fleet dispatch (no processes spawned) --------------------------

def test_build_fleet_rejects_loose_node_kw_for_socket(tmp_path):
    with pytest.raises(TypeError):
        build_fleet(2, str(tmp_path), transport="socket",
                    max_concurrency=2)


def test_build_fleet_unknown_transport_raises(tmp_path):
    with pytest.raises(ValueError):
        build_fleet(2, str(tmp_path), transport="carrier-pigeon")


# -- real 2-node socket fleet ---------------------------------------------

def _serve_config(transport: str = "socket"):
    from repro.cluster import TransferModel
    from repro.serving import PolicyConfig, RouterConfig, ServeConfig
    return ServeConfig(
        keepalive_s=2.0, warm_limit=4,
        router=RouterConfig(max_concurrency=2,
                            max_instances_per_function=2,
                            queue_depth=64, batch_restore_limit=8),
        policy=PolicyConfig(interval_s=0.05, window_s=2.0, max_warm=4,
                            min_keepalive_s=0.5),
        transfer=TransferModel(latency_s=1e-3, gbps=1.0),
        transport=transport, transport_compress=True)


@pytest.fixture(scope="module")
def socket_fleet(tmp_path_factory):
    import jax
    from repro.configs import SMOKES
    from repro.launch import steps

    store_dir = str(tmp_path_factory.mktemp("pstore"))
    cfg = SMOKES["olmo-1b"]
    batch = steps.make_batch(cfg, 16, 1, "train", jax.random.key(0))
    fleet = build_fleet(2, store_dir, config=_serve_config(),
                        cfg=ScheduleConfig(placement="locality", seed=7))
    fleet.register("pfn", cfg, seed=0, warmup_batch=batch)
    fleet.register("pfn2", cfg, seed=1)
    for name in ("pfn", "pfn2"):
        _, rep = fleet.invoke(name, batch)       # record wave
        assert rep.processing_s > 0
    yield fleet, store_dir, cfg, batch
    fleet.close()


@pytest.mark.slow
def test_socket_fleet_output_matches_inproc(socket_fleet):
    """The acceptance parity criterion: logits served across the process
    boundary are byte-identical to an in-process fleet on the same
    store."""
    fleet, store_dir, cfg, batch = socket_fleet
    # force_cold on both sides: each serve restores the same snapshot, so
    # per-instance training state can't skew the comparison
    out_sock, rep = fleet.invoke("pfn", batch, force_cold=True)
    assert rep.load_vmm_s > 0
    inproc = build_fleet(2, store_dir, config=_serve_config("inproc"),
                         cfg=ScheduleConfig(placement="locality", seed=7))
    try:
        inproc.register("pfn", cfg, seed=0, warmup_batch=batch)
        out_in, rep = inproc.invoke("pfn", batch, force_cold=True)
        assert rep.load_vmm_s > 0
    finally:
        inproc.close()
    assert np.asarray(out_sock).tobytes() == np.asarray(out_in).tobytes()


@pytest.mark.slow
def test_socket_fleet_cold_wave_and_stats_schema(socket_fleet):
    fleet, _store_dir, _cfg, batch = socket_fleet
    for name in ("pfn", "pfn2"):
        fleet.scale_to_zero(name)
    fleet.clear_caches()
    fleet.rebalance()
    fleet.reset_stats()
    reports = []
    invs = [fleet.submit(name, batch, force_cold=True)
            for name in ("pfn", "pfn2", "pfn", "pfn2")]
    for inv in invs:
        _, rep = inv.result(timeout=180)
        reports.append(rep)
    assert all(r.load_vmm_s > 0 for r in reports)     # genuinely cold
    st = fleet.stats()
    assert st["transport"] == "socket"
    assert st["placed"] == 4
    assert set(st["nodes"]) == {"node-0", "node-1"}
    for ns in st["nodes"].values():
        tr = ns["transport"]
        for key in ("wire_tx_bytes", "wire_rx_bytes", "remote_fetches",
                    "origin_reads", "dead_owner_fallbacks", "fetch_rtt_s",
                    "chunks_served", "compress_ratio"):
            assert key in tr, f"transport stats missing {key!r}"
        assert set(tr["fetch_rtt_s"]) == {"count", "sum", "p50", "p95"}


@pytest.mark.slow
def test_socket_fleet_warm_serves_without_restore(socket_fleet):
    fleet, _store_dir, _cfg, batch = socket_fleet
    _, first = fleet.invoke("pfn", batch)
    _, rep = fleet.invoke("pfn", batch)
    assert rep.load_vmm_s == 0.0                      # warm hit, no restore


@pytest.mark.slow
def test_socket_fleet_kill_reroutes_and_survivor_serves(tmp_path_factory):
    """SIGTERM one child mid-flight: pending invocations resolve on the
    survivor (lazy reroute), its PageServer death shows up as dead-owner
    fallbacks at most, and nothing hangs."""
    import jax
    from repro.configs import SMOKES
    from repro.launch import steps

    store_dir = str(tmp_path_factory.mktemp("kstore"))
    cfg = SMOKES["olmo-1b"]
    batch = steps.make_batch(cfg, 16, 1, "train", jax.random.key(1))
    fleet = build_fleet(2, store_dir, config=_serve_config(),
                        cfg=ScheduleConfig(placement="locality", seed=7,
                                           w_load=0.0))
    try:
        fleet.register("kfn", cfg, seed=0, warmup_batch=batch)
        fleet.invoke("kfn", batch)                    # record + warm
        # force_cold serializes restores behind the placement node's
        # workers, so a burst is still pending when the kill lands
        invs = [fleet.submit("kfn", batch, force_cold=True)
                for _ in range(6)]
        victim = max(fleet.stats()["placements"].items(),
                     key=lambda kv: kv[1])[0]
        fleet.kill_node(victim)
        outs = [inv.result(timeout=180) for inv in invs]
        assert len(outs) == 6
        assert all(np.asarray(o).size > 0 for o, _rep in outs)
        assert not fleet.nodes[victim].alive
        assert fleet.n_rerouted >= 1
        rerouted = [inv for inv in invs if len(inv.node_ids) > 1]
        assert rerouted and all(inv.node_ids[0] == victim
                                and inv.node_ids[-1] != victim
                                for inv in rerouted)
        # the survivor keeps serving fresh work
        _, rep = fleet.invoke("kfn", batch)
        assert rep.processing_s > 0
    finally:
        fleet.close()


@pytest.mark.slow
def test_socket_fleet_close_is_clean_and_idempotent(tmp_path_factory):
    import jax
    from repro.configs import SMOKES
    from repro.launch import steps

    store_dir = str(tmp_path_factory.mktemp("cstore"))
    cfg = SMOKES["olmo-1b"]
    batch = steps.make_batch(cfg, 16, 1, "train", jax.random.key(2))
    fleet = build_fleet(2, store_dir, config=_serve_config(),
                        cfg=ScheduleConfig(placement="locality"))
    fleet.register("zfn", cfg, seed=0, warmup_batch=batch)
    fleet.invoke("zfn", batch)
    procs = [n._proc for n in fleet.nodes.values()]
    fleet.close()
    for p in procs:
        assert not p.is_alive()
    fleet.close()                                     # second close: no-op
