"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)
plus hypothesis property tests on the scan kernels' state-passing."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypo import given, settings, st

rng = np.random.default_rng(42)


def _r(*shape, scale=1.0, dtype=np.float32):
    return jnp.asarray((rng.standard_normal(shape) * scale).astype(dtype))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,D,dtype", [
    (2, 256, 4, 2, 64, jnp.float32),
    (1, 128, 8, 8, 128, jnp.float32),
    (2, 384, 6, 2, 80, jnp.float32),
    (1, 256, 4, 1, 64, jnp.bfloat16),
])
def test_flash_attention(B, S, H, KV, D, dtype):
    from repro.kernels.flash_attention.ops import mha
    from repro.kernels.flash_attention.ref import flash_attention_ref
    q = _r(B, S, H, D).astype(dtype)
    k = _r(B, S, KV, D).astype(dtype)
    v = _r(B, S, KV, D).astype(dtype)
    out = mha(q, k, v, causal=True)
    ref = jnp.moveaxis(flash_attention_ref(
        jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2),
        causal=True), 1, 2)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,D,bk", [
    (2, 1024, 8, 2, 64, 256),
    (1, 2048, 4, 4, 128, 512),
    (3, 512, 16, 2, 80, 128),
])
def test_decode_attention(B, S, H, KV, D, bk):
    from repro.kernels.decode_attention.ops import gqa_decode
    from repro.kernels.decode_attention.ref import decode_attention_ref
    q = _r(B, 1, H, D)
    k = _r(B, S, KV, D)
    v = _r(B, S, KV, D)
    kv_len = jnp.asarray(rng.integers(1, S, B).astype(np.int32))
    out = gqa_decode(q, k, v, kv_len, bk=bk)
    G = H // KV
    ref = decode_attention_ref(q[:, 0].reshape(B, KV, G, D),
                               jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2),
                               kv_len).reshape(B, 1, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# ---------------------------------------------------------------------------
# mamba2 SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("Bz,L,H,P,N,chunk", [
    (2, 256, 4, 64, 64, 64),
    (1, 512, 2, 128, 32, 128),
    (2, 128, 8, 32, 16, 32),
])
def test_mamba2_ssd(Bz, L, H, P, N, chunk):
    from repro.kernels.mamba2_scan.ops import mamba2_ssd
    from repro.kernels.mamba2_scan.ref import ssd_scan_ref
    x = _r(Bz, L, H, P)
    dt = jnp.abs(_r(Bz, L, H, scale=0.1))
    A = -jnp.abs(_r(H))
    B = _r(Bz, L, N, scale=0.3)
    C = _r(Bz, L, N, scale=0.3)
    D = _r(H)
    h0 = _r(Bz, H, N, P, scale=0.1)
    y, hT = mamba2_ssd(x, dt, A, B, C, D, h0, chunk=chunk)
    xf = x.transpose(0, 2, 1, 3).reshape(Bz * H, L, P)
    dtf = dt.transpose(0, 2, 1).reshape(Bz * H, L)
    Bf = jnp.broadcast_to(B[:, None], (Bz, H, L, N)).reshape(Bz * H, L, N)
    Cf = jnp.broadcast_to(C[:, None], (Bz, H, L, N)).reshape(Bz * H, L, N)
    yr, hTr = ssd_scan_ref(xf, dtf, jnp.tile(A, Bz), Bf, Cf,
                           h0.reshape(Bz * H, N, P))
    yr = yr.reshape(Bz, H, L, P).transpose(0, 2, 1, 3) + x * D[None, None, :, None]
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=5e-4)
    np.testing.assert_allclose(np.asarray(hT.reshape(Bz * H, N, P)),
                               np.asarray(hTr), atol=5e-4)


# ---------------------------------------------------------------------------
# rwkv6 WKV scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,L,H,D,chunk", [
    (2, 128, 4, 64, 32),
    (1, 256, 2, 32, 64),
    (2, 96, 8, 16, 16),
])
def test_wkv6(B, L, H, D, chunk):
    from repro.kernels.rwkv6_scan.ops import wkv6
    from repro.kernels.rwkv6_scan.ref import wkv6_scan_ref
    r = _r(B, L, H, D)
    k = _r(B, L, H, D, scale=0.3)
    v = _r(B, L, H, D)
    logw = -jnp.abs(_r(B, L, H, D, scale=0.5)) - 0.05
    u = _r(H, D, scale=0.2)
    s0 = _r(B, H, D, D, scale=0.1)
    y, sT = wkv6(r, k, v, logw, u, s0, chunk=chunk)

    def flat(a):
        return a.transpose(0, 2, 1, 3).reshape(B * H, L, D)
    yr, sTr = wkv6_scan_ref(flat(r), flat(k), flat(v), flat(logw),
                            jnp.tile(u, (B, 1)), s0.reshape(B * H, D, D))
    yr = yr.reshape(B, H, L, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-3)
    np.testing.assert_allclose(np.asarray(sT.reshape(B * H, D, D)),
                               np.asarray(sTr), atol=1e-3)


# ---------------------------------------------------------------------------
# page gather/scatter (the REAP kernel)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_pages,page_elems,n,dtype", [
    (64, 300, 17, np.float32),
    (128, 512, 128, np.float32),
    (32, 128, 5, np.int32),
])
def test_page_gather_scatter(n_pages, page_elems, n, dtype):
    from repro.kernels.page_gather.ops import gather_pages, scatter_pages
    from repro.kernels.page_gather.ref import page_gather_ref
    if dtype == np.int32:
        table = jnp.asarray(rng.integers(0, 1000, (n_pages, page_elems), dtype))
    else:
        table = _r(n_pages, page_elems)
    idx = jnp.asarray(rng.permutation(n_pages)[:n].astype(np.int32))
    ws = gather_pages(table, idx)
    np.testing.assert_array_equal(np.asarray(ws),
                                  np.asarray(page_gather_ref(table, idx)))
    dest = jnp.zeros_like(table)
    out = scatter_pages(ws, idx, dest)
    ref = np.zeros_like(np.asarray(table))
    ref[np.asarray(idx)] = np.asarray(ws)
    np.testing.assert_array_equal(np.asarray(out), ref)


# ---------------------------------------------------------------------------
# property tests: chunked == recurrent for any chunk split
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(L=st.integers(2, 64), chunk=st.integers(1, 64), seed=st.integers(0, 2**16))
def test_wkv6_chunk_invariance(L, chunk, seed):
    """The chunked WKV6 evaluation must be invariant to the chunk size."""
    from repro.models.rwkv6 import wkv6_chunked
    r_ = np.random.default_rng(seed)
    B, H, D = 1, 2, 8
    r = jnp.asarray(r_.standard_normal((B, L, H, D)).astype(np.float32))
    k = jnp.asarray(r_.standard_normal((B, L, H, D)).astype(np.float32) * 0.3)
    v = jnp.asarray(r_.standard_normal((B, L, H, D)).astype(np.float32))
    logw = jnp.asarray(-np.abs(r_.standard_normal((B, L, H, D))).astype(np.float32) - 0.02)
    u = jnp.asarray(r_.standard_normal((H, D)).astype(np.float32) * 0.1)
    s0 = jnp.zeros((B, H, D, D), jnp.float32)
    y1, s1 = wkv6_chunked(r, k, v, logw, u, s0, chunk=chunk)
    y2, s2 = wkv6_chunked(r, k, v, logw, u, s0, chunk=L)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(L=st.integers(2, 64), chunk=st.integers(1, 64), seed=st.integers(0, 2**16))
def test_ssd_chunk_invariance(L, chunk, seed):
    from repro.models.mamba2 import ssd_chunked
    r_ = np.random.default_rng(seed)
    B, H, P, N = 1, 2, 8, 4
    xh = jnp.asarray(r_.standard_normal((B, L, H, P)).astype(np.float32))
    dt = jnp.asarray(np.abs(r_.standard_normal((B, L, H))).astype(np.float32) * 0.2)
    A = jnp.asarray(-np.abs(r_.standard_normal(H)).astype(np.float32))
    Bm = jnp.asarray(r_.standard_normal((B, L, N)).astype(np.float32) * 0.3)
    Cm = jnp.asarray(r_.standard_normal((B, L, N)).astype(np.float32) * 0.3)
    D = jnp.zeros(H, jnp.float32)
    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    y1, s1 = ssd_chunked(xh, dt, A, Bm, Cm, D, h0, chunk=chunk)
    y2, s2 = ssd_chunked(xh, dt, A, Bm, Cm, D, h0, chunk=L)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4)
