"""Telemetry layer (src/repro/telemetry/): registry semantics, cold-start
trace spans, the stats snapshotter on a fake clock (no sleeps — REP004
thread shutdown is the only wall-clock moment), the canonical stat-key
schema, forecast-profile persistence round trips, and the bench trend
gate (scripts/bench_compare.py --history).
"""
import importlib.util
import json
import os
import threading

import pytest
from fakeclock import FakeClock

from repro.telemetry import (LEGACY_ALIASES, SAMPLE_KEYS, MetricsRegistry,
                             StatsSnapshotter, canonicalize)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# -- registry ------------------------------------------------------------

def test_counter_gauge_histogram_roundtrip():
    reg = MetricsRegistry()
    reg.inc("a.hits")
    reg.inc("a.hits", 4)
    reg.set_gauge("a.depth", 3.5)
    for v in (0.001, 0.002, 0.004, 0.1):
        reg.observe("a.lat_s", v)
    snap = reg.collect()
    assert snap["enabled"] is True
    assert snap["counters"]["a.hits"] == 5
    assert snap["gauges"]["a.depth"] == 3.5
    h = snap["histograms"]["a.lat_s"]
    assert h["count"] == 4
    assert h["sum"] == pytest.approx(0.107)
    assert h["min"] == 0.001 and h["max"] == 0.1
    assert sum(h["buckets"]) == 4


def test_histogram_percentile_bucket_resolution():
    reg = MetricsRegistry()
    h = reg.histogram("lat_s")
    for _ in range(99):
        h.observe(0.001)
    h.observe(1.0)
    # p50 lands in the 0.001 bucket, p99.5+ in the 1.0 bucket
    assert reg.histogram("lat_s").percentile(50) < 0.01
    assert reg.histogram("lat_s").percentile(99.9) >= 0.5


def test_same_name_returns_same_instrument():
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    assert reg.histogram("y") is reg.histogram("y")


def test_disabled_registry_is_noop():
    reg = MetricsRegistry()
    reg.inc("hits", 2)
    reg.disable()
    reg.inc("hits", 100)
    reg.observe("lat_s", 1.0)
    reg.trace("cold_start").add("install", 0.0, 1.0)
    reg.enable()
    snap = reg.collect()
    assert snap["counters"]["hits"] == 2
    assert "lat_s" not in snap["histograms"]
    assert reg.traces("cold_start") == []


def test_reset_clears_everything():
    reg = MetricsRegistry()
    reg.inc("a")
    reg.observe("b", 1.0)
    t = reg.trace("cold_start")
    t.add("s", 0.0, 1.0)
    t.finish()
    reg.reset()
    snap = reg.collect()
    assert snap["counters"] == {} and snap["histograms"] == {}
    assert reg.traces() == []


# -- trace spans ---------------------------------------------------------

def test_trace_spans_record_and_ring_bound():
    reg = MetricsRegistry(trace_ring=4)
    for i in range(10):
        t = reg.trace("cold_start", base=f"fn{i}")
        t.add("load_vmm", 0.0, 0.010)
        t.add("install", 0.010, 0.020, batched=True)
        t.finish()
    traces = reg.traces("cold_start")
    assert len(traces) == 4                   # ring bound holds
    d = traces[-1].to_dict()
    assert d["kind"] == "cold_start"
    assert d["attrs"]["base"] == "fn9"
    names = [s["name"] for s in d["spans"]]
    assert names == ["load_vmm", "install"]
    assert d["spans"][1]["attrs"]["batched"] is True
    assert d["spans"][1]["duration_s"] == pytest.approx(0.020)


def test_unfinished_trace_not_listed():
    reg = MetricsRegistry()
    t = reg.trace("cold_start")
    t.add("s", 0.0, 1.0)
    assert reg.traces("cold_start") == []
    t.finish()
    assert len(reg.traces("cold_start")) == 1


# -- snapshotter ---------------------------------------------------------

def test_snapshotter_fakeclock_cadence():
    clock = FakeClock()
    reg = MetricsRegistry()
    snap = StatsSnapshotter(interval_s=1.0, clock=clock, registry=reg)
    snap.add_source("const", lambda: {"v": 1})
    assert snap.maybe_sample() is not None    # first sample always taken
    assert snap.maybe_sample() is None        # same instant: gated
    clock.advance(0.5)
    assert snap.maybe_sample() is None        # inside the interval
    clock.advance(0.5)
    assert snap.maybe_sample() is not None    # exactly one interval later
    assert snap.n_samples == 2


def test_snapshotter_schema_stability():
    clock = FakeClock()
    snap = StatsSnapshotter(clock=clock, registry=MetricsRegistry())
    snap.add_source("a", lambda: {"x": 1})
    snap.add_source("b", lambda: {"y": 2})
    for _ in range(5):
        rec = snap.sample()
        assert tuple(sorted(rec)) == tuple(sorted(SAMPLE_KEYS))
        assert set(rec["sources"]) == {"a", "b"}
        clock.advance(1.0)
    seqs = [r["seq"] for r in snap.samples()]
    assert seqs == sorted(seqs)


def test_snapshotter_ring_bound():
    clock = FakeClock()
    snap = StatsSnapshotter(ring=8, clock=clock, registry=MetricsRegistry())
    snap.add_source("a", lambda: {})
    for _ in range(30):
        snap.sample()
        clock.advance(1.0)
    assert len(snap.samples()) == 8
    assert snap.n_samples == 30


def test_snapshotter_failing_source_isolated():
    clock = FakeClock()
    snap = StatsSnapshotter(clock=clock, registry=MetricsRegistry())
    snap.add_source("good", lambda: {"v": 7})
    snap.add_source("bad", lambda: 1 / 0)
    rec = snap.sample()
    assert rec["sources"]["good"] == {"v": 7}
    assert "ZeroDivisionError" in rec["sources"]["bad"]["error"]
    assert rec["errors"] == 1


def test_snapshotter_jsonl_output(tmp_path):
    path = str(tmp_path / "telemetry" / "stream.jsonl")
    clock = FakeClock()
    reg = MetricsRegistry()
    reg.inc("hits", 3)
    snap = StatsSnapshotter(path=path, clock=clock, registry=reg)
    snap.add_source("registry", reg.collect)
    for _ in range(3):
        snap.sample()
        clock.advance(1.0)
    snap.close()                              # +1 final sample
    with open(path) as f:
        lines = [json.loads(line) for line in f if line.strip()]
    assert len(lines) == 4
    for rec in lines:
        assert tuple(sorted(rec)) == tuple(sorted(SAMPLE_KEYS))
        assert rec["sources"]["registry"]["counters"]["hits"] == 3


def test_snapshotter_thread_shutdown():
    """REP004: daemon thread, stop event, join — and close() is idempotent."""
    snap = StatsSnapshotter(interval_s=0.01, registry=MetricsRegistry())
    snap.add_source("a", lambda: {})
    snap.start()
    assert snap._thread is not None and snap._thread.daemon
    t = snap._thread
    snap.close()
    assert not t.is_alive()
    assert snap._thread is None
    snap.close()                              # second close: no-op


def test_snapshotter_concurrent_samples_consistent(tmp_path):
    path = str(tmp_path / "s.jsonl")
    snap = StatsSnapshotter(path=path, registry=MetricsRegistry())
    snap.add_source("a", lambda: {"v": 1})
    threads = [threading.Thread(target=snap.sample) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    snap.close()
    with open(path) as f:
        lines = [json.loads(line) for line in f if line.strip()]
    assert len(lines) == 9                    # 8 + close()'s final sample
    assert sorted(r["seq"] for r in lines) == list(range(9))


# -- schema / legacy aliases ---------------------------------------------

def test_canonicalize_renames_legacy_keys():
    raw = {"ws_hits": 3, "nested": [{"ws_cache_hit": 1}],
           "warm_counts": {"f": 2}, "untouched": 0}
    out = canonicalize(raw)
    assert out["ws_cache_hits"] == 3
    assert out["nested"][0]["ws_cache_hits"] == 1
    assert out["warm_instances"] == {"f": 2}
    assert out["untouched"] == 0
    assert "ws_hits" not in out


def test_canonicalize_canonical_key_wins_on_collision():
    out = canonicalize({"ws_hits": 1, "ws_cache_hits": 9})
    assert out["ws_cache_hits"] == 9


def test_legacy_aliases_map_into_schema():
    for legacy, canonical in LEGACY_ALIASES.items():
        assert legacy != canonical
        assert canonical not in LEGACY_ALIASES


# -- forecast persistence ------------------------------------------------

def _periodic_demand(clock, *, period=8.0, cycles=3):
    from repro.serving import ForecastConfig, ForecastDemand, PolicyConfig
    fcfg = ForecastConfig(bin_s=0.5, history_s=60.0, min_period_s=2.0,
                          max_period_s=30.0, lookahead_s=2.0,
                          period_hint_s=period)
    d = ForecastDemand(PolicyConfig(), fcfg, clock=clock)
    t0 = clock()
    for c in range(cycles):
        base = t0 + c * period
        d.observe([base + 0.1 * i for i in range(10)])  # one busy phase/cycle
        clock.advance(period)
    return d, fcfg


def test_forecast_demand_state_roundtrip():
    from repro.serving import ForecastDemand, PolicyConfig
    clock = FakeClock()
    d, fcfg = _periodic_demand(clock)
    state = d.export_state()
    assert state is not None
    assert state["period_s"] == pytest.approx(8.0)
    assert state["bin_s"] == pytest.approx(0.5)
    assert any(r > 0 for r in state["rates"])

    # fresh process, zero history: the seeded detector forecasts day one
    clock2 = FakeClock()
    d2 = ForecastDemand(PolicyConfig(), fcfg, clock=clock2)
    assert d2.seed_state(json.loads(json.dumps(state)))   # file round trip
    assert d2.detector.seeded
    period, conf = d2.detector.detect(clock2())
    assert period == pytest.approx(8.0)
    assert conf > 0
    assert not d2.forgettable(clock2())       # seeded entries survive sweeps


def test_forecast_seed_rejects_bin_mismatch():
    from repro.serving import (ForecastConfig, ForecastDemand, PolicyConfig)
    clock = FakeClock()
    d, _ = _periodic_demand(clock)
    state = d.export_state()
    other = ForecastDemand(PolicyConfig(),
                           ForecastConfig(bin_s=1.0), clock=clock)
    assert not other.seed_state(state)
    assert not other.detector.seeded


def test_aggregator_profile_roundtrip():
    from repro.cluster import DemandAggregator, DemandConfig
    from repro.serving import ForecastConfig

    class _StubCluster:
        store = None

        def alive_nodes(self):
            return []

    clock = FakeClock()
    fcfg = ForecastConfig(bin_s=0.5, history_s=60.0, min_period_s=2.0,
                          max_period_s=30.0, period_hint_s=8.0)
    agg = DemandAggregator(_StubCluster(),
                           DemandConfig(forecast=fcfg), clock=clock)
    t0 = clock()
    for c in range(3):
        agg.ingest({"fn_a": [t0 + c * 8.0 + 0.1 * i for i in range(10)]})
        clock.advance(8.0)
    profiles = agg.export_profiles()
    assert "fn_a" in profiles

    clock2 = FakeClock()
    agg2 = DemandAggregator(_StubCluster(),
                            DemandConfig(forecast=fcfg), clock=clock2)
    payload = json.loads(json.dumps({"version": 1, "profiles": profiles}))
    assert agg2.seed_profiles(payload["profiles"]) == 1
    assert agg2.demand["fn_a"].detector.seeded
    period, _ = agg2.demand["fn_a"].detector.detect(clock2())
    assert period == pytest.approx(8.0)


# -- bench trend gate ----------------------------------------------------

@pytest.fixture(scope="module")
def bench_compare():
    spec = importlib.util.spec_from_file_location(
        "bench_compare", os.path.join(ROOT, "scripts", "bench_compare.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _write_trajectory(path, series, direction="up"):
    with open(path, "w") as f:
        for v in series:
            f.write(json.dumps({"metrics": {"m": v},
                                "directions": {"m": direction}}) + "\n")


def test_history_fails_on_monotone_degradation(tmp_path, bench_compare):
    traj = str(tmp_path / "t.jsonl")
    _write_trajectory(traj, [1.0, 1.03, 1.06, 1.11])
    assert bench_compare.history_check(traj, window=4, trend_threshold=0.05)


def test_history_passes_on_flat_and_noisy(tmp_path, bench_compare):
    traj = str(tmp_path / "t.jsonl")
    _write_trajectory(traj, [1.0, 1.2, 0.9, 1.1])     # noisy, not monotone
    assert not bench_compare.history_check(traj, window=4)
    _write_trajectory(traj, [1.0, 1.01, 1.02, 1.03])  # monotone, tiny drift
    assert not bench_compare.history_check(traj, window=4,
                                           trend_threshold=0.05)


def test_history_direction_down_metric(tmp_path, bench_compare):
    traj = str(tmp_path / "t.jsonl")
    _write_trajectory(traj, [0.9, 0.8, 0.7, 0.6], direction="down")
    assert bench_compare.history_check(traj, window=4, trend_threshold=0.05)
    _write_trajectory(traj, [0.6, 0.7, 0.8, 0.9], direction="down")
    assert not bench_compare.history_check(traj, window=4)


def test_history_needs_full_window(tmp_path, bench_compare):
    traj = str(tmp_path / "t.jsonl")
    _write_trajectory(traj, [1.0, 2.0])
    assert not bench_compare.history_check(traj, window=4)


def test_committed_trajectory_passes(bench_compare):
    traj = os.path.join(ROOT, "benchmarks", "baselines", "trajectory.jsonl")
    assert os.path.exists(traj)
    assert not bench_compare.history_check(traj)


def test_history_append_collects_guarded_metrics(tmp_path, bench_compare):
    art_dir = str(tmp_path)
    with open(os.path.join(art_dir, "BENCH_scalability.json"), "w") as f:
        json.dump({"burst_ab": {"k8": {"batched": {"cold_e2e_p95_s": 0.08}}},
                   "overlap_ab": {}, "policy_ab": {}}, f)
    traj = str(tmp_path / "traj.jsonl")
    rec = bench_compare.history_append(traj, art_dir)
    assert rec is not None
    key = "BENCH_scalability.json:burst_ab.k8.batched.cold_e2e_p95_s"
    assert rec["metrics"][key] == pytest.approx(0.08)
    assert rec["directions"][key] == "up"
    assert len(bench_compare.load_trajectory(traj)) == 1
