"""§6.4: one-time record-phase overhead vs a vanilla cold invocation.

The paper: +15-87% on the first invocation (28% average), amortized by all
later prefetch-accelerated invocations.
"""
from __future__ import annotations

import os
import time

import numpy as np

from . import common


def run(functions=None, verbose=True):
    from repro.core import GuestMemoryFile, InstanceArena, run_invocation
    from repro.core.reap import drop_record, write_record
    from repro.core.snapshot import build_instance_snapshot
    from repro.core.executor import warm_executables

    fns = functions or common.bench_functions()
    store = common.ensure_store()
    rows, overheads = [], []
    for name, cfg in fns.items():
        base = os.path.join(store, name)
        if not os.path.exists(base + ".mem"):
            build_instance_snapshot(cfg, base)
        req = common.make_request(cfg, seed=1)
        warm_executables(cfg, req)

        common.drop_caches()
        gm = GuestMemoryFile.open(base)
        arena = InstanceArena(gm)
        t0 = time.perf_counter()
        run_invocation(cfg, arena, req)
        vanilla_s = time.perf_counter() - t0

        drop_record(base)
        common.drop_caches()
        arena2 = InstanceArena(GuestMemoryFile.open(base))
        t0 = time.perf_counter()
        run_invocation(cfg, arena2, req)
        write_record(base, arena2.stats.trace)   # trace + WS file write
        record_s = time.perf_counter() - t0
        ov = record_s / max(vanilla_s, 1e-9) - 1
        overheads.append(ov)
        rows.append((f"{name}.record_overhead", ov * 100,
                     f"vanilla={vanilla_s*1e3:.1f}ms record={record_s*1e3:.1f}ms"))
        if verbose:
            print(f"  {name:28s} +{ov*100:5.1f}%")
        arena.close()
        arena2.close()
    rows.append(("MEAN.record_overhead", float(np.mean(overheads)) * 100,
                 "paper=28%"))
    if verbose:
        print(f"  {'MEAN':28s} +{np.mean(overheads)*100:.1f}% (paper 28%)")
    common.write_rows("record_overhead", rows)
    return rows


if __name__ == "__main__":
    run()
