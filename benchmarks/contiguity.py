"""Fig. 3: contiguity of faulted guest-memory pages.

Average length of contiguous page runs in the fault trace -- the paper
finds 2-3 pages (up to ~5 for lr_training), which is why OS read-ahead
cannot help the lazy-paging baseline.
"""
from __future__ import annotations

import numpy as np

from . import common


def run_lengths(trace: list[int]) -> list[int]:
    if not trace:
        return [0]
    runs, cur = [], 1
    for a, b in zip(trace, trace[1:]):
        if b == a + 1:
            cur += 1
        else:
            runs.append(cur)
            cur = 1
    runs.append(cur)
    return runs


def run(functions=None, verbose=True):
    from repro.core import GuestMemoryFile, InstanceArena, run_invocation
    from repro.core.snapshot import build_instance_snapshot
    import os

    fns = functions or common.bench_functions()
    store = common.ensure_store()
    rows = []
    for name, cfg in fns.items():
        base = os.path.join(store, name)
        if not os.path.exists(base + ".mem"):
            build_instance_snapshot(cfg, base)
        gm = GuestMemoryFile.open(base)
        arena = InstanceArena(gm)
        run_invocation(cfg, arena, common.make_request(cfg, seed=1))
        runs = run_lengths(arena.stats.trace)
        mean_run = float(np.mean(runs))
        p90 = float(np.percentile(runs, 90))
        rows.append((f"{name}.contiguity", mean_run,
                     f"p90={p90:.0f} n_runs={len(runs)}"))
        if verbose:
            print(f"  {name:28s} mean_run={mean_run:6.1f} pages p90={p90:.0f}")
        arena.close()
    common.write_rows("contiguity", rows)
    return rows


if __name__ == "__main__":
    run()
