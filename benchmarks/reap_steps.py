"""Fig. 7: REAP optimization ladder on the smallest function.

  vanilla       -- serial 4 KB page faults (O_DIRECT)
  parallel_pfs  -- trace known, pages still scattered, parallel reads
  ws_file       -- contiguous WS file, buffered read (page cache dropped)
  reap          -- contiguous WS file, single O_DIRECT read

Reports the page-install time of each design point plus effective read
bandwidth (the paper: 43 -> 130 -> 275 -> 533 MB/s on its SSD).
"""
from __future__ import annotations

import os
import time

from . import common


def _cold(cfg, base, mode: str):
    from repro.core import (GuestMemoryFile, InstanceArena, ReapConfig,
                            run_invocation)
    from repro.core import reap as reap_mod

    gm = GuestMemoryFile.open(base)
    common.drop_caches()
    t0 = time.perf_counter()
    if mode == "vanilla":
        arena = InstanceArena(gm, o_direct=True)
        run_invocation(cfg, arena, common.make_request(cfg, seed=1))
        io_s = arena.stats.fault_seconds
        nbytes = arena.resident_bytes
    elif mode == "parallel_pfs":
        arena = InstanceArena(gm, o_direct=True)
        rc = ReapConfig(use_ws_file=False, parallel_faults=16)
        n, io_s = reap_mod.prefetch(arena, base, rc)
        run_invocation(cfg, arena, common.make_request(cfg, seed=1))
        io_s += arena.stats.fault_seconds
        nbytes = arena.resident_bytes
    elif mode == "ws_file":
        arena = InstanceArena(gm, o_direct=True)
        rc = ReapConfig(o_direct=False)  # buffered WS read (cold page cache)
        n, io_s = reap_mod.prefetch(arena, base, rc)
        run_invocation(cfg, arena, common.make_request(cfg, seed=1))
        io_s += arena.stats.fault_seconds
        nbytes = arena.resident_bytes
    else:  # reap
        arena = InstanceArena(gm, o_direct=True)
        rc = ReapConfig(o_direct=True)
        n, io_s = reap_mod.prefetch(arena, base, rc)
        run_invocation(cfg, arena, common.make_request(cfg, seed=1))
        io_s += arena.stats.fault_seconds
        nbytes = arena.resident_bytes
    total = time.perf_counter() - t0
    arena.close()
    return io_s, total, nbytes


def run(function: str = "olmo-1b", verbose=True):
    from repro.core import GuestMemoryFile, InstanceArena, run_invocation
    from repro.core.reap import write_record
    from repro.core.snapshot import build_instance_snapshot
    from repro.core.executor import warm_executables

    cfg = common.bench_functions()[function]
    store = common.ensure_store()
    base = os.path.join(store, function)
    if not os.path.exists(base + ".mem"):
        build_instance_snapshot(cfg, base)
    warm_executables(cfg, common.make_request(cfg, seed=1))
    # record once
    gm = GuestMemoryFile.open(base)
    arena = InstanceArena(gm)
    run_invocation(cfg, arena, common.make_request(cfg, seed=1))
    write_record(base, arena.stats.trace)
    arena.close()

    rows = []
    for mode in ("vanilla", "parallel_pfs", "ws_file", "reap"):
        io_s, total, nbytes = _cold(cfg, base, mode)
        bw = nbytes / max(io_s, 1e-9) / 1e6
        rows.append((f"{function}.{mode}.io", io_s * 1e6,
                     f"bw={bw:.0f}MB/s total={total*1e3:.1f}ms"))
        if verbose:
            print(f"  {mode:13s} io={io_s*1e3:7.1f}ms  bw={bw:7.0f}MB/s  "
                  f"end-to-end={total*1e3:7.1f}ms")
    common.write_rows("reap_steps", rows)
    return rows


if __name__ == "__main__":
    run()
