"""§7.1: REAP mispredictions -- prefetched-but-unused pages.

The fraction of prefetched pages an invocation with a *different* input
does not touch; the paper finds it tracks the unique-page fraction (3-39%)
and only costs bandwidth, never correctness.
"""
from __future__ import annotations

import os

import numpy as np

from . import common


def run(functions=None, verbose=True):
    from repro.core import (GuestMemoryFile, InstanceArena, ReapConfig,
                            run_invocation)
    from repro.core import reap as reap_mod
    from repro.core.snapshot import build_instance_snapshot

    fns = functions or common.bench_functions()
    store = common.ensure_store()
    rows = []
    for name, cfg in fns.items():
        base = os.path.join(store, name)
        if not os.path.exists(base + ".mem"):
            build_instance_snapshot(cfg, base)
        if not reap_mod.has_record(base):
            gm = GuestMemoryFile.open(base)
            ar = InstanceArena(gm)
            run_invocation(cfg, ar, common.make_request(cfg, seed=1))
            reap_mod.write_record(base, ar.stats.trace)
            ar.close()
        # prefetch, then serve a different input and see what was unused
        arena = InstanceArena(GuestMemoryFile.open(base))
        n_pref, _ = reap_mod.prefetch(arena, base, ReapConfig())
        pre_resident = arena.resident.copy()
        arena.stats.trace.clear()
        run_invocation(cfg, arena, common.make_request(cfg, seed=31337))
        used = set(arena.stats.trace)  # residual faults only
        # touched pages among prefetched: recompute by re-running the access
        # trace on a fresh arena
        arena2 = InstanceArena(GuestMemoryFile.open(base))
        run_invocation(cfg, arena2, common.make_request(cfg, seed=31337))
        needed = set(arena2.stats.trace)
        prefetched = set(int(i) for i in np.load(reap_mod.trace_path(base)))
        unused = len(prefetched - needed)
        frac = unused / max(len(prefetched), 1)
        residual = len(needed - prefetched)
        rows.append((f"{name}.mispredict_frac", frac * 100,
                     f"unused={unused}/{len(prefetched)} residual={residual}"))
        if verbose:
            print(f"  {name:28s} mispredicted={frac*100:5.1f}%  "
                  f"residual_faults={residual}")
        arena.close()
        arena2.close()
    common.write_rows("mispredict", rows)
    return rows


if __name__ == "__main__":
    run()
