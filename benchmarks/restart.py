"""(Beyond-paper) REAP-accelerated training restart.

A training checkpoint restore is REAP's ideal case: the working set is 100%
of the file and perfectly stable.  Compares page-by-page lazy restore (the
vanilla-snapshot baseline applied to restart) with the single-large-read
REAP restore -- fault-tolerance MTTR at cluster scale is dominated by
exactly this path.
"""
from __future__ import annotations

import os

from . import common


def run(function: str = "olmo-1b", verbose=True):
    import jax

    from repro.configs.base import reduce_for_bench
    from repro.configs import ARCHS
    from repro.launch import steps as steps_lib
    from repro.training import optimizer as opt_lib
    from repro.training.checkpoint import restore_checkpoint, save_checkpoint

    cfg = reduce_for_bench(ARCHS[function])
    params = steps_lib.init_params(cfg, jax.random.key(0))
    opt = opt_lib.OptConfig()
    opt_state = opt_lib.init_state(params, opt)
    wd = os.path.join(common.STORE, "restart_ckpt")
    os.makedirs(wd, exist_ok=True)
    base = save_checkpoint(os.path.join(wd, "ckpt"), params, opt_state, 123)

    rows = []
    for mode in ("lazy", "reap"):
        common.drop_caches()
        _, _, step, stats = restore_checkpoint(base, params, opt_state,
                                               mode=mode)
        assert step == 123
        bw = stats["bytes"] / max(stats["io_s"], 1e-9) / 1e6
        rows.append((f"restore.{mode}", stats["io_s"] * 1e6,
                     f"bytes={stats['bytes']/1e6:.0f}MB bw={bw:.0f}MB/s "
                     f"faults={stats['n_faults']}"))
        if verbose:
            print(f"  restore[{mode:4s}] {stats['io_s']*1e3:8.1f}ms  "
                  f"{bw:7.0f}MB/s  faults={stats['n_faults']}")
    common.write_rows("restart", rows)
    return rows


if __name__ == "__main__":
    run()
