"""Shared benchmark machinery.

The 10 assigned architectures (bench-reduced) ARE our FunctionBench
analogue (Table 1): a diverse suite of serverless ML functions with small
per-invocation compute and 10-100MB state.  Real disk I/O throughout;
``drop_caches`` gives true cold reads (O_DIRECT paths bypass the page cache
anyway, buffered paths get a genuine cold cache).
"""
from __future__ import annotations

import os

import jax

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
STORE = os.path.join(ROOT, ".bench_store")
RESULTS = os.path.join(ROOT, "results", "bench")

# functions with "large inputs" in the paper's sense (image/audio payloads
# or input-dependent expert routing -> lower page reuse, Fig. 5)
LARGE_INPUT = {"pixtral-12b", "seamless-m4t-medium", "deepseek-moe-16b",
               "llama4-maverick-400b-a17b"}


def bench_functions():
    from repro.configs import ARCHS
    from repro.configs.base import reduce_for_bench
    return {name: reduce_for_bench(cfg) for name, cfg in ARCHS.items()}


def make_request(cfg, seed: int, batch: int = 1, seq: int = 64):
    from repro.launch import steps
    return steps.make_batch(cfg, seq, batch, "train", jax.random.key(seed))


def drop_caches() -> None:
    try:
        os.sync()
        with open("/proc/sys/vm/drop_caches", "w") as f:
            f.write("3")
    except OSError:
        pass  # not privileged; O_DIRECT paths are still cache-free


def ensure_store(rebuild: bool = False) -> str:
    os.makedirs(STORE, exist_ok=True)
    return STORE


def write_rows(name: str, rows: list[tuple]) -> None:
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, name + ".csv"), "w") as f:
        f.write("name,us_per_call,derived\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")


def fmt_ms(s: float) -> str:
    return f"{s*1e3:8.1f}ms"
