"""Fig. 8: end-to-end cold-start, baseline snapshots vs REAP, all functions.

The paper's headline: REAP makes cold invocations 1.04-9.7x faster
(3.7x on average) and eliminates ~97% of page faults.
"""
from __future__ import annotations

import numpy as np

from . import common


def run(functions=None, verbose=True):
    from repro.core import ReapConfig
    from repro.serving import Orchestrator

    fns = functions or common.bench_functions()
    store = common.ensure_store()
    rows = []
    speedups, fault_elims = [], []

    vanilla = Orchestrator(store, mode="vanilla", reap=ReapConfig())
    reap = Orchestrator(store, mode="reap", reap=ReapConfig())
    for name, cfg in fns.items():
        req = common.make_request(cfg, seed=1)
        vanilla.register(name, cfg, warmup_batch=req)
        reap.register(name, cfg)
        reap.reset_records(name)

        common.drop_caches()
        _, base_r = vanilla.invoke(name, req, force_cold=True)
        vanilla.scale_to_zero(name)

        # REAP: record on first cold start, then measure the prefetch path
        _, rec = reap.invoke(name, req, force_cold=True)
        reap.scale_to_zero(name)
        common.drop_caches()
        req2 = common.make_request(cfg, seed=7)   # different input
        _, reap_r = reap.invoke(name, req2, force_cold=True)
        reap.scale_to_zero(name)

        speedup = base_r.total_s / max(reap_r.total_s, 1e-9)
        elim = 1 - reap_r.n_faults / max(base_r.n_faults, 1)
        speedups.append(speedup)
        fault_elims.append(elim)
        rows.append((f"{name}.baseline", base_r.total_s * 1e6,
                     f"faults={base_r.n_faults}"))
        rows.append((f"{name}.reap", reap_r.total_s * 1e6,
                     f"speedup={speedup:.2f}x faults={reap_r.n_faults} "
                     f"elim={elim*100:.1f}%"))
        if verbose:
            print(f"  {name:28s} baseline={base_r.total_s*1e3:7.1f}ms "
                  f"reap={reap_r.total_s*1e3:7.1f}ms  {speedup:4.2f}x  "
                  f"faults {base_r.n_faults}->{reap_r.n_faults}")
    gmean = float(np.exp(np.mean(np.log(np.maximum(speedups, 1e-9)))))
    rows.append(("MEAN.speedup", float(np.mean(speedups)),
                 f"gmean={gmean:.2f}x paper=3.7x"))
    rows.append(("MEAN.fault_elim", float(np.mean(fault_elims)) * 100,
                 "paper=97%"))
    if verbose:
        print(f"  {'MEAN':28s} speedup={np.mean(speedups):.2f}x "
              f"(gmean {gmean:.2f}x; paper 3.7x)  "
              f"fault-elim={np.mean(fault_elims)*100:.1f}% (paper 97%)")
    common.write_rows("functionbench", rows)
    return rows


if __name__ == "__main__":
    run()
