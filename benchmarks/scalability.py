"""Fig. 9: cold-start latency vs number of concurrently-arriving functions.

N independent functions cold-start at once; REAP should stay relatively
flat (one big read each, I/O overlaps across instances) while the baseline
degrades (serial 4 KB faults contend for the disk).  This container has a
single CPU core, so the reproduction target is the *shape* of the curves.
"""
from __future__ import annotations

import concurrent.futures as cf
import os
import time

from . import common

CONCURRENCY = (1, 2, 4, 8, 16)


def run(function: str = "olmo-1b", verbose=True):
    from repro.core import (GuestMemoryFile, InstanceArena, ReapConfig,
                            run_invocation)
    from repro.core import reap as reap_mod
    from repro.core.executor import warm_executables
    from repro.core.snapshot import build_instance_snapshot

    cfg = common.bench_functions()[function]
    store = common.ensure_store()
    warm_executables(cfg, common.make_request(cfg, seed=1))
    nmax = max(CONCURRENCY)
    bases = []
    for i in range(nmax):
        b = os.path.join(store, f"scale_{function}_{i}")
        if not os.path.exists(b + ".mem"):
            build_instance_snapshot(cfg, b, seed=i, include_boot=False)
        # record for REAP mode
        if not reap_mod.has_record(b):
            gm = GuestMemoryFile.open(b)
            ar = InstanceArena(gm)
            run_invocation(cfg, ar, common.make_request(cfg, seed=i))
            reap_mod.write_record(b, ar.stats.trace)
            ar.close()
        bases.append(b)

    def cold(base, mode, seed):
        gm = GuestMemoryFile.open(base)
        arena = InstanceArena(gm, o_direct=True)
        t0 = time.perf_counter()
        if mode == "reap":
            reap_mod.prefetch(arena, base, ReapConfig())
        run_invocation(cfg, arena, common.make_request(cfg, seed=seed))
        dt = time.perf_counter() - t0
        arena.close()
        return dt

    rows = []
    for mode in ("vanilla", "reap"):
        for n in CONCURRENCY:
            common.drop_caches()
            t0 = time.perf_counter()
            with cf.ThreadPoolExecutor(n) as ex:
                lats = list(ex.map(lambda i: cold(bases[i], mode, i), range(n)))
            wall = time.perf_counter() - t0
            mean = sum(lats) / n
            rows.append((f"{mode}.n{n}", mean * 1e6,
                         f"wall={wall*1e3:.0f}ms"))
            if verbose:
                print(f"  {mode:8s} n={n:2d}  mean={mean*1e3:7.1f}ms "
                      f"wall={wall*1e3:7.1f}ms")
    common.write_rows("scalability", rows)
    return rows


if __name__ == "__main__":
    run()
