"""Fig. 9: cold-start latency vs number of concurrently-arriving functions,
driven through the concurrent data plane (serving/router.py).

Two experiments per mode (vanilla | reap):

  * ``distinct`` — N independent functions cold-start at once (the paper's
    Fig. 9 shape): REAP stays relatively flat (one big read each, I/O
    overlaps across instances) while the baseline degrades (serial 4 KB
    faults contend for the disk).
  * ``shared``   — N concurrent cold-starts of the *same* function: with the
    shared WS page cache, N instances perform exactly one underlying
    WS-file read (the "How Low Can You Go?" redundant-restore-I/O point).

Each invocation routes through per-function queues + the worker pool, so
the emitted reports carry queueing delay as a first-class segment.

    PYTHONPATH=src python -m benchmarks.scalability [--quick] [--function f]
"""
from __future__ import annotations

import argparse
import time

from . import common

CONCURRENCY = (1, 2, 4, 8, 16)
QUICK_CONCURRENCY = (1, 4, 16)


def _fmt_row(label: str, reports, wall_s: float) -> tuple:
    from repro.serving import summarize
    s = summarize(reports)
    derived = (f"wall={wall_s*1e3:.0f}ms "
               f"queue_mean={s['queue_mean_s']*1e3:.1f}ms "
               f"queue_p95={s['queue_p95_s']*1e3:.1f}ms "
               f"e2e_p95={s['e2e_p95_s']*1e3:.1f}ms "
               f"ws_hits={s['ws_cache_hits']}")
    return (label, s["total_mean_s"] * 1e6, derived)


def run(function: str = "olmo-1b", *, quick: bool = False, verbose=True):
    from repro.configs import SMOKES
    from repro.core.reap import WS_CACHE
    from repro.serving import Orchestrator, Router, RouterConfig

    conc = QUICK_CONCURRENCY if quick else CONCURRENCY
    nmax = max(conc)
    cfg = SMOKES[function] if quick else common.bench_functions()[function]
    store = common.ensure_store()
    request = common.make_request(cfg, seed=1)

    rows = []
    for mode in ("vanilla", "reap"):
        orch = Orchestrator(store, mode=mode, warm_limit=0)
        prefix = "scaleq" if quick else "scale"
        names = [f"{prefix}_{function}_{i}" for i in range(nmax)]
        shared = f"{prefix}_{function}_shared"
        for i, name in enumerate(names):
            orch.register(name, cfg, seed=i,
                          warmup_batch=request if i == 0 else None)
        orch.register(shared, cfg, seed=nmax)
        if mode == "reap":
            # record phase: one invocation per function, then scale to zero
            for name in names + [shared]:
                orch.invoke(name, request)
                orch.scale_to_zero(name)

        for experiment in ("distinct", "shared"):
            for n in conc:
                common.drop_caches()
                WS_CACHE.clear()
                WS_CACHE.reset_stats()
                router = Router(orch, RouterConfig(
                    max_concurrency=n, max_instances_per_function=n))
                targets = (names[:n] if experiment == "distinct"
                           else [shared] * n)
                t0 = time.perf_counter()
                reports = [r for _, r in router.map(
                    [(t, request) for t in targets], force_cold=True)]
                wall = time.perf_counter() - t0
                router.close()
                for name in set(targets):
                    orch.scale_to_zero(name)
                label = f"{mode}.{experiment}.n{n}"
                rows.append(_fmt_row(label, reports, wall))
                if verbose:
                    mean = sum(r.total_s for r in reports) / n
                    q95 = sorted(r.queue_s for r in reports)[-1]
                    print(f"  {mode:8s} {experiment:9s} n={n:2d} "
                          f"mean={mean*1e3:7.1f}ms wall={wall*1e3:7.1f}ms "
                          f"queue_max={q95*1e3:6.1f}ms "
                          f"ws_reads={WS_CACHE.stats()['reads']}")
    common.write_rows("scalability", rows)
    return rows


def main(argv=None):
    from repro.configs import list_archs
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--function", default="olmo-1b")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run: smoke config, capped concurrency")
    args = ap.parse_args(argv)
    if args.function not in list_archs():
        ap.error(f"unknown --function {args.function!r}; "
                 f"known: {', '.join(list_archs())}")
    run(args.function, quick=args.quick)


if __name__ == "__main__":
    main()
