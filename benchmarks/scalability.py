"""Fig. 9: cold-start latency vs number of concurrently-arriving functions,
driven through the concurrent data plane (serving/router.py).

Two experiments per mode (vanilla | reap):

  * ``distinct`` — N independent functions cold-start at once (the paper's
    Fig. 9 shape): REAP stays relatively flat (one big read each, I/O
    overlaps across instances) while the baseline degrades (serial 4 KB
    faults contend for the disk).
  * ``shared``   — N concurrent cold-starts of the *same* function: with the
    shared WS page cache, N instances perform exactly one underlying
    WS-file read (the "How Low Can You Go?" redundant-restore-I/O point).

Plus a **provisioning-policy A/B** (``--policy``): replay the same Poisson
and diurnal traces against

  * ``reactive``  — PR 1's data plane: spawn-on-arrival, static keepalive
    swept by a background reaper (every cold start lands on an invocation);
  * ``adaptive``  — the SPES-style control plane (serving/policy.py):
    arrival-history-driven warm targets, off-path prewarming, adaptive
    keepalive;
  * ``forecast``  — adaptive + periodicity-aware demand (serving/
    forecast.py): the diurnal trace's phase-binned rate profile raises the
    warm target *ahead* of each ramp (seeded by the trace's period hint),
    instead of tracking it;

and report cold-start fraction + e2e p50/p95 per arm.

Plus a **burst-restore A/B**: a k-deep same-function cold burst replayed
with group restores off (``batch_restore_limit=1``: k pipelines, k
single-flight WS-cache waits, k per-page installs) and on (one staged
batch: one WS fetch, one fused gather pass, k vectorized installs —
core/restore.py), reporting WS reads/waits, install seconds and cold p95.

Plus an **overlapped-restore A/B**: the same k-deep burst with the install
stage split into an eager hot prefix + background tail (``overlap_install``)
vs the fully-resident PR 5 pipeline, reporting TTFB (cold e2e p95), TTFR
(wall time until every tail quiesced) and the tail-fault-wait breakdown.

``--quick`` also writes a ``BENCH_scalability.json`` artifact (uploaded by
CI) so the perf trajectory is tracked over time.

    PYTHONPATH=src python -m benchmarks.scalability [--quick] [--function f]
        [--policy {both,reactive,adaptive,forecast,off}]
        [--trace-file azure.csv]

``--trace-file`` replays a real Azure Functions 2019 invocations-per-minute
CSV (time-compressed onto the registered functions) as a third A/B trace.
"""
from __future__ import annotations

import argparse
import json
import os
import threading
import time

from . import common

CONCURRENCY = (1, 2, 4, 8, 16)
QUICK_CONCURRENCY = (1, 4, 16)
ARTIFACT = os.path.join(common.ROOT, "BENCH_scalability.json")


def _fmt_row(label: str, reports, wall_s: float) -> tuple:
    from repro.serving import summarize
    s = summarize(reports)
    derived = (f"wall={wall_s*1e3:.0f}ms "
               f"queue_mean={s['queue_mean_s']*1e3:.1f}ms "
               f"queue_p95={s['queue_p95_s']*1e3:.1f}ms "
               f"e2e_p95={s['e2e_p95_s']*1e3:.1f}ms "
               f"ws_cache_hits={s['ws_cache_hits']}")
    return (label, s["total_mean_s"] * 1e6, derived)


def run(function: str = "olmo-1b", *, quick: bool = False, verbose=True):
    from repro.configs import SMOKES
    from repro.core.reap import WS_CACHE
    from repro.serving import Orchestrator, Router, RouterConfig

    conc = QUICK_CONCURRENCY if quick else CONCURRENCY
    nmax = max(conc)
    cfg = SMOKES[function] if quick else common.bench_functions()[function]
    store = common.ensure_store()
    request = common.make_request(cfg, seed=1)

    rows = []
    for mode in ("vanilla", "reap"):
        orch = Orchestrator(store, mode=mode, warm_limit=0)
        prefix = "scaleq" if quick else "scale"
        names = [f"{prefix}_{function}_{i}" for i in range(nmax)]
        shared = f"{prefix}_{function}_shared"
        for i, name in enumerate(names):
            orch.register(name, cfg, seed=i,
                          warmup_batch=request if i == 0 else None)
        orch.register(shared, cfg, seed=nmax)
        if mode == "reap":
            # record phase: one invocation per function, then scale to zero
            for name in names + [shared]:
                orch.invoke(name, request)
                orch.scale_to_zero(name)

        for experiment in ("distinct", "shared"):
            for n in conc:
                common.drop_caches()
                WS_CACHE.clear()
                WS_CACHE.reset_stats()
                router = Router(orch, RouterConfig(
                    max_concurrency=n, max_instances_per_function=n))
                targets = (names[:n] if experiment == "distinct"
                           else [shared] * n)
                t0 = time.perf_counter()
                reports = [r for _, r in router.map(
                    [(t, request) for t in targets], force_cold=True)]
                wall = time.perf_counter() - t0
                router.close()
                for name in set(targets):
                    orch.scale_to_zero(name)
                label = f"{mode}.{experiment}.n{n}"
                rows.append(_fmt_row(label, reports, wall))
                if verbose:
                    mean = sum(r.total_s for r in reports) / n
                    q95 = sorted(r.queue_s for r in reports)[-1]
                    print(f"  {mode:8s} {experiment:9s} n={n:2d} "
                          f"mean={mean*1e3:7.1f}ms wall={wall*1e3:7.1f}ms "
                          f"queue_max={q95*1e3:6.1f}ms "
                          f"ws_reads={WS_CACHE.stats()['reads']}")
    common.write_rows("scalability", rows)
    return rows


def run_burst_ab(function: str = "olmo-1b", *, quick: bool = False,
                 verbose: bool = True) -> dict:
    """Batched vs unbatched group restores on a k-deep same-function burst.

    Both arms stage k cold invocations of one function on a paused router
    and release them at once.  The ``unbatched`` arm
    (``batch_restore_limit=1``) is the pre-group data plane: k pipelines,
    one single-flight WS read plus k-1 follower waits, k per-page install
    loops.  The ``batched`` arm restores the queue as one group — one WS
    cache transaction, one fused gather pass, k vectorized installs
    (core/restore.py).  Reported per arm: WS reads and cache transactions
    (``ws_waits``), install-stage seconds, and cold/e2e p95.
    """
    from repro.configs import SMOKES
    from repro.core.reap import WS_CACHE
    from repro.serving import (Orchestrator, Router, RouterConfig,
                               percentile, summarize)

    cfg = SMOKES[function] if quick else common.bench_functions()[function]
    store = common.ensure_store()
    request = common.make_request(cfg, seed=1)
    name = ("burstq" if quick else "burst") + f"_{function}"
    orch = Orchestrator(store, mode="reap", warm_limit=0)
    orch.register(name, cfg, warmup_batch=request)
    orch.invoke(name, request)           # record phase
    orch.scale_to_zero(name)

    depths = (8,) if quick else (4, 8, 16)
    out: dict = {}
    for k in depths:
        out[f"k{k}"] = {}
        for arm, limit in (("unbatched", 1), ("batched", k)):
            common.drop_caches()
            WS_CACHE.clear()
            WS_CACHE.reset_stats()
            orch.scale_to_zero(name)
            router = Router(orch, RouterConfig(
                max_concurrency=k, max_instances_per_function=k,
                batch_restore_limit=limit), start=False)
            invs = [router.submit(name, request, force_cold=True)
                    for _ in range(k)]
            t0 = time.perf_counter()
            router.start()
            reports = [inv.result(timeout=600)[1] for inv in invs]
            wall = time.perf_counter() - t0
            router.close()
            s = summarize(reports)
            ws = WS_CACHE.stats()
            cold_e2e = [r.e2e_s for r in reports if r.load_vmm_s > 0]
            out[f"k{k}"][arm] = {
                "k": k,
                "wall_s": round(wall, 6),
                "ws_reads": ws["reads"],
                "ws_waits": ws["hits"] + ws["misses"],
                "group_fetches": ws["group_fetches"],
                "cold": s["cold"],
                "batched": s["batched"],
                "install_mean_s": round(s["install_mean_s"], 6),
                "install_max_s": round(max(r.install_s for r in reports), 6),
                "e2e_p50_s": round(s["e2e_p50_s"], 6),
                "e2e_p95_s": round(s["e2e_p95_s"], 6),
                "cold_e2e_p95_s": round(percentile(cold_e2e, 95), 6),
            }
            if verbose:
                o = out[f"k{k}"][arm]
                print(f"  burst k={k:2d} {arm:9s} "
                      f"ws_reads={o['ws_reads']} ws_waits={o['ws_waits']} "
                      f"install_mean={o['install_mean_s']*1e3:6.2f}ms "
                      f"cold_e2e_p95={o['cold_e2e_p95_s']*1e3:7.1f}ms "
                      f"wall={o['wall_s']*1e3:7.1f}ms")
    orch.close()
    return out


def run_overlap_ab(function: str = "olmo-1b", *, quick: bool = False,
                   verbose: bool = True) -> dict:
    """Overlapped (hot prefix + background tail) vs fully-resident restore.

    Both arms replay the *same* k-deep same-function cold burst against the
    same recorded WS (identical store, identical staged router release), so
    the only difference is the restore pipeline's install contract:

      * ``resident`` — PR 5 behaviour (``overlap_install=False``): the whole
        fused WS block installs before the instance is returned, so time to
        first byte (TTFB) == time to fully resident (TTFR).
      * ``overlap``  — install the recorded hot prefix eagerly, return the
        instance, and let a background tail finish the WS; a fault on a
        not-yet-installed page blocks on the in-flight install (attributed
        to ``stage_seconds.tail_wait_s``, not to disk faults).

    Reported per arm: restore-path p95 (TTFB — how long the router waits
    before the instance can serve), cold e2e p95, wall time to all
    responses, wall time until every tail quiesced (TTFR), and the
    tail-fault-wait breakdown.  The overlap arm trades a longer TTFR for a
    shorter TTFB.
    """
    from repro.configs import SMOKES
    from repro.core.reap import WS_CACHE
    from repro.serving import (Orchestrator, Router, RouterConfig,
                               ServeConfig, percentile, summarize)

    cfg = SMOKES[function] if quick else common.bench_functions()[function]
    store = common.ensure_store()
    request = common.make_request(cfg, seed=1)
    name = ("ovlq" if quick else "ovl") + f"_{function}"

    # record phase: shared by both arms (same store, same function name)
    rec_orch = Orchestrator(store, ServeConfig(overlap_install=False,
                                               warm_limit=0))
    rec_orch.register(name, cfg, warmup_batch=request)
    rec_orch.invoke(name, request)
    rec_orch.scale_to_zero(name)
    rec_orch.close()

    k = 8
    out: dict = {"k": k}
    for arm, overlap in (("resident", False), ("overlap", True)):
        common.drop_caches()
        WS_CACHE.clear()
        WS_CACHE.reset_stats()
        orch = Orchestrator(store, ServeConfig(overlap_install=overlap,
                                               warm_limit=0))
        orch.register(name, cfg)
        router = Router(orch, RouterConfig(
            max_concurrency=k, max_instances_per_function=k,
            batch_restore_limit=k), start=False)
        invs = [router.submit(name, request, force_cold=True)
                for _ in range(k)]
        t0 = time.perf_counter()
        router.start()
        reports = [inv.result(timeout=600)[1] for inv in invs]
        ttfb_wall = time.perf_counter() - t0
        orch.tail_quiesce(timeout=600)
        ttfr_wall = time.perf_counter() - t0
        router.close()
        s = summarize(reports)
        tails = orch.tail_stats()
        cold = [r for r in reports if r.load_vmm_s > 0]
        cold_e2e = [r.e2e_s for r in cold]
        # Restore-path TTFB: how long the router waited before the instance
        # could take its invocation (load VMM + connect + eager WS
        # fetch+install).  e2e adds the request's own compute, which is
        # identical in both arms and dominated by CPU contention at k=8.
        restore = [r.load_vmm_s + r.connection_s + r.prefetch_s
                   for r in cold]
        out[arm] = {
            "cold": s["cold"],
            "cold_restore_p95_s": round(percentile(restore, 95), 6),
            "cold_e2e_p95_s": round(percentile(cold_e2e, 95), 6),
            "ttfb_wall_s": round(ttfb_wall, 6),
            "ttfr_wall_s": round(ttfr_wall, 6),
            "tails_spawned": tails["tracked"],
            "tails_demoted": tails["demoted"],
            "tail_waits": s["tail_waits"],
            "stage_seconds": {key: round(v, 6)
                              for key, v in s["stage_seconds"].items()},
        }
        orch.scale_to_zero(name)
        orch.close()
        if verbose:
            o = out[arm]
            print(f"  overlap k={k} {arm:9s} "
                  f"restore_p95={o['cold_restore_p95_s']*1e3:7.1f}ms "
                  f"cold_e2e_p95={o['cold_e2e_p95_s']*1e3:7.1f}ms "
                  f"ttfr_wall={o['ttfr_wall_s']*1e3:7.1f}ms "
                  f"tail_waits={o['tail_waits']} "
                  f"tail_wait_s={o['stage_seconds']['tail_wait_s']*1e3:.2f}ms")
    base, ovl = out["resident"], out["overlap"]
    if ovl["cold_restore_p95_s"] > 0:
        out["ttfb_speedup"] = round(
            base["cold_restore_p95_s"] / ovl["cold_restore_p95_s"], 3)
        if verbose:
            print(f"  overlap k={k} TTFB speedup: {out['ttfb_speedup']:.2f}x "
                  f"(resident restore p95 "
                  f"{base['cold_restore_p95_s']*1e3:.1f}ms -> "
                  f"overlap {ovl['cold_restore_p95_s']*1e3:.1f}ms)")
    return out


def run_telemetry_overhead(function: str = "olmo-1b", *, quick: bool = False,
                           verbose: bool = True) -> dict:
    """Cold-burst A/B with the process-wide telemetry registry enabled vs
    disabled: the lock-light counters/spans (telemetry/registry.py) must
    cost <=2% on cold e2e p95, or observability is taxing the very path it
    observes.  Reported: per-arm cold e2e p95 and the enabled/disabled
    ratio (informational — CI's absolute/trend gates own pass/fail, this
    number is run-to-run noisy on shared runners)."""
    from repro.configs import SMOKES
    from repro.core.reap import WS_CACHE
    from repro.serving import (Orchestrator, Router, RouterConfig,
                               percentile)
    from repro.telemetry import TELEMETRY

    cfg = SMOKES[function] if quick else common.bench_functions()[function]
    store = common.ensure_store()
    request = common.make_request(cfg, seed=1)
    name = ("tlmq" if quick else "tlm") + f"_{function}"
    orch = Orchestrator(store, mode="reap", warm_limit=0)
    orch.register(name, cfg, warmup_batch=request)
    orch.invoke(name, request)           # record phase
    orch.scale_to_zero(name)

    k = 8
    out: dict = {"k": k}
    try:
        for arm, enabled in (("disabled", False), ("enabled", True)):
            (TELEMETRY.enable if enabled else TELEMETRY.disable)()
            common.drop_caches()
            WS_CACHE.clear()
            WS_CACHE.reset_stats()
            orch.scale_to_zero(name)
            router = Router(orch, RouterConfig(
                max_concurrency=k, max_instances_per_function=k,
                batch_restore_limit=k), start=False)
            invs = [router.submit(name, request, force_cold=True)
                    for _ in range(k)]
            router.start()
            reports = [inv.result(timeout=600)[1] for inv in invs]
            router.close()
            cold_e2e = [r.e2e_s for r in reports if r.load_vmm_s > 0]
            out[arm] = {"cold_e2e_p95_s": round(percentile(cold_e2e, 95), 6)}
            if verbose:
                print(f"  telemetry {arm:9s} "
                      f"cold_e2e_p95={out[arm]['cold_e2e_p95_s']*1e3:7.1f}ms")
    finally:
        TELEMETRY.enable()
    base = out["disabled"]["cold_e2e_p95_s"]
    if base > 0:
        out["overhead_ratio"] = round(
            out["enabled"]["cold_e2e_p95_s"] / base, 4)
        if verbose:
            print(f"  telemetry overhead: "
                  f"{(out['overhead_ratio']-1)*100:+.1f}% on cold e2e p95")
    orch.scale_to_zero(name)
    orch.close()
    return out


def _trace_metrics(results, label: str, verbose: bool,
                   skip_until_s: float = 0.0) -> dict:
    """Metrics over the steady-state window (events at ``t >=
    skip_until_s``): the deploy-time cold start of each function is paid by
    every policy once and would only dilute the A/B signal."""
    from repro.core.reap import WS_CACHE
    from repro.serving import summarize
    results = [(ev, rep) for ev, rep in results if ev.t >= skip_until_s]
    reports = [rep for _, rep in results if rep is not None]
    s = summarize(reports)
    ws = WS_CACHE.stats()
    lookups = ws["hits"] + ws["misses"]
    out = {
        "n_events": len(results),
        "served": s["n"],
        "rejected": len(results) - s["n"],
        "cold": s["cold"],
        "cold_fraction": round(s["cold_fraction"], 4),
        "prewarmed_served": s["prewarmed"],
        "e2e_p50_s": round(s["e2e_p50_s"], 6),
        "e2e_p95_s": round(s["e2e_p95_s"], 6),
        "queue_p95_s": round(s["queue_p95_s"], 6),
        "ws_cache_hit_rate": round(ws["hits"] / lookups, 4) if lookups else 0.0,
    }
    if verbose:
        print(f"  {label:22s} cold={out['cold']:3d}/{out['served']:3d} "
              f"({100*out['cold_fraction']:.1f}%) "
              f"prewarmed={out['prewarmed_served']:3d} "
              f"e2e_p50={out['e2e_p50_s']*1e3:7.1f}ms "
              f"e2e_p95={out['e2e_p95_s']*1e3:7.1f}ms")
    return out


def run_policy_ab(function: str = "olmo-1b", *, quick: bool = False,
                  arms: tuple[str, ...] = ("reactive", "adaptive",
                                           "forecast"),
                  trace_file: str | None = None,
                  verbose: bool = True) -> dict:
    """Replay identical traces under reactive / adaptive / forecast arms.

    The reactive arm is PR 1's serving stack verbatim: instances spawn on
    arrival and a background reaper sweeps the static keepalive.  The
    adaptive arm adds the prewarming control plane; the forecast arm
    additionally folds arrival history into a phase-binned periodicity
    profile (the diurnal trace spans two cycles, so cycle 1 teaches the
    profile and cycle 2's ramp is prewarmed *ahead* of its arrivals).  All
    arms replay the *same* trace objects, so the cold-start fraction and
    p95 e2e deltas are attributable to provisioning alone.
    """
    from repro.configs import SMOKES
    from repro.core.reap import WS_CACHE
    from repro.serving import (ForecastConfig, OpenLoopGenerator,
                               Orchestrator, PolicyConfig, PrewarmPolicy,
                               Router, RouterConfig, azure_trace,
                               diurnal_trace, poisson_trace)

    cfg = SMOKES[function] if quick else common.bench_functions()[function]
    store = common.ensure_store()
    request = common.make_request(cfg, seed=1)
    prefix = "abq" if quick else "ab"
    n_fns = 3 if quick else 4
    names = [f"{prefix}_{function}_{i}" for i in range(n_fns)]

    # Static keepalive chosen so the trace's quiet gaps actually expire it
    # (the benchmark compresses hours of diurnal traffic into seconds).
    orch = Orchestrator(store, mode="reap", keepalive_s=0.75, warm_limit=8,
                        prewarm_concurrency=1)
    for i, name in enumerate(names):
        orch.register(name, cfg, seed=i,
                      warmup_batch=request if i == 0 else None)
        orch.invoke(name, request)           # record phase
        orch.scale_to_zero(name)

    dur = 5.0 if quick else 8.0
    traces = {
        "poisson": poisson_trace(rate_rps=3.0 * n_fns, duration_s=dur,
                                 functions=names, seed=11),
        # two full diurnal cycles: the forecast arm learns the period from
        # cycle 1 and must anticipate cycle 2's ramp
        "diurnal": diurnal_trace(base_rps=1.0, peak_rps=4.0 * n_fns,
                                 period_s=dur / 2, duration_s=dur,
                                 functions=names, burst_rps=6.0 * n_fns,
                                 burst_every_s=dur / 3, burst_len_s=0.05,
                                 seed=13),
    }
    if trace_file is not None:
        # real production arrival shapes (Azure Functions 2019 CSV), the
        # busiest rows mapped onto this run's registered functions and the
        # day compressed into the benchmark window
        traces["azure"] = azure_trace(trace_file, functions=names,
                                      duration_s=dur, seed=17)

    out: dict = {}
    for tname, trace in traces.items():
        out[tname] = {}
        if verbose:
            print(f"\n-- policy A/B: {tname} trace "
                  f"({len(trace.events)} arrivals over {dur:.0f}s) --")
        for arm in arms:
            for name in names:                 # identical starting state
                orch.set_policy(name, warm_limit=None, keepalive_s=None,
                                min_warm=0)
                orch.scale_to_zero(name)
            common.drop_caches()
            WS_CACHE.clear()
            WS_CACHE.reset_stats()
            router = Router(orch, RouterConfig(max_concurrency=8,
                                               max_instances_per_function=8))
            policy = None
            stop_reaper = threading.Event()
            reaper = None
            if arm in ("adaptive", "forecast"):
                pcfg = PolicyConfig(
                    interval_s=0.05, window_s=4.0, headroom=2.0,
                    max_warm=8, min_keepalive_s=0.75)
                if arm == "forecast":
                    pcfg.forecast = True
                    pcfg.forecast_cfg = ForecastConfig(
                        bin_s=0.1, history_s=dur + 2.0,
                        min_period_s=0.5, max_period_s=dur,
                        lookahead_s=0.4,
                        period_hint_s=trace.period_hint_s)
                policy = PrewarmPolicy(orch, router, pcfg).start()
            else:
                def _sweep():                  # PR 1's static-keepalive reaper
                    while not stop_reaper.wait(0.1):
                        orch.reap_idle()
                reaper = threading.Thread(target=_sweep, daemon=True)
                reaper.start()
            results = OpenLoopGenerator(router, trace,
                                        make_batch=lambda ev: request).run()
            router.close()
            if policy is not None:
                policy.stop()
                orch.prewarm_quiesce()
            stop_reaper.set()
            if reaper is not None:
                reaper.join(timeout=5)
            out[tname][arm] = _trace_metrics(results, f"{tname}.{arm}",
                                             verbose,
                                             skip_until_s=0.25 * dur)
    for name in names:
        orch.set_policy(name, warm_limit=None, keepalive_s=None, min_warm=0)
    orch.close()
    return out


def write_artifact(fig9_rows, policy_ab: dict, burst_ab: dict,
                   overlap_ab: dict | None = None,
                   telemetry_overhead: dict | None = None) -> None:
    artifact = {
        "benchmark": "scalability",
        "fig9": [{"label": label, "us_per_call": us, "derived": derived}
                 for label, us, derived in fig9_rows],
        "policy_ab": policy_ab,
        "burst_ab": burst_ab,
        "overlap_ab": overlap_ab or {},
        "telemetry_overhead": telemetry_overhead or {},
    }
    with open(ARTIFACT, "w") as f:
        json.dump(artifact, f, indent=2)
    print(f"\nwrote {ARTIFACT}")


def main(argv=None):
    from repro.configs import list_archs
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--function", default="olmo-1b")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run: smoke config, capped concurrency")
    ap.add_argument("--policy", default="both",
                    choices=("both", "reactive", "adaptive", "forecast",
                             "off"),
                    help="which provisioning-policy A/B arms to replay")
    ap.add_argument("--trace-file", default=None, metavar="CSV",
                    help="Azure Functions 2019 invocations-per-minute CSV; "
                         "adds an 'azure' trace to the policy A/B")
    args = ap.parse_args(argv)
    if args.function not in list_archs():
        ap.error(f"unknown --function {args.function!r}; "
                 f"known: {', '.join(list_archs())}")
    rows = run(args.function, quick=args.quick)
    print("\n-- burst-restore A/B: batched vs unbatched group cold starts --")
    burst = run_burst_ab(args.function, quick=args.quick)
    print("\n-- overlapped-restore A/B: hot prefix + tail vs fully resident --")
    overlap = run_overlap_ab(args.function, quick=args.quick)
    print("\n-- telemetry overhead A/B: registry enabled vs disabled --")
    tlm = run_telemetry_overhead(args.function, quick=args.quick)
    ab: dict = {}
    if args.policy != "off":
        arms = (("reactive", "adaptive", "forecast")
                if args.policy == "both" else (args.policy,))
        ab = run_policy_ab(args.function, quick=args.quick, arms=arms,
                           trace_file=args.trace_file)
    if args.quick:
        write_artifact(rows, ab, burst, overlap, tlm)


if __name__ == "__main__":
    main()
