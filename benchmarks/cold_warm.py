"""Fig. 2: cold-start latency breakdown (vanilla snapshots) vs warm latency.

Per function: Load-VMM / connection-restore / function-processing for a
cold invocation from the guest memory file, against the warm (memory-
resident) invocation latency, plus the fraction of processing spent
serving page faults (the paper reports ~95% on average).
"""
from __future__ import annotations


from . import common


def run(functions=None, verbose=True):
    from repro.core import ReapConfig
    from repro.serving import Orchestrator

    fns = functions or common.bench_functions()
    orch = Orchestrator(common.ensure_store(), mode="vanilla",
                        reap=ReapConfig())
    rows = []
    for name, cfg in fns.items():
        req = common.make_request(cfg, seed=1)
        orch.register(name, cfg, warmup_batch=req)
        common.drop_caches()
        _, cold = orch.invoke(name, req, force_cold=True)
        # warm: same instance, re-invoke twice and take the second
        orch.invoke(name, req)
        _, warm = orch.invoke(name, req)
        fault_frac = cold.fault_s / max(cold.processing_s, 1e-9)
        rows.append((f"{name}.cold_total", cold.total_s * 1e6,
                     f"vmm={cold.load_vmm_s*1e3:.1f}ms"
                     f" conn={cold.connection_s*1e3:.2f}ms"
                     f" proc={cold.processing_s*1e3:.1f}ms"
                     f" fault_frac={fault_frac:.2f}"))
        rows.append((f"{name}.warm", warm.processing_s * 1e6,
                     f"cold/warm={cold.total_s/max(warm.processing_s,1e-9):.1f}x"))
        if verbose:
            print(f"  {name:28s} cold={cold.total_s*1e3:7.1f}ms "
                  f"(faults {cold.n_faults}, {fault_frac*100:.0f}% of proc) "
                  f"warm={warm.processing_s*1e3:6.1f}ms")
        orch.scale_to_zero(name)
    common.write_rows("cold_warm", rows)
    return rows


if __name__ == "__main__":
    run()
