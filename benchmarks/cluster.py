"""Fleet-scale serving benchmark: locality-aware vs random placement.

Scales the single-host scalability experiment to a simulated multi-host
fleet (src/repro/cluster/): N WorkerNodes behind a ClusterRouter, function
working sets sharded over a consistent-hash ring with per-node L1 WS
caches and a modeled inter-host transfer cost (snapstore.py).  Restores
resolve local-hit / remote-fetch / origin-disk, so *where* an invocation
lands now changes what its cold start pays.

Experiments (identical replayed traces across arms):

  * **Placement A/B** — ``locality`` (score warm instances, WS residency,
    shard ownership, load) vs ``random`` over the same Poisson (and, full
    mode, diurnal) trace.  Reported per arm over the steady-state window:
    cold count/fraction, p95 serving latency across all invocations (the
    cold-start-driven tail), per-cold-invocation p95s, e2e p95, remote
    fetches, origin reads, L1 hit rate, transfer MB.  The headline:
    locality placement needs fewer remote fetches, fewer cold starts, and
    keeps the cold-start tail out of p95 on a >=4-node fleet.
  * **Node-kill drill** — replay the trace and kill one node at 40% of the
    timeline: every accepted invocation must still resolve (served,
    rerouted, or counted rejected) with no hung futures.
  * **Demand-plane A/B** — replay a two-cycle diurnal ramp with per-node
    adaptive policies, with and without the fleet DemandAggregator
    (cluster/demand.py).  With it, every node's arrivals merge into
    per-function forecasts pushed to the *owner shards*, so when cycle 2's
    ramp spills the hot functions beyond their home node, the spillover
    placements land on already-prewarmed replicas (``prewarmed=True``)
    instead of paying cold starts.
  * **Dedup scale** — a 10x-function-count record wave (replica functions
    deployed from a small pool of runtime images, each with a few private
    pages) written once as legacy flat WS files and once through the
    content-addressed page store (core/pagestore.py).  Reported per arm:
    on-disk store bytes at 1x and 10x the image count (flat grows
    linearly, the chunk store sublinearly), re-record bytes after a small
    delta (flat rewrites everything, cas appends only changed chunks),
    and shard-tier ``transfer_bytes`` when a cold node pulls every WS
    from its owners (the manifest wire ships only chunks the requester's
    L1 is missing from *any* function; the flat arm reproduces the
    pre-manifest protocol where every byte ships).
  * **Transport A/B** — the real socket data plane (repro.transport)
    against the in-process modeled one: a bare PageServer/PageClient
    pull matrix (shm vs inline vs compressed, byte-parity checked) plus
    a 2-node ``build_fleet(transport="socket")`` fleet replaying the
    same force-cold waves as its inproc twin.  Gates: socket cold p95
    within 2x of inproc, compressed wire strictly below raw, logits
    byte-identical across the process boundary.

``--quick`` (CI) runs 4 nodes x 6 smoke functions and writes a
``BENCH_cluster.json`` artifact next to ``BENCH_scalability.json``.

    PYTHONPATH=src python -m benchmarks.cluster [--quick] [--function f]
        [--nodes N] [--trace-file azure.csv]
"""
from __future__ import annotations

import argparse
import json
import os
import threading

from . import common

ARTIFACT = os.path.join(common.ROOT, "BENCH_cluster.json")


def _build_cluster(store_dir, cfg, names, request, *, n_nodes, placement,
                   quick, demand=None, max_instances_per_function=2,
                   replication=1):
    from repro.cluster import ScheduleConfig, TransferModel, build_fleet
    from repro.serving import PolicyConfig, RouterConfig, ServeConfig
    from repro.telemetry import TelemetryConfig

    # ~1 GbE with sub-ms RPC: slow enough that a smoke-sized WS (a few MB)
    # pays a visible transfer cost, so tier placement shows up in p95
    serve = ServeConfig(
        keepalive_s=2.0, warm_limit=4,
        router=RouterConfig(
            max_concurrency=2,
            max_instances_per_function=max_instances_per_function,
            queue_depth=256, batch_restore_limit=8),
        policy=PolicyConfig(interval_s=0.05, window_s=2.0, max_warm=4,
                            min_keepalive_s=0.5),
        demand=demand,
        transfer=TransferModel(latency_s=1e-3, gbps=1.0),
        # CI quick mode feeds the control room: every arm appends fleet
        # time-series samples to results/telemetry/fleet.jsonl
        telemetry=TelemetryConfig() if quick else None)
    cluster = build_fleet(
        n_nodes, store_dir, config=serve,
        cfg=ScheduleConfig(placement=placement, seed=42),
        replication=replication,
        cache_capacity_bytes=256 << 20)
    for i, name in enumerate(names):
        cluster.register(name, cfg, seed=i,
                         warmup_batch=request if i == 0 else None)
    # record phase: one cold invocation per function writes its WS record
    # (placed by the scheduler; with no warm state this lands on owners)
    for name in names:
        cluster.invoke(name, request)
    cluster.drain(timeout=120)
    # start every arm identical: no warm instances, cold L1 caches except
    # the shard tier — rebalance() pulls each WS into its owner shards, so
    # both arms face the same warm store and differ only in placement
    for node in cluster.nodes.values():
        for name in names:
            node.orch.scale_to_zero(name)
        if node.ws_cache is not None:
            node.ws_cache.clear()
    cluster.rebalance()
    cluster.reset_stats()
    return cluster


def _arm_metrics(cluster, results, label, verbose, skip_until_s=0.0):
    """Latency/cold metrics over the steady-state window (events at ``t >=
    skip_until_s``): the initial all-cold deploy wave is identical in both
    arms and its CPU-contention noise would swamp the placement signal
    (store counters stay cumulative — the wave's fetch traffic *is*
    placement-attributable)."""
    from repro.serving import percentile, summarize
    windowed = [(ev, rep) for ev, rep in results if ev.t >= skip_until_s]
    reports = [rep for _, rep in windowed if rep is not None]
    s = summarize(reports)
    cold = [r for r in reports if r.load_vmm_s > 0]
    cold_lat = [r.total_s for r in cold]
    restore_lat = [r.load_vmm_s + r.connection_s + r.prefetch_s
                   for r in cold]
    st = cluster.store.stats()
    out = {
        "n_events": len(windowed),
        "served": s["n"],
        "rejected": len(windowed) - s["n"],
        "cold": s["cold"],
        "cold_fraction": round(s["cold_fraction"], 4),
        # the placement headline: serving latency (queueing excluded) at
        # p95 across *all* served invocations — cold starts push this tail
        # exactly when placement fails to keep arrivals near their state,
        # and it is stable run-to-run because the cold *fraction* is (the
        # per-cold-invocation percentiles below sample only a handful of
        # residual colds on the locality arm, i.e. CPU-contention noise)
        "p95_total_s": round(
            percentile([r.total_s for r in reports], 95), 6),
        "cold_p95_s": round(percentile(cold_lat, 95), 6),
        "cold_restore_p95_s": round(percentile(restore_lat, 95), 6),
        "e2e_p50_s": round(s["e2e_p50_s"], 6),
        "e2e_p95_s": round(s["e2e_p95_s"], 6),
        "prewarmed_served": s["prewarmed"],
        "remote_fetches": st["remote_fetches"],
        "origin_reads": st["origin_reads"],
        "local_hit_rate": round(st["local_hit_rate"], 4),
        "transfer_mb": round(st["transfer_bytes"] / 1e6, 3),
        "rerouted": cluster.n_rerouted,
        "placements": cluster.stats()["placements"],
        "stage_seconds": {k: round(v, 6)
                          for k, v in s["stage_seconds"].items()},
    }
    if verbose:
        print(f"  {label:22s} cold={out['cold']:3d}/{out['served']:3d} "
              f"p95_total={out['p95_total_s']*1e3:7.1f}ms "
              f"e2e_p95={out['e2e_p95_s']*1e3:7.1f}ms "
              f"remote={out['remote_fetches']:3d} "
              f"origin={out['origin_reads']:3d} "
              f"l1_hit={100*out['local_hit_rate']:.0f}%")
    return out


def run_placement_ab(function: str = "olmo-1b", *, quick: bool = False,
                     n_nodes: int = 4, trace_file: str | None = None,
                     verbose: bool = True) -> dict:
    """Replay identical traces under locality-aware vs random placement."""
    from repro.configs import SMOKES
    from repro.serving import (OpenLoopGenerator, azure_trace, diurnal_trace,
                               poisson_trace)

    cfg = SMOKES[function] if quick else common.bench_functions()[function]
    store_dir = common.ensure_store()
    request = common.make_request(cfg, seed=1)
    prefix = "clq" if quick else "cl"
    n_fns = 6 if quick else 10
    names = [f"{prefix}_{function}_{i}" for i in range(n_fns)]
    dur = 4.0 if quick else 8.0
    # zipf-ish mix: a couple of hot functions, a long-ish tail
    mix = {n: 1.0 / (i + 1) for i, n in enumerate(names)}
    traces = {"poisson": poisson_trace(rate_rps=4.0 * n_fns, duration_s=dur,
                                       functions=names, mix=mix, seed=21)}
    if not quick:
        traces["diurnal"] = diurnal_trace(
            base_rps=1.0, peak_rps=4.0 * n_fns, period_s=dur, duration_s=dur,
            functions=names, mix=mix, burst_rps=4.0 * n_fns,
            burst_every_s=dur / 3, burst_len_s=0.05, seed=23)
    if trace_file is not None:
        traces["azure"] = azure_trace(trace_file, functions=names,
                                      duration_s=dur, seed=27)

    out: dict = {"n_nodes": n_nodes, "n_functions": n_fns}
    for tname, trace in traces.items():
        out[tname] = {}
        if verbose:
            print(f"\n-- placement A/B: {tname} trace "
                  f"({len(trace.events)} arrivals over {dur:.0f}s, "
                  f"{n_nodes} nodes x {n_fns} fns) --")
        for placement in ("random", "locality"):
            common.drop_caches()
            cluster = _build_cluster(store_dir, cfg, names, request,
                                     n_nodes=n_nodes, placement=placement,
                                     quick=quick)
            results = OpenLoopGenerator(cluster, trace,
                                        make_batch=lambda ev: request).run()
            cluster.drain(timeout=120)
            metrics = _arm_metrics(cluster, results,
                                   f"{tname}.{placement}", verbose,
                                   skip_until_s=0.25 * dur)
            cluster.close()
            out[tname][placement] = metrics
    return out


def run_node_kill(function: str = "olmo-1b", *, quick: bool = False,
                  n_nodes: int = 4, verbose: bool = True) -> dict:
    """Kill a node mid-replay; every accepted invocation must resolve."""
    from repro.configs import SMOKES
    from repro.serving import OpenLoopGenerator, poisson_trace

    cfg = SMOKES[function] if quick else common.bench_functions()[function]
    store_dir = common.ensure_store()
    request = common.make_request(cfg, seed=1)
    prefix = "clq" if quick else "cl"
    n_fns = 6 if quick else 10
    names = [f"{prefix}_{function}_{i}" for i in range(n_fns)]
    dur = 4.0 if quick else 8.0
    # overdriven relative to the A/B (2x rate): queues must exist for the
    # kill to have something to reroute
    trace = poisson_trace(rate_rps=8.0 * n_fns, duration_s=dur,
                          functions=names, seed=31)

    cluster = _build_cluster(store_dir, cfg, names, request,
                             n_nodes=n_nodes, placement="locality",
                             quick=quick)
    # at 40% of the timeline, kill whichever node is busiest — waiting (up
    # to a short patience window) for a moment when some node actually has
    # queued work, so the kill reliably exercises the reroute path instead
    # of landing on a drained fleet
    killed = {}

    def _queued(node):
        return sum(node.router.stats()["queued"].values())

    def _kill():
        import time as _time
        deadline = _time.perf_counter() + 0.25 * dur
        while _time.perf_counter() < deadline:
            # a >=2-deep backlog outlives the close() race with the worker
            # pool, so some of it is still queued when the kill lands
            if any(_queued(n) >= 2 for n in cluster.alive_nodes()):
                break
            _time.sleep(0.002)
        victim = max(cluster.alive_nodes(),
                     key=lambda n: (_queued(n), n.load(),
                                    n.warm_count(names[0]), n.node_id))
        killed["victim"] = victim.node_id
        killed["rerouted_at_kill"] = cluster.kill_node(victim.node_id)

    timer = threading.Timer(0.4 * dur, _kill)
    timer.start()
    try:
        results = OpenLoopGenerator(cluster, trace,
                                    make_batch=lambda ev: request).run()
    finally:
        timer.cancel()
    cluster.drain(timeout=120)
    served = [rep for _, rep in results if rep is not None]
    victim = killed.get("victim", "<not killed>")
    out = {
        "victim": victim,
        "rerouted_at_kill": killed.get("rerouted_at_kill", 0),
        "n_events": len(trace.events),
        "resolved": len(results),
        "served": len(served),
        "rejected": len(results) - len(served),
        "rerouted": cluster.n_rerouted,
        "dead_owner_fallbacks":
            cluster.store.stats()["dead_owner_fallbacks"],
        "hung": len(trace.events) - len(results),   # must be 0
    }
    cluster.close()
    if verbose:
        print(f"\n-- node-kill drill: killed {victim} at t={0.4*dur:.1f}s --")
        print(f"  events={out['n_events']} served={out['served']} "
              f"rejected={out['rejected']} rerouted={out['rerouted']} "
              f"hung={out['hung']}")
    assert out["hung"] == 0, "node kill left unresolved invocations"
    return out


def _replay_with_placements(cluster, trace, request):
    """Open-loop replay that records *where* each event was served.
    Returns (event, report|None, node_id|None) triples — the per-node
    attribution the spillover analysis needs and the generic
    OpenLoopGenerator does not expose."""
    import time as _time

    from repro.serving import AdmissionError
    pending = []
    t0 = _time.perf_counter()
    for ev in trace.events:
        delay = ev.t - (_time.perf_counter() - t0)
        if delay > 0:
            _time.sleep(delay)
        try:
            pending.append((ev, cluster.submit(ev.function, request)))
        except AdmissionError:
            pending.append((ev, None))
    out = []
    for ev, cinv in pending:
        if cinv is None:
            out.append((ev, None, None))
            continue
        try:
            _, rep = cinv.result(timeout=120)
            out.append((ev, rep, cinv.node_ids[-1]))
        except AdmissionError:
            out.append((ev, None, None))
    return out


def _spillover_metrics(placed, names, *, ramp_at_s, label, verbose) -> dict:
    """Spillover = an event served on a node other than its function's
    *home* (the node that served it most before the ramp).  The question
    the demand plane answers: when cycle 2's ramp pushes a function past
    its home node, is the replica it lands on already warm?"""
    home: dict[str, str] = {}
    for name in names:
        counts: dict[str, int] = {}
        for ev, _rep, node in placed:
            if node is not None and ev.function == name and ev.t < ramp_at_s:
                counts[node] = counts.get(node, 0) + 1
        if counts:
            home[name] = max(sorted(counts), key=lambda n: counts[n])
    window = [(ev, rep, node) for ev, rep, node in placed
              if ev.t >= ramp_at_s and rep is not None]
    spill = [(ev, rep) for ev, rep, node in window
             if home.get(ev.function) not in (None, node)]
    served = [rep for _, rep, _ in window]
    out = {
        "post_ramp_served": len(served),
        "post_ramp_cold": sum(1 for r in served if r.load_vmm_s > 0),
        "post_ramp_prewarmed": sum(1 for r in served if r.prewarmed),
        "spillover_served": len(spill),
        "spillover_prewarmed": sum(1 for _, r in spill if r.prewarmed),
        "spillover_cold": sum(1 for _, r in spill if r.load_vmm_s > 0),
        "spillover_warm_fraction": round(
            sum(1 for _, r in spill if r.load_vmm_s == 0)
            / max(len(spill), 1), 4),
    }
    if verbose:
        print(f"  {label:22s} post-ramp served={out['post_ramp_served']:3d} "
              f"cold={out['post_ramp_cold']:3d} "
              f"spillover={out['spillover_served']:3d} "
              f"(prewarmed={out['spillover_prewarmed']}, "
              f"cold={out['spillover_cold']})")
    return out


def run_demand_ab(function: str = "olmo-1b", *, quick: bool = False,
                  n_nodes: int = 4, verbose: bool = True) -> dict:
    """Fleet demand plane A/B: per-node adaptive policies alone vs the
    same fleet with the DemandAggregator pushing owner-shard forecasts."""
    from repro.cluster import DemandConfig
    from repro.configs import SMOKES
    from repro.serving import ForecastConfig, diurnal_trace

    cfg = SMOKES[function] if quick else common.bench_functions()[function]
    store_dir = common.ensure_store()
    request = common.make_request(cfg, seed=1)
    prefix = "dmq" if quick else "dm"
    n_fns = 6 if quick else 10
    names = [f"{prefix}_{function}_{i}" for i in range(n_fns)]
    dur = 4.0 if quick else 8.0
    mix = {n: 1.0 / (i + 1) for i, n in enumerate(names)}
    # two diurnal cycles: cycle 1 teaches the fleet forecast, cycle 2's
    # ramp is what must land prewarmed.  The peak is overdriven (plus
    # bursts riding the sinusoid) so the hot functions' instantaneous
    # concurrency exceeds one node's single instance and placement *must*
    # spill — the question the A/B answers is what the spillover finds.
    trace = diurnal_trace(base_rps=1.0, peak_rps=15.0 * n_fns,
                          period_s=dur / 2, duration_s=dur,
                          functions=names, mix=mix,
                          burst_rps=10.0 * n_fns, burst_every_s=dur / 4,
                          burst_len_s=0.1, seed=33)

    out: dict = {"n_nodes": n_nodes, "n_functions": n_fns,
                 "ramp_at_s": dur / 2}
    if verbose:
        print(f"\n-- demand-plane A/B: diurnal x2 cycles "
              f"({len(trace.events)} arrivals over {dur:.0f}s, "
              f"{n_nodes} nodes x {n_fns} fns) --")
    for arm in ("off", "on"):
        demand = None
        if arm == "on":
            demand = DemandConfig(
                interval_s=0.05, hint_ttl_s=1.0, headroom=2.0,
                forecast=ForecastConfig(
                    bin_s=0.1, history_s=dur + 2.0, min_period_s=0.5,
                    max_period_s=dur, lookahead_s=0.4,
                    period_hint_s=trace.period_hint_s))
        common.drop_caches()
        # replication=2: each function has two owner shards, so the
        # aggregator prewarms *replicas* — the node the ramp spills onto
        # is warm before the spillover placement lands
        cluster = _build_cluster(store_dir, cfg, names, request,
                                 n_nodes=n_nodes, placement="locality",
                                 quick=quick, demand=demand,
                                 max_instances_per_function=1,
                                 replication=2)
        placed = _replay_with_placements(cluster, trace, request)
        cluster.drain(timeout=120)
        metrics = _spillover_metrics(placed, names, ramp_at_s=dur / 2,
                                     label=f"demand.{arm}", verbose=verbose)
        if arm == "on":
            agg_stats = cluster.demand_plane.stats()
            metrics["aggregator"] = {
                k: agg_stats[k] for k in ("steps", "pushes", "errors")}
        cluster.close()
        out[arm] = metrics
    return out


def run_dedup_scale(*, quick: bool = False, n_nodes: int = 4,
                    verbose: bool = True) -> dict:
    """Flat-file vs content-addressed store at 10x the A/B function count.

    The fleet shape the page store targets: many functions are *replicas*
    of a few runtime images (same interpreter/framework arena, a handful
    of function-private pages).  The wave fabricates that shape
    deterministically — ``n_variants`` page pools, ``10x`` functions
    assigned round-robin, ``uniq`` private pages each — and records every
    function twice, once per format, through the real
    :func:`repro.core.reap.write_record` path.

    Measured per arm: on-disk WS bytes after the first ``n_variants``
    records (1x) and after all (10x); bytes written by a small-delta
    re-record wave; and the shard tier's ``transfer_bytes`` when one cold
    node pulls every WS from its owners.  The cas arm's wire diffs the
    serving peer's chunk hashes against the requester L1's cross-function
    chunk index; the flat arm clears the requester L1 between fetches to
    reproduce the pre-manifest protocol (every fetch ships the full WS —
    exactly what ``transfer_bytes`` charged before manifests existed).
    Every reassembled WS is verified byte-identical to its source arena.
    """
    import shutil

    import numpy as np

    from repro.cluster.shardmap import ConsistentHashRing
    from repro.cluster.snapstore import ShardedSnapshotStore, TransferModel
    from repro.core import pagestore
    from repro.core.reap import (PAGE, WS_CACHE, ReapConfig, _read_ws,
                                 write_record, ws_path)

    n_variants = 6 if quick else 10      # distinct runtime images
    scale = 10                            # the 10x arm
    n_fns = n_variants * scale
    n_pages = 48 if quick else 128        # WS pages per function
    uniq = 4                              # function-private pages
    delta_pages = 3                       # pages changed by the re-record
    cfg = ReapConfig(o_direct=False)
    root = os.path.join(common.ensure_store(), "dedup_scale")
    if verbose:
        print(f"\n-- dedup scale: {n_fns} fns from {n_variants} images "
              f"({n_pages} pages each, {uniq} private) --")

    out: dict = {"n_functions": n_fns, "n_variants": n_variants,
                 "pages_per_fn": n_pages, "unique_pages_per_fn": uniq,
                 "arms": {}}
    for fmt in ("flat", "cas"):
        arm_dir = os.path.join(root, fmt)
        shutil.rmtree(arm_dir, ignore_errors=True)
        os.makedirs(arm_dir)
        # drop any registered store whose directory we just removed — a
        # cached instance would keep serving chunks from a deleted fd
        pagestore.reset_stores()
        WS_CACHE.clear()
        pools = [np.random.default_rng(1000 + v).integers(
                     0, 256, size=(n_pages, PAGE), dtype=np.uint8)
                 for v in range(n_variants)]

        # -- record wave -------------------------------------------------
        arenas: dict[str, np.ndarray] = {}
        bases: list[str] = []
        size_at_1x = 0.0

        def _ws_bytes():
            b = sum(os.path.getsize(ws_path(bb)) for bb in bases)
            if fmt == "cas":
                b += pagestore.get_store(arm_dir).stats()["store_bytes"]
            return b

        for i in range(n_fns):
            name = f"ds_{i:03d}"
            base = os.path.join(arm_dir, name)
            arena = pools[i % n_variants].copy()
            priv = np.random.default_rng(7000 + i).integers(
                0, 256, size=(uniq, PAGE), dtype=np.uint8)
            arena[n_pages - uniq:] = priv
            with open(base + ".mem", "wb") as f:
                f.write(arena.tobytes())
            trace = [int(p) for p in
                     np.random.default_rng(5000 + i).permutation(n_pages)]
            write_record(base, trace, fmt=fmt)
            arenas[base] = arena
            bases.append(base)
            if i + 1 == n_variants:
                size_at_1x = _ws_bytes()
        size_at_10x = _ws_bytes()

        # -- restore parity: every WS reassembles byte-identically -------
        parity = True
        for base in bases:
            pages, data = _read_ws(base, cfg)
            arena = arenas[base]
            for j, p in enumerate(pages):
                if data[j * PAGE:(j + 1) * PAGE] != arena[p].tobytes():
                    parity = False
        assert parity, f"{fmt}: reassembled WS differs from source arena"

        # -- delta re-record: change a few private pages of one image's
        #    replicas (flat rewrites the whole file; cas appends chunks)
        if fmt == "cas":
            writes_before = pagestore.get_store(arm_dir).stats()[
                "chunk_writes"]
        rerecord_bytes = 0
        for i in range(0, n_fns, n_variants):
            base = bases[i]
            arena = arenas[base]
            mod = np.random.default_rng(9000 + i).integers(
                0, 256, size=(delta_pages, PAGE), dtype=np.uint8)
            arena[n_pages - delta_pages:] = mod
            with open(base + ".mem", "r+b") as f:
                f.seek((n_pages - delta_pages) * PAGE)
                f.write(mod.tobytes())
            trace = [int(p) for p in
                     np.random.default_rng(5000 + i).permutation(n_pages)]
            write_record(base, trace, fmt=fmt)
            if fmt == "flat":
                rerecord_bytes += os.path.getsize(ws_path(base))
        if fmt == "cas":
            st = pagestore.get_store(arm_dir).stats()
            rerecord_bytes = (st["chunk_writes"] - writes_before) * PAGE

        # -- shard-tier transfer: a cold node pulls every WS from owners
        ring = ConsistentHashRing()
        store = ShardedSnapshotStore(
            ring, transfer=TransferModel(latency_s=1e-6, gbps=100.0),
            reap=cfg)
        for k in range(n_nodes):
            store.attach(f"node-{k}")
        requester = store.attach("requester")
        store.set_alive("requester", False)   # off-ring: never an owner
        for base in bases:
            store.warm_owners(base)
        store.reset_stats()
        for base in bases:
            if fmt == "flat":
                requester.clear()             # pre-manifest wire protocol
            requester.fetch(base, cfg)
        st = store.stats()
        arm = {
            "store_mb_1x": round(size_at_1x / 1e6, 3),
            "store_mb_10x": round(size_at_10x / 1e6, 3),
            "store_growth_10x": round(size_at_10x / max(size_at_1x, 1), 2),
            "rerecord_mb": round(rerecord_bytes / 1e6, 3),
            "remote_fetches": st["remote_fetches"],
            "transfer_bytes": st["transfer_bytes"],
            "transfer_mb": round(st["transfer_bytes"] / 1e6, 3),
            "dedup_bytes_saved_mb": round(st["dedup_bytes_saved"] / 1e6, 3),
            "restore_parity": parity,
        }
        if fmt == "cas":
            ps = pagestore.get_store(arm_dir).stats()
            arm["dedup_ratio"] = round(ps["dedup_ratio"], 3)
            arm["delta_chunks"] = ps["delta_chunks"]
            arm["dedup_hits"] = ps["dedup_hits"]
        store.close()
        out["arms"][fmt] = arm
        if verbose:
            extra = (f" dedup_ratio={arm['dedup_ratio']:.2f}"
                     if fmt == "cas" else "")
            print(f"  {fmt:5s} store {arm['store_mb_1x']:.2f}MB @1x -> "
                  f"{arm['store_mb_10x']:.2f}MB @10x "
                  f"(x{arm['store_growth_10x']:.1f}) "
                  f"rerecord={arm['rerecord_mb']:.2f}MB "
                  f"transfer={arm['transfer_mb']:.2f}MB{extra}")

    flat, cas = out["arms"]["flat"], out["arms"]["cas"]
    assert cas["transfer_bytes"] < flat["transfer_bytes"], (
        "manifest wire shipped no less than the flat protocol")
    assert cas["dedup_ratio"] > 1.5, (
        f"shared-image configs must dedup >1.5x, got {cas['dedup_ratio']}")
    assert cas["store_growth_10x"] < flat["store_growth_10x"], (
        "chunk store grew no slower than flat files at 10x")
    return out


def run_transport_ab(function: str = "olmo-1b", *, quick: bool = False,
                     verbose: bool = True) -> dict:
    """Real-transport A/B (PR 10): the socket data plane vs the modeled one.

    Two subsections:

    * **pull** — a bare PageServer/PageClient pair pulling fabricated
      low-entropy WS records (compressible, like real guest memory — an
      all-random WS would make any codec look useless).  Arms: ``inproc``
      (direct in-heap read + chunk-hash verify, the no-wire floor),
      ``socket_shm`` (descriptors on the socket, bytes through shared
      memory), ``socket_inline`` (raw chunks on the socket), and
      ``socket_compress`` (codec'd chunks on the socket).  Every arm's
      reassembled blob must be byte-identical to the source record, the
      shm arm's ``install_block`` view must match it too, and the
      compressed arm must put strictly fewer bytes on the wire than raw.
    * **e2e** — two 2-node fleets on the identical store and invocation
      sequence, ``build_fleet(transport="inproc")`` vs ``"socket"``.
      After a scale-to-zero + cache-clear + rebalance quiesce, replay
      ``reps`` concurrent force-cold waves; the socket fleet's cold p95
      must stay within 2x of the inproc fleet's, and the logits coming
      back over the process boundary must be byte-identical to the
      in-process ones.
    """
    import time

    import numpy as np

    from repro.core import pagestore
    from repro.core.reap import PAGE
    from repro.transport import PageClient, PageServer, shm_available

    out: dict = {}

    # -- pull: bare wire protocol over fabricated low-entropy records -----
    n_rec = 4 if quick else 8
    n_pages = 192 if quick else 512          # 768KB/2MB WS >> inline_max
    reps = 3 if quick else 5
    records: dict[str, tuple[list[int], bytes, list[str]]] = {}
    for i in range(n_rec):
        rng = np.random.default_rng(4200 + i)
        # 64-byte runs from a 4-symbol alphabet: entropy ~2 bits/byte at
        # the run level, far below the codec's skip threshold
        pages = np.repeat(rng.integers(0, 4, size=(n_pages, 64),
                                       dtype=np.uint8), PAGE // 64, axis=1)
        data = pages.tobytes()
        hashes = [pagestore.chunk_hash(data[j * PAGE:(j + 1) * PAGE])
                  for j in range(n_pages)]
        records[f"tp_rec_{i}"] = (list(range(n_pages)), data, hashes)
    serve = records.get

    class _CaptureArena:
        block = None

        def install_block(self, pages, block):
            self.block = np.array(block, copy=True)

    if verbose:
        print(f"\n-- transport A/B: pull ({n_rec} records x {n_pages} "
              f"pages x {reps} reps) --")
    sock_root = os.path.join(common.ensure_store(), "transport_sock")
    os.makedirs(sock_root, exist_ok=True)
    pull: dict = {}
    lat: dict[str, list[float]] = {}

    # the no-wire floor: read the record from the in-heap dict and pay
    # only the chunk-hash verification the client arms also pay
    lat["inproc"] = []
    for _ in range(reps):
        for base, (pages, data, hashes) in records.items():
            t0 = time.perf_counter()
            _p, blob, hs = serve(base)
            ok = all(pagestore.chunk_hash(blob[j * PAGE:(j + 1) * PAGE])
                     == hs[j] for j in range(len(hs)))
            lat["inproc"].append(time.perf_counter() - t0)
            assert ok
    pull["inproc"] = {"wire_bytes": 0, "shm_bytes": 0}

    arms = {"socket_shm": dict(use_shm=True, compress=False),
            "socket_inline": dict(use_shm=False, compress=False),
            "socket_compress": dict(use_shm=False, compress=True)}
    if not shm_available():
        arms.pop("socket_shm")
    for arm, knobs in arms.items():
        path = os.path.join(sock_root, f"{arm}.sock")
        server = PageServer(path, serve, **knobs)
        client = PageClient(path)
        lat[arm] = []
        parity = True
        try:
            for _ in range(reps):
                for base, (_pages, data, _hashes) in records.items():
                    t0 = time.perf_counter()
                    res = client.fetch(base)
                    lat[arm].append(time.perf_counter() - t0)
                    parity &= res is not None and res.assemble() == data
            install_parity = None
            if arm == "socket_shm":
                cap = _CaptureArena()
                base0 = next(iter(records))
                client.fetch_install(base0, cap)
                install_parity = (cap.block is not None
                                  and cap.block.tobytes()
                                  == records[base0][1])
            cs = client.stats.as_dict()
            pull[arm] = {
                "wire_bytes": cs["wire_tx_bytes"] + cs["wire_rx_bytes"],
                "shm_bytes": cs["shm_bytes"],
                "inline_bytes": cs["inline_bytes"],
                "compress_ratio": round(server.codec.as_dict()
                                        ["compress_ratio"], 3),
                "parity": parity,
            }
            if install_parity is not None:
                pull[arm]["install_parity"] = install_parity
        finally:
            client.close()
            server.close()
        assert parity, f"{arm}: reassembled WS differs from source record"
    from repro.serving import percentile
    logical = n_rec * n_pages * PAGE * reps
    for arm, samples in lat.items():
        pull.setdefault(arm, {})
        pull[arm]["pull_p50_s"] = round(percentile(samples, 50), 6)
        pull[arm]["pull_p95_s"] = round(percentile(samples, 95), 6)
        if verbose:
            w = pull[arm].get("wire_bytes", 0)
            print(f"  {arm:16s} p50={pull[arm]['pull_p50_s']*1e3:6.2f}ms "
                  f"p95={pull[arm]['pull_p95_s']*1e3:6.2f}ms "
                  f"wire={w/1e6:7.3f}MB "
                  f"shm={pull[arm].get('shm_bytes', 0)/1e6:7.3f}MB")
    pull["logical_bytes"] = logical
    assert pull["socket_compress"]["wire_bytes"] < \
        pull["socket_inline"]["wire_bytes"], (
            "codec'd stream put no fewer bytes on the wire than raw")
    if "socket_shm" in pull:
        assert pull["socket_shm"]["install_parity"], (
            "shm install_block view differs from the source record")
    out["pull"] = pull

    # -- e2e: 2-node fleets, identical store + trace, inproc vs socket ----
    from repro.cluster import ScheduleConfig, TransferModel, build_fleet
    from repro.configs import SMOKES
    from repro.serving import PolicyConfig, RouterConfig, ServeConfig

    cfg = SMOKES[function] if quick else common.bench_functions()[function]
    store_dir = common.ensure_store()
    request = common.make_request(cfg, seed=1)
    prefix = "tpq" if quick else "tp"
    n_fns = 4 if quick else 6
    waves = 3 if quick else 5
    names = [f"{prefix}_{function}_{i}" for i in range(n_fns)]
    if verbose:
        print(f"\n-- transport A/B: e2e (2 nodes x {n_fns} fns x "
              f"{waves} force-cold waves) --")
    e2e: dict = {}
    logits: dict[str, bytes] = {}
    for transport in ("inproc", "socket"):
        common.drop_caches()
        serve_cfg = ServeConfig(
            keepalive_s=2.0, warm_limit=4,
            router=RouterConfig(max_concurrency=2,
                                max_instances_per_function=2,
                                queue_depth=256, batch_restore_limit=8),
            policy=PolicyConfig(interval_s=0.05, window_s=2.0, max_warm=4,
                                min_keepalive_s=0.5),
            transfer=TransferModel(latency_s=1e-3, gbps=1.0),
            transport=transport, transport_compress=True)
        cluster = build_fleet(
            2, store_dir, config=serve_cfg,
            cfg=ScheduleConfig(placement="locality", seed=42),
            cache_capacity_bytes=256 << 20)
        try:
            for i, name in enumerate(names):
                cluster.register(name, cfg, seed=i,
                                 warmup_batch=request if i == 0 else None)
            for name in names:
                cluster.invoke(name, request)     # record wave: WS on disk
            cluster.drain(timeout=120)
            if hasattr(cluster, "clear_caches"):  # socket fleet
                for name in names:
                    cluster.scale_to_zero(name)
                cluster.clear_caches()
            else:
                for node in cluster.nodes.values():
                    for name in names:
                        node.orch.scale_to_zero(name)
                    if node.ws_cache is not None:
                        node.ws_cache.clear()
            cluster.rebalance()
            cluster.reset_stats()
            reports = []
            for w in range(waves):
                invs = [cluster.submit(name, request, force_cold=True)
                        for name in names]
                for j, inv in enumerate(invs):
                    got, rep = inv.result(timeout=180)
                    reports.append(rep)
                    if w == 0 and j == 0:
                        logits[transport] = np.asarray(got).tobytes()
            cold = [r.total_s for r in reports if r.load_vmm_s > 0]
            arm = {
                "served": len(reports),
                "cold": len(cold),
                "cold_p50_s": round(percentile(cold, 50), 6),
                "cold_p95_s": round(percentile(cold, 95), 6),
            }
            st = cluster.stats()
            if transport == "socket":
                tr = [ns.get("transport", {})
                      for ns in st.get("nodes", {}).values()]
                arm["wire_mb"] = round(sum(
                    t.get("wire_tx_bytes", 0) + t.get("wire_rx_bytes", 0)
                    for t in tr) / 1e6, 3)
                arm["remote_fetches"] = sum(
                    t.get("remote_fetches", 0) for t in tr)
                arm["origin_reads"] = sum(
                    t.get("origin_reads", 0) for t in tr)
            else:
                sst = cluster.store.stats()
                arm["remote_fetches"] = sst["remote_fetches"]
                arm["origin_reads"] = sst["origin_reads"]
        finally:
            cluster.close()
        e2e[transport] = arm
        if verbose:
            print(f"  {transport:8s} cold={arm['cold']:3d} "
                  f"cold_p50={arm['cold_p50_s']*1e3:7.1f}ms "
                  f"cold_p95={arm['cold_p95_s']*1e3:7.1f}ms "
                  f"remote={arm['remote_fetches']}")
    ratio = e2e["socket"]["cold_p95_s"] / max(e2e["inproc"]["cold_p95_s"],
                                              1e-9)
    e2e["socket_over_inproc_p95"] = round(ratio, 3)
    e2e["logits_parity"] = logits["inproc"] == logits["socket"]
    assert e2e["logits_parity"], (
        "socket-fleet logits differ from the in-process fleet's")
    assert ratio <= 2.0, (
        f"socket cold p95 is {ratio:.2f}x inproc (budget: 2.0x)")
    out["e2e"] = e2e
    if verbose:
        print(f"  socket/inproc cold p95 = {ratio:.2f}x "
              f"(budget 2.0x), logits parity = {e2e['logits_parity']}")
    return out


def write_artifact(ab: dict, kill: dict, demand: dict, dedup: dict,
                   transport: dict) -> None:
    with open(ARTIFACT, "w") as f:
        json.dump({"benchmark": "cluster", "placement_ab": ab,
                   "node_kill": kill, "demand_plane": demand,
                   "dedup_scale": dedup, "transport_ab": transport},
                  f, indent=2)
    print(f"\nwrote {ARTIFACT}")


def main(argv=None):
    from repro.configs import list_archs
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--function", default="olmo-1b")
    ap.add_argument("--nodes", type=int, default=4,
                    help="fleet size (>=4 for the A/B claim)")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run: smoke config, 1 trace, artifact")
    ap.add_argument("--trace-file", default=None, metavar="CSV",
                    help="Azure 2019 invocations-per-minute CSV as an "
                         "extra replayed trace")
    args = ap.parse_args(argv)
    if args.function not in list_archs():
        ap.error(f"unknown --function {args.function!r}; "
                 f"known: {', '.join(list_archs())}")
    ab = run_placement_ab(args.function, quick=args.quick,
                          n_nodes=args.nodes, trace_file=args.trace_file)
    kill = run_node_kill(args.function, quick=args.quick, n_nodes=args.nodes)
    demand = run_demand_ab(args.function, quick=args.quick,
                           n_nodes=args.nodes)
    dedup = run_dedup_scale(quick=args.quick, n_nodes=args.nodes)
    transport = run_transport_ab(args.function, quick=args.quick)
    for tname, arms in ab.items():
        if not isinstance(arms, dict) or "locality" not in arms:
            continue
        loc, rnd = arms["locality"], arms["random"]
        print(f"\n{tname}: locality remote={loc['remote_fetches']} "
              f"vs random remote={rnd['remote_fetches']}; "
              f"cold starts {loc['cold']} vs {rnd['cold']}; "
              f"p95 serve latency (the cold-start tail) "
              f"{loc['p95_total_s']*1e3:.1f}ms "
              f"vs {rnd['p95_total_s']*1e3:.1f}ms")
    on, off = demand["on"], demand["off"]
    print(f"\ndemand plane: post-ramp spillover hit prewarmed replicas "
          f"{on['spillover_prewarmed']}/{on['spillover_served']} with the "
          f"aggregator vs {off['spillover_prewarmed']}/"
          f"{off['spillover_served']} without; post-ramp cold "
          f"{on['post_ramp_cold']} vs {off['post_ramp_cold']}")
    flat, cas = dedup["arms"]["flat"], dedup["arms"]["cas"]
    print(f"\ndedup scale ({dedup['n_functions']} fns): store at 10x "
          f"{cas['store_mb_10x']:.1f}MB cas vs {flat['store_mb_10x']:.1f}MB "
          f"flat (dedup {cas['dedup_ratio']:.1f}x); cold-node transfer "
          f"{cas['transfer_mb']:.1f}MB vs {flat['transfer_mb']:.1f}MB")
    te = transport["e2e"]
    print(f"\ntransport: socket fleet cold p95 "
          f"{te['socket']['cold_p95_s']*1e3:.1f}ms vs inproc "
          f"{te['inproc']['cold_p95_s']*1e3:.1f}ms "
          f"({te['socket_over_inproc_p95']:.2f}x); compressed pull wire "
          f"{transport['pull']['socket_compress']['wire_bytes']/1e6:.2f}MB "
          f"vs raw "
          f"{transport['pull']['socket_inline']['wire_bytes']/1e6:.2f}MB")
    if args.quick:
        write_artifact(ab, kill, demand, dedup, transport)


if __name__ == "__main__":
    main()
