"""Benchmark driver: one section per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick] [--only <name>]``
prints ``name,us_per_call,derived`` CSV rows for every benchmark.
"""
from __future__ import annotations

import argparse
import time

SECTIONS = [
    ("cold_warm", "Fig 2: cold vs warm latency breakdown"),
    ("contiguity", "Fig 3: faulted-page contiguity"),
    ("footprint", "Fig 4: booted footprint vs working set"),
    ("reuse", "Fig 5: page reuse across inputs"),
    ("reap_steps", "Fig 7: REAP optimization ladder"),
    ("functionbench", "Fig 8: baseline vs REAP cold starts"),
    ("scalability", "Fig 9: concurrent cold starts"),
    ("record_overhead", "S6.4: record-phase overhead"),
    ("mispredict", "S7.1: mispredicted pages"),
    ("restart", "beyond-paper: REAP training restart"),
    ("roofline", "SRoofline: dry-run derived terms"),
]

QUICK_FUNCTIONS = ["olmo-1b", "qwen2-7b", "deepseek-moe-16b", "rwkv6-7b"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="subset of functions for a fast pass")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import common
    fns = None
    if args.quick:
        all_fns = common.bench_functions()
        fns = {k: all_fns[k] for k in QUICK_FUNCTIONS}

    all_rows: list[tuple] = []
    for name, title in SECTIONS:
        if args.only and name != args.only:
            continue
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        print(f"== {title} ==", flush=True)
        t0 = time.perf_counter()
        try:
            import inspect
            kwargs = {}
            if "functions" in inspect.signature(mod.run).parameters and fns:
                kwargs["functions"] = fns
            rows = mod.run(**kwargs)
            all_rows.extend(rows)
        except Exception as e:  # keep the harness going; report at the end
            import traceback
            traceback.print_exc()
            all_rows.append((f"{name}.FAILED", -1, str(e)[:80]))
        print(f"   ({time.perf_counter()-t0:.1f}s)", flush=True)

    print("\nname,us_per_call,derived")
    for r in all_rows:
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()
