"""§Roofline: aggregate the dry-run JSONs into the per-cell roofline table.

Reads results/dryrun/*.json (produced by ``python -m repro.launch.dryrun``)
and prints compute / memory / collective terms, the dominant bottleneck,
and the MODEL_FLOPS utilization bound for every (arch x shape x mesh) cell.
"""
from __future__ import annotations

import glob
import json
import os

from . import common

DRYRUN_DIR = os.path.join(common.ROOT, "results", "dryrun")


def load_cells(mesh: str | None = None) -> list[dict]:
    cells = []
    for fn in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(fn) as f:
            cells.append(json.load(f))
    if mesh:
        cells = [c for c in cells if c["mesh"] == mesh]
    return cells


def run(verbose=True):
    rows = []
    cells = load_cells()
    for c in cells:
        key = f"{c['arch']}.{c['shape']}.{c['mesh']}"
        if c["status"] == "skipped":
            rows.append((key, 0, "skipped: " + c["reason"][:40]))
            continue
        if c["status"] != "ok":
            rows.append((key, -1, "ERROR"))
            continue
        r = c["roofline"]
        rows.append((key, r["step_s"] * 1e6,
                     f"bottleneck={r['bottleneck']} "
                     f"comp={r['compute_s']*1e3:.1f}ms "
                     f"mem={r['memory_s']*1e3:.1f}ms "
                     f"coll={r['collective_s']*1e3:.1f}ms "
                     f"roofline_frac={r['roofline_fraction']:.3f} "
                     f"peak={c['peak_bytes_per_device']/1e9:.1f}GB"))
        if verbose:
            print(f"  {key:48s} {r['bottleneck']:10s} "
                  f"step={r['step_s']*1e3:9.1f}ms "
                  f"frac={r['roofline_fraction']:.3f}")
    common.write_rows("roofline", rows)
    return rows


if __name__ == "__main__":
    run()
