"""Fig. 5: guest-memory page reuse across invocations with different inputs.

Dense weights are fully stable; embedding rows and routed experts vary with
the input -- the paper's "unique pages" (>=97% identical for 7/10
functions; lower for large-input functions).
"""
from __future__ import annotations

import os

from . import common


def page_set(cfg, base, seed):
    from repro.core import GuestMemoryFile, InstanceArena, run_invocation
    gm = GuestMemoryFile.open(base)
    arena = InstanceArena(gm)
    run_invocation(cfg, arena, common.make_request(cfg, seed=seed))
    pages = set(arena.stats.trace)
    arena.close()
    return pages


def run(functions=None, verbose=True):
    from repro.core.snapshot import build_instance_snapshot

    fns = functions or common.bench_functions()
    store = common.ensure_store()
    rows = []
    for name, cfg in fns.items():
        base = os.path.join(store, name)
        if not os.path.exists(base + ".mem"):
            build_instance_snapshot(cfg, base)
        a = page_set(cfg, base, seed=1)
        b = page_set(cfg, base, seed=202)
        same = len(a & b)
        frac = same / max(len(b), 1)
        rows.append((f"{name}.reuse_frac", frac * 100,
                     f"same={same} uniq_b={len(b - a)} large_input="
                     f"{name in common.LARGE_INPUT}"))
        if verbose:
            print(f"  {name:28s} same={frac*100:5.1f}%  unique={len(b-a)}")
    common.write_rows("reuse", rows)
    return rows


if __name__ == "__main__":
    run()
