"""Fig. 4: booted-instance footprint vs snapshot-restore working set.

The booted image carries boot-only state (fp32 master weights + optimizer
moments -- the guest-OS/init analogue); an invocation from a snapshot only
touches the serving working set.  The paper reports a 61-96% reduction.
"""
from __future__ import annotations

import os

from . import common


def run(functions=None, verbose=True):
    from repro.core import GuestMemoryFile, InstanceArena, run_invocation
    from repro.core.snapshot import build_instance_snapshot, booted_footprint_bytes

    fns = functions or common.bench_functions()
    store = common.ensure_store()
    rows = []
    for name, cfg in fns.items():
        base = os.path.join(store, name)
        if not os.path.exists(base + ".mem"):
            build_instance_snapshot(cfg, base)
        booted = booted_footprint_bytes(cfg)
        gm = GuestMemoryFile.open(base)
        arena = InstanceArena(gm)
        run_invocation(cfg, arena, common.make_request(cfg, seed=1))
        ws = arena.resident_bytes
        rows.append((f"{name}.booted_mb", booted / 1e6, ""))
        rows.append((f"{name}.ws_mb", ws / 1e6,
                     f"reduction={100*(1-ws/booted):.0f}%"))
        if verbose:
            print(f"  {name:28s} booted={booted/1e6:7.1f}MB "
                  f"ws={ws/1e6:6.1f}MB  (-{100*(1-ws/booted):.0f}%)")
        arena.close()
    common.write_rows("footprint", rows)
    return rows


if __name__ == "__main__":
    run()
