"""Scenario: elastic re-shard restore.

A checkpoint written by one training topology is restored onto a DIFFERENT
mesh by reading exactly the per-shard byte ranges each host owns -- the
arena layout is mesh-agnostic, so scaling from N to M hosts is a restore,
not a re-write.

    PYTHONPATH=src python examples/elastic_restore.py
"""
import os
import sys
from types import SimpleNamespace

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import SMOKES  # noqa: E402
from repro.launch import steps  # noqa: E402
from repro.models import get_family  # noqa: E402
from repro.training import optimizer as opt_lib  # noqa: E402
from repro.training.checkpoint import (restore_for_mesh,  # noqa: E402
                                       save_checkpoint)


def main():
    cfg = SMOKES["qwen2-7b"]
    fam = get_family(cfg)
    params = steps.init_params(cfg, jax.random.key(0))
    state = opt_lib.init_state(params, opt_lib.OptConfig())
    base = save_checkpoint(".elastic/ckpt", params, state, 42)
    print(f"checkpoint written by the 'old' topology: {base}.mem")

    for n_hosts in (2, 4, 8):
        mesh = SimpleNamespace(shape={"data": n_hosts}, axis_names=("data",))
        restored = restore_for_mesh(base, fam.param_specs(cfg), mesh, {})
        ok = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)))
        print(f"  restore onto {n_hosts:2d}-host mesh: "
              f"{'bit-identical' if ok else 'MISMATCH'}")


if __name__ == "__main__":
    main()
