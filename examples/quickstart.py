"""Quickstart: deploy a serverless ML function, watch REAP slash its
cold-start, all through the public API.

    PYTHONPATH=src python examples/quickstart.py [--arch qwen2-7b]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.configs import ARCHS, SMOKES  # noqa: E402
from repro.core import ReapConfig  # noqa: E402
from repro.launch import steps  # noqa: E402
from repro.serving import Orchestrator  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=list(ARCHS))
    ap.add_argument("--store", default=".quickstart_store")
    args = ap.parse_args()

    cfg = SMOKES[args.arch]  # reduced same-family config (CPU-scale)
    request = steps.make_batch(cfg, seq=64, batch=1, kind="train",
                               key=jax.random.key(0))

    orch = Orchestrator(args.store, mode="reap", reap=ReapConfig())
    print(f"deploying {cfg.name} (builds the snapshot on first deploy)...")
    orch.register(args.arch, cfg, warmup_batch=request)

    print("\n1) first cold invocation (REAP record phase):")
    _, r = orch.invoke(args.arch, request, force_cold=True)
    print(f"   load_vmm={r.load_vmm_s*1e3:.1f}ms conn={r.connection_s*1e3:.2f}ms "
          f"processing={r.processing_s*1e3:.1f}ms  page_faults={r.n_faults}")

    print("2) warm invocation (instance stayed resident):")
    _, r = orch.invoke(args.arch, request)
    print(f"   processing={r.processing_s*1e3:.1f}ms  page_faults={r.n_faults}")

    orch.scale_to_zero(args.arch)
    print("3) cold again -- but now REAP prefetches the working set:")
    _, r = orch.invoke(args.arch, request, force_cold=True)
    print(f"   prefetch={r.prefetch_s*1e3:.1f}ms ({r.n_prefetched_pages} pages, "
          f"one O_DIRECT read) processing={r.processing_s*1e3:.1f}ms "
          f"page_faults={r.n_faults}")


if __name__ == "__main__":
    main()
