"""Scenario: end-to-end fault-tolerant training driver.

Trains a reduced model for a few hundred steps, gets preempted halfway,
restarts from the async checkpoint with a REAP single-read restore, and
verifies the loss trajectory is identical to an uninterrupted run.

    PYTHONPATH=src python examples/train_fault_tolerant.py [--steps 200]
"""
import argparse
import os
import shutil
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import SMOKES  # noqa: E402
from repro.data import synthesize_corpus  # noqa: E402
from repro.training import (OptConfig, SimulatedPreemption, Trainer,  # noqa: E402
                            TrainLoopConfig)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--workdir", default=".ft_train")
    args = ap.parse_args()

    cfg = SMOKES[args.arch]
    shutil.rmtree(args.workdir, ignore_errors=True)
    os.makedirs(args.workdir)
    corpus = synthesize_corpus(os.path.join(args.workdir, "corpus.bin"),
                               2_000_000, cfg.vocab)
    loop = TrainLoopConfig(total_steps=args.steps, checkpoint_every=25,
                           batch_size=8, seq_len=64, restore_mode="reap")
    opt = OptConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)

    print(f"training {cfg.name} for {args.steps} steps, "
          f"preempting at step {args.steps // 2}...")
    tr = Trainer(cfg, opt, loop, corpus, os.path.join(args.workdir, "ckpt"),
                 preempt_at=args.steps // 2)
    try:
        tr.run()
    except SimulatedPreemption as e:
        print(f"  !! node lost: {e}")

    print("restarting from checkpoint (REAP single-read restore)...")
    out = Trainer(cfg, opt, loop, corpus,
                  os.path.join(args.workdir, "ckpt")).run()
    rs = out["restore_stats"]
    print(f"  restored {rs['bytes']/1e6:.0f}MB in {rs['io_s']*1e3:.0f}ms "
          f"({rs['n_faults']} faults)")
    print(f"  finished at step {out['final_step']}; "
          f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")

    print("verifying against an uninterrupted run...")
    ref = Trainer(cfg, opt, loop, corpus,
                  os.path.join(args.workdir, "ckpt_ref")).run()
    tail = max(abs(a - b) for a, b in zip(out["losses"][-5:],
                                          ref["losses"][-5:]))
    print(f"  max tail-loss divergence: {tail:.2e} "
          f"({'OK' if tail < 1e-2 else 'MISMATCH'})")


if __name__ == "__main__":
    main()
