"""Scenario: a multi-tenant worker serving ALL TEN assigned architectures
as serverless functions with batched requests, keepalive-driven
scale-to-zero, and REAP-accelerated cold starts.

    PYTHONPATH=src python examples/serve_fleet.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.configs import ARCHS, SMOKES  # noqa: E402
from repro.core import ReapConfig  # noqa: E402
from repro.launch import steps  # noqa: E402
from repro.serving import Orchestrator  # noqa: E402


def main():
    store = ".fleet_store"
    orch = Orchestrator(store, mode="reap", reap=ReapConfig(),
                        keepalive_s=2.0, warm_limit=4)
    requests = {}
    for name in ARCHS:
        cfg = SMOKES[name]
        requests[name] = steps.make_batch(cfg, seq=48, batch=2, kind="train",
                                          key=jax.random.key(hash(name) % 2**31))
        orch.register(name, cfg, warmup_batch=requests[name])
        print(f"deployed {name}")

    # round 1: every function cold (record phase)
    print("\n-- round 1: cold starts (record) --")
    for name in ARCHS:
        _, r = orch.invoke(name, requests[name])
        print(f"  {name:28s} total={r.total_s*1e3:7.1f}ms faults={r.n_faults}")

    # idle long enough for the autoscaler to reclaim everything
    time.sleep(2.2)
    n = orch.reap_idle()
    print(f"\nautoscaler reclaimed {n} idle instances (scale-to-zero)")

    # round 2: cold again, now with REAP prefetch
    print("\n-- round 2: cold starts (REAP prefetch) --")
    for name in ARCHS:
        _, r = orch.invoke(name, requests[name])
        print(f"  {name:28s} total={r.total_s*1e3:7.1f}ms "
              f"prefetch={r.prefetch_s*1e3:5.1f}ms faults={r.n_faults}")


if __name__ == "__main__":
    main()
