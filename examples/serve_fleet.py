"""Scenario: a multi-tenant worker serving ALL TEN assigned architectures
as serverless functions behind the concurrent data plane: per-function
queues, a bounded worker pool, admission control, keepalive-driven
scale-to-zero, REAP-accelerated cold starts, and a shared WS page cache.

Phases:
  1. deploy + record  -- every function cold-starts once (record phase)
  2. scale to zero    -- the autoscaler reclaims all idle instances
  3. trace replay     -- a replayable open-loop Poisson trace drives the
                         router; cold starts hit the REAP prefetch path and
                         concurrent restores of one function share one WS
                         read through the process-wide cache
  4. adaptive replay  -- the same trace again, now with the SPES-style
                         prewarming control plane predicting arrivals and
                         pre-spawning instances off the critical path:
                         compare the cold-start fractions

    PYTHONPATH=src python examples/serve_fleet.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.configs import ARCHS, SMOKES  # noqa: E402
from repro.core import ReapConfig  # noqa: E402
from repro.core.reap import WS_CACHE  # noqa: E402
from repro.launch import steps  # noqa: E402
from repro.serving import (Orchestrator, Router, RouterConfig,  # noqa: E402
                           PolicyConfig, PrewarmPolicy, poisson_trace,
                           OpenLoopGenerator, summarize)


def steady_state(results):
    """Reports excluding each function's first replay arrival: that one is
    cold under any policy (no history yet), so the provisioning comparison
    is over the remaining, predictable traffic."""
    seen, out = set(), []
    for ev, rep in results:
        if rep is None:
            continue
        if ev.function not in seen:
            seen.add(ev.function)
            continue
        out.append(rep)
    return out


def main():
    store = ".fleet_store"
    orch = Orchestrator(store, mode="reap", reap=ReapConfig(),
                        keepalive_s=2.0, warm_limit=4,
                        prewarm_concurrency=1)
    requests = {}
    for name in ARCHS:
        cfg = SMOKES[name]
        requests[name] = steps.make_batch(cfg, seq=48, batch=2, kind="train",
                                          key=jax.random.key(hash(name) % 2**31))
        orch.register(name, cfg, warmup_batch=requests[name])
        print(f"deployed {name}")

    # phase 1: every function cold (record phase)
    print("\n-- phase 1: cold starts (record) --")
    for name in ARCHS:
        _, r = orch.invoke(name, requests[name])
        print(f"  {name:28s} total={r.total_s*1e3:7.1f}ms faults={r.n_faults}")

    # phase 2: idle long enough for the autoscaler to reclaim everything
    time.sleep(2.2)
    n = orch.reap_idle()
    print(f"\nautoscaler reclaimed {n} idle instances (scale-to-zero)")

    # phase 3: replayable open-loop Poisson trace through the router.
    # A skewed mix concentrates arrivals on a few functions so concurrent
    # cold-starts of one function exercise the shared WS cache.
    names = list(ARCHS)
    mix = {n: (4.0 if i < 3 else 1.0) for i, n in enumerate(names)}
    trace = poisson_trace(rate_rps=15.0, duration_s=3.0, functions=names,
                          mix=mix, seed=7)
    trace.save(os.path.join(store, "fleet_trace.json"))
    print(f"\n-- phase 3: open-loop replay ({len(trace.events)} arrivals, "
          f"{trace.duration_s:.2f}s trace) --")
    WS_CACHE.reset_stats()
    router = Router(orch, RouterConfig(max_concurrency=8,
                                       max_instances_per_function=4))
    gen = OpenLoopGenerator(router, trace,
                            make_batch=lambda ev: requests[ev.function])
    results = gen.run()
    router.close()

    reports = [rep for _, rep in results if rep is not None]
    s = summarize(reports)
    print(f"  served {s['n']}/{len(results)} "
          f"queue_mean={s['queue_mean_s']*1e3:.1f}ms "
          f"queue_p95={s['queue_p95_s']*1e3:.1f}ms "
          f"e2e_p50={s['e2e_p50_s']*1e3:.1f}ms "
          f"e2e_p95={s['e2e_p95_s']*1e3:.1f}ms")
    print(f"  cold starts: {s['cold']} "
          f"({100*s['cold_fraction']:.0f}% of served, "
          f"ws_cache_hits={s['ws_cache_hits']}) "
          f"ws_cache={WS_CACHE.stats()}")
    ss = summarize(steady_state(results))

    # phase 4: identical trace with the adaptive prewarming control plane —
    # arrival history sizes per-function warm pools, instances are spawned
    # on pool threads, and served invocations carry prewarmed=True
    for name in ARCHS:
        orch.scale_to_zero(name)
    time.sleep(2.2)
    print("\n-- phase 4: adaptive replay (prewarming policy) --")
    WS_CACHE.clear()              # same cold cache as phase 3, fair compare
    WS_CACHE.reset_stats()
    router = Router(orch, RouterConfig(max_concurrency=8,
                                       max_instances_per_function=4))
    with PrewarmPolicy(orch, router,
                       PolicyConfig(interval_s=0.05, max_warm=4)) as policy:
        results = OpenLoopGenerator(
            router, trace, make_batch=lambda ev: requests[ev.function]).run()
        router.close()
    sa = summarize([rep for _, rep in results if rep is not None])
    ssa = summarize(steady_state(results))
    print(f"  served {sa['n']}/{len(results)} "
          f"e2e_p50={sa['e2e_p50_s']*1e3:.1f}ms "
          f"e2e_p95={sa['e2e_p95_s']*1e3:.1f}ms")
    print(f"  cold starts: {sa['cold']} total; steady-state "
          f"(excl. each function's first arrival): "
          f"{ssa['cold']}/{ssa['n']} adaptive vs {ss['cold']}/{ss['n']} "
          f"reactive, prewarmed-served={sa['prewarmed']}")
    print(f"  policy targets={policy.stats()['targets']}")
    orch.close()


if __name__ == "__main__":
    main()
