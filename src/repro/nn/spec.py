"""Parameter specification trees.

Models in REAP-JX describe their parameters as a nested-dict tree of
:class:`TensorSpec` leaves (shape, dtype, logical axis names, init law).
The same spec tree drives four consumers:

* ``initialize``     -- materialize real arrays (smoke tests / examples),
* ``abstract``       -- ``jax.ShapeDtypeStruct`` stand-ins (multi-pod dry-run,
                        nothing is ever allocated),
* ``shardings``      -- ``NamedSharding`` per leaf from logical-axis rules,
* ``core.snapshot``  -- the flat page-aligned guest-memory-file layout.

Everything is plain functional JAX: no framework dependency.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

__all__ = [
    "TensorSpec",
    "tensor",
    "abstract",
    "initialize",
    "shardings",
    "partition_specs",
    "tree_paths",
    "leaf_items",
    "num_params",
    "num_bytes",
    "map_leaves",
]


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """A single parameter/buffer declaration."""

    shape: tuple[int, ...]
    dtype: Any = jnp.bfloat16
    axes: tuple[str | None, ...] = ()
    init: str = "normal"  # normal | zeros | ones | embed | trunc_fan_in
    scale: float | None = None

    def __post_init__(self):
        if self.axes and len(self.axes) != len(self.shape):
            raise ValueError(
                f"axes {self.axes} does not match shape {self.shape}"
            )

    @property
    def size(self) -> int:
        return math.prod(self.shape)

    @property
    def nbytes(self) -> int:
        return self.size * jnp.dtype(self.dtype).itemsize

    def as_sds(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def tensor(*shape: int, axes: tuple[str | None, ...] = (), dtype=jnp.bfloat16,
           init: str = "normal", scale: float | None = None) -> TensorSpec:
    if not axes:
        axes = (None,) * len(shape)
    return TensorSpec(tuple(shape), dtype, tuple(axes), init, scale)


def _is_leaf(x) -> bool:
    return isinstance(x, TensorSpec)


def tree_paths(tree, prefix: str = "") -> Iterator[tuple[str, TensorSpec]]:
    """Deterministic depth-first (path, leaf) iteration, sorted by key."""
    if _is_leaf(tree):
        yield prefix.rstrip("/"), tree
        return
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            yield from tree_paths(tree[k], prefix + str(k) + "/")
        return
    if isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from tree_paths(v, prefix + str(i) + "/")
        return
    raise TypeError(f"unsupported spec-tree node: {type(tree)}")


def leaf_items(tree) -> list[tuple[str, TensorSpec]]:
    return list(tree_paths(tree))


def map_leaves(fn: Callable[[str, TensorSpec], Any], tree, prefix: str = ""):
    """Structure-preserving map with path argument."""
    if _is_leaf(tree):
        return fn(prefix.rstrip("/"), tree)
    if isinstance(tree, dict):
        return {k: map_leaves(fn, v, prefix + str(k) + "/") for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        seq = [map_leaves(fn, v, prefix + str(i) + "/") for i, v in enumerate(tree)]
        return type(tree)(seq)
    raise TypeError(f"unsupported spec-tree node: {type(tree)}")


def num_params(tree) -> int:
    return sum(s.size for _, s in tree_paths(tree))


def num_bytes(tree) -> int:
    return sum(s.nbytes for _, s in tree_paths(tree))


def abstract(tree):
    """ShapeDtypeStruct tree -- used by the dry-run, never allocates."""
    return map_leaves(lambda _, s: s.as_sds(), tree)


def _path_key(key: jax.Array, path: str) -> jax.Array:
    digest = hashlib.md5(path.encode()).digest()
    return jax.random.fold_in(key, int.from_bytes(digest[:4], "little"))


def _init_one(key: jax.Array, s: TensorSpec) -> jax.Array:
    if s.init == "zeros":
        return jnp.zeros(s.shape, s.dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, s.dtype)
    if s.init in ("normal", "embed"):
        scale = s.scale if s.scale is not None else 0.02
        x = jax.random.normal(key, s.shape, jnp.float32) * scale
        return x.astype(s.dtype)
    if s.init == "trunc_fan_in":
        fan_in = s.shape[0] if len(s.shape) >= 2 else s.size
        scale = s.scale if s.scale is not None else 1.0
        std = scale / math.sqrt(max(fan_in, 1))
        x = jax.random.truncated_normal(key, -2.0, 2.0, s.shape, jnp.float32) * std
        return x.astype(s.dtype)
    raise ValueError(f"unknown init law {s.init!r}")


def initialize(tree, key: jax.Array):
    """Materialize the spec tree into real arrays (deterministic per-path)."""
    return map_leaves(lambda p, s: _init_one(_path_key(key, p), s), tree)


def _partition_spec(s: TensorSpec, rules: dict[str, Any],
                    mesh=None) -> PartitionSpec:
    """Logical axes -> PartitionSpec under `rules`.

    Never reuses a mesh axis within one tensor, and (when ``mesh`` is given)
    only assigns mesh axes whose product divides the dimension -- jit
    in_shardings require exact divisibility (e.g. kv_heads=8 cannot shard a
    16-way model axis and falls back to replication).
    """
    used: set[str] = set()
    entries = []
    for dim, name in zip(s.shape, s.axes):
        mesh_axes = rules.get(name) if name is not None else None
        if mesh_axes is None:
            entries.append(None)
            continue
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        picked = [a for a in mesh_axes if a not in used]
        if mesh is not None:
            # longest prefix whose size divides the dimension
            while picked:
                prod = math.prod(mesh.shape[a] for a in picked)
                if dim % prod == 0:
                    break
                picked = picked[:-1]
        if not picked:
            entries.append(None)
            continue
        used.update(picked)
        entries.append(tuple(picked) if len(picked) > 1 else picked[0])
    while entries and entries[-1] is None:
        entries.pop()
    return PartitionSpec(*entries)


def partition_specs(tree, rules: dict[str, Any], mesh=None):
    return map_leaves(lambda _, s: _partition_spec(s, rules, mesh), tree)


def shardings(tree, mesh, rules: dict[str, Any]):
    return map_leaves(
        lambda _, s: NamedSharding(mesh, _partition_spec(s, rules, mesh)), tree
    )


def host_initialize(tree, seed: int = 0):
    """NumPy-side initialization for the snapshot substrate (no device arrays).

    Used when building guest-memory files for instances far larger than what
    we want to keep as jax arrays; deterministic per path.
    """
    out = {}
    for path, s in tree_paths(tree):
        rng = np.random.default_rng(
            int.from_bytes(hashlib.md5(f"{seed}:{path}".encode()).digest()[:8], "little")
        )
        if s.init == "zeros":
            arr = np.zeros(s.shape, dtype=jnp.dtype(s.dtype))
        elif s.init == "ones":
            arr = np.ones(s.shape, dtype=jnp.dtype(s.dtype))
        else:
            scale = s.scale if s.scale is not None else 0.02
            arr = (rng.standard_normal(s.shape, dtype=np.float32) * scale).astype(
                jnp.dtype(s.dtype)
            )
        out[path] = arr
    return out
