"""Functional building blocks shared by all model families.

Every block comes as a pair: ``<block>_spec(cfg...) -> spec tree`` and
``apply_<block>(params, ...) -> array``.  Specs carry logical axis names
("embed", "heads", "kv_heads", "head_dim", "mlp", "vocab", "expert",
"layers", "state", ...) that the sharding rules in
``repro.distributed.sharding`` map onto mesh axes.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from .spec import tensor

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_spec(d: int) -> dict:
    return {"scale": tensor(d, axes=("embed",), dtype=jnp.float32, init="ones")}


def apply_rmsnorm(p: dict | None, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    if p is not None:
        y = y * p["scale"]
    return y.astype(x.dtype)


def apply_nonparam_ln(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """OLMo-style non-parametric LayerNorm (no scale, no bias)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def apply_norm(kind: str, p: dict | None, x: jax.Array) -> jax.Array:
    if kind == "rmsnorm":
        return apply_rmsnorm(p, x)
    if kind == "nonparam_ln":
        return apply_nonparam_ln(x)
    raise ValueError(f"unknown norm {kind}")


def norm_spec(kind: str, d: int) -> dict | None:
    return rmsnorm_spec(d) if kind == "rmsnorm" else None


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def embedding_spec(vocab: int, d: int) -> dict:
    return {"table": tensor(vocab, d, axes=("vocab", "embed"), init="embed")}


def apply_embedding(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def lm_head_spec(d: int, vocab: int) -> dict:
    return {"w": tensor(d, vocab, axes=("embed", "vocab"), init="trunc_fan_in")}


def apply_lm_head(p: dict, x: jax.Array) -> jax.Array:
    return jnp.einsum("...d,dv->...v", x, p["w"])


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope_table(positions: jax.Array, head_dim: int, theta: float) -> tuple:
    """positions: (...,) int -> (cos, sin) of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (S, D/2) or (B, S, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:  # (S, half) -> broadcast over batch and heads
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:  # (B, S, half)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., :half], xf[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, chunked online-softmax => memory-bounded at 32k/500k)
# ---------------------------------------------------------------------------


def attention_spec(d: int, n_heads: int, n_kv: int, head_dim: int,
                   qkv_bias: bool = False) -> dict:
    s = {
        "wq": tensor(d, n_heads, head_dim, axes=("embed", "heads", "head_dim"),
                     init="trunc_fan_in"),
        "wk": tensor(d, n_kv, head_dim, axes=("embed", "kv_heads", "head_dim"),
                     init="trunc_fan_in"),
        "wv": tensor(d, n_kv, head_dim, axes=("embed", "kv_heads", "head_dim"),
                     init="trunc_fan_in"),
        "wo": tensor(n_heads, head_dim, d, axes=("heads", "head_dim", "embed"),
                     init="trunc_fan_in"),
    }
    if qkv_bias:
        s["bq"] = tensor(n_heads, head_dim, axes=("heads", "head_dim"), init="zeros")
        s["bk"] = tensor(n_kv, head_dim, axes=("kv_heads", "head_dim"), init="zeros")
        s["bv"] = tensor(n_kv, head_dim, axes=("kv_heads", "head_dim"), init="zeros")
    return s


def _qkv(p: dict, x: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, k, v


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, q_offset: Any = 0,
                      kv_len: Any = None, chunk: int = 1024) -> jax.Array:
    """Online-softmax attention, scanned over KV chunks (flash semantics).

    q: (B, Sq, H, D); k, v: (B, Skv, KV, D) with H % KV == 0.
    ``q_offset`` -- absolute position of q[0] (for causal masking in decode).
    ``kv_len``   -- valid prefix length of the KV cache (None = all valid).
    Peak activation is O(B * H * Sq * chunk) regardless of Skv, which is what
    makes 32k prefill / 500k decode lowerable without O(L^2) buffers.
    """
    from ..distributed.sharding import act_heads

    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    q = act_heads(q)  # shard heads on 'model' in activations (prefill scores)
    qg = q.reshape(B, Sq, KV, G, D).astype(jnp.float32) * scale

    chunk = min(chunk, Skv)
    n_chunks = (Skv + chunk - 1) // chunk
    q_pos = q_offset + jnp.arange(Sq)
    limit = Skv if kv_len is None else kv_len
    NEG = jnp.float32(-1e30)

    def block(kb, vb, kv_start):
        """One KV block: scores + additive bias (never a broadcast pred)."""
        kv_pos = kv_start + jnp.arange(kb.shape[1])
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, kb.astype(jnp.float32))
        bias = jnp.where(kv_pos[None, :] < limit, 0.0, NEG)
        if causal:
            bias = bias + jnp.where(kv_pos[None, :] <= q_pos[:, None], 0.0, NEG)
        return s + bias[None, :, None, None, :]

    if n_chunks == 1:
        # decode / short-KV fast path: no scan, no cache resharding; the
        # softmax over the (possibly sequence-sharded) KV axis lowers to
        # partial reductions + a small all-reduce.
        s = block(k, v, 0)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        out = jnp.einsum("bqkgc,bckd->bqkgd", p, v.astype(jnp.float32))
        out = out / jnp.maximum(jnp.sum(p, axis=-1)[..., None], 1e-20)
        return out.reshape(B, Sq, H, D).astype(q.dtype)

    pad = n_chunks * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, KV, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, KV, D).transpose(1, 0, 2, 3, 4)

    # checkpoint the step: the backward recomputes per-chunk scores instead
    # of storing O(Sq x chunk) probability residuals for every chunk
    @jax.checkpoint
    def step(carry, inp):
        m, l, acc = carry
        idx, kb, vb = inp
        s = block(kb, vb, idx * chunk)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # fully-masked rows: m_new is very negative; exp underflows to 0
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, KV, G), NEG, jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, KV, G, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l[..., None], 1e-20)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def apply_attention(p: dict, x: jax.Array, *, rope_theta: float,
                    positions: jax.Array | None = None,
                    cache: dict | None = None, cache_pos: Any = None,
                    chunk: int = 1024):
    """Self-attention. If ``cache`` is given, runs in decode mode: appends the
    new K/V at ``cache_pos`` and attends over the valid cache prefix.

    Returns (out, new_cache) where new_cache is None when cache is None.
    """
    B, S, _ = x.shape
    q, k, v = _qkv(p, x)
    head_dim = q.shape[-1]
    if positions is None:
        base = 0 if cache is None else cache_pos
        positions = base + jnp.arange(S)
    cos, sin = rope_table(positions, head_dim, rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    if cache is None:
        out = chunked_attention(q, k, v, causal=True, chunk=chunk)
        new_cache = None
    else:
        int8_kv = "k_scale" in cache
        if int8_kv:
            kq, ks = _quant_kv(k)
            vq, vs = _quant_kv(v)
            dus = jax.lax.dynamic_update_slice_in_dim
            ck = dus(cache["k"], kq, cache_pos, axis=1)
            cv = dus(cache["v"], vq, cache_pos, axis=1)
            cks = dus(cache["k_scale"], ks, cache_pos, axis=1)
            cvs = dus(cache["v_scale"], vs, cache_pos, axis=1)
            new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
            # dequantized views are per-layer transients
            ck = ck.astype(jnp.bfloat16) * cks[..., None].astype(jnp.bfloat16)
            cv = cv.astype(jnp.bfloat16) * cvs[..., None].astype(jnp.bfloat16)
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), cache_pos, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), cache_pos, axis=1)
            new_cache = {"k": ck, "v": cv}
        if S == 1:
            # decode: one-shot attention over the (sequence-sharded) cache.
            # Scores are (B, 1, KV, G, S_kv) -- tiny per device -- and the
            # softmax over the sharded axis becomes partial-reduce +
            # all-reduce instead of a scan that would reshard the cache
            # chunk-by-chunk (involuntary full rematerialization).
            out = chunked_attention(q, ck, cv, causal=True, q_offset=cache_pos,
                                    kv_len=cache_pos + S,
                                    chunk=cache["k"].shape[1])
        else:
            # prefill from position 0: attending over the fresh K/V is
            # mathematically identical to attending over the cache prefix
            # and avoids re-slicing the sequence-sharded cache.
            out = chunked_attention(q, k, v, causal=True, chunk=chunk)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


def attention_cache_spec(batch: int, max_len: int, n_kv: int, head_dim: int,
                         dtype=jnp.bfloat16) -> dict:
    # The KV cache is sharded batch x sequence (not kv_heads): at 32k-500k
    # contexts the cache dominates HBM and kv_heads (4-8) cannot fill a
    # 16-way model axis without padding waste -- see DESIGN.md §4.
    s = {
        "k": tensor(batch, max_len, n_kv, head_dim,
                    axes=("batch", "seq", None, "head_dim"),
                    dtype=dtype, init="zeros"),
        "v": tensor(batch, max_len, n_kv, head_dim,
                    axes=("batch", "seq", None, "head_dim"),
                    dtype=dtype, init="zeros"),
    }
    if jnp.dtype(dtype) == jnp.int8:
        # per (token, kv-head) quantization scales (beyond-paper: int8 KV
        # cache halves the decode working set vs bf16)
        for n in ("k_scale", "v_scale"):
            s[n] = tensor(batch, max_len, n_kv,
                          axes=("batch", "seq", None),
                          dtype=jnp.float32, init="zeros")
    return s


def _quant_kv(x: jax.Array):
    """(B, S, KV, D) -> int8 values + per-(token, head) scales."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def kv_cache_dtype(cfg) -> Any:
    return jnp.dtype(getattr(cfg, "kv_cache_dtype", "bfloat16"))


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------


def mlp_spec(d: int, d_ff: int) -> dict:
    return {
        "wi_gate": tensor(d, d_ff, axes=("embed", "mlp"), init="trunc_fan_in"),
        "wi_up": tensor(d, d_ff, axes=("embed", "mlp"), init="trunc_fan_in"),
        "wo": tensor(d_ff, d, axes=("mlp", "embed"), init="trunc_fan_in"),
    }


def apply_mlp(p: dict, x: jax.Array) -> jax.Array:
    g = jnp.einsum("bsd,df->bsf", x, p["wi_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p["wi_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])
