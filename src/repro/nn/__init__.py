from . import layers, spec
from .spec import TensorSpec, abstract, initialize, shardings, tensor
