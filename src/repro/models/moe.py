"""Mixture-of-Experts decoders.

Covers both assigned MoE shapes:
  * deepseek-moe-16b  -- fine-grained: 1 leading dense layer, then every layer
    MoE with 64 routed experts (top-6) + 2 shared experts.
  * llama4-maverick   -- coarse: MoE every 2nd layer, 128 routed experts
    (top-1) + 1 shared expert.

Dispatch is capacity-based scatter/gather (GShard-style but without the
(B,S,E,C) one-hot combine tensor): tokens are flattened, ranked into their
expert's capacity slots via a cumulative-sum over the top-k assignment
matrix, scattered into an (E, C, d) buffer, run through a batched expert
FFN, and gathered back with router weights.  Under pjit the expert axis is
sharded on the "model" mesh axis (expert parallelism) and XLA inserts the
dispatch/combine all-to-alls.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import act_batch, act_expert
from ..nn import layers as nn
from .transformer import _logits, _trunk_in, stack_specs

# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def moe_mlp_spec(cfg: ModelConfig) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert or cfg.d_ff
    s = {
        "router": nn.tensor(d, e, axes=("embed", "expert"), dtype=jnp.float32,
                            init="trunc_fan_in"),
        "wi_gate": nn.tensor(e, d, f, axes=("expert", "embed", None),
                             init="trunc_fan_in"),
        "wi_up": nn.tensor(e, d, f, axes=("expert", "embed", None),
                           init="trunc_fan_in"),
        "wo": nn.tensor(e, f, d, axes=("expert", None, "embed"),
                        init="trunc_fan_in"),
    }
    if cfg.n_shared_experts:
        s["shared"] = nn.mlp_spec(d, cfg.n_shared_experts * (cfg.d_ff_expert or cfg.d_ff))
    return s


def dense_layer_spec(cfg: ModelConfig, d_ff: int) -> dict:
    hd = cfg.resolved_head_dim
    return {
        "attn": nn.attention_spec(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, hd,
                                  cfg.qkv_bias),
        "mlp": nn.mlp_spec(cfg.d_model, d_ff),
        "ln1": nn.rmsnorm_spec(cfg.d_model),
        "ln2": nn.rmsnorm_spec(cfg.d_model),
    }


def moe_layer_spec(cfg: ModelConfig) -> dict:
    hd = cfg.resolved_head_dim
    return {
        "attn": nn.attention_spec(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, hd,
                                  cfg.qkv_bias),
        "moe": moe_mlp_spec(cfg),
        "ln1": nn.rmsnorm_spec(cfg.d_model),
        "ln2": nn.rmsnorm_spec(cfg.d_model),
    }


def _group_spec(cfg: ModelConfig) -> dict:
    """One scanned group: (moe_every - 1) dense layers + 1 MoE layer."""
    g = {"moe_layer": moe_layer_spec(cfg)}
    if cfg.moe_every > 1:
        g["dense_layers"] = stack_specs(
            dense_layer_spec(cfg, cfg.d_ff_dense or cfg.d_ff), cfg.moe_every - 1)
    return g


def n_groups(cfg: ModelConfig) -> int:
    rest = cfg.n_layers - cfg.first_dense
    assert rest % cfg.moe_every == 0, (cfg.n_layers, cfg.first_dense, cfg.moe_every)
    return rest // cfg.moe_every


def param_specs(cfg: ModelConfig) -> dict:
    s = {
        "embed": nn.embedding_spec(cfg.vocab, cfg.d_model),
        "groups": stack_specs(_group_spec(cfg), n_groups(cfg)),
        "ln_f": nn.rmsnorm_spec(cfg.d_model),
        "lm_head": nn.lm_head_spec(cfg.d_model, cfg.vocab),
    }
    if cfg.first_dense:
        s["first_dense"] = stack_specs(
            dense_layer_spec(cfg, cfg.d_ff_dense or cfg.d_ff), cfg.first_dense)
    return s


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    hd = cfg.resolved_head_dim
    kv = lambda: nn.attention_cache_spec(batch, max_len, cfg.n_kv_heads, hd, nn.kv_cache_dtype(cfg))
    s = {"group_moe": stack_specs(kv(), n_groups(cfg))}
    if cfg.moe_every > 1:
        s["group_dense"] = stack_specs(stack_specs(kv(), cfg.moe_every - 1), n_groups(cfg))
    if cfg.first_dense:
        s["first_dense"] = stack_specs(kv(), cfg.first_dense)
    return s


# ---------------------------------------------------------------------------
# MoE dispatch / combine
# ---------------------------------------------------------------------------


def apply_moe_mlp(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    B, S, d = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                      # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    capacity = max(int(cfg.capacity_factor * k * T / E), 4)
    flat_idx = idx.reshape(T * k)
    assign = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)    # (T*k, E)
    pos = (jnp.cumsum(assign, axis=0) - assign)              # rank within expert
    pos = jnp.sum(pos * assign, axis=-1)                     # (T*k,)
    keep = pos < capacity

    token_of = jnp.repeat(jnp.arange(T), k)
    safe_pos = jnp.where(keep, pos, capacity - 1)
    buf = jnp.zeros((E, capacity, d), x.dtype)
    buf = buf.at[flat_idx, safe_pos].add(
        jnp.where(keep[:, None], xt[token_of], 0).astype(x.dtype))
    buf = act_expert(buf)

    g = jnp.einsum("ecd,edf->ecf", buf, p["wi_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["wi_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    out_buf = act_expert(jnp.einsum("ecf,efd->ecd", h, p["wo"]))  # (E, C, d)

    gathered = out_buf[flat_idx, safe_pos]                   # (T*k, d)
    w = (gate.reshape(T * k) * keep).astype(jnp.float32)
    y = jnp.zeros((T, d), jnp.float32).at[token_of].add(
        gathered.astype(jnp.float32) * w[:, None])
    y = y.astype(x.dtype).reshape(B, S, d)

    if "shared" in p:
        y = y + nn.apply_mlp(p["shared"], x)
    return y


def routed_experts(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Return per-token routed expert ids (used by the REAP access tracer)."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32), p["router"])
    return jax.lax.top_k(logits, cfg.top_k)[1]


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _dense_fwd(cfg, lp, x, cache=None, pos=None):
    h = nn.apply_rmsnorm(lp["ln1"], x)
    h, nc = nn.apply_attention(lp["attn"], h, rope_theta=cfg.rope_theta,
                               cache=cache, cache_pos=pos, chunk=cfg.attn_chunk)
    x = x + h
    x = act_batch(x + nn.apply_mlp(lp["mlp"], nn.apply_rmsnorm(lp["ln2"], x)))
    return x, nc


def _moe_fwd(cfg, lp, x, cache=None, pos=None):
    h = nn.apply_rmsnorm(lp["ln1"], x)
    h, nc = nn.apply_attention(lp["attn"], h, rope_theta=cfg.rope_theta,
                               cache=cache, cache_pos=pos, chunk=cfg.attn_chunk)
    x = x + h
    x = act_batch(x + apply_moe_mlp(lp["moe"], nn.apply_rmsnorm(lp["ln2"], x), cfg))
    return x, nc


def _group_fwd(cfg, gp, x, gcache=None, pos=None):
    new_dense_cache = None
    if "dense_layers" in gp:
        def body(carry, xs):
            if gcache is None:
                y, _ = _dense_fwd(cfg, xs, carry)
                return y, None
            lp, lc = xs
            y, nc = _dense_fwd(cfg, lp, carry, lc, pos)
            return y, nc
        if gcache is None:
            x, _ = jax.lax.scan(body, x, gp["dense_layers"])
        else:
            x, new_dense_cache = jax.lax.scan(
                body, x, (gp["dense_layers"], gcache["dense"]))
    x, new_moe_cache = _moe_fwd(
        cfg, gp["moe_layer"], x,
        None if gcache is None else gcache["moe"], pos)
    return x, (new_dense_cache, new_moe_cache)


def _run(cfg: ModelConfig, params: dict, x: jax.Array, cache: dict | None,
         pos, remat: bool = False, remat_policy=None):
    new_cache: dict = {}
    if cfg.first_dense:
        def fd_body(carry, xs):
            if cache is None:
                y, _ = _dense_fwd(cfg, xs, carry)
                return y, None
            lp, lc = xs
            y, nc = _dense_fwd(cfg, lp, carry, lc, pos)
            return y, nc
        if cache is None:
            x, _ = jax.lax.scan(fd_body, x, params["first_dense"])
        else:
            x, fd_cache = jax.lax.scan(
                fd_body, x, (params["first_dense"], cache["first_dense"]))
            new_cache["first_dense"] = fd_cache

    def g_body(carry, xs):
        if cache is None:
            y, _ = _group_fwd(cfg, xs, carry)
            return y, None
        gp, gc = xs
        y, (ndc, nmc) = _group_fwd(cfg, gp, carry, gc, pos)
        out = {"moe": nmc} if ndc is None else {"moe": nmc, "dense": ndc}
        return y, out

    if cache is None:
        body = jax.checkpoint(g_body, policy=remat_policy) if remat else g_body
        x, _ = jax.lax.scan(body, x, params["groups"])
    else:
        gxs = {"moe": cache["group_moe"]}
        if "group_dense" in cache:
            gxs["dense"] = cache["group_dense"]
        def g_body2(carry, xs):
            gp, gc = xs
            y, (ndc, nmc) = _group_fwd(cfg, gp, carry, gc, pos)
            out = {"moe": nmc}
            if ndc is not None:
                out["dense"] = ndc
            return y, out
        x, g_cache = jax.lax.scan(g_body2, x, (params["groups"], gxs))
        new_cache["group_moe"] = g_cache["moe"]
        if "dense" in g_cache:
            new_cache["group_dense"] = g_cache["dense"]
    return x, (new_cache if cache is not None else None)


def _group_cache_view(cache):
    return cache


def forward(cfg, params, batch, *, remat=False, remat_policy=None):
    x = _trunk_in(cfg, params, batch)
    x, _ = _run(cfg, params, x, None, None, remat, remat_policy)
    return _logits(cfg, params, x)


def prefill(cfg, params, batch, cache):
    x = _trunk_in(cfg, params, batch)
    x, cache = _run(cfg, params, x, cache, 0)
    return _logits(cfg, params, x[:, -1:, :]), cache


def decode(cfg, params, cache, batch, pos):
    x = nn.apply_embedding(params["embed"], batch["tokens"])
    x, cache = _run(cfg, params, x, cache, pos)
    return _logits(cfg, params, x), cache


def loss(cfg, params, batch, *, remat=False, remat_policy=None):
    from .transformer import ce_from_hidden
    x = _trunk_in(cfg, params, batch)
    x, _ = _run(cfg, params, x, None, None, remat, remat_policy)
    return ce_from_hidden(cfg, params, x, batch["tokens"])
