"""RWKV6 ("Finch"): attention-free, data-dependent per-channel decay.

Train/prefill use a *chunked* WKV6 evaluation: within a chunk the pairwise
per-channel decay matrix is built from cum-log-decay differences (all
exponents <= 0, numerically safe) and contracted on the MXU; the chunk
boundary state (H, D, D) is carried by ``lax.scan``.  This replaces the CUDA
wkv6 kernel with a TPU-idiomatic matrix form (DESIGN.md §3).  Decode is the
O(1) recurrence.

Sub-quadratic: runs long_500k (state is (H, D, D) regardless of context).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import act_batch
from ..nn import layers as nn
from ..nn.spec import tensor
from .transformer import _logits, stack_specs


def dims(cfg: ModelConfig):
    H = cfg.d_model // cfg.rwkv_head_dim
    return H, cfg.rwkv_head_dim


def time_mix_spec(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H, hd = dims(cfg)
    r = cfg.decay_lora
    return {
        "mu_r": tensor(d, axes=("embed",), dtype=jnp.float32, init="zeros"),
        "mu_k": tensor(d, axes=("embed",), dtype=jnp.float32, init="zeros"),
        "mu_v": tensor(d, axes=("embed",), dtype=jnp.float32, init="zeros"),
        "mu_w": tensor(d, axes=("embed",), dtype=jnp.float32, init="zeros"),
        "mu_g": tensor(d, axes=("embed",), dtype=jnp.float32, init="zeros"),
        "wr": tensor(d, H, hd, axes=("embed", "heads", "head_dim"), init="trunc_fan_in"),
        "wk": tensor(d, H, hd, axes=("embed", "heads", "head_dim"), init="trunc_fan_in"),
        "wv": tensor(d, H, hd, axes=("embed", "heads", "head_dim"), init="trunc_fan_in"),
        "wg": tensor(d, H, hd, axes=("embed", "heads", "head_dim"), init="trunc_fan_in"),
        "w0": tensor(H, hd, axes=("heads", "head_dim"), dtype=jnp.float32, init="zeros"),
        "wA": tensor(d, r, axes=("embed", None), init="trunc_fan_in"),
        "wB": tensor(r, H, hd, axes=(None, "heads", "head_dim"), init="trunc_fan_in"),
        "u": tensor(H, hd, axes=("heads", "head_dim"), dtype=jnp.float32, init="zeros"),
        "ln_x": nn.rmsnorm_spec(cfg.d_model),
        "wo": tensor(H, hd, d, axes=("heads", "head_dim", "embed"), init="trunc_fan_in"),
    }


def channel_mix_spec(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    return {
        "mu_k": tensor(d, axes=("embed",), dtype=jnp.float32, init="zeros"),
        "mu_r": tensor(d, axes=("embed",), dtype=jnp.float32, init="zeros"),
        "wk": tensor(d, cfg.d_ff, axes=("embed", "mlp"), init="trunc_fan_in"),
        "wv": tensor(cfg.d_ff, d, axes=("mlp", "embed"), init="trunc_fan_in"),
        "wr": tensor(d, d, axes=("embed", None), init="trunc_fan_in"),
    }


def layer_spec(cfg: ModelConfig) -> dict:
    return {
        "ln1": nn.rmsnorm_spec(cfg.d_model),
        "ln2": nn.rmsnorm_spec(cfg.d_model),
        "tm": time_mix_spec(cfg),
        "cm": channel_mix_spec(cfg),
    }


def param_specs(cfg: ModelConfig) -> dict:
    return {
        "embed": nn.embedding_spec(cfg.vocab, cfg.d_model),
        "ln_in": nn.rmsnorm_spec(cfg.d_model),
        "layers": stack_specs(layer_spec(cfg), cfg.n_layers),
        "ln_f": nn.rmsnorm_spec(cfg.d_model),
        "lm_head": nn.lm_head_spec(cfg.d_model, cfg.vocab),
    }


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    H, hd = dims(cfg)
    layer_state = {
        "wkv": tensor(batch, H, hd, hd, axes=("batch", "heads", None, None),
                      dtype=jnp.float32, init="zeros"),
        "tm_shift": tensor(batch, cfg.d_model, axes=("batch", "embed"),
                           dtype=jnp.bfloat16, init="zeros"),
        "cm_shift": tensor(batch, cfg.d_model, axes=("batch", "embed"),
                           dtype=jnp.bfloat16, init="zeros"),
    }
    return {"layers": stack_specs(layer_state, cfg.n_layers)}


def _token_shift(x, prev):
    """x: (B, L, d); prev: (B, d) last token of previous segment."""
    shifted = jnp.concatenate([prev[:, None, :].astype(x.dtype), x[:, :-1, :]],
                              axis=1)
    return shifted


def _mix(x, shifted, mu):
    return x + (shifted - x) * jax.nn.sigmoid(mu)


def wkv6_chunked(r, k, v, logw, u, s0, chunk: int = 32):
    """Chunked WKV6.

    r, k, v: (B, L, H, D); logw: (B, L, H, D) (log decay, < 0);
    u: (H, D) bonus; s0: (B, H, D, D) state (key-major: S[i, j], i key dim).
    y_t = sum_{s<t} (r_t . exp(d_{t-1}-d_s) k_s) v_s + (r_t . u k_t) v_t + r_t^T Dec_t S
    """
    B, L, H, D = r.shape
    chunk = min(chunk, L)
    pad = (-L) % chunk
    if pad:
        # zero k/v and zero log-decay on padded steps leave state untouched
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    nc = Lp // chunk
    rc = r.reshape(B, nc, chunk, H, D).astype(jnp.float32)
    kc = k.reshape(B, nc, chunk, H, D).astype(jnp.float32)
    vc = v.reshape(B, nc, chunk, H, D).astype(jnp.float32)
    wc = logw.reshape(B, nc, chunk, H, D).astype(jnp.float32)

    def step(s, inp):
        rk, kk, vk, wk = inp  # (B, Lc, H, D)
        cum = jnp.cumsum(wk, axis=1)            # inclusive d_t
        d_prev = cum - wk                        # d_{t-1} (exclusive)
        # inter-chunk: y_t += (r_t * exp(d_prev_t))^T S
        rdec = rk * jnp.exp(d_prev)
        y = jnp.einsum("blhi,bhij->blhj", rdec, s)
        # intra-chunk, strictly causal: A[t,s] = sum_i r_t exp(d_{t-1}-d_s) k_s
        diff = d_prev[:, :, None] - cum[:, None, :, :, :]   # (B, Lc, Lc, H, D)
        Lc = rk.shape[1]
        mask = jnp.tril(jnp.ones((Lc, Lc), bool), -1)
        dec = jnp.where(mask[None, :, :, None, None], jnp.exp(diff), 0.0)
        A = jnp.einsum("bthi,btshi,bshi->btsh", rk, dec, kk)
        y = y + jnp.einsum("btsh,bshj->bthj", A, vk)
        # current token bonus
        y = y + jnp.einsum("bthi,bthi,bthj->bthj", rk, u[None, None] * kk, vk)
        # state update: S' = Diag(exp(cum_L)) S + sum_s exp(cum_L - cum_s) k_s v_s^T
        last = cum[:, -1]                        # (B, H, D)
        kdec = kk * jnp.exp(last[:, None] - cum)
        s_new = s * jnp.exp(last)[..., None] + jnp.einsum(
            "bshi,bshj->bhij", kdec, vk)
        return s_new, y

    inputs = tuple(a.transpose(1, 0, 2, 3, 4) for a in (rc, kc, vc, wc))
    sT, yc = jax.lax.scan(step, s0.astype(jnp.float32), inputs)
    y = yc.transpose(1, 0, 2, 3, 4).reshape(B, Lp, H, D)[:, :L]
    return y, sT


def apply_time_mix(p, x, cfg, state=None):
    """x: (B, L, d). state: {"wkv": (B,H,D,D), "shift": (B,d)} or None."""
    B, L, d = x.shape
    H, hd = dims(cfg)
    prev = (jnp.zeros((B, d), x.dtype) if state is None else state["shift"])
    xs = _token_shift(x, prev)
    xr = _mix(x, xs, p["mu_r"]).astype(x.dtype)
    xk = _mix(x, xs, p["mu_k"]).astype(x.dtype)
    xv = _mix(x, xs, p["mu_v"]).astype(x.dtype)
    xw = _mix(x, xs, p["mu_w"]).astype(x.dtype)
    xg = _mix(x, xs, p["mu_g"]).astype(x.dtype)

    r = jnp.einsum("bld,dhk->blhk", xr, p["wr"])
    k = jnp.einsum("bld,dhk->blhk", xk, p["wk"])
    v = jnp.einsum("bld,dhk->blhk", xv, p["wv"])
    g = jnp.einsum("bld,dhk->blhk", xg, p["wg"])
    # data-dependent decay (the RWKV6 signature): w = exp(-exp(w0 + lora(xw)))
    lora = jnp.einsum("bld,dr->blr", xw, p["wA"])
    lora = jnp.einsum("blr,rhk->blhk", jnp.tanh(lora.astype(jnp.float32)).astype(x.dtype), p["wB"])
    logw = -jnp.exp(p["w0"][None, None] + lora.astype(jnp.float32))

    s0 = (jnp.zeros((B, H, hd, hd), jnp.float32) if state is None
          else state["wkv"])
    y, sT = wkv6_chunked(r, k, v, logw, p["u"], s0,
                         chunk=min(32, max(1, L)))
    y = y.reshape(B, L, d).astype(x.dtype)
    y = nn.apply_rmsnorm(p["ln_x"], y)
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype).reshape(B, L, d)
    out = jnp.einsum("blhk,hkd->bld", y.reshape(B, L, H, hd), p["wo"])
    new_state = None if state is None else {"wkv": sT, "shift": x[:, -1, :].astype(jnp.bfloat16)}
    return out, new_state


def apply_channel_mix(p, x, state=None):
    B, L, d = x.shape
    prev = (jnp.zeros((B, d), x.dtype) if state is None else state.astype(x.dtype))
    xs = _token_shift(x, prev)
    xk = _mix(x, xs, p["mu_k"]).astype(x.dtype)
    xr = _mix(x, xs, p["mu_r"]).astype(x.dtype)
    kk = jnp.einsum("bld,df->blf", xk, p["wk"])
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(x.dtype)
    val = jnp.einsum("blf,fd->bld", kk, p["wv"])
    rr = jax.nn.sigmoid(jnp.einsum("bld,de->ble", xr, p["wr"]).astype(jnp.float32))
    out = (rr * val.astype(jnp.float32)).astype(x.dtype)
    new_state = None if state is None else x[:, -1, :].astype(jnp.bfloat16)
    return out, new_state


def _layer_fwd(cfg, lp, x, lstate=None):
    tm_state = None if lstate is None else {"wkv": lstate["wkv"],
                                            "shift": lstate["tm_shift"]}
    h, new_tm = apply_time_mix(lp["tm"], nn.apply_rmsnorm(lp["ln1"], x), cfg,
                               tm_state)
    x = x + h
    h, new_cm = apply_channel_mix(lp["cm"], nn.apply_rmsnorm(lp["ln2"], x),
                                  None if lstate is None else lstate["cm_shift"])
    x = act_batch(x + h)
    new_state = None
    if lstate is not None:
        new_state = {"wkv": new_tm["wkv"], "tm_shift": new_tm["shift"],
                     "cm_shift": new_cm}
    return x, new_state


def _run(cfg, params, x, cache, remat=False, remat_policy=None):
    x = nn.apply_rmsnorm(params["ln_in"], x)
    if cache is None:
        def body(carry, lp):
            y, _ = _layer_fwd(cfg, lp, carry)
            return y, None
        if remat:
            body = jax.checkpoint(body, policy=remat_policy)
        x, _ = jax.lax.scan(body, x, params["layers"])
        return x, None

    def body(carry, xs):
        lp, ls = xs
        return _layer_fwd(cfg, lp, carry, ls)
    x, new_layers = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
    return x, {"layers": new_layers}


def forward(cfg, params, batch, *, remat=False, remat_policy=None):
    x = nn.apply_embedding(params["embed"], batch["tokens"])
    x, _ = _run(cfg, params, x, None, remat, remat_policy)
    return _logits(cfg, params, x)


def prefill(cfg, params, batch, cache):
    x = nn.apply_embedding(params["embed"], batch["tokens"])
    x, cache = _run(cfg, params, x, cache)
    return _logits(cfg, params, x[:, -1:, :]), cache


def decode(cfg, params, cache, batch, pos):
    del pos  # state is position-free
    x = nn.apply_embedding(params["embed"], batch["tokens"])
    x, cache = _run(cfg, params, x, cache)
    return _logits(cfg, params, x), cache


def loss(cfg, params, batch, *, remat=False, remat_policy=None):
    from .transformer import ce_from_hidden
    x = nn.apply_embedding(params["embed"], batch["tokens"])
    x, _ = _run(cfg, params, x, None, remat, remat_policy)
    return ce_from_hidden(cfg, params, x, batch["tokens"])
