"""Model-family registry.

Every family module exposes the same functional interface:
  param_specs(cfg)                  -> spec tree
  cache_specs(cfg, batch, max_len)  -> spec tree for decode state
  forward(cfg, params, batch, *, remat=..., remat_policy=...) -> logits
  prefill(cfg, params, batch, cache) -> (last_logits, cache)
  decode(cfg, params, cache, batch, pos) -> (logits, cache)
  loss(cfg, params, batch, ...)     -> scalar
"""
from __future__ import annotations

from ..configs.base import ModelConfig
from . import encdec, moe, rwkv6, transformer, zamba

FAMILIES = {
    "dense": transformer,
    "vlm": transformer,
    "moe": moe,
    "hybrid": zamba,
    "rwkv": rwkv6,
    "encdec": encdec,
}


def get_family(cfg: ModelConfig):
    return FAMILIES[cfg.family]
