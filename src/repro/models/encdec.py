"""Encoder-decoder (seamless-m4t-medium backbone).

The audio frontend is a STUB per the brief: ``input_specs()`` supplies
precomputed frame embeddings (B, seq//frame_stride, d_model); the encoder is
a bidirectional transformer over frames, the decoder a causal transformer
with cross-attention.  Decode shapes run (the decoder has a KV cache);
long_500k is skipped (full attention).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import act_batch
from ..nn import layers as nn
from .transformer import stack_specs


def enc_layer_spec(cfg: ModelConfig) -> dict:
    hd = cfg.resolved_head_dim
    return {
        "attn": nn.attention_spec(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, hd),
        "mlp": nn.mlp_spec(cfg.d_model, cfg.d_ff),
        "ln1": nn.rmsnorm_spec(cfg.d_model),
        "ln2": nn.rmsnorm_spec(cfg.d_model),
    }


def dec_layer_spec(cfg: ModelConfig) -> dict:
    hd = cfg.resolved_head_dim
    return {
        "self_attn": nn.attention_spec(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, hd),
        "cross_q": nn.tensor(cfg.d_model, cfg.n_heads, hd,
                             axes=("embed", "heads", "head_dim"), init="trunc_fan_in"),
        "cross_k": nn.tensor(cfg.d_model, cfg.n_kv_heads, hd,
                             axes=("embed", "kv_heads", "head_dim"), init="trunc_fan_in"),
        "cross_v": nn.tensor(cfg.d_model, cfg.n_kv_heads, hd,
                             axes=("embed", "kv_heads", "head_dim"), init="trunc_fan_in"),
        "cross_o": nn.tensor(cfg.n_heads, hd, cfg.d_model,
                             axes=("heads", "head_dim", "embed"), init="trunc_fan_in"),
        "mlp": nn.mlp_spec(cfg.d_model, cfg.d_ff),
        "ln1": nn.rmsnorm_spec(cfg.d_model),
        "ln_x": nn.rmsnorm_spec(cfg.d_model),
        "ln2": nn.rmsnorm_spec(cfg.d_model),
    }


def param_specs(cfg: ModelConfig) -> dict:
    return {
        "embed": nn.embedding_spec(cfg.vocab, cfg.d_model),
        "enc_layers": stack_specs(enc_layer_spec(cfg), cfg.n_enc_layers or cfg.n_layers),
        "dec_layers": stack_specs(dec_layer_spec(cfg), cfg.n_layers),
        "ln_enc": nn.rmsnorm_spec(cfg.d_model),
        "ln_f": nn.rmsnorm_spec(cfg.d_model),
        "lm_head": nn.lm_head_spec(cfg.d_model, cfg.vocab),
    }


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    hd = cfg.resolved_head_dim
    n_frames = max(1, max_len // cfg.frame_stride)
    return {
        "self_kv": stack_specs(
            nn.attention_cache_spec(batch, max_len, cfg.n_kv_heads, hd, nn.kv_cache_dtype(cfg)),
            cfg.n_layers),
        "cross_kv": stack_specs(
            nn.attention_cache_spec(batch, n_frames, cfg.n_kv_heads, hd, cfg.dtype),
            cfg.n_layers),
        # valid encoder length, replicated scalar per batch entry
        "enc_len": nn.tensor(batch, axes=("batch",), dtype=jnp.int32, init="zeros"),
    }


def encode(cfg, params, frames):
    x = frames.astype(cfg.dtype)

    def body(carry, lp):
        h = nn.apply_rmsnorm(lp["ln1"], carry)
        h, _ = nn.apply_attention(lp["attn"], h, rope_theta=cfg.rope_theta,
                                  chunk=cfg.attn_chunk)
        # bidirectional: rerun without causal mask via chunked_attention directly
        return carry, None

    # bidirectional attention needs causal=False; build explicitly
    def enc_layer(carry, lp):
        h = nn.apply_rmsnorm(lp["ln1"], carry)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wv"])
        pos = jnp.arange(h.shape[1])
        cos, sin = nn.rope_table(pos, q.shape[-1], cfg.rope_theta)
        q = nn.apply_rope(q, cos, sin)
        k = nn.apply_rope(k, cos, sin)
        o = nn.chunked_attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
        h = jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
        x2 = carry + h
        x2 = act_batch(x2 + nn.apply_mlp(lp["mlp"], nn.apply_rmsnorm(lp["ln2"], x2)))
        return x2, None

    x, _ = jax.lax.scan(enc_layer, x, params["enc_layers"])
    return nn.apply_rmsnorm(params["ln_enc"], x)


def _cross_attend(cfg, lp, x, enc_k, enc_v, enc_len=None):
    q = jnp.einsum("bsd,dhk->bshk", x, lp["cross_q"])
    o = nn.chunked_attention(q, enc_k, enc_v, causal=False, kv_len=enc_len,
                             chunk=cfg.attn_chunk)
    return jnp.einsum("bshk,hkd->bsd", o, lp["cross_o"])


def _dec_layer(cfg, lp, x, enc_kv, self_cache=None, pos=None, enc_len=None):
    h = nn.apply_rmsnorm(lp["ln1"], x)
    h, new_kv = nn.apply_attention(lp["self_attn"], h, rope_theta=cfg.rope_theta,
                                   cache=self_cache, cache_pos=pos,
                                   chunk=cfg.attn_chunk)
    x = x + h
    h = nn.apply_rmsnorm(lp["ln_x"], x)
    x = x + _cross_attend(cfg, lp, h, enc_kv[0], enc_kv[1], enc_len)
    x = act_batch(x + nn.apply_mlp(lp["mlp"], nn.apply_rmsnorm(lp["ln2"], x)))
    return x, new_kv


def _dec_run(cfg, params, tokens, enc_out, cache=None, pos=None, enc_len=None):
    x = nn.apply_embedding(params["embed"], tokens)

    if cache is None:
        def body(carry, lp):
            enc_k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_k"])
            enc_v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_v"])
            y, _ = _dec_layer(cfg, lp, carry, (enc_k, enc_v))
            return y, None
        x, _ = jax.lax.scan(body, x, params["dec_layers"])
        return x, None

    def body(carry, xs):
        lp, sc, cc = xs
        y, new_kv = _dec_layer(cfg, lp, carry, (cc["k"], cc["v"]), sc, pos,
                               enc_len)
        return y, new_kv
    x, new_self = jax.lax.scan(
        body, x, (params["dec_layers"], cache["self_kv"], cache["cross_kv"]))
    return x, new_self


def forward(cfg, params, batch, *, remat=False, remat_policy=None):
    del remat, remat_policy
    enc_out = encode(cfg, params, batch["frames"])
    x, _ = _dec_run(cfg, params, batch["tokens"], enc_out)
    x = nn.apply_rmsnorm(params["ln_f"], x)
    return nn.apply_lm_head(params["lm_head"], x)


def prefill(cfg, params, batch, cache):
    enc_out = encode(cfg, params, batch["frames"])
    n_frames = enc_out.shape[1]

    # materialize cross K/V into the cache once
    def fill(lp, cc):
        k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_k"]).astype(cc["k"].dtype)
        v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_v"]).astype(cc["v"].dtype)
        ck = jax.lax.dynamic_update_slice_in_dim(cc["k"], k, 0, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cc["v"], v, 0, axis=1)
        return {"k": ck, "v": cv}
    cross = jax.vmap(lambda lp, cc: fill(lp, cc))(params["dec_layers"], cache["cross_kv"])
    enc_len = jnp.full(batch["tokens"].shape[0], n_frames, jnp.int32)
    cache = {"self_kv": cache["self_kv"], "cross_kv": cross, "enc_len": enc_len}
    x, new_self = _dec_run(cfg, params, batch["tokens"], enc_out,
                           cache={"self_kv": cache["self_kv"],
                                  "cross_kv": cache["cross_kv"]},
                           pos=0, enc_len=n_frames)
    x = nn.apply_rmsnorm(params["ln_f"], x[:, -1:, :])
    logits = nn.apply_lm_head(params["lm_head"], x)
    return logits, {"self_kv": new_self, "cross_kv": cross, "enc_len": enc_len}


def decode(cfg, params, cache, batch, pos):
    x, new_self = _dec_run(cfg, params, batch["tokens"], None,
                           cache={"self_kv": cache["self_kv"],
                                  "cross_kv": cache["cross_kv"]},
                           pos=pos, enc_len=cache["enc_len"][0])
    xo = nn.apply_rmsnorm(params["ln_f"], x)
    logits = nn.apply_lm_head(params["lm_head"], xo)
    return logits, {"self_kv": new_self, "cross_kv": cache["cross_kv"],
                    "enc_len": cache["enc_len"]}


def loss(cfg, params, batch, *, remat=False, remat_policy=None):
    from .transformer import ce_from_hidden
    enc_out = encode(cfg, params, batch["frames"])
    x, _ = _dec_run(cfg, params, batch["tokens"], enc_out)
    return ce_from_hidden(cfg, params, x, batch["tokens"])
