"""Mamba2 (SSD) block: chunked parallel form for train/prefill, O(1)-state
recurrent step for decode.

TPU adaptation note (DESIGN.md §3): the CUDA Mamba2 kernel's warp-level
selective scan is replaced by the *chunked matrix* (SSD) formulation --
intra-chunk contributions become (Lc x Lc) MXU matmuls and inter-chunk state
is carried through a ``lax.scan``, which is the TPU-idiomatic realization of
the same recurrence.  Projections are split (z/x/B/C/dt as separate weights)
so each is cleanly shardable; the depthwise conv is applied to x only
(documented simplification vs. conv over [x,B,C]).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..nn import layers as nn
from ..nn.spec import tensor


def dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_state


def mamba2_spec(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_inner, H, N = dims(cfg)
    return {
        "wz": tensor(d, d_inner, axes=("embed", "mlp"), init="trunc_fan_in"),
        "wx": tensor(d, d_inner, axes=("embed", "mlp"), init="trunc_fan_in"),
        "wB": tensor(d, N, axes=("embed", "state"), init="trunc_fan_in"),
        "wC": tensor(d, N, axes=("embed", "state"), init="trunc_fan_in"),
        "wdt": tensor(d, H, axes=("embed", "heads"), init="trunc_fan_in"),
        "dt_bias": tensor(H, axes=("heads",), dtype=jnp.float32, init="zeros"),
        "A_log": tensor(H, axes=("heads",), dtype=jnp.float32, init="zeros"),
        "D": tensor(H, axes=("heads",), dtype=jnp.float32, init="ones"),
        "conv_w": tensor(cfg.conv_kernel, d_inner, axes=(None, "mlp"),
                         init="trunc_fan_in"),
        "conv_b": tensor(d_inner, axes=("mlp",), dtype=jnp.float32, init="zeros"),
        "norm": nn.rmsnorm_spec(d_inner),
        "wo": tensor(d_inner, d, axes=("mlp", "embed"), init="trunc_fan_in"),
    }


def mamba2_state_spec(cfg: ModelConfig, batch: int) -> dict:
    d_inner, H, N = dims(cfg)
    return {
        "ssm": tensor(batch, H, N, cfg.ssm_head_dim,
                      axes=("batch", "heads", "state", None),
                      dtype=jnp.float32, init="zeros"),
        "conv": tensor(batch, cfg.conv_kernel - 1, d_inner,
                       axes=("batch", None, "mlp"), dtype=jnp.bfloat16,
                       init="zeros"),
    }


def _proj(p, x):
    z = jnp.einsum("bld,de->ble", x, p["wz"])
    xi = jnp.einsum("bld,de->ble", x, p["wx"])
    Bm = jnp.einsum("bld,dn->bln", x, p["wB"]).astype(jnp.float32)
    Cm = jnp.einsum("bld,dn->bln", x, p["wC"]).astype(jnp.float32)
    dt = jax.nn.softplus(
        jnp.einsum("bld,dh->blh", x, p["wdt"]).astype(jnp.float32) + p["dt_bias"])
    return z, xi, Bm, Cm, dt


def _conv(p, xi, conv_state=None):
    """Depthwise causal conv along L. conv_state: (B, K-1, d_inner)."""
    K = p["conv_w"].shape[0]
    if conv_state is None:
        pad = jnp.zeros((xi.shape[0], K - 1, xi.shape[2]), xi.dtype)
    else:
        pad = conv_state.astype(xi.dtype)
    xp = jnp.concatenate([pad, xi], axis=1)
    out = sum(xp[:, i:i + xi.shape[1], :] * p["conv_w"][i] for i in range(K))
    out = jax.nn.silu(out.astype(jnp.float32) + p["conv_b"]).astype(xi.dtype)
    new_state = xp[:, -(K - 1):, :]
    return out, new_state


def ssd_chunked(xh, dt, A, Bm, Cm, D, h0, chunk: int = 128):
    """Chunked SSD scan.

    xh: (B, L, H, P) inputs per head; dt: (B, L, H); A: (H,) (negative);
    Bm, Cm: (B, L, N); h0: (B, H, N, P) initial state.
    Returns y: (B, L, H, P), hT.
    """
    Bsz, L, H, P = xh.shape
    N = Bm.shape[-1]
    chunk = min(chunk, L)
    pad = (-L) % chunk
    if pad:
        # zero x/B and zero dt on padded steps leave the state untouched
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Lp = L + pad
    nc = Lp // chunk

    xc = xh.reshape(Bsz, nc, chunk, H, P).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, chunk, H)
    Bc = Bm.reshape(Bsz, nc, chunk, N)
    Cc = Cm.reshape(Bsz, nc, chunk, N)
    la = dtc * A  # log decay per step (<= 0): (B, nc, Lc, H)
    cum = jnp.cumsum(la, axis=2)  # inclusive

    def step(h, inp):
        xk, dtk, bk, ck, lak, cumk = inp  # chunk-major leading B
        # intra-chunk: M[t,s] = (C_t . B_s) * exp(cum_t - cum_s) * dt_s, s <= t
        diff = cumk[:, :, None, :] - cumk[:, None, :, :]  # (B, Lc, Lc, H)
        Lc = xk.shape[1]
        mask = jnp.tril(jnp.ones((Lc, Lc), bool))
        decay = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
        cb = jnp.einsum("btn,bsn->bts", ck, bk)
        M = cb[..., None] * decay * dtk[:, None, :, :]
        y = jnp.einsum("btsh,bshp->bthp", M, xk)
        # inter-chunk: y_t += exp(cum_t) * C_t @ h
        y = y + jnp.einsum("btn,bhnp,bth->bthp", ck, h,
                           jnp.exp(cumk))
        # state update
        last = cumk[:, -1:, :]  # (B,1,H)
        w = jnp.exp(last - cumk) * dtk  # (B, Lc, H)
        h_new = h * jnp.exp(last[:, 0, :])[:, :, None, None] + jnp.einsum(
            "bsn,bshp,bsh->bhnp", bk, xk, w)
        return h_new, y

    inputs = tuple(a.transpose(1, 0, *range(2, a.ndim)) for a in
                   (xc, dtc, Bc, Cc, la, cum))
    hT, yc = jax.lax.scan(step, h0.astype(jnp.float32), inputs)
    y = yc.transpose(1, 0, 2, 3, 4).reshape(Bsz, Lp, H, P)[:, :L]
    y = y + xh[:, :L].astype(jnp.float32) * D[None, None, :, None]
    return y, hT


def apply_mamba2(p: dict, x: jax.Array, cfg: ModelConfig,
                 state: dict | None = None):
    """x: (B, L, d). Returns (y, new_state|None)."""
    Bsz, L, d = x.shape
    d_inner, H, N = dims(cfg)
    P = cfg.ssm_head_dim
    z, xi, Bm, Cm, dt = _proj(p, x)
    conv_state = None if state is None else state["conv"]
    xi, new_conv = _conv(p, xi, conv_state)
    A = -jnp.exp(p["A_log"])
    xh = xi.reshape(Bsz, L, H, P)
    h0 = (jnp.zeros((Bsz, H, N, P), jnp.float32) if state is None
          else state["ssm"])
    y, hT = ssd_chunked(xh, dt, A, Bm, Cm, p["D"], h0,
                        chunk=min(128, max(8, L)))
    y = y.reshape(Bsz, L, d_inner).astype(x.dtype)
    y = nn.apply_rmsnorm(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype))
    out = jnp.einsum("ble,ed->bld", y, p["wo"])
    new_state = None if state is None else {"ssm": hT, "conv": new_conv}
    return out, new_state


def mamba2_step(p: dict, x: jax.Array, cfg: ModelConfig, state: dict):
    """Single-token decode step. x: (B, 1, d)."""
    return apply_mamba2(p, x, cfg, state)
