"""Zamba2-style hybrid: Mamba2 backbone with a *shared* attention block
(weight-tied across applications) applied after every ``attn_every`` Mamba
layers -- the weight sharing is the architecture's signature and is also the
ideal case for REAP snapshots (one page set serves many layer applications).

Sub-quadratic: runs the long_500k shape (SSM state is O(1); the shared
attention applications use the chunked online-softmax attention over the
cached prefix).
"""
from __future__ import annotations

import jax

from ..configs.base import ModelConfig
from ..distributed.sharding import act_batch
from ..nn import layers as nn
from .mamba2 import apply_mamba2, mamba2_spec, mamba2_state_spec
from .transformer import _logits, stack_specs


def n_groups(cfg: ModelConfig) -> int:
    assert cfg.n_layers % cfg.attn_every == 0
    return cfg.n_layers // cfg.attn_every


def param_specs(cfg: ModelConfig) -> dict:
    hd = cfg.resolved_head_dim
    group = {
        "mamba": stack_specs(
            {"block": mamba2_spec(cfg), "ln": nn.rmsnorm_spec(cfg.d_model)},
            cfg.attn_every),
    }
    return {
        "embed": nn.embedding_spec(cfg.vocab, cfg.d_model),
        "groups": stack_specs(group, n_groups(cfg)),
        # one shared attention+mlp block, reused by every group
        "shared_attn": {
            "attn": nn.attention_spec(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                      hd, cfg.qkv_bias),
            "mlp": nn.mlp_spec(cfg.d_model, cfg.d_ff),
            "ln1": nn.rmsnorm_spec(cfg.d_model),
            "ln2": nn.rmsnorm_spec(cfg.d_model),
        },
        "ln_f": nn.rmsnorm_spec(cfg.d_model),
        "lm_head": nn.lm_head_spec(cfg.d_model, cfg.vocab),
    }


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    hd = cfg.resolved_head_dim
    return {
        "mamba": stack_specs(stack_specs(mamba2_state_spec(cfg, batch),
                                         cfg.attn_every), n_groups(cfg)),
        "attn_kv": stack_specs(
            nn.attention_cache_spec(batch, max_len, cfg.n_kv_heads, hd, nn.kv_cache_dtype(cfg)),
            n_groups(cfg)),
    }


def _shared_block(cfg, sp, x, cache=None, pos=None):
    h = nn.apply_rmsnorm(sp["ln1"], x)
    h, nc = nn.apply_attention(sp["attn"], h, rope_theta=cfg.rope_theta,
                               cache=cache, cache_pos=pos, chunk=cfg.attn_chunk)
    x = x + h
    x = act_batch(x + nn.apply_mlp(sp["mlp"], nn.apply_rmsnorm(sp["ln2"], x)))
    return x, nc


def _run(cfg, params, x, cache, pos, remat=False, remat_policy=None):
    shared = params["shared_attn"]

    def mamba_body(carry, xs):
        if cache is None:
            lp = xs
            h, _ = apply_mamba2(lp["block"], nn.apply_rmsnorm(lp["ln"], carry), cfg)
            return act_batch(carry + h), None
        lp, st = xs
        h, ns = apply_mamba2(lp["block"], nn.apply_rmsnorm(lp["ln"], carry), cfg,
                             state=st)
        return act_batch(carry + h), ns

    def group_body(carry, xs):
        if cache is None:
            gp = xs
            y, _ = jax.lax.scan(mamba_body, carry, gp["mamba"])
            y, _ = _shared_block(cfg, shared, y)
            return y, None
        gp, gc = xs
        y, new_mamba = jax.lax.scan(mamba_body, carry, (gp["mamba"], gc["mamba"]))
        y, new_kv = _shared_block(cfg, shared, y, cache=gc["attn_kv"], pos=pos)
        return y, {"mamba": new_mamba, "attn_kv": new_kv}

    if cache is None:
        body = jax.checkpoint(group_body, policy=remat_policy) if remat else group_body
        x, _ = jax.lax.scan(body, x, params["groups"])
        return x, None
    x, new_cache = jax.lax.scan(
        group_body, x, (params["groups"],
                        {"mamba": cache["mamba"], "attn_kv": cache["attn_kv"]}))
    return x, new_cache


def forward(cfg, params, batch, *, remat=False, remat_policy=None):
    x = nn.apply_embedding(params["embed"], batch["tokens"])
    x, _ = _run(cfg, params, x, None, None, remat, remat_policy)
    return _logits(cfg, params, x)


def prefill(cfg, params, batch, cache):
    x = nn.apply_embedding(params["embed"], batch["tokens"])
    x, cache = _run(cfg, params, x, cache, 0)
    return _logits(cfg, params, x[:, -1:, :]), cache


def decode(cfg, params, cache, batch, pos):
    x = nn.apply_embedding(params["embed"], batch["tokens"])
    x, cache = _run(cfg, params, x, cache, pos)
    return _logits(cfg, params, x), cache


def loss(cfg, params, batch, *, remat=False, remat_policy=None):
    from .transformer import ce_from_hidden
    x = nn.apply_embedding(params["embed"], batch["tokens"])
    x, _ = _run(cfg, params, x, None, None, remat, remat_policy)
    return ce_from_hidden(cfg, params, x, batch["tokens"])
