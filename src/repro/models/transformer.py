"""Dense GQA decoder-only transformer (qwen/mistral/olmo) + VLM backbone.

Layers are stacked along a leading "layers" axis and executed with
``lax.scan`` so the lowered HLO stays compact at 80 layers and XLA sees a
homogeneous loop (prereq for scan-level remat + FSDP all-gather overlap).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import act_batch, act_logits
from ..nn import layers as nn
from ..nn.spec import TensorSpec, map_leaves

# ---------------------------------------------------------------------------
# Spec construction
# ---------------------------------------------------------------------------


def stack_specs(spec_tree, n: int):
    """Prepend a scanned 'layers' axis to every leaf."""
    return map_leaves(
        lambda _, s: TensorSpec((n,) + s.shape, s.dtype, ("layers",) + s.axes,
                                s.init, s.scale),
        spec_tree,
    )


def layer_spec(cfg: ModelConfig) -> dict:
    hd = cfg.resolved_head_dim
    s = {
        "attn": nn.attention_spec(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, hd,
                                  cfg.qkv_bias),
        "mlp": nn.mlp_spec(cfg.d_model, cfg.d_ff),
    }
    if cfg.norm == "rmsnorm":
        s["ln1"] = nn.rmsnorm_spec(cfg.d_model)
        s["ln2"] = nn.rmsnorm_spec(cfg.d_model)
    return s


def param_specs(cfg: ModelConfig) -> dict:
    s = {
        "embed": nn.embedding_spec(cfg.vocab, cfg.d_model),
        "layers": stack_specs(layer_spec(cfg), cfg.n_layers),
    }
    if cfg.norm == "rmsnorm":
        s["ln_f"] = nn.rmsnorm_spec(cfg.d_model)
    if not cfg.tied_embeddings:
        s["lm_head"] = nn.lm_head_spec(cfg.d_model, cfg.vocab)
    return s


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    hd = cfg.resolved_head_dim
    return {
        "kv": stack_specs(
            nn.attention_cache_spec(batch, max_len, cfg.n_kv_heads, hd, nn.kv_cache_dtype(cfg)),
            cfg.n_layers,
        )
    }


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _layer_fwd(cfg: ModelConfig, lp: dict, x: jax.Array,
               cache: dict | None = None, cache_pos: Any = None):
    h = nn.apply_norm(cfg.norm, lp.get("ln1"), x)
    h, new_cache = nn.apply_attention(
        lp["attn"], h, rope_theta=cfg.rope_theta, cache=cache,
        cache_pos=cache_pos, chunk=cfg.attn_chunk)
    x = x + h
    h = nn.apply_norm(cfg.norm, lp.get("ln2"), x)
    x = act_batch(x + nn.apply_mlp(lp["mlp"], h))
    return x, new_cache


def _scan_layers(cfg: ModelConfig, params: dict, x: jax.Array,
                 cache: dict | None, cache_pos: Any, remat: bool,
                 remat_policy=None):
    if cache is None:
        def body(carry, lp):
            y, _ = _layer_fwd(cfg, lp, carry)
            return y, None
        if remat:
            body = jax.checkpoint(body, policy=remat_policy)
        x, _ = jax.lax.scan(body, x, params["layers"])
        return x, None

    def body(carry, xs):
        lp, lc = xs
        y, nc = _layer_fwd(cfg, lp, carry, cache=lc, cache_pos=cache_pos)
        return y, nc
    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache["kv"]))
    return x, {"kv": new_cache}


def _trunk_in(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    x = nn.apply_embedding(params["embed"], batch["tokens"])
    if cfg.family == "vlm" and "patch_embeds" in batch:
        x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)
    return act_batch(x)


def _logits(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    x = nn.apply_norm(cfg.norm, params.get("ln_f"), x)
    if cfg.tied_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, params["embed"]["table"])
    return nn.apply_lm_head(params["lm_head"], x)


def forward(cfg: ModelConfig, params: dict, batch: dict, *,
            remat: bool = False, remat_policy=None) -> jax.Array:
    """Full training/scoring forward -> logits (B, S_total, vocab)."""
    x = _trunk_in(cfg, params, batch)
    x, _ = _scan_layers(cfg, params, x, None, None, remat, remat_policy)
    return _logits(cfg, params, x)


def prefill(cfg: ModelConfig, params: dict, batch: dict, cache: dict):
    """Populate the KV cache from a full prompt; returns last-pos logits."""
    x = _trunk_in(cfg, params, batch)
    x, cache = _scan_layers(cfg, params, x, cache, 0, False)
    logits = _logits(cfg, params, x[:, -1:, :])
    return logits, cache


def decode(cfg: ModelConfig, params: dict, cache: dict, batch: dict, pos):
    """One-token decode step with KV cache valid up to ``pos``."""
    x = nn.apply_embedding(params["embed"], batch["tokens"])  # (B, 1, d)
    x, cache = _scan_layers(cfg, params, x, cache, pos, False)
    return _logits(cfg, params, x), cache


def loss(cfg: ModelConfig, params: dict, batch: dict, *,
         remat: bool = False, remat_policy=None) -> jax.Array:
    x = _trunk_in(cfg, params, batch)
    x, _ = _scan_layers(cfg, params, x, None, None, remat, remat_policy)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        x = x[:, batch["patch_embeds"].shape[1]:, :]
    return ce_from_hidden(cfg, params, x, batch["tokens"])


def ce_from_hidden(cfg: ModelConfig, params: dict, x: jax.Array,
                   tokens: jax.Array, chunk: int | None = None) -> jax.Array:
    """Memory-efficient next-token CE: the (B, S, vocab) logits tensor is
    never materialized -- the head matmul + logsumexp run per sequence
    chunk inside a rematerialized scan, so peak activation is
    O(B * chunk * vocab / model_parallel) instead of O(B * S * vocab)."""
    x = nn.apply_norm(cfg.norm, params.get("ln_f"), x)
    w = (params["embed"]["table"].T if cfg.tied_embeddings
         else params["lm_head"]["w"])
    xs = x[:, :-1, :]
    targets = tokens[:, 1:]
    B, S, D = xs.shape
    chunk = min(chunk or cfg.ce_chunk, S)
    pad = (-S) % chunk
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)), constant_values=-1)
    nc = (S + pad) // chunk
    xs = xs.reshape(B, nc, chunk, D).transpose(1, 0, 2, 3)
    targets = targets.reshape(B, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_ce(xc, tc):
        logits = act_logits(jnp.einsum("bcd,dv->bcv", xc, w).astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        safe_t = jnp.maximum(tc, 0)
        picked = jnp.take_along_axis(logits, safe_t[..., None], axis=-1)[..., 0]
        valid = (tc >= 0).astype(jnp.float32)
        return jnp.sum((lse - picked) * valid), jnp.sum(valid)

    def body(carry, inp):
        tot, cnt = carry
        s, c = chunk_ce(*inp)
        return (tot + s, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xs, targets))
    return tot / jnp.maximum(cnt, 1.0)


def next_token_loss(logits: jax.Array, tokens: jax.Array) -> jax.Array:
    """Dense-logits CE (smoke-scale reference; big cells use ce_from_hidden)."""
    lf = logits[:, :-1, :].astype(jnp.float32)
    targets = tokens[:, 1:]
    lse = jax.nn.logsumexp(lf, axis=-1)
    picked = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)
