"""REAP-JX: Record-and-Prefetch snapshot substrate for serverless ML
functions on TPU pods (ASPLOS'21 REAP/vHive, rebuilt in JAX).

Subpackages:
  core         the paper's contribution (arena, record, WS file, prefetch)
  serving      orchestrator + instance lifecycle (vHive-CRI analogue)
  models/nn    the 10 assigned architectures as functional JAX
  kernels      Pallas TPU kernels with jnp oracles
  distributed  sharding rules, HLO roofline analyzer, grad compression
  training     optimizer, fault-tolerant loop, snapshot checkpoints
  data         memmap token pipeline
  configs      architecture registry (--arch <id>)
  launch       mesh / dryrun / train / serve entrypoints
"""
