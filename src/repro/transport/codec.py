"""Per-chunk wire compression for the page transport.

A WS chunk is one 4 KiB arena page.  Model-weight pages (structured
floats, zero runs, repeated embeddings) compress well; already-dense
pages (random-looking bf16 mantissas) do not, and running zlib over them
wastes CPU on both ends of the wire.  The codec therefore decides *per
chunk* with a cheap entropy probe: a byte histogram over a strided
sample of the chunk, skip compression when the sampled entropy says the
chunk is effectively incompressible, and fall back to raw whenever the
encoded form would not actually be smaller.

The compressor is lz4 when importable ("lz4-style": fast, low ratio),
else zlib level 1 — the container bakes no lz4, so zlib-1 is the
portable floor.  This module supersedes ``distributed/compress.py`` as
the reference for wire-compression accounting: stats split compressed
vs raw chunk counts and logical vs wire bytes, so benchmarks can report
the ratio without re-deriving it from transfer counters.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import zlib

try:                                  # optional; absent in the base image
    import lz4.frame as _lz4
except ImportError:                   # pragma: no cover - environment detail
    _lz4 = None

ENC_RAW = "raw"
ENC_ZLIB = "zlib"
ENC_LZ4 = "lz4"

#: Sampled bits/byte above which a chunk is treated as incompressible.
#: 8.0 is a uniformly random byte stream; dense float pages probe ~7.5+.
ENTROPY_SKIP_BITS = 7.2

#: Histogram sample size (bytes, strided over the chunk).  512 of 4096
#: keeps the probe ~8x cheaper than hashing the chunk.
PROBE_SAMPLE = 512


def probe_entropy(block: bytes, sample: int = PROBE_SAMPLE) -> float:
    """Shannon entropy (bits/byte) of a strided byte sample of ``block``."""
    n = len(block)
    if n == 0:
        return 0.0
    step = max(n // sample, 1)
    counts: dict[int, int] = {}
    total = 0
    for i in range(0, n, step):
        b = block[i]
        counts[b] = counts.get(b, 0) + 1
        total += 1
    ent = 0.0
    for c in counts.values():
        p = c / total
        ent -= p * math.log2(p)
    return ent


def encode_chunk(block: bytes, *, compress: bool = True,
                 level: int = 1) -> tuple[str, bytes]:
    """``(encoding, payload)`` for one chunk.

    ``compress=False`` (the raw-socket arm) always ships raw.  Otherwise
    the entropy probe gates the compressor, and an encoded form that is
    not strictly smaller than the chunk ships raw anyway (the decoder
    must never pay inflation for a chunk the probe misjudged).
    """
    if not compress or probe_entropy(block) >= ENTROPY_SKIP_BITS:
        return ENC_RAW, block
    if _lz4 is not None:
        packed = _lz4.compress(block)
        enc = ENC_LZ4
    else:
        packed = zlib.compress(block, level)
        enc = ENC_ZLIB
    if len(packed) >= len(block):
        return ENC_RAW, block
    return enc, packed


def decode_chunk(enc: str, payload: bytes) -> bytes:
    if enc == ENC_RAW:
        return payload
    if enc == ENC_ZLIB:
        return zlib.decompress(payload)
    if enc == ENC_LZ4:
        if _lz4 is None:
            raise ValueError("lz4-encoded chunk but lz4 is not importable")
        return _lz4.decompress(payload)
    raise ValueError(f"unknown chunk encoding {enc!r}")


@dataclasses.dataclass
class CodecStats:
    """Compressed/raw split for one endpoint's chunk traffic.

    ``logical_bytes`` counts pre-codec chunk bytes, ``wire_bytes`` the
    encoded bytes actually framed; ``ratio`` is their quotient (1.0 for
    an all-raw stream).  Thread-safe: wire handler threads record into
    one instance per server/client.
    """
    raw_chunks: int = 0
    compressed_chunks: int = 0
    logical_bytes: int = 0
    wire_bytes: int = 0

    def __post_init__(self) -> None:
        self._mu = threading.Lock()

    def record(self, enc: str, logical: int, wire: int) -> None:
        with self._mu:
            if enc == ENC_RAW:
                self.raw_chunks += 1
            else:
                self.compressed_chunks += 1
            self.logical_bytes += logical
            self.wire_bytes += wire

    def ratio(self) -> float:
        with self._mu:
            return (self.logical_bytes / self.wire_bytes
                    if self.wire_bytes else 1.0)

    def as_dict(self) -> dict:
        with self._mu:
            out = {"raw_chunks": self.raw_chunks,
                   "compressed_chunks": self.compressed_chunks,
                   "logical_bytes": self.logical_bytes,
                   "wire_bytes": self.wire_bytes}
        out["compress_ratio"] = round(
            out["logical_bytes"] / out["wire_bytes"], 4) \
            if out["wire_bytes"] else 1.0
        return out
