"""Framed chunk protocol over Unix-domain sockets.

One frame is ``MAGIC(4) | type(1) | length(4, big-endian) | payload``.
The conversation mirrors PR 9's dedup accounting, but over a real wire:

  requester                         responder
  ---------                         ---------
  FETCH base + digest(have) ---->
                             <----  MANIFEST {pages, hashes, chunks...}
                             <----  CHUNKS <blob>          (inline mode)
  RELEASE ------------------>                              (shm mode)

The requester sends the 16-byte digests of every chunk it already holds
in its L1 index; the responder ships only the unique missing chunks.
Transport is chosen per response: payloads above ``inline_max_bytes``
ride a shared-memory segment (wire carries only ``(hash, off, len)``
descriptors; see :mod:`~repro.transport.shm` for the lifetime
contract), smaller ones are framed inline with optional per-chunk
compression (:mod:`~repro.transport.codec`).  A cold pull with an empty
have-set gets ``layout: full`` — the responder memcpys the whole WS
blob into the segment in page order so the requester can verify and
``install_block`` straight out of the mapping with zero intermediate
copy.

Every received chunk is re-hashed against the manifest before it is
surfaced; a corrupt payload raises :class:`ChunkHashMismatchError` and
nothing is installed.
"""
from __future__ import annotations

import dataclasses
import json
import os
import socket
import struct
import threading
import time

import numpy as np

from ..core.arena import PAGE
from ..core.pagestore import chunk_hash
from .codec import CodecStats, decode_chunk, encode_chunk
from .shm import ShmSegment, ShmView, shm_available

MAGIC = b"RPT1"
HEADER = struct.Struct(">4sBI")       # magic, frame type, payload length
MAX_FRAME = 1 << 28                   # 256 MiB: a frame larger than any WS

T_FETCH = 1
T_MANIFEST = 2
T_CHUNKS = 3
T_RELEASE = 4
T_OK = 5
T_ERR = 6

DIGEST_BYTES = 16                     # blake2b-128, matches pagestore.chunk_hash


class WireError(Exception):
    """Base for transport protocol failures."""


class TruncatedFrameError(WireError):
    """Peer closed (or corrupted) mid-frame."""


class BadMagicError(WireError):
    """Frame header does not start with ``RPT1``."""


class ChunkHashMismatchError(WireError):
    """A received chunk does not hash to its manifest entry."""


# ---------------------------------------------------------------- framing

def _recv_exact(conn: socket.socket, n: int, *, what: str = "frame") -> bytes:
    buf = bytearray()
    while len(buf) < n:
        try:
            got = conn.recv(n - len(buf))
        except OSError as e:
            raise TruncatedFrameError(f"recv failed mid-{what}: {e}") from e
        if not got:
            raise TruncatedFrameError(
                f"peer closed mid-{what} ({len(buf)}/{n} bytes)")
        buf += got
    return bytes(buf)


def send_frame(conn: socket.socket, ftype: int, payload: bytes = b"") -> int:
    """Send one frame; returns bytes put on the wire."""
    if len(payload) > MAX_FRAME:
        raise WireError(f"frame payload {len(payload)} exceeds MAX_FRAME")
    head = HEADER.pack(MAGIC, ftype, len(payload))
    conn.sendall(head + payload)
    return HEADER.size + len(payload)


def recv_frame(conn: socket.socket, *,
               allow_eof: bool = False) -> tuple[int, bytes] | None:
    """Receive one frame as ``(type, payload)``.

    ``allow_eof=True`` returns None on a clean close at a frame
    boundary (zero bytes before any header byte); EOF anywhere else is
    always a :class:`TruncatedFrameError`.
    """
    try:
        first = conn.recv(1)
    except OSError as e:
        raise TruncatedFrameError(f"recv failed at frame start: {e}") from e
    if not first:
        if allow_eof:
            return None
        raise TruncatedFrameError("peer closed at frame start")
    head = first + _recv_exact(conn, HEADER.size - 1, what="header")
    magic, ftype, length = HEADER.unpack(head)
    if magic != MAGIC:
        raise BadMagicError(f"bad frame magic {magic!r}")
    if length > MAX_FRAME:
        raise WireError(f"frame length {length} exceeds MAX_FRAME")
    return ftype, _recv_exact(conn, length, what="payload")


def pack_fetch(base: str, have) -> bytes:
    """FETCH payload: base name + the requester's L1 chunk-index digest
    (the 16-byte binary form of each held chunk hash)."""
    b = base.encode("utf-8")
    digests = b"".join(bytes.fromhex(h) for h in have)
    return struct.pack(">H", len(b)) + b + digests


def unpack_fetch(payload: bytes) -> tuple[str, set[str]]:
    (blen,) = struct.unpack_from(">H", payload)
    base = payload[2:2 + blen].decode("utf-8")
    raw = payload[2 + blen:]
    if len(raw) % DIGEST_BYTES:
        raise WireError("fetch digest list not a multiple of 16 bytes")
    have = {raw[i:i + DIGEST_BYTES].hex()
            for i in range(0, len(raw), DIGEST_BYTES)}
    return base, have


# ----------------------------------------------------------------- server

@dataclasses.dataclass
class ServerStats:
    """Per-server wire accounting (thread-safe via the handler lock)."""
    requests: int = 0
    misses: int = 0
    chunks_shipped: int = 0
    shm_responses: int = 0
    inline_responses: int = 0
    wire_tx_bytes: int = 0
    wire_rx_bytes: int = 0
    shm_bytes: int = 0

    def __post_init__(self) -> None:
        self._mu = threading.Lock()

    def as_dict(self) -> dict:
        with self._mu:
            return {k: getattr(self, k) for k in (
                "requests", "misses", "chunks_shipped", "shm_responses",
                "inline_responses", "wire_tx_bytes", "wire_rx_bytes",
                "shm_bytes")}


class PageServer:
    """Serves WS chunks for one node over a Unix-domain socket.

    ``serve(base)`` must return ``(pages, data, hashes)`` — the
    ``peek_chunks`` shape — or None when the WS is not resident.  Each
    connection gets a handler thread; handlers are tracked and joined in
    :meth:`close`.
    """

    def __init__(self, path: str, serve, *, inline_max_bytes: int = 64 << 10,
                 compress: bool = False, use_shm: bool = True,
                 level: int = 1):
        self.path = path
        self.serve = serve
        self.inline_max_bytes = inline_max_bytes
        self.compress = compress
        self.use_shm = use_shm and shm_available()
        self.level = level
        self.stats = ServerStats()
        self.codec = CodecStats()
        self._closed = threading.Event()
        self._conns: set[socket.socket] = set()
        self._threads: list[threading.Thread] = []
        self._mu = threading.Lock()
        if os.path.exists(path):
            os.unlink(path)           # stale endpoint from a dead server
        self._sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        self._sock.bind(path)
        self._sock.listen(16)
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name=f"pageserver:{path}", daemon=True)
        self._accept_thread.start()

    # -- connection plumbing

    def _accept_loop(self) -> None:
        while not self._closed.is_set():
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return                # listener closed
            with self._mu:
                if self._closed.is_set():
                    conn.close()
                    return
                self._conns.add(conn)
                t = threading.Thread(target=self._handle, args=(conn,),
                                     name="pageserver-conn", daemon=True)
                self._threads.append(t)
            t.start()

    def _handle(self, conn: socket.socket) -> None:
        try:
            while not self._closed.is_set():
                got = recv_frame(conn, allow_eof=True)
                if got is None:
                    return
                ftype, payload = got
                with self.stats._mu:
                    self.stats.wire_rx_bytes += HEADER.size + len(payload)
                if ftype == T_RELEASE:
                    continue          # stray release: nothing held
                if ftype != T_FETCH:
                    send_frame(conn, T_ERR, json.dumps(
                        {"error": f"unexpected frame type {ftype}"}).encode())
                    return
                self._respond(conn, payload)
        except WireError:
            pass                      # peer vanished; nothing to salvage
        finally:
            with self._mu:
                self._conns.discard(conn)
            try:
                conn.close()
            except OSError:
                pass

    # -- one fetch/response exchange

    def _respond(self, conn: socket.socket, payload: bytes) -> None:
        base, have = unpack_fetch(payload)
        with self.stats._mu:
            self.stats.requests += 1
        try:
            served = self.serve(base)
        except Exception as e:        # serve hook failed: report, keep conn
            tx = send_frame(conn, T_ERR,
                            json.dumps({"error": str(e)}).encode())
            with self.stats._mu:
                self.stats.wire_tx_bytes += tx
            return
        if served is None:
            tx = send_frame(conn, T_MANIFEST,
                            json.dumps({"status": "miss"}).encode())
            with self.stats._mu:
                self.stats.misses += 1
                self.stats.wire_tx_bytes += tx
            return

        pages, data, hashes = served
        pages = [int(p) for p in np.asarray(pages)]
        hashes = list(hashes)
        missing: list[str] = []       # unique, first-occurrence order
        seen = set(have)
        for h in hashes:
            if h not in seen:
                seen.add(h)
                missing.append(h)
        first_idx = {}
        for i, h in enumerate(hashes):
            first_idx.setdefault(h, i)
        raw_bytes = len(missing) * PAGE
        full = not have and len(missing) == len(hashes)

        manifest: dict = {"status": "ok", "pages": pages, "hashes": hashes}
        seg: ShmSegment | None = None
        blob = b""
        if missing and self.use_shm and raw_bytes > self.inline_max_bytes:
            manifest["transport"] = "shm"
            if full:
                # Cold pull: the WS blob is already the page-ordered
                # chunk sequence — one memcpy, identity descriptors.
                seg = ShmSegment(len(data))
                seg.seg.buf[:len(data)] = data
                manifest["layout"] = "full"
            else:
                seg = ShmSegment(raw_bytes)
                chunks = []
                for h in missing:
                    i = first_idx[h]
                    block = data[i * PAGE:(i + 1) * PAGE]
                    off = seg.write_chunk(block)
                    chunks.append({"h": h, "off": off, "len": PAGE,
                                   "enc": "raw"})
                manifest["layout"] = "sparse"
                manifest["chunks"] = chunks
            manifest["shm"] = {"name": seg.name, "size": seg.size}
            with self.stats._mu:
                self.stats.shm_responses += 1
                self.stats.shm_bytes += raw_bytes
                self.stats.chunks_shipped += len(missing)
        else:
            manifest["transport"] = "inline"
            manifest["layout"] = "sparse"
            chunks = []
            parts = []
            off = 0
            for h in missing:
                i = first_idx[h]
                block = data[i * PAGE:(i + 1) * PAGE]
                enc, packed = encode_chunk(block, compress=self.compress,
                                           level=self.level)
                self.codec.record(enc, len(block), len(packed))
                chunks.append({"h": h, "off": off, "len": len(packed),
                               "enc": enc})
                parts.append(packed)
                off += len(packed)
            manifest["chunks"] = chunks
            blob = b"".join(parts)
            with self.stats._mu:
                self.stats.inline_responses += 1
                self.stats.chunks_shipped += len(missing)

        try:
            tx = send_frame(conn, T_MANIFEST, json.dumps(manifest).encode())
            if manifest["transport"] == "inline" and missing:
                tx += send_frame(conn, T_CHUNKS, blob)
            with self.stats._mu:
                self.stats.wire_tx_bytes += tx
            if seg is not None:
                # Hold the segment until the requester releases it (a
                # dead connection is an implicit release).
                got = recv_frame(conn, allow_eof=True)
                if got is not None:
                    rtype, rpayload = got
                    with self.stats._mu:
                        self.stats.wire_rx_bytes += HEADER.size + len(rpayload)
                    if rtype != T_RELEASE:
                        raise WireError(
                            f"expected RELEASE after shm manifest, got {rtype}")
        finally:
            if seg is not None:
                seg.release()

    def close(self) -> None:
        self._closed.set()
        # closing a listener does not wake a thread blocked in accept();
        # shutdown + a throwaway self-connect guarantees it returns now
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            wake = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            wake.settimeout(0.5)
            wake.connect(self.path)
            wake.close()
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass
        with self._mu:
            conns = list(self._conns)
            threads = list(self._threads)
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                c.close()
            except OSError:
                pass
        self._accept_thread.join(timeout=5.0)
        for t in threads:
            t.join(timeout=5.0)
        if os.path.exists(self.path):
            try:
                os.unlink(self.path)
            except OSError:
                pass


# ----------------------------------------------------------------- client

@dataclasses.dataclass
class FetchResult:
    """One verified fetch: full page list plus the shipped payloads.

    ``chunks`` maps chunk hash -> raw bytes for every chunk the
    responder shipped (already hash-verified); chunks in the have-set
    were not shipped and must come from the requester's own index.
    """
    base: str
    pages: np.ndarray
    hashes: list[str]
    transport: str                    # "shm" | "inline" | "none"
    chunks: dict[str, bytes]
    wire_bytes: int                   # socket bytes both ways, this fetch
    shm_bytes: int
    rtt_s: float

    def assemble(self, lookup=None) -> bytes:
        """Reassemble the full page-ordered WS blob.

        ``lookup(hash) -> bytes`` supplies chunks the responder skipped
        because the requester's digest said it already held them.
        """
        parts = []
        for h in self.hashes:
            blk = self.chunks.get(h)
            if blk is None:
                if lookup is None:
                    raise KeyError(f"chunk {h} not shipped and no lookup")
                blk = lookup(h)
                if blk is None:
                    raise KeyError(f"chunk {h} unavailable locally")
            parts.append(blk)
        return b"".join(parts)


@dataclasses.dataclass
class ClientStats:
    fetches: int = 0
    misses: int = 0
    wire_tx_bytes: int = 0
    wire_rx_bytes: int = 0
    shm_bytes: int = 0
    inline_bytes: int = 0
    dedup_chunks_skipped: int = 0

    def __post_init__(self) -> None:
        self._mu = threading.Lock()
        self._rtts: list[float] = []

    def record_rtt(self, s: float) -> None:
        with self._mu:
            self._rtts.append(s)

    def rtt_percentiles(self) -> dict:
        with self._mu:
            r = sorted(self._rtts)
        if not r:
            return {"count": 0, "sum": 0.0, "p50": 0.0, "p95": 0.0}
        return {"count": len(r), "sum": round(sum(r), 6),
                "p50": round(r[len(r) // 2], 6),
                "p95": round(r[min(len(r) - 1, int(len(r) * 0.95))], 6)}

    def as_dict(self) -> dict:
        with self._mu:
            out = {k: getattr(self, k) for k in (
                "fetches", "misses", "wire_tx_bytes", "wire_rx_bytes",
                "shm_bytes", "inline_bytes", "dedup_chunks_skipped")}
        out["fetch_rtt_s"] = self.rtt_percentiles()
        return out


class PageClient:
    """Requester end: one persistent connection to a node's PageServer."""

    def __init__(self, path: str, *, timeout_s: float = 10.0):
        self.path = path
        self.stats = ClientStats()
        self._sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        self._sock.settimeout(timeout_s)
        self._sock.connect(path)
        self._mu = threading.Lock()

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass

    # -- core exchange

    def fetch(self, base: str, have=()) -> FetchResult | None:
        """Negotiate + pull ``base``'s WS; None when the peer lacks it.

        Every shipped chunk is re-hashed before the result is returned;
        a mismatch raises :class:`ChunkHashMismatchError` and the fetch
        yields nothing.
        """
        with self._mu:
            return self._fetch_locked(base, have, install=None)

    def fetch_install(self, base: str, arena) -> FetchResult | None:
        """Cold pull with zero-copy install.

        Sends an empty have-set so the responder ships the full WS; on
        the shm path the (n, PAGE) view over the mapped segment is
        handed straight to ``arena.install_block`` — the scatter reads
        shared memory, no intermediate copy.  Chunks are verified from
        the mapping *before* the install.
        """
        with self._mu:
            return self._fetch_locked(base, (), install=arena)

    def _fetch_locked(self, base: str, have, install) -> FetchResult | None:
        t0 = time.monotonic()
        tx = send_frame(self._sock, T_FETCH, pack_fetch(base, have))
        got = recv_frame(self._sock)
        ftype, payload = got
        rx = HEADER.size + len(payload)
        if ftype == T_ERR:
            raise WireError(json.loads(payload).get("error", "remote error"))
        if ftype != T_MANIFEST:
            raise WireError(f"expected MANIFEST, got frame type {ftype}")
        manifest = json.loads(payload)
        if manifest.get("status") != "ok":
            with self.stats._mu:
                self.stats.fetches += 1
                self.stats.misses += 1
                self.stats.wire_tx_bytes += tx
                self.stats.wire_rx_bytes += rx
            self.stats.record_rtt(time.monotonic() - t0)
            return None

        pages = np.asarray(manifest["pages"], dtype=np.int64)
        hashes: list[str] = manifest["hashes"]
        transport = manifest.get("transport", "none")
        chunks: dict[str, bytes] = {}
        shm_bytes = 0

        if transport == "shm":
            view = ShmView(manifest["shm"]["name"])
            try:
                if manifest.get("layout") == "full":
                    n = len(hashes)
                    block = view.block(0, n)
                    try:
                        for i, h in enumerate(hashes):
                            if chunk_hash(block[i].tobytes()) != h:
                                raise ChunkHashMismatchError(
                                    f"chunk {i} of {base} corrupt in shm")
                        shm_bytes = n * PAGE
                        if install is not None:
                            install.install_block(pages, block)
                        else:
                            for i, h in enumerate(hashes):
                                if h not in chunks:
                                    chunks[h] = block[i].tobytes()
                    finally:
                        # The numpy view exports a pointer into the
                        # mapping; it must die before view.close().
                        del block
                else:
                    for c in manifest["chunks"]:
                        blk = bytes(view.chunk(c["off"], c["len"]))
                        if chunk_hash(blk) != c["h"]:
                            raise ChunkHashMismatchError(
                                f"chunk {c['h']} of {base} corrupt in shm")
                        chunks[c["h"]] = blk
                        shm_bytes += c["len"]
            finally:
                view.close()
                tx += send_frame(self._sock, T_RELEASE)
        elif transport == "inline" and manifest.get("chunks"):
            cgot = recv_frame(self._sock)
            ctype, blob = cgot
            rx += HEADER.size + len(blob)
            if ctype != T_CHUNKS:
                raise WireError(f"expected CHUNKS, got frame type {ctype}")
            for c in manifest["chunks"]:
                blk = decode_chunk(c["enc"], blob[c["off"]:c["off"] + c["len"]])
                if chunk_hash(blk) != c["h"]:
                    raise ChunkHashMismatchError(
                        f"chunk {c['h']} of {base} corrupt on wire")
                chunks[c["h"]] = blk
            with self.stats._mu:
                self.stats.inline_bytes += len(blob)

        full_shm = transport == "shm" and manifest.get("layout") == "full"
        if install is not None and not full_shm:
            # Small/deduped pull that came back inline: assemble the
            # page-ordered block and install in one scatter.  (The shm
            # full layout already installed straight from the mapping.)
            blob = b"".join(chunks[h] for h in hashes)
            block = np.frombuffer(blob, dtype=np.uint8).reshape(-1, PAGE)
            install.install_block(pages, block)

        rtt = time.monotonic() - t0
        with self.stats._mu:
            self.stats.fetches += 1
            self.stats.wire_tx_bytes += tx
            self.stats.wire_rx_bytes += rx
            self.stats.shm_bytes += shm_bytes
            if not full_shm:
                self.stats.dedup_chunks_skipped += len(set(hashes)) - len(chunks)
        self.stats.record_rtt(rtt)
        return FetchResult(base=base, pages=pages, hashes=hashes,
                           transport=transport, chunks=chunks,
                           wire_bytes=tx + rx, shm_bytes=shm_bytes,
                           rtt_s=rtt)
