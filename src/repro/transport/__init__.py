"""Real inter-process page transport (ROADMAP item 1).

Everything that touches a raw ``socket`` or a
``multiprocessing.shared_memory`` segment lives behind this package
(lint REP008): the rest of the tree talks chunks and manifests, never
file descriptors.

  * :mod:`~repro.transport.codec` — per-chunk wire compression (raw vs
    zlib level 1, chosen by a cheap entropy probe).
  * :mod:`~repro.transport.wire` — length-prefixed framed protocol over
    Unix-domain sockets: chunk-hash negotiation, shm descriptors or
    inline payloads, per-chunk hash verification on receive.
  * :mod:`~repro.transport.shm` — the shared-memory data plane the wire
    rides for large transfers (zero-copy ``install_block`` installs).
  * :mod:`~repro.transport.procnode` — process-per-node fleet harness:
    a ``WorkerNode`` per child process with a private WS cache and a
    transport server, plus a supervisor speaking the ``ClusterRouter``
    scheduling interface (``build_fleet(transport="socket")``).
"""
from .codec import CodecStats, decode_chunk, encode_chunk
from .shm import shm_available
from .wire import (BadMagicError, ChunkHashMismatchError, PageClient,
                   PageServer, TruncatedFrameError, WireError)

__all__ = [
    "BadMagicError", "ChunkHashMismatchError", "CodecStats", "PageClient",
    "PageServer", "TruncatedFrameError", "WireError", "decode_chunk",
    "encode_chunk", "shm_available",
]
