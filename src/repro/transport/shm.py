"""Shared-memory data plane for the page transport.

Large chunk transfers never ride the socket: the responder writes the
missing chunks' raw bytes into a ``multiprocessing.shared_memory``
segment and the wire carries only ``(page_index, shm_offset, length)``
descriptors.  The requester maps the segment and scatters straight into
its :class:`~repro.core.arena.InstanceArena` via the existing
``install_block`` fast path — one copy total (segment -> arena), no
intermediate socket buffer.

Segment lifetime contract (the wire enforces it):

  * the **responder** creates + writes the segment and keeps it alive
    until the requester's RELEASE frame (or the connection dying, which
    counts as an implicit release);
  * the **requester** attaches, verifies chunk hashes against the
    manifest, installs/copies, closes its mapping, then releases;
  * the responder ``close()`` + ``unlink()``s — exactly one unlink per
    segment, so a crashed requester can never leak ``/dev/shm`` entries
    past its connection.

Chunks below the inline threshold (or hosts without shm support) fall
back to inline-on-socket payloads; wire.py makes that call.
"""
from __future__ import annotations

import numpy as np

try:
    from multiprocessing import shared_memory as _shm
except ImportError:                   # pragma: no cover - platform detail
    _shm = None

from ..core.arena import PAGE

_AVAILABLE: bool | None = None


def shm_available() -> bool:
    """True when a shared-memory segment can actually be created here
    (import succeeding is not enough: /dev/shm may be absent or sealed).
    Probed once per process."""
    global _AVAILABLE
    if _AVAILABLE is None:
        if _shm is None:
            _AVAILABLE = False
        else:
            try:
                seg = _shm.SharedMemory(create=True, size=PAGE)
                seg.close()
                seg.unlink()
                _AVAILABLE = True
            except (OSError, ValueError):
                _AVAILABLE = False
    return _AVAILABLE


class ShmSegment:
    """Responder-side segment: chunk payloads written back to back.

    ``write_chunks`` returns per-chunk offsets; the wire ships those as
    descriptors.  The segment stays alive until :meth:`release`.
    """

    def __init__(self, n_bytes: int):
        if _shm is None:
            raise OSError("multiprocessing.shared_memory unavailable")
        self.seg = _shm.SharedMemory(create=True, size=max(n_bytes, 1))
        self.name = self.seg.name
        self.size = self.seg.size
        self._off = 0

    def write_chunk(self, block: bytes) -> int:
        """Append one chunk; returns its segment offset."""
        off = self._off
        end = off + len(block)
        if end > self.size:
            raise ValueError(f"shm segment overflow ({end} > {self.size})")
        self.seg.buf[off:end] = block
        self._off = end
        return off

    def release(self) -> None:
        """Close and unlink (responder side, exactly once)."""
        try:
            self.seg.close()
            self.seg.unlink()
        except (OSError, FileNotFoundError):
            pass                      # already gone: release is idempotent


class ShmView:
    """Requester-side mapping of a responder's segment.

    ``block(off, n_chunks)`` exposes ``n_chunks`` contiguous chunks as a
    zero-copy ``(n_chunks, PAGE)`` uint8 view suitable for
    ``InstanceArena.install_block`` — the scatter reads the mapped
    segment directly.  Close the view only after the install."""

    def __init__(self, name: str):
        if _shm is None:
            raise OSError("multiprocessing.shared_memory unavailable")
        self.seg = _shm.SharedMemory(name=name)

    def chunk(self, off: int, length: int) -> memoryview:
        return self.seg.buf[off:off + length]

    def block(self, off: int, n_chunks: int) -> np.ndarray:
        return np.frombuffer(self.seg.buf, dtype=np.uint8,
                             count=n_chunks * PAGE,
                             offset=off).reshape(-1, PAGE)

    def close(self) -> None:
        try:
            self.seg.close()          # never unlink: the responder owns it
        except (OSError, BufferError):
            pass
