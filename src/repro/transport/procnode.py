"""Process-per-node fleet: real address spaces, real page movement.

The inproc fleet (cluster/scheduler.py) is threads in one heap — its
"transfers" are numpy references and a :class:`TransferModel` sleep.
This harness runs each :class:`~repro.cluster.node.WorkerNode` in its
own **child process** with a private ``WSCache``, so a WS moving between
nodes must actually cross an address-space boundary:

  * every child runs a :class:`~repro.transport.wire.PageServer` over a
    Unix-domain socket, serving its L1 via ``peek_chunks``;
  * a child's L1 miss resolves through :class:`TransportSource` — it
    dials the function's owner shards (same consistent-hash ring, built
    independently but deterministically in every process), negotiates
    the chunk diff against its own L1 index, and reassembles the WS from
    shipped + locally-held chunks; dead owners fall back to the origin
    read exactly like the inproc shard tier (``dead_owner_fallbacks``);
  * the supervisor (:class:`ProcessFleet`) speaks the same scheduling
    interface as :class:`~repro.cluster.ClusterRouter` — submit/invoke/
    map/register/rebalance/kill_node/stats — so
    ``build_fleet(..., transport="socket")`` A/Bs the two fleets on
    identical traces.

Children are ``spawn``ed (fork is unsafe once jax has initialised) and
controlled over a ``multiprocessing.Pipe``: small sync RPCs for control
and signals, a two-phase submit (sync admission ack, async result) for
the data plane.  Invocation outputs come back as numpy arrays, so the
benchmark's byte-parity check against the inproc fleet is exact.
"""
from __future__ import annotations

import dataclasses
import multiprocessing as mp
import os
import tempfile
import threading
import time
import traceback

import numpy as np

from ..cluster.scheduler import ScheduleConfig
from ..cluster.shardmap import ConsistentHashRing
from .wire import PageClient, PageServer, WireError


class FleetNodeDownError(RuntimeError):
    """The child process backing this node is gone."""


@dataclasses.dataclass
class NodeSpec:
    """Everything a child needs to assemble its node (must pickle)."""
    node_id: str
    store_dir: str
    sock_dir: str
    node_ids: tuple[str, ...]        # full fleet, for the local ring copy
    config: object                   # ServeConfig (telemetry/demand stripped)
    replication: int = 1
    vnodes: int = 64
    cache_capacity_bytes: int = 256 << 20
    transport_compress: bool = False
    transport_shm: bool = True
    transport_inline_max: int = 64 << 10

    def sock_path(self, node_id: str) -> str:
        return os.path.join(self.sock_dir, f"{node_id}.sock")


class TransportSource:
    """A child L1's miss resolver: owner sockets first, origin disk last.

    Mirrors ``ShardedSnapshotStore._shard_fetch``'s accounting — remote
    fetch / cold-owner miss / dead-owner fallback / origin read — but
    the bytes actually move: the owner's PageServer ships the chunk diff
    over shm or the socket, and this side reassembles from shipped plus
    locally-held chunks.
    """

    def __init__(self, spec: NodeSpec, ring: ConsistentHashRing):
        self.spec = spec
        self.ring = ring
        self.cache = None            # wired after WSCache construction
        self._clients: dict[str, PageClient] = {}
        self._mu = threading.Lock()
        self.remote_fetches = 0
        self.remote_misses = 0
        self.origin_reads = 0
        self.dead_owner_fallbacks = 0

    def _client(self, owner: str) -> PageClient:
        with self._mu:
            cli = self._clients.get(owner)
        if cli is None:
            cli = PageClient(self.spec.sock_path(owner))
            with self._mu:
                self._clients[owner] = cli
        return cli

    def _drop_client(self, owner: str) -> None:
        with self._mu:
            cli = self._clients.pop(owner, None)
        if cli is not None:
            cli.close()

    def _assemble(self, result) -> bytes:
        held = [h for h in result.hashes if h not in result.chunks]
        local = (self.cache.chunk_payloads(held)
                 if self.cache is not None and held else {})
        return result.assemble(lookup=local.get)

    def __call__(self, base: str, cfg, group: int = 1):
        name = os.path.basename(base)
        owners = self.ring.lookup(name, self.spec.replication)
        any_dead = False
        for owner in owners:
            if owner == self.spec.node_id:
                continue             # own L1 already missed
            try:
                cli = self._client(owner)
                have = (self.cache.chunk_index()
                        if self.cache is not None else ())
                result = cli.fetch(base, have)
                if result is None:
                    with self._mu:
                        self.remote_misses += 1
                    continue         # owner is cold: try next replica
                try:
                    data = self._assemble(result)
                except KeyError:
                    # a locally-held chunk was evicted between the index
                    # digest and reassembly: refetch without negotiation
                    result = cli.fetch(base, ())
                    if result is None:
                        with self._mu:
                            self.remote_misses += 1
                        continue
                    data = self._assemble(result)
            except (WireError, OSError):
                # owner process is gone (or mid-death): drop the broken
                # connection and treat it like a dead shard
                self._drop_client(owner)
                any_dead = True
                continue
            with self._mu:
                self.remote_fetches += 1
            return [int(p) for p in result.pages], data
        if any_dead:
            with self._mu:
                self.dead_owner_fallbacks += 1
        from ..core.reap import _read_ws
        pages, data = _read_ws(base, cfg)
        with self._mu:
            self.origin_reads += 1
        return pages, data

    def stats(self) -> dict:
        with self._mu:
            out = {"remote_fetches": self.remote_fetches,
                   "remote_misses": self.remote_misses,
                   "origin_reads": self.origin_reads,
                   "dead_owner_fallbacks": self.dead_owner_fallbacks}
            clients = list(self._clients.values())
        merged: dict = {}
        rtts: list[float] = []
        for cli in clients:
            d = cli.stats.as_dict()
            rtt = d.pop("fetch_rtt_s")
            with cli.stats._mu:
                rtts.extend(cli.stats._rtts)
            for k, v in d.items():
                merged[k] = merged.get(k, 0) + v
        out.update(merged)
        rtts.sort()
        out["fetch_rtt_s"] = (
            {"count": len(rtts), "sum": round(sum(rtts), 6),
             "p50": round(rtts[len(rtts) // 2], 6),
             "p95": round(rtts[min(len(rtts) - 1, int(len(rtts) * 0.95))], 6)}
            if rtts else {"count": 0, "sum": 0.0, "p50": 0.0, "p95": 0.0})
        return out

    def reset_stats(self) -> None:
        with self._mu:
            self.remote_fetches = self.remote_misses = 0
            self.origin_reads = self.dead_owner_fallbacks = 0
            clients = list(self._clients.values())
        for cli in clients:
            with cli.stats._mu:
                for k in ("fetches", "misses", "wire_tx_bytes",
                          "wire_rx_bytes", "shm_bytes", "inline_bytes",
                          "dedup_chunks_skipped"):
                    setattr(cli.stats, k, 0)
                cli.stats._rtts.clear()

    def close(self) -> None:
        with self._mu:
            clients = list(self._clients.values())
            self._clients.clear()
        for cli in clients:
            cli.close()


# --------------------------------------------------------------- child side

def _node_main(spec: NodeSpec, conn) -> None:
    """Child entry point: build node + transport, serve the control pipe."""
    from ..cluster.node import WorkerNode
    from ..core.reap import WSCache

    ring = ConsistentHashRing(spec.node_ids, vnodes=spec.vnodes)
    source = TransportSource(spec, ring)
    cache = WSCache(spec.cache_capacity_bytes, source=source)
    source.cache = cache
    node = WorkerNode(spec.node_id, spec.store_dir, spec.config,
                      ws_cache=cache)
    server = PageServer(spec.sock_path(spec.node_id),
                        lambda base: cache.peek_chunks(base),
                        inline_max_bytes=spec.transport_inline_max,
                        compress=spec.transport_compress,
                        use_shm=spec.transport_shm)
    send_mu = threading.Lock()

    def reply(rid, kind, payload=None):
        with send_mu:
            try:
                conn.send((rid, kind, payload))
            except (OSError, ValueError, BrokenPipeError):
                pass                 # supervisor gone: nothing to tell

    def _wait_result(rid, inv):
        try:
            out, report = inv.result()
            reply(rid, "result", (np.asarray(out), report))
        except BaseException as e:
            reply(rid, "result_err", _shippable(e))

    def transport_stats() -> dict:
        out = source.stats()
        srv = server.stats.as_dict()
        out["wire_tx_bytes"] = out.get("wire_tx_bytes", 0) + srv["wire_tx_bytes"]
        out["wire_rx_bytes"] = out.get("wire_rx_bytes", 0) + srv["wire_rx_bytes"]
        out["chunks_served"] = srv["chunks_shipped"]
        out["shm_responses"] = srv["shm_responses"]
        out["inline_responses"] = srv["inline_responses"]
        codec = server.codec.as_dict()
        out["raw_chunks"] = codec["raw_chunks"]
        out["compressed_chunks"] = codec["compressed_chunks"]
        out["compress_ratio"] = codec["compress_ratio"]
        return out

    running = True
    while running:
        try:
            rid, op, args = conn.recv()
        except (EOFError, OSError):
            break                    # supervisor died: shut down
        try:
            if op == "register":
                name, cfg, seed, warmup = args
                node.register(name, cfg, seed=seed, warmup_batch=warmup)
                reply(rid, "ok")
            elif op == "submit":
                name, batch, force_cold = args
                inv = node.submit(name, batch, force_cold=force_cold)
                reply(rid, "ok")     # admitted; result streams back later
                threading.Thread(target=_wait_result, args=(rid, inv),
                                 daemon=True).start()
            elif op == "signals":
                (name,) = args
                reply(rid, "ok", (node.alive, node.load(),
                                  node.warm_count(name),
                                  node.ws_resident(name), node.capacity))
            elif op == "stats":
                s = node.stats()
                s["transport"] = transport_stats()
                reply(rid, "ok", s)
            elif op == "warm_owner":
                (base,) = args
                from ..core.reap import has_record
                if has_record(base):
                    cache.fetch(base, node.config.resolved_reap())
                    reply(rid, "ok", True)
                else:
                    reply(rid, "ok", False)
            elif op == "scale_to_zero":
                (name,) = args
                node.orch.scale_to_zero(name)
                reply(rid, "ok")
            elif op == "clear_cache":
                cache.clear()
                reply(rid, "ok")
            elif op == "reset_stats":
                cache.reset_stats()
                source.reset_stats()
                reply(rid, "ok")
            elif op == "push_forecast":
                node.push_forecast(*args)
                reply(rid, "ok")
            elif op == "clear_forecast":
                node.clear_forecast(*args)
                reply(rid, "ok")
            elif op == "drain":
                (timeout,) = args
                node.router.drain(timeout)
                reply(rid, "ok")
            elif op == "close":
                node.close()
                server.close()
                source.close()
                reply(rid, "ok")
                running = False
            else:
                reply(rid, "err", ValueError(f"unknown op {op!r}"))
        except BaseException as e:
            reply(rid, "err", _shippable(e))
    try:
        conn.close()
    except OSError:
        pass


def _shippable(e: BaseException) -> BaseException:
    """Exceptions cross the pipe; one that can't pickle becomes a
    RuntimeError carrying its traceback text."""
    try:
        import pickle
        pickle.dumps(e)
        return e
    except Exception:
        return RuntimeError(
            "".join(traceback.format_exception(type(e), e, e.__traceback__)))


# ---------------------------------------------------------- supervisor side

class FleetInvocation:
    """Future for one socket-fleet invocation (two-phase submit)."""

    def __init__(self, fleet: "ProcessFleet", name: str, batch: dict,
                 force_cold: bool):
        self._fleet = fleet
        self.name = name
        self.batch = batch
        self.force_cold = force_cold
        self.node_ids: list[str] = []
        self._ev = threading.Event()
        self._out = None
        self._err: BaseException | None = None

    def _resolve(self, out=None, err=None) -> None:
        self._out, self._err = out, err
        self._ev.set()

    def done(self) -> bool:
        return self._ev.is_set() and not isinstance(
            self._err, FleetNodeDownError)

    def result(self, timeout: float | None = None):
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        while True:
            left = (None if deadline is None
                    else max(deadline - time.monotonic(), 0.0))
            if not self._ev.wait(left):
                raise TimeoutError(f"{self.name}: no result in {timeout}s")
            if self._err is None:
                return self._out
            if isinstance(self._err, FleetNodeDownError):
                # placement died: reroute onto a survivor and wait again
                self._ev.clear()
                self._fleet._reroute(self)
                continue
            raise self._err

    @property
    def report(self):
        return self.result()[1]


class ProcessNode:
    """Supervisor-side proxy for one child process."""

    def __init__(self, spec: NodeSpec, ctx):
        self.node_id = spec.node_id
        self.spec = spec
        self.capacity = 4            # refreshed from the first signals RPC
        self.alive = True
        self._conn, child_conn = ctx.Pipe()
        self._proc = ctx.Process(target=_node_main, args=(spec, child_conn),
                                 name=f"procnode-{spec.node_id}", daemon=True)
        self._proc.start()
        child_conn.close()
        self._mu = threading.Lock()
        self._next_rid = 0
        self._waiters: dict[int, dict] = {}   # rid -> {"ev", "kind", "payload"}
        self._invs: dict[int, FleetInvocation] = {}
        self._reader = threading.Thread(target=self._read_loop,
                                        name=f"procnode-rx-{spec.node_id}",
                                        daemon=True)
        self._reader.start()

    # -- pipe plumbing

    def _read_loop(self) -> None:
        while True:
            try:
                rid, kind, payload = self._conn.recv()
            except (EOFError, OSError):
                break
            if kind in ("result", "result_err"):
                with self._mu:
                    inv = self._invs.pop(rid, None)
                if inv is not None:
                    if kind == "result":
                        out, report = payload
                        inv._resolve(out=(out, report))
                    else:
                        inv._resolve(err=payload)
                continue
            with self._mu:
                w = self._waiters.pop(rid, None)
            if w is not None:
                w["kind"], w["payload"] = kind, payload
                w["ev"].set()
        self._fail_pending()

    def _fail_pending(self) -> None:
        self.alive = False
        with self._mu:
            waiters = list(self._waiters.values())
            self._waiters.clear()
            invs = list(self._invs.values())
            self._invs.clear()
        for w in waiters:
            w["kind"], w["payload"] = "down", None
            w["ev"].set()
        for inv in invs:
            inv._resolve(err=FleetNodeDownError(
                f"node {self.node_id} died mid-invocation"))

    def _call(self, op: str, *args, timeout: float = 300.0,
              inv: FleetInvocation | None = None):
        if not self.alive:
            raise FleetNodeDownError(f"node {self.node_id} is down")
        w = {"ev": threading.Event(), "kind": None, "payload": None}
        with self._mu:
            rid = self._next_rid
            self._next_rid += 1
            self._waiters[rid] = w
            if inv is not None:
                self._invs[rid] = inv
            try:
                self._conn.send((rid, op, args))
            except (OSError, ValueError, BrokenPipeError) as e:
                self._waiters.pop(rid, None)
                self._invs.pop(rid, None)
                raise FleetNodeDownError(
                    f"node {self.node_id} pipe is closed") from e
        if not w["ev"].wait(timeout):
            with self._mu:
                self._waiters.pop(rid, None)
            raise TimeoutError(f"{self.node_id}: {op} RPC timed out")
        if w["kind"] == "down":
            with self._mu:
                self._invs.pop(rid, None)
            raise FleetNodeDownError(f"node {self.node_id} died during {op}")
        if w["kind"] == "err":
            with self._mu:
                self._invs.pop(rid, None)
            raise w["payload"]
        return w["payload"]

    # -- WorkerNode-shaped surface

    def register(self, name, cfg, *, seed=0, warmup_batch=None,
                 timeout=600.0):
        return self._call("register", name, cfg, seed, warmup_batch,
                          timeout=timeout)

    def submit(self, name: str, batch: dict, inv: FleetInvocation, *,
               force_cold: bool = False) -> None:
        """Two-phase: this call returns once the child *admitted* the
        invocation (AdmissionError raises here, synchronously, like the
        inproc node); the result resolves ``inv`` later."""
        self._call("submit", name, batch, force_cold, inv=inv)
        inv.node_ids.append(self.node_id)

    def signals(self, name: str) -> tuple:
        alive, load, warm, ws_res, cap = self._call("signals", name,
                                                    timeout=30.0)
        self.capacity = cap
        return alive, load, warm, ws_res

    def stats(self) -> dict:
        return self._call("stats", timeout=60.0)

    def warm_owner(self, base: str) -> bool:
        return self._call("warm_owner", base)

    def scale_to_zero(self, name: str) -> None:
        self._call("scale_to_zero", name)

    def clear_cache(self) -> None:
        self._call("clear_cache")

    def reset_stats(self) -> None:
        self._call("reset_stats")

    def push_forecast(self, name, rate_rps, expires_at) -> None:
        self._call("push_forecast", name, rate_rps, expires_at, timeout=30.0)

    def clear_forecast(self, name) -> None:
        self._call("clear_forecast", name, timeout=30.0)

    def drain(self, timeout: float | None = None) -> None:
        self._call("drain", timeout,
                   timeout=(timeout or 300.0) + 30.0)

    def kill(self) -> None:
        """Hard host failure: SIGTERM the child.  Its PageServer socket
        dies with it, so peers mid-fetch see connection errors and take
        the dead-owner fallback; pending invocations here resolve with
        FleetNodeDownError and reroute."""
        self.alive = False
        if self._proc.is_alive():
            self._proc.terminate()
        self._proc.join(timeout=10.0)
        self._fail_pending()

    def close(self) -> None:
        if self.alive:
            try:
                self._call("close", timeout=120.0)
            except (FleetNodeDownError, TimeoutError):
                pass
        self.alive = False
        self._proc.join(timeout=30.0)
        if self._proc.is_alive():
            self._proc.terminate()
            self._proc.join(timeout=10.0)
        try:
            self._conn.close()
        except OSError:
            pass


class ProcessFleet:
    """Supervisor speaking the ClusterRouter scheduling interface over a
    fleet of child processes.

    Placement reuses :class:`~repro.cluster.ScheduleConfig` scoring —
    warm instances, WS residency, shard ownership, load — but reads the
    signals with one RPC per node instead of an in-heap method call.
    """

    def __init__(self, nodes: list[ProcessNode], *,
                 cfg: ScheduleConfig | None = None,
                 replication: int = 1, vnodes: int = 64,
                 sock_dir: str | None = None):
        self.cfg = cfg or ScheduleConfig()
        self.nodes: dict[str, ProcessNode] = {n.node_id: n for n in nodes}
        self.ring = ConsistentHashRing(tuple(self.nodes), vnodes=vnodes)
        self.replication = replication
        self._sock_dir = sock_dir
        self._functions: dict[str, tuple] = {}
        self._mu = threading.Lock()
        self.store = None            # no in-heap shard tier: data is remote
        self.demand_plane = None
        self.telemetry = None
        self.n_placed = 0
        self.n_rerouted = 0
        self.n_rejected = 0
        self.placements: dict[str, int] = {n: 0 for n in self.nodes}

    # -- membership / control plane

    def alive_nodes(self) -> list[ProcessNode]:
        return [n for n in self.nodes.values() if n.alive]

    def register(self, name, cfg, *, seed=0, warmup_batch=None,
                 replication=None) -> None:
        """Register fleet-wide.  Sequential on purpose: the first child
        builds the snapshot in the shared store_dir, the rest reuse it
        read-only (racing children could double-build).  Each child gets
        the warm-up batch — jit caches are per-process."""
        with self._mu:
            self._functions[name] = (cfg, seed)
        for node in self.alive_nodes():
            node.register(name, cfg, seed=seed, warmup_batch=warmup_batch)

    def rebalance(self) -> dict[str, int]:
        """Pull each function's WS into its owner shards' child caches."""
        with self._mu:
            names = list(self._functions)
        store_dirs = {n.spec.store_dir for n in self.nodes.values()}
        warmed = {}
        for name in names:
            owners = self.ring.lookup(name, self.replication)
            n = 0
            for owner in owners:
                node = self.nodes.get(owner)
                if node is None or not node.alive:
                    continue
                for d in store_dirs:
                    if node.warm_owner(os.path.join(d, name)):
                        n += 1
            warmed[name] = n
        return warmed

    def kill_node(self, node_id: str) -> int:
        node = self.nodes[node_id]
        self.ring.remove(node_id)
        node.kill()
        return 0                     # reroutes happen lazily in result()

    # -- placement

    def rank(self, name: str) -> list[ProcessNode]:
        alive = self.alive_nodes()
        if not alive:
            return []
        owners = set(self.ring.lookup(name, self.replication))
        c = self.cfg
        scored = []
        for n in alive:
            try:
                up, load, warm, ws_res = n.signals(name)
            except (FleetNodeDownError, TimeoutError):
                continue
            if not up:
                continue
            s = 0.0
            if warm > 0:
                s += c.w_warm
            if ws_res:
                s += c.w_ws
            if n.node_id in owners:
                s += c.w_owner
            s -= c.w_load * load / max(n.capacity, 1)
            scored.append((-s, load, n.node_id, n))
        scored.sort(key=lambda t: (t[0], t[1], t[2]))
        return [t[3] for t in scored]

    def _submit_once(self, inv: FleetInvocation) -> None:
        from ..serving import AdmissionError
        admission = None
        for node in self.rank(inv.name):
            try:
                node.submit(inv.name, inv.batch, inv,
                            force_cold=inv.force_cold)
            except AdmissionError as e:
                admission = e
                continue
            except (FleetNodeDownError, TimeoutError):
                continue
            with self._mu:
                self.n_placed += 1
                self.placements[node.node_id] = (
                    self.placements.get(node.node_id, 0) + 1)
            return
        if admission is not None:
            with self._mu:
                self.n_rejected += 1
            raise admission
        raise FleetNodeDownError("no alive nodes in the fleet")

    def _reroute(self, inv: FleetInvocation) -> None:
        with self._mu:
            self.n_rerouted += 1
        if len(inv.node_ids) > self.cfg.max_reroutes:
            inv._resolve(err=RuntimeError(
                f"{inv.name}: reroute budget exhausted ({inv.node_ids})"))
            return
        try:
            self._submit_once(inv)
        except BaseException as e:
            inv._resolve(err=e)

    # -- client API

    def submit(self, name: str, batch: dict, *,
               force_cold: bool = False) -> FleetInvocation:
        inv = FleetInvocation(self, name, batch, force_cold)
        self._submit_once(inv)
        return inv

    def invoke(self, name: str, batch: dict, *, force_cold: bool = False,
               timeout: float | None = None):
        return self.submit(name, batch, force_cold=force_cold).result(timeout)

    def map(self, items, *, force_cold: bool = False) -> list:
        invs = [self.submit(n, b, force_cold=force_cold) for n, b in items]
        return [inv.result() for inv in invs]

    # -- maintenance / observability

    def drain(self, timeout: float | None = None) -> None:
        for node in self.alive_nodes():
            node.drain(timeout)

    def scale_to_zero(self, name: str) -> None:
        for node in self.alive_nodes():
            node.scale_to_zero(name)

    def clear_caches(self) -> None:
        for node in self.alive_nodes():
            node.clear_cache()

    def reset_stats(self) -> None:
        with self._mu:
            self.n_placed = self.n_rerouted = self.n_rejected = 0
            self.placements = {n: 0 for n in self.nodes}
        for node in self.alive_nodes():
            node.reset_stats()

    def stats(self) -> dict:
        with self._mu:
            out = {"placement": self.cfg.placement,
                   "placed": self.n_placed,
                   "rerouted": self.n_rerouted,
                   "rejected": self.n_rejected,
                   "placements": dict(self.placements),
                   "transport": "socket"}
        out["nodes"] = {}
        for node in self.alive_nodes():
            try:
                out["nodes"][node.node_id] = node.stats()
            except (FleetNodeDownError, TimeoutError):
                continue
        return out

    def close(self) -> None:
        if self.telemetry is not None:
            self.telemetry.close()
        for node in self.nodes.values():
            node.close()
        if self._sock_dir is not None:
            try:
                for f in os.listdir(self._sock_dir):
                    os.unlink(os.path.join(self._sock_dir, f))
                os.rmdir(self._sock_dir)
            except OSError:
                pass

    def __enter__(self) -> "ProcessFleet":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def build_process_fleet(n_nodes: int, store_dir: str, *,
                        config=None, cfg: ScheduleConfig | None = None,
                        replication: int = 1, vnodes: int = 64,
                        cache_capacity_bytes: int = 256 << 20,
                        sock_dir: str | None = None) -> ProcessFleet:
    """Assemble the socket fleet: N spawned children + supervisor.

    The per-child ServeConfig is the supervisor's with telemetry and
    demand stripped (children must not race each other's output files;
    the fleet-level snapshotter nests their stats instead) and the
    transport knobs read off ``config`` (``transport_compress``,
    ``transport_shm``, ``transport_inline_max``).
    """
    from ..serving import ServeConfig
    if config is None:
        config = ServeConfig(overlap_install=False)
    child_cfg = dataclasses.replace(config, telemetry=None, demand=None)
    own_sock_dir = sock_dir is None
    if sock_dir is None:
        sock_dir = tempfile.mkdtemp(prefix="rpt-")
    node_ids = tuple(f"node-{i}" for i in range(n_nodes))
    ctx = mp.get_context("spawn")
    nodes = []
    for node_id in node_ids:
        spec = NodeSpec(
            node_id=node_id, store_dir=store_dir, sock_dir=sock_dir,
            node_ids=node_ids, config=child_cfg,
            replication=replication, vnodes=vnodes,
            cache_capacity_bytes=cache_capacity_bytes,
            transport_compress=getattr(config, "transport_compress", False),
            transport_shm=getattr(config, "transport_shm", True),
            transport_inline_max=getattr(config, "transport_inline_max",
                                         64 << 10))
        nodes.append(ProcessNode(spec, ctx))
    fleet = ProcessFleet(nodes, cfg=cfg, replication=replication,
                         vnodes=vnodes,
                         sock_dir=sock_dir if own_sock_dir else None)
    tcfg = getattr(config, "telemetry", None)
    if tcfg is not None:
        from ..telemetry import TELEMETRY, StatsSnapshotter
        path = (os.path.join(tcfg.out_dir, "fleet.jsonl")
                if tcfg.out_dir else None)
        snap = StatsSnapshotter(interval_s=tcfg.interval_s, path=path,
                                ring=tcfg.ring)
        snap.add_source("cluster", fleet.stats)
        snap.add_source("registry", TELEMETRY.collect)
        fleet.telemetry = snap.start()
    return fleet
