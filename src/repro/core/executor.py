"""Demand-paged invocation executor.

Runs one function invocation against an :class:`InstanceArena`, faulting
guest pages in execution order -- the framework-level userfaultfd analogue
(DESIGN.md §3).  The fault schedule is *model-aware*:

  * infra pages first (runtime/tokenizer/channel state -- every invocation),
  * embedding rows for exactly the request's tokens,
  * trunk weights layer by layer (row-sliced from the scanned stacks),
  * for MoE layers: attention + router + shared experts first, then -- after
    computing the true routing on the actual activations -- only the pages
    of the *routed* experts (the input-dependent "unique pages" of Fig. 5),
  * modality frontend banks only when the invocation carries that modality.

Compute runs eagerly (jnp on host) using the same family apply functions as
the jitted path, so the result is numerically identical to a warm
invocation; unrouted expert slots stay zero-filled and are provably unused.
"""
from __future__ import annotations

import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import get_family, moe as moe_mod
from ..nn import layers as nn
from ..nn import spec as nnspec
from .arena import InstanceArena


def _np(arena: InstanceArena, path: str, fault: bool = True, parallel: int = 0):
    return arena.tensor(path, fault=fault, parallel=parallel)


# Jitted compute pieces.  ``cfg`` is a frozen dataclass => hashable => static;
# executables are compiled once per (cfg, shapes) at function deploy time and
# *restored* (cache lookup) at cold start, like Firecracker's device-state
# restore.  Invocation-time compute therefore reflects steady-state serving.
import functools


@functools.partial(jax.jit, static_argnums=0)
def _jit_forward(cfg, params, batch):
    return get_family(cfg).forward(cfg, params, batch)


@functools.partial(jax.jit, static_argnums=0)
def _jit_dense_layer(cfg, lp, x):
    return moe_mod._dense_fwd(cfg, lp, x)[0]


@functools.partial(jax.jit, static_argnums=0)
def _jit_moe_attn(cfg, mp, x):
    """Attention sub-block + router logits of an MoE layer."""
    h = nn.apply_rmsnorm(mp["ln1"], x)
    h_attn, _ = nn.apply_attention(mp["attn"], h, rope_theta=cfg.rope_theta,
                                   chunk=cfg.attn_chunk)
    x = x + h_attn
    h2 = nn.apply_rmsnorm(mp["ln2"], x)
    experts = moe_mod.routed_experts(mp["moe"], h2, cfg)
    return x, h2, experts


@functools.partial(jax.jit, static_argnums=0)
def _jit_moe_apply(cfg, moe_p, x, h2):
    return x + moe_mod.apply_moe_mlp(moe_p, h2, cfg)


@functools.partial(jax.jit, static_argnums=0)
def _jit_embed(cfg, table, tokens):
    return nn.apply_embedding({"table": table}, tokens)


@functools.partial(jax.jit, static_argnums=0)
def _jit_head(cfg, ln_f, lm_head, x):
    x = nn.apply_rmsnorm(ln_f, x)
    return nn.apply_lm_head(lm_head, x)


def warm_executables(cfg: ModelConfig, example_batch: dict) -> None:
    """Compile (once, at function deploy) every executable an invocation
    needs, by running them on zero-filled params of the right shapes."""
    specs = get_family(cfg).param_specs(cfg)
    zeros = nnspec.map_leaves(lambda _, s: jnp.zeros(s.shape, s.dtype), specs)
    if cfg.family != "moe":
        _jit_forward(cfg, zeros, example_batch)[0].block_until_ready()
        return
    tokens = jnp.asarray(example_batch["tokens"])
    x = _jit_embed(cfg, zeros["embed"]["table"], tokens)
    if cfg.first_dense:
        lp = jax.tree.map(lambda a: a[0], zeros["first_dense"])
        x = _jit_dense_layer(cfg, lp, x)
    gp = jax.tree.map(lambda a: a[0], zeros["groups"])
    if "dense_layers" in gp:
        lp = jax.tree.map(lambda a: a[0], gp["dense_layers"])
        x = _jit_dense_layer(cfg, lp, x)
    x2, h2, _ = _jit_moe_attn(cfg, gp["moe_layer"], x)
    x3 = _jit_moe_apply(cfg, gp["moe_layer"]["moe"], x2, h2)
    _jit_head(cfg, zeros["ln_f"], zeros["lm_head"], x3).block_until_ready()


class LazyParams:
    """Materializes the (stacked) param tree from the arena, page-faulting
    tensors on first access.  ``touch_order`` controls fault scheduling."""

    def __init__(self, cfg: ModelConfig, arena: InstanceArena, *,
                 parallel: int = 0):
        self.cfg = cfg
        self.arena = arena
        self.parallel = parallel
        self.specs = get_family(cfg).param_specs(cfg)
        self.paths = [p for p, _ in nnspec.tree_paths(self.specs)]

    def fault_all(self, skip_prefixes: tuple[str, ...] = (),
                  embed_rows: np.ndarray | None = None) -> None:
        for p in self.paths:
            full = f"params/{p}"
            if any(p.startswith(s) for s in skip_prefixes):
                continue
            if embed_rows is not None and p == "embed/table":
                self.arena.tensor_rows(full, embed_rows.tolist(),
                                       parallel=self.parallel)
            else:
                self.arena.touch_pages(
                    self.arena.layout.pages_of(full), parallel=self.parallel)

    def tree(self) -> Any:
        """Full param tree as jnp arrays (zero-filled where never faulted)."""
        return nnspec.map_leaves(
            lambda p, s: jnp.asarray(
                _np(self.arena, f"params/{p}", fault=False)),
            self.specs)


def _touch_infra(arena: InstanceArena) -> None:
    arena.touch_pages(sorted(arena.layout.region_pages("infra")))


def _expert_paths(prefix: str) -> tuple[str, ...]:
    return tuple(f"{prefix}/{n}" for n in ("wi_gate", "wi_up", "wo"))


def run_invocation(cfg: ModelConfig, arena: InstanceArena, batch: dict, *,
                   parallel: int = 0) -> tuple[jax.Array, float]:
    """Execute one inference invocation against the demand-paged arena.

    Returns (logits, seconds).  Every page the computation needs is faulted
    through the arena (so ``arena.stats`` is the paper's fault trace).
    """
    t0 = time.perf_counter()
    _touch_infra(arena)
    lp = LazyParams(cfg, arena, parallel=parallel)
    tokens = np.asarray(batch["tokens"])
    embed_rows = np.unique(tokens)

    if "patch_embeds" in batch and "vision/vit_stub" in arena.layout.entries:
        arena.touch_pages(arena.layout.pages_of("vision/vit_stub"),
                          parallel=parallel)
    if "frames" in batch and "audio/frontend_stub" in arena.layout.entries:
        arena.touch_pages(arena.layout.pages_of("audio/frontend_stub"),
                          parallel=parallel)

    if cfg.family != "moe":
        lp.fault_all(embed_rows=embed_rows)
        params = lp.tree()
        logits = _jit_forward(cfg, params, batch)
        return logits, time.perf_counter() - t0

    # ---- MoE: interleave routing with expert faulting ---------------------
    lp.fault_all(skip_prefixes=("groups/moe_layer/moe/wi",
                                "groups/moe_layer/moe/wo"),
                 embed_rows=embed_rows)
    params = lp.tree()
    x = _jit_embed(cfg, params["embed"]["table"], jnp.asarray(tokens))

    if cfg.first_dense:
        for i in range(cfg.first_dense):
            lpar = jax.tree.map(lambda a, i=i: a[i], params["first_dense"])
            x = _jit_dense_layer(cfg, lpar, x)

    for g in range(moe_mod.n_groups(cfg)):
        gp = jax.tree.map(lambda a, g=g: a[g], params["groups"])
        if "dense_layers" in gp:
            for j in range(cfg.moe_every - 1):
                lpar = jax.tree.map(lambda a, j=j: a[j], gp["dense_layers"])
                x = _jit_dense_layer(cfg, lpar, x)
        # route on the true activations, then fault only the routed experts
        mp = gp["moe_layer"]
        x, h2, routed = _jit_moe_attn(cfg, mp, x)
        experts = np.unique(np.asarray(routed))
        for path in _expert_paths("params/groups/moe_layer/moe"):
            e = arena.layout.entries[path]
            # stacked layout (n_groups, E, ...): rows within group g
            per_group = e.nbytes // e.shape[0]
            per_expert = per_group // e.shape[1]
            pages: set[int] = set()
            for ex in experts:
                lo = e.offset + g * per_group + int(ex) * per_expert
                hi = lo + per_expert
                pages.update(range(lo // 4096, (hi - 1) // 4096 + 1))
            arena.touch_pages(sorted(pages), parallel=parallel)
        # re-read the (now faulted) expert bank for this group
        moe_p = dict(mp["moe"])
        for name in ("wi_gate", "wi_up", "wo"):
            full = _np(arena, f"params/groups/moe_layer/moe/{name}", fault=False)
            moe_p[name] = jnp.asarray(full[g])
        x = _jit_moe_apply(cfg, moe_p, x, h2)

    logits = _jit_head(cfg, params["ln_f"], params["lm_head"], x)
    return logits, time.perf_counter() - t0
