"""Guest-memory-file analogue: a flat, page-aligned snapshot arena.

The paper's Firecracker snapshot maps a *guest memory file* and lazily
faults 4 KB pages from disk.  Here the "guest memory" of an ML function
instance is the flat byte arena holding every tensor of the booted instance
(serving weights, embedding tables, expert banks, runtime/infra tables, and
-- for instances deployed from training checkpoints -- master weights and
optimizer moments, which are *boot-only* state never touched at serve time).

Tensors are laid out back-to-back at PAGE-aligned offsets; a JSON manifest
maps tensor path -> (offset, shape, dtype).  The :class:`InstanceArena` is
the demand-paged in-memory image: first touch of a page triggers a "fault"
serviced by a monitor (serial 4 KB O_DIRECT reads -- the vanilla-snapshot
baseline), mirroring userfaultfd semantics at framework level
(DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
import json
import mmap
import os
import threading
import time
from typing import Iterable, Sequence

import numpy as np

PAGE = 4096


def _align(n: int, a: int = PAGE) -> int:
    return (n + a - 1) // a * a


@dataclasses.dataclass(frozen=True)
class Entry:
    path: str
    offset: int          # byte offset in the arena (PAGE aligned)
    shape: tuple[int, ...]
    dtype: str
    nbytes: int
    region: str = "serve"  # serve | boot | infra

    @property
    def first_page(self) -> int:
        return self.offset // PAGE

    @property
    def n_pages(self) -> int:
        return _align(self.nbytes) // PAGE

    def pages(self) -> range:
        return range(self.first_page, self.first_page + self.n_pages)

    def row_pages(self, rows: Iterable[int]) -> set[int]:
        """Pages covering specific leading-axis rows (embedding/expert access)."""
        if not self.shape:
            return set(self.pages())
        row_bytes = self.nbytes // self.shape[0]
        out: set[int] = set()
        for r in rows:
            lo = self.offset + r * row_bytes
            hi = lo + row_bytes
            out.update(range(lo // PAGE, (hi - 1) // PAGE + 1))
        return out


class ArenaLayout:
    """Deterministic page-aligned layout of named tensors."""

    def __init__(self, entries: dict[str, Entry], total_bytes: int):
        self.entries = entries
        self.total_bytes = total_bytes
        self.n_pages = total_bytes // PAGE
        self._by_page: np.ndarray | None = None

    @classmethod
    def build(cls, tensors: Sequence[tuple[str, tuple[int, ...], str, str]]):
        """tensors: (path, shape, dtype_str, region) in layout order."""
        entries: dict[str, Entry] = {}
        off = 0
        for path, shape, dtype, region in tensors:
            nbytes = int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize if shape else np.dtype(dtype).itemsize
            entries[path] = Entry(path, off, tuple(shape), dtype, int(nbytes), region)
            off += _align(int(nbytes))
        return cls(entries, off)

    def to_json(self) -> str:
        return json.dumps({
            "page": PAGE,
            "total_bytes": self.total_bytes,
            "entries": [dataclasses.asdict(e) for e in self.entries.values()],
        })

    @classmethod
    def from_json(cls, text: str) -> "ArenaLayout":
        d = json.loads(text)
        entries = {}
        for e in d["entries"]:
            e["shape"] = tuple(e["shape"])
            entries[e["path"]] = Entry(**e)
        return cls(entries, d["total_bytes"])

    def pages_of(self, path: str) -> range:
        return self.entries[path].pages()

    def region_pages(self, region: str) -> set[int]:
        out: set[int] = set()
        for e in self.entries.values():
            if e.region == region:
                out.update(e.pages())
        return out


class GuestMemoryFile:
    """The on-disk snapshot: ``<base>.mem`` (raw arena) + ``<base>.manifest.json``."""

    def __init__(self, base: str, layout: ArenaLayout):
        self.base = base
        self.layout = layout
        self.mem_path = base + ".mem"
        self.manifest_path = base + ".manifest.json"

    @classmethod
    def create(cls, base: str, layout: ArenaLayout,
               arrays: dict[str, np.ndarray]) -> "GuestMemoryFile":
        os.makedirs(os.path.dirname(base) or ".", exist_ok=True)
        gm = cls(base, layout)
        with open(gm.mem_path, "wb") as f:
            f.truncate(layout.total_bytes)
            for path, e in layout.entries.items():
                a = arrays[path]
                assert a.nbytes == e.nbytes, (path, a.nbytes, e.nbytes)
                f.seek(e.offset)
                f.write(np.ascontiguousarray(a).view(np.uint8).reshape(-1).tobytes())
        with open(gm.manifest_path, "w") as f:
            f.write(layout.to_json())
        return gm

    @classmethod
    def open(cls, base: str) -> "GuestMemoryFile":
        with open(base + ".manifest.json") as f:
            layout = ArenaLayout.from_json(f.read())
        return cls(base, layout)


@dataclasses.dataclass
class FaultStats:
    n_faults: int = 0
    n_pages_installed: int = 0
    fault_seconds: float = 0.0
    trace: list[int] = dataclasses.field(default_factory=list)  # page order
    trace_t: list[float] = dataclasses.field(default_factory=list)  # per-fault time
    # overlapped-restore accounting: faults that blocked on an in-flight
    # background (tail) install instead of reading disk, the time they
    # spent waiting, and tail pages demoted to the disk-fault path by the
    # straggler deadline
    tail_waits: int = 0
    tail_wait_seconds: float = 0.0
    tail_demoted: int = 0


class PageSource:
    """Serves page reads from the guest memory file.

    ``o_direct`` bypasses the host page cache (the paper's cold-disk model),
    so every serial 4 KB fault pays true device latency.
    """

    def __init__(self, mem_path: str, o_direct: bool = True):
        flags = os.O_RDONLY
        self._direct = False
        if o_direct and hasattr(os, "O_DIRECT"):
            try:
                self.fd = os.open(mem_path, flags | os.O_DIRECT)
                self._direct = True
            except OSError:
                self.fd = os.open(mem_path, flags)
        else:
            self.fd = os.open(mem_path, flags)
        self.size = os.fstat(self.fd).st_size
        # O_DIRECT needs an aligned buffer: one page, reused per fault
        self._buf = mmap.mmap(-1, PAGE)
        self._mv = memoryview(self._buf)

    def read_page(self, page: int, out: memoryview) -> None:
        os.preadv(self.fd, [self._mv], page * PAGE)
        out[:] = self._mv

    def read_span(self, offset: int, nbytes: int) -> bytes:
        """One large aligned read (REAP prefetch path)."""
        n = _align(nbytes)
        buf = mmap.mmap(-1, n)
        mv = memoryview(buf)
        got = 0
        while got < n:
            r = os.preadv(self.fd, [mv[got:]], offset + got)
            if r <= 0:
                break
            got += r
        return bytes(mv[:nbytes])

    def close(self):
        os.close(self.fd)
        self._mv.release()
        self._buf.close()


class InstanceArena:
    """Demand-paged in-memory image of one function instance.

    Fault service is *serial by default* (the paper's baseline: the faulting
    vCPU is halted while the host reads one page), with a parallel mode used
    by the "Parallel PFs" design point of Fig. 7.
    """

    def __init__(self, gm: GuestMemoryFile, *, o_direct: bool = True):
        self.gm = gm
        self.layout = gm.layout
        self.buf = mmap.mmap(-1, max(self.layout.total_bytes, PAGE))
        self.view = memoryview(self.buf)
        self.resident = np.zeros(self.layout.n_pages, dtype=bool)
        self.stats = FaultStats()
        self.source = PageSource(gm.mem_path, o_direct=o_direct)
        self._lock = threading.RLock()
        # fault-vs-background-install rendezvous: pages in ``_pending`` have
        # an in-flight tail install; a fault on one waits on ``_cv`` for the
        # installer's notify instead of reading disk
        self._cv = threading.Condition(self._lock)
        self._pending: set[int] = set()
        #: liveness backstop for waiters — a tail stuck past this falls
        #: through to the disk-fault path regardless of the pending marker
        self.pending_wait_s = 30.0
        #: §6 recorder gate: only a monitor in record mode keeps the full
        #: fault trace (bugfix: the trace grew without bound on long
        #: serving runs).  Raw arenas default to recording.
        self.record_trace = True
        self._closed = False

    # -- fault paths --------------------------------------------------------

    def touch_pages(self, pages: Iterable[int], *, parallel: int = 0) -> int:
        """Ensure pages are resident; returns number of faults served.

        Thread-safe: the residence check, page install, and stats update are
        one atomic step, so concurrent fault paths (e.g. ``make_warm`` racing
        a monitor) never double-install or corrupt the trace.  A fault on a
        page with an in-flight background install blocks on the installer's
        completion (counted in ``tail_waits``/``tail_wait_seconds``, not as
        a disk fault) instead of falling through to disk.
        """
        with self._cv:
            missing = [p for p in pages if not self.resident[p]]
            if not missing:
                return 0
            if self._pending:
                waited = self._wait_pending_locked(
                    [p for p in missing if p in self._pending])
                if waited:
                    missing = [p for p in pages if not self.resident[p]]
                    if not missing:
                        return 0
            t0 = time.perf_counter()
            if parallel > 1:
                self._fault_parallel(missing, parallel)
            else:
                for p in missing:
                    self.source.read_page(
                        p, self.view[p * PAGE:(p + 1) * PAGE])
                    self.resident[p] = True
            self.stats.fault_seconds += time.perf_counter() - t0
            self.stats.n_faults += len(missing)
            self.stats.n_pages_installed += len(missing)
            if self.record_trace:
                t_now = time.perf_counter()
                self.stats.trace.extend(missing)
                self.stats.trace_t.extend([t_now] * len(missing))
            # pages this fault installed from disk can have no useful
            # pending marker left (e.g. after a timed-out wait)
            if self._pending:
                self._pending.difference_update(missing)
                self._cv.notify_all()
            return len(missing)

    def _wait_pending_locked(self, pend: list[int]) -> bool:
        """Wait (``_cv`` held) for in-flight installs covering ``pend``;
        returns True when any wait actually happened."""
        if not pend:
            return False
        t0 = time.perf_counter()
        deadline = t0 + self.pending_wait_s
        while (not self._closed
               and any(p in self._pending for p in pend)):
            left = deadline - time.perf_counter()
            if left <= 0:
                break
            self._cv.wait(timeout=left)
        self.stats.tail_waits += 1
        self.stats.tail_wait_seconds += time.perf_counter() - t0
        return True

    # -- background (tail) install rendezvous -------------------------------

    def begin_pending(self, pages: Iterable[int]) -> None:
        """Mark ``pages`` as having an in-flight background install: a
        fault on any of them blocks on that install instead of reading
        disk.  Already-resident pages are skipped."""
        with self._cv:
            self._pending.update(
                int(p) for p in pages if not self.resident[p])

    def install_pending(self, page_indices, block) -> int:
        """Install one chunk of pending pages (vectorized scatter) and wake
        fault waiters.  Returns pages actually installed."""
        with self._cv:
            n = self.install_block(page_indices, block)
            self._pending.difference_update(int(p) for p in page_indices)
            self._cv.notify_all()
            return n

    def cancel_pending(self, pages: Iterable[int] | None = None, *,
                       demote: bool = True) -> int:
        """Drop pending markers (all of them when ``pages`` is None) so
        waiters fall through to the normal disk-fault path.  ``demote``
        counts the drop as a straggler demotion (``tail_demoted``) —
        teardown cancels pass False."""
        with self._cv:
            if pages is None:
                dropped = len(self._pending)
                self._pending.clear()
            else:
                dropped = 0
                for p in pages:
                    p = int(p)
                    if p in self._pending:
                        self._pending.discard(p)
                        dropped += 1
            if dropped and demote:
                self.stats.tail_demoted += dropped
            self._cv.notify_all()
            return dropped

    @property
    def pending_count(self) -> int:
        with self._cv:
            return len(self._pending)

    def _fault_parallel(self, pages: list[int], workers: int) -> None:
        import concurrent.futures as cf

        def job(chunk):
            src = PageSource(self.gm.mem_path, o_direct=True)
            try:
                for p in chunk:
                    src.read_page(p, self.view[p * PAGE:(p + 1) * PAGE])
            finally:
                src.close()

        chunks = [pages[i::workers] for i in range(workers)]
        with cf.ThreadPoolExecutor(workers) as ex:
            list(ex.map(job, [c for c in chunks if c]))
        for p in pages:
            self.resident[p] = True

    def install_span(self, page_indices: Sequence[int], data: bytes) -> None:
        """Eagerly install prefetched page contents (REAP prefetch phase)."""
        with self._lock:
            mv = memoryview(data)
            for i, p in enumerate(page_indices):
                if not self.resident[p]:
                    self.view[p * PAGE:(p + 1) * PAGE] = mv[i * PAGE:(i + 1) * PAGE]
                    self.resident[p] = True
            self.stats.n_pages_installed += len(page_indices)

    def install_block(self, page_indices, block) -> int:
        """Fused eager install: one vectorized scatter of a prefetched page
        block (``block[i]`` -> page ``page_indices[i]``), instead of
        ``install_span``'s per-page loop.  ``block`` is a ``(n, PAGE)``
        uint8 array (the output of a fused gather pass — restore.py); pages
        already resident are skipped, byte-identically to ``install_span``.
        Returns the number of pages actually installed."""
        with self._lock:
            idx = np.asarray(page_indices, dtype=np.int64)
            missing = ~self.resident[idx]
            tgt = idx[missing]
            if len(tgt):
                arr = np.frombuffer(
                    self.buf, dtype=np.uint8,
                    count=self.layout.n_pages * PAGE).reshape(-1, PAGE)
                arr[tgt] = block[missing]
                self.resident[tgt] = True
            self.stats.n_pages_installed += len(idx)
            return int(len(tgt))

    # -- tensor access ------------------------------------------------------

    def tensor(self, path: str, *, fault: bool = True,
               parallel: int = 0) -> np.ndarray:
        e = self.layout.entries[path]
        if fault:
            self.touch_pages(e.pages(), parallel=parallel)
        arr = np.frombuffer(self.view, dtype=np.dtype(e.dtype),
                            count=e.nbytes // np.dtype(e.dtype).itemsize,
                            offset=e.offset)
        return arr.reshape(e.shape)

    def tensor_rows(self, path: str, rows: Iterable[int],
                    parallel: int = 0) -> np.ndarray:
        """Fault only the pages covering ``rows`` (embedding/expert access)."""
        e = self.layout.entries[path]
        self.touch_pages(sorted(e.row_pages(rows)), parallel=parallel)
        return self.tensor(path, fault=False)

    @property
    def resident_bytes(self) -> int:
        return int(self.resident.sum()) * PAGE

    def close(self):
        with self._cv:
            if self._closed:
                return
            self._closed = True
            # no waiter may hang on a pending marker past close
            self._pending.clear()
            self._cv.notify_all()
            self.source.close()
            self.view.release()
            try:
                self.buf.close()
            except BufferError:
                # zero-copy jnp/np views may still alias the mmap; the OS frees
                # it when the last reference dies.
                pass
