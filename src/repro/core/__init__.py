"""The paper's primary contribution: snapshot arena + REAP record/prefetch.

  arena.py    -- guest-memory-file format + demand-paged InstanceArena
  snapshot.py -- booted-instance image builder (infra/serve/boot regions)
  reap.py     -- trace + WS files, record & prefetch phases, re-record policy
  restore.py  -- staged RestorePipeline + batched RestoreBatch group restores
  executor.py -- model-aware fault-scheduling invocation executor
"""
from .arena import PAGE, ArenaLayout, GuestMemoryFile, InstanceArena, PageSource
from .executor import run_invocation
from .reap import (WS_CACHE, ColdStartReport, Monitor, ReapConfig, WSCache,
                   has_record, prefetch, prefetch_shared,
                   register_invalidation_listener,
                   unregister_invalidation_listener, write_record)
from .restore import (STAGES, RestoreBatch, RestorePipeline, StageTimings,
                      TailInstall, fuse_ws_block)
from .snapshot import booted_footprint_bytes, build_instance_snapshot
