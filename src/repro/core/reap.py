"""REAP: Record-and-Prefetch (the paper's §5).

* **Record phase**: the first cold invocation runs against a demand-paged
  :class:`InstanceArena`; the monitor records the ordered page-fault trace.
  Afterwards the recorded pages are copied into a *contiguous, compact
  working-set (WS) file* and the page indices into a *trace file*.

* **Prefetch phase**: every later cold invocation fetches the whole WS file
  with a single large read (``O_DIRECT``, bypassing the page cache --
  §5.2.3) and eagerly installs the pages into the instance arena before the
  function runs.  Residual faults (mispredicted pages, §7.1) are served on
  demand by the monitor.

* **Re-record policy** (§7.2): if the residual fault count exceeds
  ``rerecord_threshold`` x |WS|, the orchestrator re-records on the next
  invocation.

* **Shared WS page cache**: under concurrent load, N simultaneous
  cold-starts of the same function would each re-read the identical WS file
  from disk.  The process-wide :class:`WSCache` collapses those into a
  single O_DIRECT read (single-flight: late arrivals block on the leader's
  read), keyed by ``(base, ws-file mtime)`` so re-recording invalidates
  naturally.  ``drop_record`` / ``write_record`` also invalidate explicitly.

* **Content-addressed records** (pagestore.py): by default ``f.ws`` holds
  a *manifest* — the ordered page indices mapped to content hashes — and
  the page bytes live once, fleet-wide, in the store directory's shared
  chunk store.  A re-record writes only the chunks the store doesn't
  already hold (delta), ``drop_record`` refcounts/GCs, and legacy flat WS
  files (or ``record_format="flat"``) still read through the
  :func:`_read_ws_flat` fallback seam.

Files for function ``f`` under ``store_dir``:
  ``f.mem`` + ``f.manifest.json``   guest memory file (arena.py)
  ``f.ws``                          WS manifest (v2) or flat pages (legacy)
  ``f.trace.npy``                   int64 page indices (original offsets)
  ``.pagestore/``                   shared content-addressed chunk store
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time

import numpy as np

from . import pagestore
from .arena import PAGE, GuestMemoryFile, InstanceArena, PageSource
from ..telemetry import TELEMETRY


@dataclasses.dataclass
class ReapConfig:
    o_direct: bool = True            # bypass page cache for the WS read
    parallel_faults: int = 0         # >1 => "Parallel PFs" design point
    use_ws_file: bool = True         # False => prefetch via per-page reads
    rerecord_threshold: float = 0.5  # residual faults / |WS| triggering re-record
    min_ws_read: int = 8 << 20       # single-read floor noted in §5.2.3 (bytes)
    share_ws_cache: bool = True      # dedupe concurrent WS reads process-wide
    fuse_engine: str = "auto"        # group-install gather: auto|numpy|pallas
    record_format: str = "cas"       # cas => content-addressed manifest;
    #                                  flat => legacy contiguous WS file
    # -- overlapped restore (serve from a hot prefix, install the tail in
    # the background).  Off by default so raw pipelines keep the PR-5
    # fully-resident-at-materialize contract; the serving layer's
    # ServeConfig flips it on as the recommended construction path.
    overlap_install: bool = False
    hot_prefix_frac: float = 0.125   # blind fallback when no cut point exists
    tail_workers: int = 2            # background tail-install pool size
    tail_deadline_s: float = 5.0     # straggler demotion to the disk-fault path


@dataclasses.dataclass
class StageTimings:
    """Per-stage wall-clock seconds of one restore pipeline run.

    ``ws_fetch_s + install_s`` is the paper's "prefetch" segment;
    ``materialize_s`` (param residency) only runs off-path (prewarms).
    With overlapped restore, ``install_s`` covers only the eager hot
    prefix; ``materialize_to_resident_s`` is the overlap window from
    materialize until the background tail made the arena fully resident,
    and ``tail_wait_s`` is the time faults spent blocked on the pending
    tail instead of going to disk.
    """
    load_vmm_s: float = 0.0
    connection_s: float = 0.0
    ws_fetch_s: float = 0.0
    install_s: float = 0.0
    materialize_s: float = 0.0
    materialize_to_resident_s: float = 0.0
    tail_wait_s: float = 0.0

    @property
    def prefetch_s(self) -> float:
        return self.ws_fetch_s + self.install_s

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ColdStartReport:
    """Per-invocation latency report, §4.2 split.

    ``stages`` is the source of truth for the restore-stage seconds (the
    same :class:`StageTimings` the pipeline produced); the historical flat
    names (``load_vmm_s``, ``connection_s``, ``prefetch_s``, ``install_s``)
    remain available as read-only compat properties.
    """
    queue_s: float = 0.0             # router queueing delay (pre-dispatch)
    stages: StageTimings = dataclasses.field(default_factory=StageTimings)
    processing_s: float = 0.0        # function execution (incl. demand faults)
    fault_s: float = 0.0             # portion of processing spent in faults
    n_faults: int = 0
    n_prefetched_pages: int = 0
    ws_bytes: int = 0
    ws_cache_hit: bool = False       # WS served from the shared page cache
    prewarmed: bool = False          # served by a pre-spawned warm instance
    batch_size: int = 1              # instances restored in this one's group
    tail_waits: int = 0              # faults that blocked on the pending tail

    # -- read-only compat properties over ``stages`` -------------------

    @property
    def load_vmm_s(self) -> float:
        return self.stages.load_vmm_s

    @property
    def connection_s(self) -> float:
        return self.stages.connection_s

    @property
    def prefetch_s(self) -> float:
        return self.stages.prefetch_s

    @property
    def install_s(self) -> float:
        return self.stages.install_s

    @property
    def tail_wait_s(self) -> float:
        return self.stages.tail_wait_s

    @property
    def total_s(self) -> float:
        """Cold-start latency as the paper measures it (excl. queueing)."""
        return (self.load_vmm_s + self.connection_s + self.prefetch_s
                + self.processing_s)

    @property
    def e2e_s(self) -> float:
        """Client-observed latency: queueing delay + cold start + run."""
        return self.queue_s + self.total_s


# Record-invalidation broadcast: a re-record (write_record) or record drop
# invalidates the process-wide WS_CACHE directly, but other caches may hold
# the stale WS too — the cluster's per-node L1s key by (base, mtime) and
# would only notice on their next fetch.  Listeners registered here are
# called with the base on every invalidation so a shard tier can push the
# drop to peer caches eagerly (snapstore.py).  Listener errors are swallowed:
# an observability hook must never fail a record write.
_INVALIDATION_LISTENERS: list = []


def register_invalidation_listener(fn) -> None:
    """``fn(base)`` is called on every ``write_record``/``drop_record``."""
    if fn not in _INVALIDATION_LISTENERS:
        _INVALIDATION_LISTENERS.append(fn)


def unregister_invalidation_listener(fn) -> None:
    if fn in _INVALIDATION_LISTENERS:
        _INVALIDATION_LISTENERS.remove(fn)


def _broadcast_invalidation(base: str) -> None:
    for fn in list(_INVALIDATION_LISTENERS):
        try:
            fn(base)
        except Exception:
            pass


def trace_path(base: str) -> str:
    return base + ".trace.npy"


def ws_path(base: str) -> str:
    return base + ".ws"


def cut_path(base: str) -> str:
    return base + ".cut.json"


def has_record(base: str) -> bool:
    return os.path.exists(trace_path(base)) and os.path.exists(ws_path(base))


def choose_hot_prefix(times: list[float], *,
                      lo_frac: float = 0.05, hi_frac: float = 0.9,
                      min_gap_s: float = 0.005) -> int | None:
    """Pick the hot-prefix cut point from recorded fault timestamps.

    The recorded trace interleaves two phases: a dense burst of boot/setup
    faults, then the execution-driven tail.  The cut is the largest
    inter-fault time gap (the boot→execution knee) searched inside
    ``[lo_frac, hi_frac]`` of the trace; returns the number of leading
    trace pages in the hot prefix, or ``None`` when no gap stands out
    (flat timing, or too few samples).  ``None`` means the timestamps
    carry no phase signal — callers fall back to the runtime
    ``hot_prefix_frac`` knob, which deliberately is NOT frozen into the
    persisted cut file at record time.
    """
    n = len(times)
    if n < 8:
        return None
    lo = max(1, int(n * lo_frac))
    hi = max(lo + 1, int(n * hi_frac))
    gaps = [(times[i] - times[i - 1], i) for i in range(lo, hi)]
    if not gaps:
        return None
    best_gap, best_i = max(gaps)
    # the baseline is the *other* gaps' median: including the winner in
    # its own baseline inflates the 8x bar on short traces (a handful of
    # gaps shift the median toward the knee itself) and suppresses
    # legitimate cuts
    others = sorted(g for g, i in gaps if i != best_i)
    threshold = min_gap_s
    if others:
        median = others[len(others) // 2]
        # a knee must dominate the typical inter-fault spacing AND be a
        # real phase boundary in absolute terms — a scheduler hiccup in a
        # microsecond-spaced record easily clears a relative-only bar and
        # would pin a spurious cut
        threshold = max(8 * median, min_gap_s)
    if best_gap < threshold:
        return None
    return best_i


def read_hot_prefix(base: str) -> int | None:
    """Recorded hot-prefix page count for ``base``, or None (no cut file)."""
    try:
        with open(cut_path(base)) as f:
            return int(json.loads(f.read())["hot_pages"])
    except (OSError, ValueError, KeyError):
        return None


# Record mutations for one base are serialized: two concurrent write_record
# calls would otherwise each read the same prior manifest and double-release
# its chunk refs — a chunk shared with a third live manifest could hit
# refcount zero and be GC'd while still referenced.  Bounded by the number
# of distinct recorded functions.
_RECORD_LOCKS: dict[str, threading.Lock] = {}
_RECORD_LOCKS_MU = threading.Lock()


def _record_lock(base: str) -> threading.Lock:
    with _RECORD_LOCKS_MU:
        return _RECORD_LOCKS.setdefault(base, threading.Lock())


def _sweep_tmp(base: str) -> int:
    """Remove crash leftovers of an interrupted ``write_record``: a failure
    between a ``.tmp`` write and its ``os.replace`` strands the temp file
    forever (nothing else ever matches its name).  Returns files removed.
    """
    removed = 0
    for p in (ws_path(base) + ".tmp",
              trace_path(base) + ".tmp.npy",
              cut_path(base) + ".tmp"):
        try:
            os.remove(p)
            removed += 1
        except OSError:
            pass
    return removed


def _write_ws_flat(base: str, pages: list[int], src: PageSource) -> None:
    """Legacy flat WS writer: contiguous page bytes in fault order.  Kept
    for the ``record_format="flat"`` baseline arm; with the REP007 seam
    :func:`_read_ws_flat` this is the only flat-file producer."""
    with open(ws_path(base) + ".tmp", "wb") as f:
        for p in pages:
            f.write(src.read_span(p * PAGE, PAGE))
    os.replace(ws_path(base) + ".tmp", ws_path(base))


def write_record(base: str, trace: list[int],
                 times: list[float] | None = None, *,
                 fmt: str = "cas") -> tuple[int, int]:
    """Persist the traced pages as a WS record + write the trace file.

    Returns (n_pages, ws_bytes).  Duplicates are dropped, order preserved
    (the order is the fault order -- §5.2.1).  When per-fault ``times``
    accompany the trace, the hot-prefix cut point (overlapped restore) is
    derived from the boot→execution timing knee and persisted alongside.

    ``fmt="cas"`` (default) writes a content-addressed manifest: page
    bytes are chunk-hashed into the store directory's shared
    :class:`~repro.core.pagestore.PageStore`, so a re-record appends only
    chunks the store doesn't hold (delta) and identical pages across
    functions are stored once.  ``fmt="flat"`` keeps the legacy
    contiguous WS file.  ``ws_bytes`` is the logical WS size either way.
    """
    seen: set[int] = set()
    pages: list[int] = []
    page_times: list[float] = []
    for i, p in enumerate(trace):
        if p not in seen:
            seen.add(p)
            pages.append(p)
            if times is not None and i < len(times):
                page_times.append(times[i])
    arr = np.asarray(pages, dtype=np.int64)
    src = PageSource(base + ".mem", o_direct=False)
    try:
        with _record_lock(base):
            _sweep_tmp(base)
            prior = pagestore.read_manifest(ws_path(base))
            if fmt == "flat":
                _write_ws_flat(base, pages, src)
                if prior is not None:
                    # format downgrade: the flat file replaced a manifest,
                    # so its chunk refs must not pin store bytes forever
                    store = pagestore.get_store(os.path.dirname(base) or ".")
                    store.release_manifest(prior["chunks"])
            else:
                blocks: dict[str, bytes] = {}
                hashes: list[str] = []
                for p in pages:
                    blk = src.read_span(p * PAGE, PAGE)
                    h = pagestore.chunk_hash(blk)
                    hashes.append(h)
                    blocks.setdefault(h, blk)
                store = pagestore.get_store(os.path.dirname(base) or ".")
                store.commit_manifest(hashes, blocks,
                                      delta=prior is not None)
                pagestore.write_manifest(ws_path(base), pages, hashes)
                if prior is not None:
                    # release the superseded manifest's refs only now that
                    # f.ws durably points at the new one: a crash anywhere
                    # above leaves a readable record (old or new) and at
                    # worst a leaked incref, never a live manifest whose
                    # unique chunks were GC'd
                    store.release_manifest(prior["chunks"])
            np.save(trace_path(base) + ".tmp.npy", arr)
            os.replace(trace_path(base) + ".tmp.npy", trace_path(base))
            if len(page_times) == len(pages) and pages:
                cut = choose_hot_prefix(page_times)
                if cut is not None:
                    with open(cut_path(base) + ".tmp", "w") as f:
                        f.write(json.dumps({"hot_pages": cut,
                                            "n_pages": len(pages)}))
                    os.replace(cut_path(base) + ".tmp", cut_path(base))
                elif os.path.exists(cut_path(base)):
                    os.remove(cut_path(base))  # stale knee, prior record
        WS_CACHE.invalidate(base)  # a fresh record obsoletes cached WS pages
        _broadcast_invalidation(base)
    finally:
        src.close()
    return len(pages), len(pages) * PAGE


def drop_record(base: str) -> None:
    WS_CACHE.invalidate(base)
    _broadcast_invalidation(base)
    with _record_lock(base):
        _sweep_tmp(base)
        man = pagestore.read_manifest(ws_path(base))
        if man is not None:
            # release this manifest's chunk references; chunks shared with
            # other functions' manifests survive, orphans are GC'd
            store = pagestore.get_store(os.path.dirname(base) or ".")
            store.release_manifest(man["chunks"])
        for p in (trace_path(base), ws_path(base), cut_path(base)):
            if os.path.exists(p):
                os.remove(p)


def _read_ws_flat(base: str, cfg: ReapConfig,
                  k: int | None = None) -> tuple[list[int], bytes]:
    """Legacy flat-WS fallback seam: one O_DIRECT span read of a
    pre-manifest (or ``record_format="flat"``) WS file.  ``k`` limits the
    read to the first ``k`` fault-order pages (the file's head IS the hot
    prefix, §5.2.1).  This function and :class:`PageStore` internals are
    the only places allowed to read WS bytes directly (lint REP007)."""
    pages = np.load(trace_path(base))
    n = len(pages) if k is None else min(k, len(pages))
    src = PageSource(ws_path(base), o_direct=cfg.o_direct)
    try:
        data = src.read_span(0, n * PAGE)
    finally:
        src.close()
    return [int(p) for p in pages], data


def _read_ws(base: str, cfg: ReapConfig) -> tuple[list[int], bytes]:
    """Resolve the full WS: reassemble a v2 manifest from the shared
    chunk store (adjacent chunks coalesce back into span reads), or fall
    back to the flat reader for legacy files."""
    man = pagestore.read_manifest(ws_path(base))
    if man is None:
        return _read_ws_flat(base, cfg)
    pages = np.load(trace_path(base))
    chunks = man["chunks"]
    if len(chunks) != len(pages):
        raise RuntimeError(
            f"WS manifest/trace length mismatch for {base}: "
            f"{len(chunks)} chunks vs {len(pages)} trace pages")
    store = pagestore.get_store(os.path.dirname(base) or ".")
    try:
        data = store.read_chunks(chunks, o_direct=cfg.o_direct)
    except KeyError as e:
        # a concurrent §7.2 drop/re-record released the chunks under us;
        # surface the same signal a vanished flat file would
        raise FileNotFoundError(f"WS chunks for {base} dropped: {e}") from e
    return [int(p) for p in pages], data


def _read_ws_prefix(base: str, cfg: ReapConfig,
                    k: int) -> tuple[list[int], bytes]:
    """Read only the first ``k`` fault-order pages of the WS.

    The WS layout IS the fault order (§5.2.1), so the hot prefix of an
    overlapped restore is the manifest's (or flat file's) head — a short
    chunk-store read instead of the full reassembly.  Returns the FULL
    page-index list (the tail indices are needed for the pending-install
    markers) with data covering only the prefix."""
    man = pagestore.read_manifest(ws_path(base))
    if man is None:
        return _read_ws_flat(base, cfg, k)
    pages = np.load(trace_path(base))
    k = min(k, len(pages))
    store = pagestore.get_store(os.path.dirname(base) or ".")
    try:
        data = store.read_chunks(man["chunks"][:k], o_direct=cfg.o_direct)
    except KeyError as e:
        raise FileNotFoundError(f"WS chunks for {base} dropped: {e}") from e
    return [int(p) for p in pages], data


class WSCache:
    """Process-wide shared working-set page cache.

    N concurrent cold-starts of the same function perform exactly one
    underlying WS-file read: the first arrival becomes the *leader* and
    reads; followers block on its completion and install from memory.
    Entries are keyed by ``(base, mtime)`` so a re-record (new WS file)
    invalidates stale data; ``invalidate`` drops an entry eagerly.

    A per-base **generation counter** closes the invalidate-during-read
    race: a leader mid-``_read_ws`` must not re-insert its (possibly stale)
    entry after ``write_record``/``drop_record`` invalidated the base —
    that would resurrect dropped WS data under the old mtime.  The leader
    snapshots the generation before reading and discards its insert if an
    invalidation bumped it meanwhile (the caller still installs from the
    data it read; only the *cache entry* is suppressed).

    **Tiering hook**: ``source`` replaces the default origin-disk read
    (:func:`_read_ws`) with an arbitrary ``(base, cfg) -> (pages, data)``
    callable.  The cluster layer uses this to make a per-node cache
    *two-tier*: on a local miss, the node's source fetches the WS from its
    owner shard's cache over a modeled network instead of re-reading the
    origin disk (snapstore.py).  Single-flight still applies — concurrent
    local misses trigger exactly one source call.

    The cache is **bounded**: inserts beyond ``capacity_bytes`` evict LRU
    entries (``evicted`` stat), so a long fleet run over many functions
    cannot grow the cache without bound.

    **Chunk index**: every entry also carries its per-page content hashes
    (pagestore.py), maintained in a cross-entry refcount index so the
    shard tier can ask what this cache already holds *from any function*
    (:meth:`missing_chunks`) and ship only the missing chunks over the
    wire (:meth:`peek_chunks` on the serving side).
    """

    def __init__(self, capacity_bytes: int = 512 << 20, *,
                 source=None):
        self.capacity_bytes = capacity_bytes
        self.source = source             # None => origin-disk _read_ws
        self._lock = threading.Lock()
        # base -> (mtime, pages, data, per-page chunk hashes)
        self._entries: dict[str, tuple[float, list[int], bytes, list[str]]] = {}
        self._chunks: dict[str, int] = {}  # chunk hash -> #entries holding it
        self._inflight: dict[str, threading.Event] = {}
        self._gens: dict[str, int] = {}  # bumped by every invalidation
        self._order: list[str] = []      # LRU order, oldest first
        self._bytes = 0                  # running total of cached WS bytes
        self.hits = 0
        self.misses = 0
        self.reads = 0                   # underlying WS-file reads performed
        self.invalidations = 0
        self.discarded = 0               # inserts dropped: raced an invalidate
        self.evicted = 0                 # LRU entries dropped at capacity
        self.peek_hits = 0               # remote-peer serves via peek()
        self.group_fetches = 0           # fetches serving a restore group
        self.group_instances = 0         # instances amortized over those

    def _lru_touch(self, base: str) -> None:
        if base in self._order:
            self._order.remove(base)
        self._order.append(base)

    def _chunks_add(self, hashes: list[str]) -> None:
        for h in set(hashes):
            self._chunks[h] = self._chunks.get(h, 0) + 1

    def _chunks_sub(self, hashes: list[str]) -> None:
        for h in set(hashes):
            n = self._chunks.get(h, 0) - 1
            if n <= 0:
                self._chunks.pop(h, None)
            else:
                self._chunks[h] = n

    def _evict(self) -> None:
        # Never evict the newest entry: an entry larger than the whole
        # capacity must survive its own insert so concurrent followers can
        # still hit it (it becomes LRU-oldest and goes on the next insert).
        while self._bytes > self.capacity_bytes and len(self._order) > 1:
            victim = self._order.pop(0)
            _, _, data, hashes = self._entries.pop(victim)
            self._bytes -= len(data)
            self._chunks_sub(hashes)
            self.evicted += 1
            TELEMETRY.inc("ws_cache.evicted")

    def _call_source(self, base: str, cfg: ReapConfig, group: int):
        """Invoke the miss resolver, passing ``group`` through when the
        source accepts it (the shard tier counts once-per-group remote
        fetches); plain ``(base, cfg)`` sources keep working."""
        import inspect
        try:
            params = inspect.signature(self.source).parameters
            accepts = ("group" in params
                       or any(p.kind is p.VAR_KEYWORD
                              for p in params.values()))
        except (TypeError, ValueError):
            accepts = False
        if accepts:
            return self.source(base, cfg, group=group)
        return self.source(base, cfg)

    def fetch(self, base: str, cfg: ReapConfig,
              group: int = 1) -> tuple[list[int], bytes, bool]:
        """Return (pages, data, cache_hit) for ``base``'s WS file.

        ``group`` declares how many instance restores this one fetch will
        feed (a :class:`~repro.core.restore.RestoreBatch` fetches once per
        group instead of once per instance) — it only affects accounting
        and is forwarded to a group-aware ``source``.
        """
        mtime = os.path.getmtime(ws_path(base))
        if group > 1:
            with self._lock:
                self.group_fetches += 1
                self.group_instances += group
        while True:
            with self._lock:
                ent = self._entries.get(base)
                if ent is not None and ent[0] == mtime:
                    self.hits += 1
                    self._lru_touch(base)
                    TELEMETRY.inc("ws_cache.hits")
                    return ent[1], ent[2], True
                ev = self._inflight.get(base)
                if ev is None:
                    # become the leader for this (base, mtime)
                    ev = threading.Event()
                    self._inflight[base] = ev
                    self.misses += 1
                    TELEMETRY.inc("ws_cache.misses")
                    gen = self._gens.get(base, 0)
                    break
            # follower: wait for the leader's read, then re-check the entry
            ev.wait()
        try:
            pages, data = (_read_ws(base, cfg) if self.source is None
                           else self._call_source(base, cfg, group))
            hashes = pagestore.page_hashes(data)  # outside the lock
            with self._lock:
                self.reads += 1
                if self._gens.get(base, 0) == gen:
                    old = self._entries.get(base)
                    if old is not None:
                        self._bytes -= len(old[2])
                        self._chunks_sub(old[3])
                    self._entries[base] = (mtime, pages, data, hashes)
                    self._bytes += len(data)
                    self._chunks_add(hashes)
                    self._lru_touch(base)
                    self._evict()
                else:
                    self.discarded += 1  # invalidated mid-read: don't resurrect
            return pages, data, False
        finally:
            with self._lock:
                self._inflight.pop(base, None)
                self._gens.pop(base, None)  # no leader left holding a snapshot
            ev.set()

    def contains(self, base: str) -> bool:
        """Residency probe (no disk I/O, no LRU touch): is a WS entry for
        ``base`` cached?  The cluster scheduler scores placement locality
        with this; a stale-mtime entry answering True merely costs one
        fresh read on the placed node, so staleness is acceptable here."""
        with self._lock:
            return base in self._entries

    def peek(self, base: str, *,
             count: bool = True) -> tuple[list[int], bytes] | None:
        """Serve ``base`` from a *completed* entry or return None — never
        joins an in-flight read and never triggers one.  This is the
        cluster shard tier's remote-serve primitive: a peer peeking an
        owner's cache can't block on the owner's single-flight event, so
        cross-node cache waits (and therefore cross-cache deadlock) are
        impossible by construction.  Freshness is still mtime-checked.

        ``count=False`` makes the probe stat-silent — the overlapped
        restore path peeks to decide whether to split its fetch and then
        fetches anyway on a hit, which would otherwise double-count."""
        served = self.peek_chunks(base, count=count)
        if served is None:
            return None
        pages, data, _hashes = served
        return pages, data

    def peek_chunks(self, base: str, *, count: bool = True
                    ) -> tuple[list[int], bytes, list[str]] | None:
        """:meth:`peek` plus the entry's per-page chunk hashes — the shard
        tier serves a peer from this and charges the transfer only for
        the chunks the *requester's* cache is missing."""
        try:
            mtime = os.path.getmtime(ws_path(base))
        except OSError:
            return None                  # record dropped: nothing to serve
        with self._lock:
            ent = self._entries.get(base)
            if ent is None or ent[0] != mtime:
                return None
            if count:
                # counted apart from hits/misses: a peek serves a *peer*,
                # and folding it into hits would inflate this node's local
                # hit rate
                self.peek_hits += 1
                TELEMETRY.inc("ws_cache.peek_hits")
            self._lru_touch(base)
            return ent[1], ent[2], ent[3]

    def missing_chunks(self, hashes) -> set[str]:
        """Subset of ``hashes`` held by NO cached entry — of *any*
        function (cross-function wire dedup: a chunk cached here under
        one function's WS need not be shipped again for another's)."""
        with self._lock:
            return {h for h in set(hashes) if h not in self._chunks}

    def chunk_index(self) -> set[str]:
        """Every chunk hash any cached entry holds — the L1 index digest
        a transport requester sends so the responder ships only what is
        actually missing here (wire.py negotiation)."""
        with self._lock:
            return set(self._chunks)

    def chunk_payloads(self, hashes) -> dict[str, bytes]:
        """Resolve held chunk hashes to their page bytes (best effort:
        hashes evicted since :meth:`chunk_index` are simply absent).
        The transport client reassembles a negotiated fetch from this —
        chunks the responder skipped because our digest covered them."""
        want = set(hashes)
        out: dict[str, bytes] = {}
        with self._lock:
            for _mtime, _pages, data, entry_hashes in self._entries.values():
                if not want:
                    break
                for i, h in enumerate(entry_hashes):
                    if h in want:
                        out[h] = data[i * pagestore.PAGE:
                                      (i + 1) * pagestore.PAGE]
                        want.discard(h)
        return out

    def invalidate(self, base: str) -> bool:
        """Drop ``base``'s entry; True when an entry was actually held (the
        shard tier counts eager peer drops with this)."""
        with self._lock:
            if base in self._inflight:
                # only an in-flight leader holds a generation snapshot, so
                # only then does a bump matter — this keeps _gens bounded by
                # the number of concurrent reads instead of growing with
                # every base ever invalidated
                self._gens[base] = self._gens.get(base, 0) + 1
            dropped = self._entries.pop(base, None)
            if dropped is not None:
                self._bytes -= len(dropped[2])
                self._chunks_sub(dropped[3])
                self.invalidations += 1
                TELEMETRY.inc("ws_cache.invalidations")
            if base in self._order:
                self._order.remove(base)
            return dropped is not None

    def clear(self) -> None:
        with self._lock:
            for base in self._inflight:
                self._gens[base] = self._gens.get(base, 0) + 1
            self._entries.clear()
            self._chunks.clear()
            self._order.clear()
            self._bytes = 0

    def reset_stats(self) -> None:
        with self._lock:
            self.hits = self.misses = self.reads = 0
            self.invalidations = self.discarded = self.evicted = 0
            self.peek_hits = 0
            self.group_fetches = self.group_instances = 0

    def stats(self) -> dict:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "reads": self.reads, "invalidations": self.invalidations,
                    "discarded": self.discarded, "evicted": self.evicted,
                    "peek_hits": self.peek_hits,
                    "group_fetches": self.group_fetches,
                    "group_instances": self.group_instances,
                    "entries": len(self._entries), "bytes": self._bytes,
                    "chunks": len(self._chunks)}


#: Process-wide singleton (the orchestrator's host-level page cache analogue).
WS_CACHE = WSCache()


def prefetch(arena: InstanceArena, base: str, cfg: ReapConfig) -> tuple[int, float]:
    """REAP prefetch phase: fetch WS with one read, eagerly install.

    Always performs the underlying read (no sharing) — this is the raw
    phase primitive the step benchmarks time.  Returns (n_pages, seconds).
    """
    t0 = time.perf_counter()
    if cfg.use_ws_file:
        pages, data = _read_ws(base, cfg)
        arena.install_span(pages, data)
    else:
        # "Parallel PFs" design point: trace known, but pages still read from
        # the (scattered) guest memory file
        pages = [int(p) for p in np.load(trace_path(base))]
        arena.touch_pages(pages, parallel=max(cfg.parallel_faults, 1))
    return len(pages), time.perf_counter() - t0


def prefetch_shared(arena: InstanceArena, base: str, cfg: ReapConfig,
                    cache: WSCache | None = None) -> tuple[int, float, bool]:
    """Cache-aware prefetch used by the serving data plane.

    Concurrent cold-starts of the same function share one WS read through
    ``cache`` (default: the process-wide :data:`WS_CACHE`; the cluster
    layer passes each node's own two-tier cache).  Returns
    (n_pages, seconds, ws_cache_hit).
    """
    if not (cfg.use_ws_file and cfg.share_ws_cache):
        n, secs = prefetch(arena, base, cfg)
        return n, secs, False
    t0 = time.perf_counter()
    pages, data, hit = (cache or WS_CACHE).fetch(base, cfg)
    arena.install_span(pages, data)
    return len(pages), time.perf_counter() - t0, hit


class Monitor:
    """Per-instance monitor thread analogue (§5.2): owns the arena, records
    or prefetches, and serves residual faults.  In-process (goroutine ->
    Python object whose fault service runs on the caller thread; I/O releases
    the GIL so concurrent instances overlap, cf. Fig. 9)."""

    def __init__(self, gm: GuestMemoryFile, base: str, cfg: ReapConfig,
                 *, mode: str | None = None, cache: WSCache | None = None):
        """``mode``: None => auto (prefetch if a record exists, else record);
        'vanilla' => ignore records, serve every page as a demand fault.
        ``cache``: WS page cache for the prefetch (None => process-wide
        :data:`WS_CACHE`; cluster nodes pass their own tiered cache)."""
        self.gm = gm
        self.base = base
        self.cfg = cfg
        self.cache = cache
        self.arena = InstanceArena(gm, o_direct=cfg.o_direct)
        self.mode = mode or ("prefetch" if has_record(base) else "record")
        if self.mode == "record":
            # record-open hygiene: a crash between a prior recorder's
            # .tmp write and its os.replace strands temp files next to
            # the record; sweep them before producing fresh ones
            _sweep_tmp(base)
        self.prefetched = 0
        self.prefetch_s = 0.0
        self.ws_cache_hit = False

    @property
    def mode(self) -> str:
        return self._mode

    @mode.setter
    def mode(self, m: str) -> None:
        # the §6 recorder is the only consumer of the full fault trace —
        # outside record mode the arena stops accumulating it, so a
        # long-serving prefetch/vanilla instance can't grow it unboundedly
        self._mode = m
        self.arena.record_trace = (m == "record")

    def start(self) -> None:
        if self.mode == "prefetch":
            try:
                self.prefetched, self.prefetch_s, self.ws_cache_hit = (
                    prefetch_shared(self.arena, self.base, self.cfg,
                                    self.cache))
            except FileNotFoundError:
                # a concurrent §7.2 re-record dropped the WS/trace files
                # between mode selection and this prefetch: record afresh
                # instead of failing the invocation
                self.mode = "record"

    def finish(self) -> dict:
        """Called when the orchestrator receives the function response."""
        stats = self.arena.stats
        out = {
            "mode": self.mode,
            "n_faults": stats.n_faults,
            "fault_s": stats.fault_seconds,
            "prefetched_pages": self.prefetched,
            "prefetch_s": self.prefetch_s,
            "resident_bytes": self.arena.resident_bytes,
        }
        if self.mode == "record":
            n, nbytes = write_record(self.base, stats.trace, stats.trace_t,
                                     fmt=self.cfg.record_format)
            out["ws_pages"] = n
            out["ws_bytes"] = nbytes
        elif self.prefetched:
            # disk faults caused by a demoted (straggling) tail install are
            # prefetch pages the record *did* predict — counting them as
            # residual mispredictions would trigger §7.2 re-record storms
            residual = (max(stats.n_faults - stats.tail_demoted, 0)
                        / max(self.prefetched, 1))
            out["residual_ratio"] = residual
            if residual > self.cfg.rerecord_threshold:
                drop_record(self.base)  # §7.2 fallback: re-record next time
                out["rerecord"] = True
        return out
