"""REAP: Record-and-Prefetch (the paper's §5).

* **Record phase**: the first cold invocation runs against a demand-paged
  :class:`InstanceArena`; the monitor records the ordered page-fault trace.
  Afterwards the recorded pages are copied into a *contiguous, compact
  working-set (WS) file* and the page indices into a *trace file*.

* **Prefetch phase**: every later cold invocation fetches the whole WS file
  with a single large read (``O_DIRECT``, bypassing the page cache --
  §5.2.3) and eagerly installs the pages into the instance arena before the
  function runs.  Residual faults (mispredicted pages, §7.1) are served on
  demand by the monitor.

* **Re-record policy** (§7.2): if the residual fault count exceeds
  ``rerecord_threshold`` x |WS|, the orchestrator re-records on the next
  invocation.

Files for function ``f`` under ``store_dir``:
  ``f.mem`` + ``f.manifest.json``   guest memory file (arena.py)
  ``f.ws``                          working-set file (contiguous pages)
  ``f.trace.npy``                   int64 page indices (original offsets)
"""
from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

from .arena import PAGE, GuestMemoryFile, InstanceArena, PageSource


@dataclasses.dataclass
class ReapConfig:
    o_direct: bool = True            # bypass page cache for the WS read
    parallel_faults: int = 0         # >1 => "Parallel PFs" design point
    use_ws_file: bool = True         # False => prefetch via per-page reads
    rerecord_threshold: float = 0.5  # residual faults / |WS| triggering re-record
    min_ws_read: int = 8 << 20       # single-read floor noted in §5.2.3 (bytes)


@dataclasses.dataclass
class ColdStartReport:
    load_vmm_s: float = 0.0          # manifest + arena + exec-handle restore
    connection_s: float = 0.0        # dispatcher (re-)binding
    prefetch_s: float = 0.0          # WS fetch + eager install (REAP only)
    processing_s: float = 0.0        # function execution (incl. demand faults)
    fault_s: float = 0.0             # portion of processing spent in faults
    n_faults: int = 0
    n_prefetched_pages: int = 0
    ws_bytes: int = 0

    @property
    def total_s(self) -> float:
        return (self.load_vmm_s + self.connection_s + self.prefetch_s
                + self.processing_s)


def trace_path(base: str) -> str:
    return base + ".trace.npy"


def ws_path(base: str) -> str:
    return base + ".ws"


def has_record(base: str) -> bool:
    return os.path.exists(trace_path(base)) and os.path.exists(ws_path(base))


def write_record(base: str, trace: list[int]) -> tuple[int, int]:
    """Copy traced pages into the compact WS file + write the trace file.

    Returns (n_pages, ws_bytes).  Duplicates are dropped, order preserved
    (the order is the fault order -- §5.2.1).
    """
    seen: set[int] = set()
    pages: list[int] = []
    for p in trace:
        if p not in seen:
            seen.add(p)
            pages.append(p)
    arr = np.asarray(pages, dtype=np.int64)
    src = PageSource(base + ".mem", o_direct=False)
    try:
        with open(ws_path(base) + ".tmp", "wb") as f:
            for p in pages:
                f.write(src.read_span(p * PAGE, PAGE))
        os.replace(ws_path(base) + ".tmp", ws_path(base))
        np.save(trace_path(base) + ".tmp.npy", arr)
        os.replace(trace_path(base) + ".tmp.npy", trace_path(base))
    finally:
        src.close()
    return len(pages), len(pages) * PAGE


def drop_record(base: str) -> None:
    for p in (trace_path(base), ws_path(base)):
        if os.path.exists(p):
            os.remove(p)


def prefetch(arena: InstanceArena, base: str, cfg: ReapConfig) -> tuple[int, float]:
    """REAP prefetch phase: fetch WS with one read, eagerly install.

    Returns (n_pages, seconds).
    """
    t0 = time.perf_counter()
    pages = np.load(trace_path(base))
    if cfg.use_ws_file:
        src = PageSource(ws_path(base), o_direct=cfg.o_direct)
        try:
            data = src.read_span(0, len(pages) * PAGE)
        finally:
            src.close()
        arena.install_span([int(p) for p in pages], data)
    else:
        # "Parallel PFs" design point: trace known, but pages still read from
        # the (scattered) guest memory file
        arena.touch_pages([int(p) for p in pages],
                          parallel=max(cfg.parallel_faults, 1))
    return len(pages), time.perf_counter() - t0


class Monitor:
    """Per-instance monitor thread analogue (§5.2): owns the arena, records
    or prefetches, and serves residual faults.  In-process (goroutine ->
    Python object whose fault service runs on the caller thread; I/O releases
    the GIL so concurrent instances overlap, cf. Fig. 9)."""

    def __init__(self, gm: GuestMemoryFile, base: str, cfg: ReapConfig):
        self.gm = gm
        self.base = base
        self.cfg = cfg
        self.arena = InstanceArena(gm, o_direct=cfg.o_direct)
        self.mode = "prefetch" if has_record(base) else "record"
        self.prefetched = 0
        self.prefetch_s = 0.0

    def start(self) -> None:
        if self.mode == "prefetch":
            self.prefetched, self.prefetch_s = prefetch(
                self.arena, self.base, self.cfg)

    def finish(self) -> dict:
        """Called when the orchestrator receives the function response."""
        stats = self.arena.stats
        out = {
            "mode": self.mode,
            "n_faults": stats.n_faults,
            "fault_s": stats.fault_seconds,
            "prefetched_pages": self.prefetched,
            "prefetch_s": self.prefetch_s,
            "resident_bytes": self.arena.resident_bytes,
        }
        if self.mode == "record":
            n, nbytes = write_record(self.base, stats.trace)
            out["ws_pages"] = n
            out["ws_bytes"] = nbytes
        elif self.prefetched:
            residual = stats.n_faults / max(self.prefetched, 1)
            out["residual_ratio"] = residual
            if residual > self.cfg.rerecord_threshold:
                drop_record(self.base)  # §7.2 fallback: re-record next time
                out["rerecord"] = True
        return out
