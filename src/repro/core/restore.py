"""Staged cold-start restore pipeline + batched group restores.

The paper's §4.2 latency split (load VMM / connection restore / prefetch /
processing) used to be produced implicitly by ``FunctionInstance.__init__``
doing blocking I/O in a constructor.  This module makes the restore path an
explicit, separately-timed pipeline:

    load_vmm -> connect -> ws_fetch -> install -> materialize

and adds the group form the single-instance path cannot express: under
concurrent load, N queued cold starts of one function used to run N full
pipelines — N manifest parses, N WS-cache waits (single-flight followers
blocking on the leader's read), and N serial per-page ``install_span``
loops.  :class:`RestoreBatch` restores all N as **one** staged operation:

  * one manifest parse (the layout is shared across the group's arenas),
  * one WS fetch (a single cache transaction instead of leader+followers),
  * one fused page-gather pass producing an ascending-page install block,
  * N vectorized block installs (one scatter per arena, no per-page loop).

The fuse step is the ``page_gather`` kernel's job description: reorder the
trace-order WS into the contiguous block the installs want.  On a TPU
backend the Pallas kernel (``kernels/page_gather``) runs it as a
scalar-prefetched DMA sweep; on CPU the same permutation is a single numpy
fancy-index (the kernel's interpret mode would cost more than it saves), so
``fuse_engine="auto"`` picks per backend and both engines are parity-tested
byte-for-byte.

The ``ws_fetch`` stage is format-agnostic: ``_read_ws``/``_read_ws_prefix``
reassemble a content-addressed manifest from the store directory's shared
chunk store (core/pagestore.py) — adjacent chunks coalesce back into span
reads — or fall back to the legacy flat-file seam, so the pipeline and the
group restore never see which format recorded the WS.
"""
from __future__ import annotations

import threading
import socket
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from .arena import PAGE, GuestMemoryFile, InstanceArena
from .reap import (WS_CACHE, Monitor, ReapConfig, StageTimings, _read_ws,
                   _read_ws_prefix, read_hot_prefix, trace_path)
from ..telemetry import TELEMETRY

__all__ = [
    "STAGES", "StageTimings", "TailInstall", "RestorePipeline",
    "RestoreBatch", "connect_handshake", "default_fuse_engine",
    "fuse_ws_block", "shutdown_tail_pool",
]

#: Stage names in execution order (benchmarks iterate this).
STAGES = ("load_vmm", "connect", "ws_fetch", "install", "materialize")


# Shared background pool for tail installs: tails are short memcpy bursts,
# so one small process-wide pool beats a thread per restore.  Sized by the
# first ``tail_workers`` seen (later configs reuse the pool).
_TAIL_POOL: ThreadPoolExecutor | None = None
_TAIL_POOL_LOCK = threading.Lock()


def _tail_pool(workers: int) -> ThreadPoolExecutor:
    global _TAIL_POOL
    with _TAIL_POOL_LOCK:
        if _TAIL_POOL is None:
            _TAIL_POOL = ThreadPoolExecutor(
                max_workers=max(1, workers),
                thread_name_prefix="tail-install")
        return _TAIL_POOL


def shutdown_tail_pool(wait: bool = True) -> None:
    """Join the shared tail-install pool's threads (idempotent).

    Tails themselves are cancel/join-able per instance
    (:meth:`TailInstall.cancel`); this releases the *pool* — process
    teardown, or tests asserting no thread leaks.  The next TailInstall
    lazily rebuilds it.
    """
    global _TAIL_POOL
    with _TAIL_POOL_LOCK:
        pool, _TAIL_POOL = _TAIL_POOL, None
    if pool is not None:
        pool.shutdown(wait=wait)


class TailInstall:
    """Background fetch+install of the working-set tail after materialize.

    The arena's pending markers are set *before* the task is scheduled, so
    a fault racing the installer always either waits on the pending page or
    finds it resident — never reads disk for a page the tail holds.  Pages
    are installed in chunks (each chunk notifies waiters) and a straggler
    deadline demotes the remaining tail to the normal disk-fault path.

    ``block`` of None defers even the tail's *bytes* to the background:
    ``fetch()`` (run first, on the worker) returns the tail's page rows —
    the overlapped pipeline uses this on a WS-cache miss so the eager path
    reads only the hot-prefix span of the WS file.
    """

    CHUNK_PAGES = 256
    #: test seam: ``throttle(tail, chunk_start)`` runs before each chunk.
    throttle = None

    def __init__(self, arena: InstanceArena, pages, block=None, *,
                 fetch=None, deadline_s: float = 5.0, workers: int = 2,
                 clock=time.perf_counter, registry=None):
        if block is None and fetch is None:
            raise ValueError("TailInstall needs a block or a fetch")
        self.arena = arena
        self.pages = np.asarray(pages, dtype=np.int64)
        self.block = block
        self.fetch = fetch
        self.fetch_s = 0.0
        self.deadline_s = deadline_s
        self.demoted = False
        self.clock = clock
        self.registry = TELEMETRY if registry is None else registry
        self.done_at: float | None = None   # clock() at full residency
        self.t0 = clock()
        self._cancel = threading.Event()
        self.registry.inc("tail.started")
        arena.begin_pending(self.pages)
        self._future = _tail_pool(workers).submit(self._run)

    def _run(self) -> None:
        try:
            if self.block is None:
                if self._cancel.is_set():
                    self.arena.cancel_pending(self.pages, demote=False)
                    self.registry.inc("tail.cancelled")
                    return
                if self.clock() - self.t0 > self.deadline_s:
                    self.arena.cancel_pending(self.pages, demote=True)
                    self.demoted = True
                    self.registry.inc("tail.demoted")
                    return
                t0 = self.clock()
                self.block = self.fetch()
                self.fetch_s = self.clock() - t0
                self.registry.observe("tail.fetch_s", self.fetch_s)
            n = len(self.pages)
            for i in range(0, n, self.CHUNK_PAGES):
                if self._cancel.is_set():
                    self.arena.cancel_pending(self.pages[i:], demote=False)
                    self.registry.inc("tail.cancelled")
                    return
                if self.clock() - self.t0 > self.deadline_s:
                    # straggler: demote the rest to the disk-fault path
                    self.arena.cancel_pending(self.pages[i:], demote=True)
                    self.demoted = True
                    self.registry.inc("tail.demoted")
                    return
                if TailInstall.throttle is not None:
                    TailInstall.throttle(self, i)
                j = i + self.CHUNK_PAGES
                self.arena.install_pending(self.pages[i:j], self.block[i:j])
            self.done_at = self.clock()
            self.registry.inc("tail.completed")
            self.registry.observe("tail.resident_s", self.done_at - self.t0)
        except BaseException:
            # never leave waiters parked on pages nobody will install
            self.arena.cancel_pending(self.pages)
            raise

    def done(self) -> bool:
        return self._future.done()

    def wait(self, timeout: float | None = None) -> None:
        self._future.result(timeout)

    def cancel(self, join: bool = True) -> None:
        """Stop installing (remaining pending markers are dropped without
        counting as demotions); ``join`` waits for the worker to leave the
        arena so a subsequent ``arena.close()`` is safe."""
        self._cancel.set()
        if join:
            try:
                self._future.result(timeout=30.0)
            except BaseException:
                pass


def connect_handshake() -> None:
    """Real loopback handshake standing in for gRPC connection restore."""
    a, b = socket.socketpair()
    try:
        a.sendall(b"PING")
        assert b.recv(4) == b"PING"
        b.sendall(b"PONG")
        assert a.recv(4) == b"PONG"
    finally:
        a.close()
        b.close()


def default_fuse_engine() -> str:
    """'pallas' on a TPU backend (the kernel compiles to a DMA sweep),
    'numpy' elsewhere (interpret-mode Pallas is slower than the copy)."""
    try:
        import jax
        if jax.default_backend() == "tpu":
            return "pallas"
    except Exception:
        pass
    return "numpy"


def fuse_ws_block(pages, data: bytes, *, engine: str = "auto",
                  interpret: bool | None = None
                  ) -> tuple[np.ndarray, np.ndarray]:
    """One fused gather pass over the trace-order WS bytes.

    Returns ``(sorted_pages, block)`` where ``block[i]`` is the content of
    arena page ``sorted_pages[i]`` — the WS permuted into ascending-page
    order so each instance's install is a single monotonic scatter.

    ``engine='pallas'`` runs the permutation through the
    :func:`~repro.kernels.gather_pages` kernel (the TPU-native realization);
    ``engine='numpy'`` is the vectorized host path.  Both produce identical
    bytes (tested).  ``interpret`` of None compiles the kernel on TPU and
    interprets elsewhere (interpret mode on the hot path would cost more
    than the fuse saves).
    """
    idx = np.asarray(pages, dtype=np.int64)
    ws = np.frombuffer(data, dtype=np.uint8,
                       count=len(idx) * PAGE).reshape(len(idx), PAGE)
    order = np.argsort(idx, kind="stable")
    if engine == "auto":
        engine = default_fuse_engine()
    if engine == "pallas":
        import jax
        import jax.numpy as jnp

        from ..kernels import gather_pages
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        block = np.asarray(gather_pages(
            jnp.asarray(ws), jnp.asarray(order.astype(np.int32)),
            interpret=interpret))
    elif engine == "numpy":
        block = np.ascontiguousarray(ws[order])
    else:
        raise ValueError(f"unknown fuse engine {engine!r}")
    return idx[order], block


class RestorePipeline:
    """Explicit staged restore of one function instance's state.

    Stages are separate methods so a group restore (:class:`RestoreBatch`)
    can interleave them across instances — e.g. run every ``load_vmm``
    against one parsed manifest, then one shared ``ws_fetch`` for the whole
    group.  ``run()`` is the single-instance convenience that executes them
    in order.

    ``clock`` injects the timer (tests pass a fake clock so stage
    attribution is deterministic); ``exec_restore`` is the jit-cache lookup
    (Firecracker's device-state restore analogue) supplied by the serving
    layer; ``connector`` stands in for the gRPC connection restore.
    """

    def __init__(self, base: str, reap: ReapConfig | None = None, *,
                 mode: str | None = None, cache=None, exec_restore=None,
                 connector=connect_handshake, clock=time.perf_counter,
                 registry=None):
        self.base = base
        self.reap = reap or ReapConfig()
        self.mode = mode                 # None => auto; 'vanilla' => no REAP
        self.cache = cache
        self.exec_restore = exec_restore
        self.connector = connector
        self.clock = clock
        self.registry = TELEMETRY if registry is None else registry
        self._trace = self.registry.trace("cold_start", base=base)
        self.timings = StageTimings()
        self.gm: GuestMemoryFile | None = None
        self.monitor: Monitor | None = None
        #: live background tail install (overlapped restore), else None.
        self.tail: TailInstall | None = None
        #: hot-prefix size when ws_fetch split (read only the prefix span);
        #: the tail's bytes then come from ``_tail_fetch`` in the background.
        self._split_k: int | None = None
        self._tail_fetch = None          # () -> (pages, data) full WS

    def _span(self, stage: str, t0: float, dur_s: float, **attrs) -> None:
        """Record one stage span in the cold-start trace.  ``dur_s`` is
        always the value just written to ``self.timings`` — StageTimings
        stays the single stage-seconds sink (REP005); the trace only
        mirrors it for per-invocation attribution."""
        self._trace.add(stage, t0, dur_s, **attrs)
        self.registry.observe(f"restore.{stage}_s", dur_s)

    # -- stages ---------------------------------------------------------

    def load_vmm(self, layout=None) -> None:
        """Manifest parse + arena map + executable-handle restore.

        ``layout`` short-circuits the manifest parse with an
        already-parsed :class:`~repro.core.arena.ArenaLayout` — a group
        restore parses the manifest once and shares it.
        """
        t0 = self.clock()
        self.gm = (GuestMemoryFile(self.base, layout) if layout is not None
                   else GuestMemoryFile.open(self.base))
        self.monitor = Monitor(self.gm, self.base, self.reap,
                               mode=self.mode, cache=self.cache)
        if self.exec_restore is not None:
            self.exec_restore()
        self.timings.load_vmm_s = self.clock() - t0
        self._span("load_vmm", t0, self.timings.load_vmm_s)

    def connect(self) -> None:
        t0 = self.clock()
        self.connector()
        self.timings.connection_s = self.clock() - t0
        self._span("connect", t0, self.timings.connection_s)

    def ws_fetch(self, group: int = 1):
        """Fetch the working set (REAP prefetch phase, read half).

        Returns ``(pages, data, cache_hit)`` — ``data`` is None on the
        "Parallel PFs" design point (``use_ws_file=False``), where the
        install stage demand-reads the traced pages instead — or None when
        this monitor is not in prefetch mode.

        A concurrent §7.2 re-record may ``drop_record`` the WS file between
        the monitor's mode selection and this fetch; the resulting
        ``FileNotFoundError`` falls back to record mode (the §7.2 path)
        instead of failing the invocation.
        """
        mon = self.monitor
        if mon.mode != "prefetch":
            return None
        cfg = self.reap
        t0 = self.clock()
        try:
            if not cfg.use_ws_file:
                pages = [int(p) for p in np.load(trace_path(self.base))]
                data, hit = None, False
            else:
                split = (self._split_fetch(group)
                         if cfg.overlap_install else None)
                if split is not None:
                    pages, data, hit = split
                elif cfg.share_ws_cache:
                    pages, data, hit = (self.cache or WS_CACHE).fetch(
                        self.base, cfg, group=group)
                else:
                    pages, data = _read_ws(self.base, cfg)
                    hit = False
        except FileNotFoundError:
            mon.mode = "record"          # record dropped under us: re-record
            return None
        self.timings.ws_fetch_s = self.clock() - t0
        self._span("ws_fetch", t0, self.timings.ws_fetch_s, cache_hit=hit)
        return pages, data, hit

    def _split_fetch(self, group: int):
        """Overlapped fetch: eagerly read only the hot-prefix span of the
        fault-order WS file; the background tail fetches the full WS (via
        the single-flight cache when shared, so a group and later restores
        all ride one read) before installing.  Returns ``(pages,
        prefix_data, False)`` or None when splitting doesn't apply — a
        cache hit already holds the full bytes (only the install then
        overlaps) or the WS is too small to cut."""
        cfg = self.reap
        cache = (self.cache or WS_CACHE) if cfg.share_ws_cache else None
        if cache is not None and cache.peek(self.base, count=False) is not None:
            return None
        n = len(np.load(trace_path(self.base)))
        k = self.hot_count(n)
        if k >= n:
            return None
        pages, data = _read_ws_prefix(self.base, cfg, k)
        self._split_k = k
        if cache is not None:
            self._tail_fetch = lambda: cache.fetch(
                self.base, cfg, group=group)[:2]
        else:
            self._tail_fetch = lambda: _read_ws(self.base, cfg)
        return pages, data, False

    def _tail_rows(self, k: int, want_pages):
        """Closure for :class:`TailInstall`: resolve the full WS in the
        background and slice out the tail's page rows.  A §7.2 re-record
        can swap the WS under the in-flight fetch — the guard raises and
        the tail's pending markers drop to the disk-fault path instead of
        installing rows against the wrong page indices."""
        fetch = self._tail_fetch
        want = [int(p) for p in want_pages]
        base = self.base

        def rows():
            pages_all, data = fetch()
            if [int(p) for p in pages_all[k:]] != want:
                raise RuntimeError(
                    f"WS for {base} re-recorded during tail fetch")
            return np.frombuffer(
                data, dtype=np.uint8,
                count=len(pages_all) * PAGE).reshape(-1, PAGE)[k:]
        return rows

    def hot_count(self, n_pages: int) -> int:
        """Size of the eager hot prefix for an ``n_pages`` working set.

        Without ``overlap_install`` (or for trivially small sets) the whole
        WS is installed eagerly.  With it, the recorded cut point (the
        boot→execution timing knee — reap.py) wins over the blind
        ``hot_prefix_frac`` fallback.
        """
        if not self.reap.overlap_install or n_pages <= 8:
            return n_pages
        k = read_hot_prefix(self.base)
        if k is None:
            k = int(round(n_pages * self.reap.hot_prefix_frac))
        return max(1, min(k, n_pages))

    def _start_tail(self, pages, block=None, *, fetch=None) -> None:
        self.tail = TailInstall(
            self.monitor.arena, pages, block, fetch=fetch,
            deadline_s=self.reap.tail_deadline_s,
            workers=self.reap.tail_workers, clock=self.clock,
            registry=self.registry)

    def install(self, fetched) -> None:
        """Single-instance eager install (per-page ``install_span`` path).

        With ``overlap_install`` only the hot prefix (fault-order head of
        the WS) installs eagerly; the tail is handed to a background
        :class:`TailInstall` and this pipeline MATERIALIZES before the
        arena is fully resident — the arena's pending-fault path covers
        the gap.
        """
        if fetched is None:
            return
        pages, data, hit = fetched
        t0 = self.clock()
        if data is None:
            self.monitor.arena.touch_pages(
                pages, parallel=max(self.reap.parallel_faults, 1))
        else:
            k = (self._split_k if self._split_k is not None
                 else self.hot_count(len(pages)))
            self.monitor.arena.install_span(
                pages[:k], memoryview(data)[:k * PAGE])
            if k < len(pages):
                self.timings.install_s = self.clock() - t0
                self._span("install", t0, self.timings.install_s,
                           hot_pages=k, total_pages=len(pages))
                self._mark_prefetched(len(pages), hit)
                if self._tail_fetch is not None:
                    # split fetch: the tail's bytes arrive in the background
                    self._start_tail(pages[k:],
                                     fetch=self._tail_rows(k, pages[k:]))
                else:
                    tail_block = np.frombuffer(
                        data, dtype=np.uint8,
                        count=len(pages) * PAGE).reshape(-1, PAGE)[k:]
                    self._start_tail(pages[k:], tail_block)
                return
        self.timings.install_s = self.clock() - t0
        self._span("install", t0, self.timings.install_s,
                   total_pages=len(pages))
        self._mark_prefetched(len(pages), hit)

    def install_block(self, sorted_pages: np.ndarray, block: np.ndarray,
                      hit: bool, *, ws_fetch_s: float = 0.0,
                      tail: tuple[np.ndarray, np.ndarray | None] | None = None,
                      tail_fetch=None) -> None:
        """Fused group install: one vectorized scatter of the shared block.

        ``ws_fetch_s`` charges this instance its share of the group's
        single fetch (every member waited on it, like followers used to
        wait on the single-flight leader).  ``tail`` — the (pages, block)
        remainder of an overlapped restore — starts a background
        :class:`TailInstall` after the eager prefix lands; a tail block of
        None defers the tail's bytes to ``tail_fetch`` (split fetch).
        """
        t0 = self.clock()
        self.monitor.arena.install_block(sorted_pages, block)
        self.timings.install_s = self.clock() - t0
        self.timings.ws_fetch_s = ws_fetch_s
        self._span("ws_fetch", t0, self.timings.ws_fetch_s,
                   cache_hit=hit, group_share=True)
        self._span("install", t0, self.timings.install_s,
                   batched=True, total_pages=len(sorted_pages))
        n_total = len(sorted_pages)
        if tail is not None and len(tail[0]):
            n_total += len(tail[0])
            self._start_tail(tail[0], tail[1], fetch=tail_fetch)
        self._mark_prefetched(n_total, hit)

    def materialize(self, fn) -> None:
        """Timed post-install residency work (e.g. param materialization)."""
        t0 = self.clock()
        fn()
        self.timings.materialize_s = self.clock() - t0
        self._span("materialize", t0, self.timings.materialize_s)
        self._trace.finish()             # materialize ends the cold start

    def _mark_prefetched(self, n_pages: int, hit: bool) -> None:
        # keep the monitor's view consistent so finish() computes the
        # residual-fault ratio (§7.2 re-record policy) exactly as before
        mon = self.monitor
        mon.prefetched = n_pages
        mon.prefetch_s = self.timings.prefetch_s
        mon.ws_cache_hit = hit

    # -- convenience ----------------------------------------------------

    def run(self) -> "RestorePipeline":
        """Execute load_vmm → connect → ws_fetch → install in order."""
        self.load_vmm()
        self.connect()
        self.install(self.ws_fetch())
        return self

    def close(self) -> None:
        """Tear down a partially-restored pipeline (error paths)."""
        if self.tail is not None:
            # the tail worker writes into the arena mmap; join it before
            # the close releases the buffer under it
            self.tail.cancel(join=True)
            self.tail = None
        if self.monitor is not None:
            self.monitor.arena.close()


class RestoreBatch:
    """Restore N pipelines of ONE function as a single staged group.

    All pipelines must target the same ``base``.  The group performs one
    manifest parse, one WS fetch, and one fused gather pass; every member
    then installs the shared block with one vectorized scatter.  With
    ``len(pipes) == 1`` the batch degrades to the plain per-page pipeline
    (identical semantics to an unbatched restore).

    A mode fallback on the group's fetch (record dropped mid-restore)
    propagates to every member: the whole group re-records, exactly as N
    independent restores would have.
    """

    def __init__(self, pipes: list[RestorePipeline]):
        if not pipes:
            raise ValueError("empty restore batch")
        bases = {p.base for p in pipes}
        if len(bases) > 1:
            raise ValueError(f"restore batch spans bases {sorted(bases)}")
        self.pipes = pipes
        self.fuse_s = 0.0                # the shared gather pass, once

    def run(self) -> "RestoreBatch":
        pipes = self.pipes
        try:
            layout = None
            for p in pipes:
                p.load_vmm(layout=layout)
                layout = p.gm.layout     # manifest parsed once per group
            for p in pipes:
                p.connect()
            leader = pipes[0]
            fetched = leader.ws_fetch(group=len(pipes))
            if fetched is None:
                # record/vanilla mode — or the §7.2 fallback; every member
                # must agree (followers may have resolved 'prefetch' from a
                # record that a concurrent re-record has since dropped)
                if leader.monitor.mode == "record":
                    for p in pipes[1:]:
                        p.monitor.mode = "record"
                return self
            pages, data, hit = fetched
            if len(pipes) == 1 or data is None:
                # single restore, or the "Parallel PFs" design point where
                # every arena demand-reads its own pages (nothing to fuse)
                for p in pipes:
                    p.install(fetched)
                return self
            t0 = leader.clock()
            if leader._split_k is not None:
                # the leader's fetch split: ``data`` holds only the hot
                # prefix span.  Fuse just the prefix; every member's tail
                # resolves the full WS in the background (the per-pipe
                # fetch closures collapse to one read via the single-flight
                # cache, the rest hit the fresh entry)
                k = leader._split_k
                sorted_hot, hot_block = fuse_ws_block(
                    pages[:k], data, engine=leader.reap.fuse_engine)
                self.fuse_s = leader.clock() - t0
                fetch_s = leader.timings.ws_fetch_s + self.fuse_s
                tail_pages = np.asarray(pages[k:], dtype=np.int64)
                for p in pipes:
                    p.install_block(
                        sorted_hot, hot_block, hit, ws_fetch_s=fetch_s,
                        tail=(tail_pages, None),
                        tail_fetch=leader._tail_rows(k, tail_pages))
                return self
            sorted_pages, block = fuse_ws_block(
                pages, data, engine=leader.reap.fuse_engine)
            self.fuse_s = leader.clock() - t0
            # the fuse pass and the fetch sit on every member's critical
            # path — charge them to each report like follower waits were
            fetch_s = leader.timings.ws_fetch_s + self.fuse_s
            k_hot = leader.hot_count(len(pages))
            if k_hot < len(pages):
                # overlapped group restore: the hot set is the fault-order
                # head of the trace; split the ascending fused block by
                # membership so each member eagerly scatters only the
                # prefix and backgrounds the rest
                hot = set(int(p) for p in pages[:k_hot])
                mask = np.fromiter((int(p) in hot for p in sorted_pages),
                                   dtype=bool, count=len(sorted_pages))
                hot_pages, hot_block = sorted_pages[mask], block[mask]
                tail_pages, tail_block = sorted_pages[~mask], block[~mask]
                for p in pipes:
                    p.install_block(hot_pages, hot_block, hit,
                                    ws_fetch_s=fetch_s,
                                    tail=(tail_pages, tail_block))
            else:
                for p in pipes:
                    p.install_block(sorted_pages, block, hit,
                                    ws_fetch_s=fetch_s)
            return self
        except BaseException:
            for p in pipes:
                p.close()                # never leak half-restored arenas
            raise

    def stage_seconds(self) -> dict:
        """Aggregate per-stage seconds across the group (+ the fuse pass)."""
        out = {k: 0.0 for k in StageTimings().as_dict()}
        for p in self.pipes:
            for k, v in p.timings.as_dict().items():
                out[k] += v
        out["fuse_s"] = self.fuse_s
        return out
