"""Staged cold-start restore pipeline + batched group restores.

The paper's §4.2 latency split (load VMM / connection restore / prefetch /
processing) used to be produced implicitly by ``FunctionInstance.__init__``
doing blocking I/O in a constructor.  This module makes the restore path an
explicit, separately-timed pipeline:

    load_vmm -> connect -> ws_fetch -> install -> materialize

and adds the group form the single-instance path cannot express: under
concurrent load, N queued cold starts of one function used to run N full
pipelines — N manifest parses, N WS-cache waits (single-flight followers
blocking on the leader's read), and N serial per-page ``install_span``
loops.  :class:`RestoreBatch` restores all N as **one** staged operation:

  * one manifest parse (the layout is shared across the group's arenas),
  * one WS fetch (a single cache transaction instead of leader+followers),
  * one fused page-gather pass producing an ascending-page install block,
  * N vectorized block installs (one scatter per arena, no per-page loop).

The fuse step is the ``page_gather`` kernel's job description: reorder the
trace-order WS into the contiguous block the installs want.  On a TPU
backend the Pallas kernel (``kernels/page_gather``) runs it as a
scalar-prefetched DMA sweep; on CPU the same permutation is a single numpy
fancy-index (the kernel's interpret mode would cost more than it saves), so
``fuse_engine="auto"`` picks per backend and both engines are parity-tested
byte-for-byte.
"""
from __future__ import annotations

import dataclasses
import socket
import time

import numpy as np

from .arena import PAGE, GuestMemoryFile
from .reap import WS_CACHE, Monitor, ReapConfig, _read_ws, trace_path

#: Stage names in execution order (benchmarks iterate this).
STAGES = ("load_vmm", "connect", "ws_fetch", "install", "materialize")


@dataclasses.dataclass
class StageTimings:
    """Per-stage wall-clock seconds of one pipeline run.

    ``ws_fetch_s + install_s`` is the paper's "prefetch" segment;
    ``materialize_s`` (param residency) only runs off-path (prewarms).
    """
    load_vmm_s: float = 0.0
    connection_s: float = 0.0
    ws_fetch_s: float = 0.0
    install_s: float = 0.0
    materialize_s: float = 0.0

    @property
    def prefetch_s(self) -> float:
        return self.ws_fetch_s + self.install_s

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def connect_handshake() -> None:
    """Real loopback handshake standing in for gRPC connection restore."""
    a, b = socket.socketpair()
    try:
        a.sendall(b"PING")
        assert b.recv(4) == b"PING"
        b.sendall(b"PONG")
        assert a.recv(4) == b"PONG"
    finally:
        a.close()
        b.close()


def default_fuse_engine() -> str:
    """'pallas' on a TPU backend (the kernel compiles to a DMA sweep),
    'numpy' elsewhere (interpret-mode Pallas is slower than the copy)."""
    try:
        import jax
        if jax.default_backend() == "tpu":
            return "pallas"
    except Exception:
        pass
    return "numpy"


def fuse_ws_block(pages, data: bytes, *, engine: str = "auto",
                  interpret: bool | None = None
                  ) -> tuple[np.ndarray, np.ndarray]:
    """One fused gather pass over the trace-order WS bytes.

    Returns ``(sorted_pages, block)`` where ``block[i]`` is the content of
    arena page ``sorted_pages[i]`` — the WS permuted into ascending-page
    order so each instance's install is a single monotonic scatter.

    ``engine='pallas'`` runs the permutation through the
    :func:`~repro.kernels.gather_pages` kernel (the TPU-native realization);
    ``engine='numpy'`` is the vectorized host path.  Both produce identical
    bytes (tested).  ``interpret`` of None compiles the kernel on TPU and
    interprets elsewhere (interpret mode on the hot path would cost more
    than the fuse saves).
    """
    idx = np.asarray(pages, dtype=np.int64)
    ws = np.frombuffer(data, dtype=np.uint8,
                       count=len(idx) * PAGE).reshape(len(idx), PAGE)
    order = np.argsort(idx, kind="stable")
    if engine == "auto":
        engine = default_fuse_engine()
    if engine == "pallas":
        import jax
        import jax.numpy as jnp

        from ..kernels import gather_pages
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        block = np.asarray(gather_pages(
            jnp.asarray(ws), jnp.asarray(order.astype(np.int32)),
            interpret=interpret))
    elif engine == "numpy":
        block = np.ascontiguousarray(ws[order])
    else:
        raise ValueError(f"unknown fuse engine {engine!r}")
    return idx[order], block


class RestorePipeline:
    """Explicit staged restore of one function instance's state.

    Stages are separate methods so a group restore (:class:`RestoreBatch`)
    can interleave them across instances — e.g. run every ``load_vmm``
    against one parsed manifest, then one shared ``ws_fetch`` for the whole
    group.  ``run()`` is the single-instance convenience that executes them
    in order.

    ``clock`` injects the timer (tests pass a fake clock so stage
    attribution is deterministic); ``exec_restore`` is the jit-cache lookup
    (Firecracker's device-state restore analogue) supplied by the serving
    layer; ``connector`` stands in for the gRPC connection restore.
    """

    def __init__(self, base: str, reap: ReapConfig | None = None, *,
                 mode: str | None = None, cache=None, exec_restore=None,
                 connector=connect_handshake, clock=time.perf_counter):
        self.base = base
        self.reap = reap or ReapConfig()
        self.mode = mode                 # None => auto; 'vanilla' => no REAP
        self.cache = cache
        self.exec_restore = exec_restore
        self.connector = connector
        self.clock = clock
        self.timings = StageTimings()
        self.gm: GuestMemoryFile | None = None
        self.monitor: Monitor | None = None

    # -- stages ---------------------------------------------------------

    def load_vmm(self, layout=None) -> None:
        """Manifest parse + arena map + executable-handle restore.

        ``layout`` short-circuits the manifest parse with an
        already-parsed :class:`~repro.core.arena.ArenaLayout` — a group
        restore parses the manifest once and shares it.
        """
        t0 = self.clock()
        self.gm = (GuestMemoryFile(self.base, layout) if layout is not None
                   else GuestMemoryFile.open(self.base))
        self.monitor = Monitor(self.gm, self.base, self.reap,
                               mode=self.mode, cache=self.cache)
        if self.exec_restore is not None:
            self.exec_restore()
        self.timings.load_vmm_s = self.clock() - t0

    def connect(self) -> None:
        t0 = self.clock()
        self.connector()
        self.timings.connection_s = self.clock() - t0

    def ws_fetch(self, group: int = 1):
        """Fetch the working set (REAP prefetch phase, read half).

        Returns ``(pages, data, cache_hit)`` — ``data`` is None on the
        "Parallel PFs" design point (``use_ws_file=False``), where the
        install stage demand-reads the traced pages instead — or None when
        this monitor is not in prefetch mode.

        A concurrent §7.2 re-record may ``drop_record`` the WS file between
        the monitor's mode selection and this fetch; the resulting
        ``FileNotFoundError`` falls back to record mode (the §7.2 path)
        instead of failing the invocation.
        """
        mon = self.monitor
        if mon.mode != "prefetch":
            return None
        cfg = self.reap
        t0 = self.clock()
        try:
            if not cfg.use_ws_file:
                pages = [int(p) for p in np.load(trace_path(self.base))]
                data, hit = None, False
            elif cfg.share_ws_cache:
                pages, data, hit = (self.cache or WS_CACHE).fetch(
                    self.base, cfg, group=group)
            else:
                pages, data = _read_ws(self.base, cfg)
                hit = False
        except FileNotFoundError:
            mon.mode = "record"          # record dropped under us: re-record
            return None
        self.timings.ws_fetch_s = self.clock() - t0
        return pages, data, hit

    def install(self, fetched) -> None:
        """Single-instance eager install (per-page ``install_span`` path)."""
        if fetched is None:
            return
        pages, data, hit = fetched
        t0 = self.clock()
        if data is None:
            self.monitor.arena.touch_pages(
                pages, parallel=max(self.reap.parallel_faults, 1))
        else:
            self.monitor.arena.install_span(pages, data)
        self.timings.install_s = self.clock() - t0
        self._mark_prefetched(len(pages), hit)

    def install_block(self, sorted_pages: np.ndarray, block: np.ndarray,
                      hit: bool, *, ws_fetch_s: float = 0.0) -> None:
        """Fused group install: one vectorized scatter of the shared block.

        ``ws_fetch_s`` charges this instance its share of the group's
        single fetch (every member waited on it, like followers used to
        wait on the single-flight leader).
        """
        t0 = self.clock()
        self.monitor.arena.install_block(sorted_pages, block)
        self.timings.install_s = self.clock() - t0
        self.timings.ws_fetch_s = ws_fetch_s
        self._mark_prefetched(len(sorted_pages), hit)

    def materialize(self, fn) -> None:
        """Timed post-install residency work (e.g. param materialization)."""
        t0 = self.clock()
        fn()
        self.timings.materialize_s = self.clock() - t0

    def _mark_prefetched(self, n_pages: int, hit: bool) -> None:
        # keep the monitor's view consistent so finish() computes the
        # residual-fault ratio (§7.2 re-record policy) exactly as before
        mon = self.monitor
        mon.prefetched = n_pages
        mon.prefetch_s = self.timings.prefetch_s
        mon.ws_cache_hit = hit

    # -- convenience ----------------------------------------------------

    def run(self) -> "RestorePipeline":
        """Execute load_vmm → connect → ws_fetch → install in order."""
        self.load_vmm()
        self.connect()
        self.install(self.ws_fetch())
        return self

    def close(self) -> None:
        """Tear down a partially-restored pipeline (error paths)."""
        if self.monitor is not None:
            self.monitor.arena.close()


class RestoreBatch:
    """Restore N pipelines of ONE function as a single staged group.

    All pipelines must target the same ``base``.  The group performs one
    manifest parse, one WS fetch, and one fused gather pass; every member
    then installs the shared block with one vectorized scatter.  With
    ``len(pipes) == 1`` the batch degrades to the plain per-page pipeline
    (identical semantics to an unbatched restore).

    A mode fallback on the group's fetch (record dropped mid-restore)
    propagates to every member: the whole group re-records, exactly as N
    independent restores would have.
    """

    def __init__(self, pipes: list[RestorePipeline]):
        if not pipes:
            raise ValueError("empty restore batch")
        bases = {p.base for p in pipes}
        if len(bases) > 1:
            raise ValueError(f"restore batch spans bases {sorted(bases)}")
        self.pipes = pipes
        self.fuse_s = 0.0                # the shared gather pass, once

    def run(self) -> "RestoreBatch":
        pipes = self.pipes
        try:
            layout = None
            for p in pipes:
                p.load_vmm(layout=layout)
                layout = p.gm.layout     # manifest parsed once per group
            for p in pipes:
                p.connect()
            leader = pipes[0]
            fetched = leader.ws_fetch(group=len(pipes))
            if fetched is None:
                # record/vanilla mode — or the §7.2 fallback; every member
                # must agree (followers may have resolved 'prefetch' from a
                # record that a concurrent re-record has since dropped)
                if leader.monitor.mode == "record":
                    for p in pipes[1:]:
                        p.monitor.mode = "record"
                return self
            pages, data, hit = fetched
            if len(pipes) == 1 or data is None:
                # single restore, or the "Parallel PFs" design point where
                # every arena demand-reads its own pages (nothing to fuse)
                for p in pipes:
                    p.install(fetched)
                return self
            t0 = leader.clock()
            sorted_pages, block = fuse_ws_block(
                pages, data, engine=leader.reap.fuse_engine)
            self.fuse_s = leader.clock() - t0
            # the fuse pass and the fetch sit on every member's critical
            # path — charge them to each report like follower waits were
            fetch_s = leader.timings.ws_fetch_s + self.fuse_s
            for p in pipes:
                p.install_block(sorted_pages, block, hit, ws_fetch_s=fetch_s)
            return self
        except BaseException:
            for p in pipes:
                p.close()                # never leak half-restored arenas
            raise

    def stage_seconds(self) -> dict:
        """Aggregate per-stage seconds across the group (+ the fuse pass)."""
        out = {k: 0.0 for k in ("load_vmm_s", "connection_s", "ws_fetch_s",
                                "install_s", "materialize_s")}
        for p in self.pipes:
            for k, v in p.timings.as_dict().items():
                out[k] += v
        out["fuse_s"] = self.fuse_s
        return out
