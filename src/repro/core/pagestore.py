"""Content-addressed page store: fleet-wide WS chunk dedup (ROADMAP item 2).

The 12 model configs share one runtime, so their recorded working sets
share pages — yet the flat REAP layout stores and ships every function's
full WS file.  This module turns the WS record into a *manifest* (ordered
page indices -> content hashes) over a **content-addressed chunk store**:

* **Chunking**: 1 chunk == 1 arena page (``PAGE`` bytes).  The WS file's
  natural granularity *is* the page — the fault trace, the install path
  and the shard transfer all already move page multiples, so page-sized
  chunks dedup exactly the unit everything else reasons about.
* **Hashing**: ``blake2b(digest_size=16)`` over the raw page bytes.
  128-bit digests make accidental collisions negligible at fleet scale
  (birthday bound ~2^64 chunks) while keeping manifests compact.
* **Store layout** (one per snapshot-store directory, shared by every
  function recorded under it)::

      <store_dir>/.pagestore/chunks.data   packed unique chunks, appended
      <store_dir>/.pagestore/index.json    hash -> [offset, refcount]

* **Delta re-records**: a §7.2 re-record only appends chunks absent from
  the store; unchanged pages are pure refcount traffic (``dedup_hits``).
* **GC**: manifests refcount their unique chunks.  ``release_manifest``
  (``drop_record``) decrefs; a chunk hitting zero is dropped from the
  index and its bytes become dead.  Compaction rewrites ``chunks.data``
  with live chunks only once dead bytes dominate.

Concurrency contract (keeps the static lock analyzer clean):

* ``_mu`` guards the in-memory index/cache/stat maps and is never held
  across file I/O.
* ``_write_mu`` serializes mutators (append, refcount commit, index
  persist, compaction swap); reads never take it.
* Reads are single-flight per chunk (WSCache's leader/follower pattern):
  concurrent cold-starts of two functions sharing chunks perform one
  underlying read per unique chunk, and adjacent chunks coalesce into
  span reads (a fresh record's chunks are contiguous, so its first cold
  read stays one large ``preadv``).
* Compaction is optimistic: it snapshots, rewrites outside the locks and
  commits only if no writer raced it (generation check), so it never
  holds a lock across the bulk copy.
"""
from __future__ import annotations

import hashlib
import json
import mmap
import os
import threading
import weakref

from .arena import PAGE
from ..telemetry import TELEMETRY

#: Magic prefix distinguishing a v2 manifest from a legacy flat WS file.
#: A flat file holds raw page bytes; the probability of page 0 starting
#: with this exact string is negligible, and the legacy reader is still
#: reachable for any non-matching file.
WS_MAGIC = b"REAPWS2\n"

WS_FORMAT_VERSION = 2


def chunk_hash(block: bytes) -> str:
    """Content hash of one page-sized chunk (blake2b-128 hex)."""
    return hashlib.blake2b(block, digest_size=16).hexdigest()


def page_hashes(data: bytes) -> list[str]:
    """Hash ``data`` page by page (``len(data)`` must be a PAGE multiple;
    a trailing partial page — never produced by the record path — is
    hashed as its own short chunk rather than silently dropped)."""
    return [chunk_hash(data[off:off + PAGE])
            for off in range(0, len(data), PAGE)]


# -- manifest file format ------------------------------------------------

def read_manifest(path: str) -> dict | None:
    """Parse a v2 WS manifest at ``path``.

    Returns ``None`` for a legacy flat WS file, a missing file, or
    unparseable contents — callers fall back to the flat reader (which
    surfaces the usual ``FileNotFoundError`` for missing records).
    """
    try:
        with open(path, "rb") as f:
            head = f.read(len(WS_MAGIC))
            if head != WS_MAGIC:
                return None
            doc = json.loads(f.read().decode("utf-8"))
    except (OSError, ValueError):
        return None
    if doc.get("version") != WS_FORMAT_VERSION:
        return None
    return doc


def write_manifest(path: str, pages: list[int], chunks: list[str],
                   *, page_size: int = PAGE) -> int:
    """Atomically write a v2 manifest (tmp + ``os.replace``); returns the
    manifest byte size.  The ordered ``pages``/``chunks`` pair IS the WS:
    reassembly concatenates the chunks in this order."""
    doc = {"version": WS_FORMAT_VERSION, "page": page_size,
           "n_pages": len(pages), "pages": [int(p) for p in pages],
           "chunks": list(chunks)}
    blob = WS_MAGIC + json.dumps(doc).encode("utf-8")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(blob)
    os.replace(tmp, path)
    return len(blob)


def _close_fds(fds: list[int]) -> None:
    while fds:
        try:
            os.close(fds.pop())
        except OSError:
            pass


class PageStore:
    """One content-addressed chunk store (use :func:`get_store`)."""

    def __init__(self, store_dir: str, *,
                 cache_bytes: int = 64 << 20,
                 compact_min_bytes: int = 4 << 20):
        self.root = os.path.join(store_dir, ".pagestore")
        os.makedirs(self.root, exist_ok=True)
        self.data_path = os.path.join(self.root, "chunks.data")
        self.index_path = os.path.join(self.root, "index.json")
        self.cache_capacity = cache_bytes
        self.compact_min_bytes = compact_min_bytes
        self._mu = threading.Lock()        # index/cache/stats; no I/O under it
        self._write_mu = threading.Lock()  # serializes mutators; outer lock
        self._fds: list[int] = []          # open data fds (close()/finalize)
        self._fd = os.open(self.data_path, os.O_RDWR | os.O_CREAT, 0o644)
        self._fds.append(self._fd)
        self._fd_gen = 0                   # bumped when compaction swaps fds
        self._gen_readers: dict[int, int] = {}   # fd gen -> active readers
        self._retired_fds: dict[int, list[int]] = {}  # fd gen -> close pending
        # a SEPARATE O_DIRECT read fd: setting the flag on a dup of the
        # write fd would poison it too (dup'd fds share the open file
        # description), making every later unaligned pwrite fail EINVAL
        self._dfd = self._open_direct()
        weakref.finalize(self, _close_fds, self._fds)
        self._index: dict[str, list[int]] = {}   # hash -> [offset, refcount]
        self._data_end = 0
        self._dead_bytes = 0
        self._logical_bytes = 0            # sum of manifest WS sizes (flat-equiv)
        self._manifests = 0
        self._gen = 0                      # bumped by every mutator (compaction)
        self._cache: dict[str, bytes] = {}  # chunk LRU (insertion-ordered)
        self._cache_bytes = 0
        self._inflight: dict[str, threading.Event] = {}
        self.chunk_writes = 0              # unique chunks appended
        self.dedup_hits = 0                # chunks already present at write
        self.delta_chunks = 0              # new chunks written by re-records
        self.chunk_reads = 0               # chunks read from the data file
        self.span_reads = 0                # coalesced preadv calls issued
        self.cache_hits = 0
        self.cache_evicted = 0
        self.gc_freed = 0                  # chunks dropped at refcount zero
        self.compactions = 0
        self._load_index()

    def _open_direct(self) -> int | None:
        """O_DIRECT read fd on the current data file, or ``None`` when the
        flag or filesystem refuses (reads fall back to the buffered fd).
        Tracked in ``_fds`` so close()/finalize reap it."""
        if not hasattr(os, "O_DIRECT"):    # pragma: no cover - non-Linux
            return None
        try:
            dfd = os.open(self.data_path, os.O_RDONLY | os.O_DIRECT)
        except OSError:
            return None
        self._fds.append(dfd)
        return dfd

    # -- persistence ----------------------------------------------------

    def _load_index(self) -> None:
        try:
            with open(self.index_path, "r", encoding="utf-8") as f:
                doc = json.load(f)
        except (OSError, ValueError):
            return
        self._index = {h: [int(off), int(refs)]
                       for h, (off, refs) in doc.get("chunks", {}).items()}
        self._data_end = int(doc.get("data_end", 0))
        self._dead_bytes = int(doc.get("dead_bytes", 0))
        self._logical_bytes = int(doc.get("logical_bytes", 0))
        self._manifests = int(doc.get("manifests", 0))

    def _persist_index(self) -> None:
        """Atomic index snapshot (caller holds ``_write_mu``)."""
        with self._mu:
            doc = {"chunks": {h: [off, refs]
                              for h, (off, refs) in self._index.items()},
                   "data_end": self._data_end,
                   "dead_bytes": self._dead_bytes,
                   "logical_bytes": self._logical_bytes,
                   "manifests": self._manifests}
        blob = json.dumps(doc).encode("utf-8")
        tmp = self.index_path + ".tmp"
        fd = os.open(tmp, os.O_WRONLY | os.O_CREAT | os.O_TRUNC, 0o644)
        try:
            os.write(fd, blob)
        finally:
            os.close(fd)
        os.replace(tmp, self.index_path)

    # -- write path -----------------------------------------------------

    def commit_manifest(self, hashes: list[str],
                        blocks: dict[str, bytes],
                        *, delta: bool = False) -> tuple[int, int]:
        """Atomically publish one manifest's chunks: append the chunks the
        store doesn't hold and incref every unique chunk, write + incref
        in one mutator step so a concurrent ``release_manifest`` of a
        sharing function can never GC a chunk between the two.  A delta
        re-record (``delta=True``, counted as ``delta_chunks``) must
        release the superseded manifest's refs via ``release_manifest``
        only AFTER its own manifest pointer is durable on disk — a crash
        in between then leaves a readable record and at worst a leaked
        incref, never a live manifest whose chunks were GC'd.

        Returns ``(n_new, n_dedup)``: chunks appended vs already present.
        """
        uniq = list(dict.fromkeys(hashes))
        with self._write_mu:
            with self._mu:
                new = [h for h in uniq if h not in self._index]
                off = self._data_end
                offsets = {}
                for h in new:
                    offsets[h] = off
                    off += PAGE
                fd = self._fd
            for h in new:
                blk = blocks[h]
                if len(blk) != PAGE:
                    raise ValueError(
                        f"chunk {h} is {len(blk)} bytes, want {PAGE}")
                os.pwrite(fd, blk, offsets[h])
            with self._mu:
                for h in new:
                    self._index[h] = [offsets[h], 0]
                self._data_end = off
                for h in uniq:
                    self._index[h][1] += 1
                self._logical_bytes += len(hashes) * PAGE
                self._manifests += 1
                self._gen += 1
                self.chunk_writes += len(new)
                self.dedup_hits += len(uniq) - len(new)
                if delta:
                    self.delta_chunks += len(new)
            TELEMETRY.inc("pagestore.chunk_writes", len(new))
            TELEMETRY.inc("pagestore.dedup_hits", len(uniq) - len(new))
            self._persist_index()
        return len(new), len(uniq) - len(new)

    def _release_locked(self, hashes: list[str]) -> int:
        """Decref one manifest's unique chunks (caller holds ``_mu``)."""
        freed = 0
        for h in dict.fromkeys(hashes):
            ent = self._index.get(h)
            if ent is None:
                continue                 # already freed (double release)
            ent[1] -= 1
            if ent[1] <= 0:
                del self._index[h]
                self._dead_bytes += PAGE
                blk = self._cache.pop(h, None)
                if blk is not None:
                    self._cache_bytes -= len(blk)
                freed += 1
        self._logical_bytes = max(self._logical_bytes - len(hashes) * PAGE, 0)
        self._manifests = max(self._manifests - 1, 0)
        self.gc_freed += freed
        return freed

    def release_manifest(self, hashes: list[str]) -> int:
        """Drop one manifest's references (``drop_record``).  Chunks still
        referenced by any other manifest survive; orphans are GC'd.
        Returns the number of chunks freed."""
        with self._write_mu:
            with self._mu:
                freed = self._release_locked(hashes)
                if freed:
                    self._gen += 1
            self._persist_index()
        if freed:
            TELEMETRY.inc("pagestore.gc_freed", freed)
            self._maybe_compact()
        return freed

    # -- read path ------------------------------------------------------

    def contains(self, h: str) -> bool:
        with self._mu:
            return h in self._index

    def missing(self, hashes) -> set[str]:
        """Subset of ``hashes`` the store does not hold."""
        with self._mu:
            return {h for h in set(hashes) if h not in self._index}

    def read_chunks(self, hashes: list[str], *,
                    o_direct: bool = False) -> bytes:
        """Reassemble ``b"".join(chunk bytes in hash order)``.

        Single-flight per chunk: concurrent readers sharing chunks elect
        one leader per missing chunk; followers block on its completion
        and serve from the chunk cache.  Adjacent store offsets coalesce
        into one span read, so a fresh (contiguous) record costs one
        large read just like the flat WS file did.
        """
        out: dict[str, bytes] = {}
        pending = list(dict.fromkeys(hashes))
        while pending:
            waits: list[threading.Event] = []
            rest: list[str] = []
            claimed: list[tuple[str, int]] = []
            missing: str | None = None
            with self._mu:
                for h in pending:
                    blk = self._cache.get(h)
                    if blk is not None:
                        del self._cache[h]       # LRU touch: reinsert last
                        self._cache[h] = blk
                        self.cache_hits += 1
                        out[h] = blk
                        continue
                    ev = self._inflight.get(h)
                    if ev is not None:
                        waits.append(ev)
                        rest.append(h)
                        continue
                    ent = self._index.get(h)
                    if ent is None:
                        missing = h
                        break
                    self._inflight[h] = threading.Event()
                    claimed.append((h, ent[0]))
                if missing is not None:
                    # the raise must not strand this pass's claims: no
                    # waiter can have seen them yet (registered under this
                    # same lock hold), so pop + set before surfacing
                    for ch, _ in claimed:
                        ev = self._inflight.pop(ch, None)
                        if ev is not None:
                            ev.set()
                    raise KeyError(f"chunk {missing} not in page store")
                if claimed:
                    fd, dfd, fgen = self._acquire_read_locked()
            if claimed:
                try:
                    offs = [off for _, off in claimed]
                    blks = self._read_offsets(fd, offs, o_direct, dfd=dfd)
                    with self._mu:
                        for (h, _), blk in zip(claimed, blks):
                            out[h] = blk
                            self._cache_put(h, blk)
                        self.chunk_reads += len(claimed)
                finally:
                    self._release_read(fgen)
                    with self._mu:
                        events = [self._inflight.pop(h, None)
                                  for h, _ in claimed]
                    for ev in events:
                        if ev is not None:
                            ev.set()
            for ev in waits:
                ev.wait()
            pending = rest
        return b"".join(out[h] for h in hashes)

    def _acquire_read_locked(self) -> tuple[int, int | None, int]:
        """Snapshot ``(fd, dfd, fd-generation)`` for a read and pin the
        generation: a concurrent compaction swap defers closing the
        retired fds until the last pinned reader releases (caller holds
        ``_mu``)."""
        g = self._fd_gen
        self._gen_readers[g] = self._gen_readers.get(g, 0) + 1
        return self._fd, self._dfd, g

    def _release_read(self, gen: int) -> None:
        """Unpin one read of fd generation ``gen``; the last reader of a
        retired generation closes its fds (bounding open fds at two per
        *live* generation instead of two per compaction ever run)."""
        close: list[int] = []
        with self._mu:
            n = self._gen_readers.get(gen, 0) - 1
            if n > 0:
                self._gen_readers[gen] = n
            else:
                self._gen_readers.pop(gen, None)
                close = self._retired_fds.pop(gen, [])
                for fd in close:
                    try:
                        self._fds.remove(fd)
                    except ValueError:
                        pass
        for fd in close:
            try:
                os.close(fd)
            except OSError:
                pass

    def _cache_put(self, h: str, blk: bytes) -> None:
        # caller holds _mu; never evict the entry just inserted
        if h in self._cache:
            return
        self._cache[h] = blk
        self._cache_bytes += len(blk)
        while self._cache_bytes > self.cache_capacity and len(self._cache) > 1:
            victim = next(iter(self._cache))
            self._cache_bytes -= len(self._cache.pop(victim))
            self.cache_evicted += 1

    def _read_offsets(self, fd: int, offsets: list[int],
                      o_direct: bool, dfd: int | None = None) -> list[bytes]:
        """Read one PAGE chunk per offset, coalescing adjacent offsets
        into span reads.  Runs outside every store lock.  ``dfd`` is the
        dedicated O_DIRECT fd snapshotted with ``fd`` (same data-file
        generation): offsets, lengths and the anonymous-mmap buffer are
        all PAGE-aligned, and a refusal mid-read falls back to the
        buffered fd for that span."""
        order = sorted(set(offsets))
        runs: list[list[int]] = []       # [start, n_pages]
        for off in order:
            if runs and off == runs[-1][0] + runs[-1][1] * PAGE:
                runs[-1][1] += 1
            else:
                runs.append([off, 1])
        rfd = dfd if (o_direct and dfd is not None) else fd
        blocks: dict[int, bytes] = {}
        for start, n in runs:
            n_bytes = n * PAGE
            buf = mmap.mmap(-1, n_bytes)
            mv = memoryview(buf)
            got = 0
            while got < n_bytes:
                try:
                    r = os.preadv(rfd, [mv[got:]], start + got)
                except OSError:
                    if rfd == fd:
                        mv.release()
                        buf.close()
                        raise
                    rfd = fd             # O_DIRECT refused: go buffered
                    continue
                if r <= 0:
                    # EOF mid-span == truncated/corrupt data file; silently
                    # serving the rest of the anonymous mmap would restore
                    # zero-filled guest memory
                    mv.release()
                    buf.close()
                    raise IOError(
                        f"short read in {self.data_path}: wanted "
                        f"{n_bytes} bytes at offset {start}, got {got}")
                got += r
            for i in range(n):
                blocks[start + i * PAGE] = bytes(
                    mv[i * PAGE:(i + 1) * PAGE])
            mv.release()
            buf.close()
        with self._mu:
            self.span_reads += len(runs)
        return [blocks[off] for off in offsets]

    # -- compaction -----------------------------------------------------

    def _should_compact(self) -> bool:
        with self._mu:
            live = len(self._index) * PAGE
            return (self._dead_bytes >= self.compact_min_bytes
                    and self._dead_bytes > live)

    def _maybe_compact(self) -> None:
        if self._should_compact():
            self.compact()

    def compact(self) -> bool:
        """Rewrite ``chunks.data`` with live chunks only.  Optimistic: the
        bulk copy runs outside the locks; the swap commits only when no
        writer raced it (generation check), else it retries.  Readers
        mid-flight keep their pinned snapshot fds; the retired generation
        is closed as soon as its last reader releases."""
        for _ in range(4):
            with self._mu:
                snap = sorted((off, h)
                              for h, (off, _refs) in self._index.items())
                gen = self._gen
                fd, _dfd, fgen = self._acquire_read_locked()
            try:
                blks = (self._read_offsets(fd, [off for off, _ in snap],
                                           False)
                        if snap else [])
            finally:
                self._release_read(fgen)
            tmp = self.data_path + ".tmp"
            tfd = os.open(tmp, os.O_WRONLY | os.O_CREAT | os.O_TRUNC, 0o644)
            new_off: dict[str, int] = {}
            pos = 0
            try:
                for (_, h), blk in zip(snap, blks):
                    os.pwrite(tfd, blk, pos)
                    new_off[h] = pos
                    pos += PAGE
            finally:
                os.close(tfd)
            with self._write_mu:
                to_close: list[int] = []
                with self._mu:
                    if self._gen != gen:
                        raced = True
                    else:
                        raced = False
                        os.replace(tmp, self.data_path)
                        old_fds = [f for f in (self._fd, self._dfd)
                                   if f is not None]
                        nfd = os.open(self.data_path, os.O_RDWR)
                        self._fds.append(nfd)
                        self._fd = nfd
                        self._dfd = self._open_direct()
                        # retire the old generation: readers mid-flight
                        # pinned it and the last _release_read closes it;
                        # with no pinned reader it closes right here
                        old_gen = self._fd_gen
                        self._fd_gen += 1
                        if self._gen_readers.get(old_gen):
                            self._retired_fds[old_gen] = old_fds
                        else:
                            to_close = old_fds
                            for f in to_close:
                                try:
                                    self._fds.remove(f)
                                except ValueError:
                                    pass
                        for h, noff in new_off.items():
                            self._index[h][0] = noff
                        self._data_end = pos
                        self._dead_bytes = 0
                        self.compactions += 1
                for f in to_close:
                    try:
                        os.close(f)
                    except OSError:
                        pass
                if not raced:
                    self._persist_index()
                    TELEMETRY.inc("pagestore.compactions")
                    return True
            try:
                os.remove(tmp)           # raced a writer: retry fresh
            except OSError:
                pass
        return False

    # -- stats / lifecycle ----------------------------------------------

    def reset_stats(self) -> None:
        with self._mu:
            self.chunk_writes = self.dedup_hits = self.delta_chunks = 0
            self.chunk_reads = self.span_reads = 0
            self.cache_hits = self.cache_evicted = 0
            self.gc_freed = self.compactions = 0

    def stats(self) -> dict:
        with self._mu:
            store_bytes = len(self._index) * PAGE
            logical = self._logical_bytes
            return {
                "chunks": len(self._index),
                "manifests": self._manifests,
                "store_bytes": store_bytes,          # live chunk bytes
                "data_bytes": self._data_end,        # file incl. dead bytes
                "dead_bytes": self._dead_bytes,
                "logical_bytes": logical,            # flat-file equivalent
                "dedup_ratio": (logical / store_bytes if store_bytes
                                else 1.0),
                "chunk_writes": self.chunk_writes,
                "dedup_hits": self.dedup_hits,
                "delta_chunks": self.delta_chunks,
                "chunk_reads": self.chunk_reads,
                "span_reads": self.span_reads,
                "cache_hits": self.cache_hits,
                "cache_evicted": self.cache_evicted,
                "cache_bytes": self._cache_bytes,
                "gc_freed": self.gc_freed,
                "compactions": self.compactions,
            }

    def close(self) -> None:
        with self._mu:
            self._cache.clear()
            self._cache_bytes = 0
            # _close_fds owns every remaining fd now; a straggling
            # _release_read must not close (possibly reused) fd numbers
            self._retired_fds.clear()
            self._gen_readers.clear()
        _close_fds(self._fds)


# -- process-wide registry ----------------------------------------------

_STORES: dict[str, PageStore] = {}
_STORES_MU = threading.Lock()


def get_store(store_dir: str) -> PageStore:
    """The (process-wide) PageStore for a snapshot-store directory.  All
    functions recorded under one directory share one chunk store — that
    sharing IS the cross-function dedup."""
    key = os.path.realpath(store_dir)
    with _STORES_MU:
        store = _STORES.get(key)
    if store is not None:
        return store
    # construct outside the registry lock (init reads the persisted
    # index); a racing constructor loses setdefault and is discarded
    store = PageStore(key)
    with _STORES_MU:
        winner = _STORES.setdefault(key, store)
    if winner is not store:
        store.close()
    return winner


def reset_stores() -> None:
    """Close and forget every registered store (test isolation; persisted
    index/data files survive, so a later get_store() reloads them)."""
    with _STORES_MU:
        stores = list(_STORES.values())
        _STORES.clear()
    for s in stores:
        s.close()
