"""Building guest-memory files for function instances.

Arena regions (DESIGN.md §3):
  * ``infra/...``  -- runtime tables every invocation touches (tokenizer,
    dispatch tables, executable-cache metadata): the analogue of the ~8 MB
    of guest-kernel/gRPC pages the paper measures as stable across
    invocations (§4.4).
  * ``params/...`` -- the serving weights (bf16): the function working set.
  * ``vision/...`` / ``audio/...`` -- modality-frontend banks, touched only
    when the invocation carries that modality.
  * ``boot/...``   -- boot-only state (fp32 master weights + optimizer
    moments for instances deployed from training checkpoints): present in
    the booted image, never touched while serving -- this is what makes the
    snapshot working set a small fraction of the booted footprint (Fig. 4).
"""
from __future__ import annotations

import numpy as np

from ..configs.base import ModelConfig
from ..models import get_family
from ..nn import spec as nnspec
from .arena import ArenaLayout, GuestMemoryFile

INFRA_TENSORS = (
    ("infra/tokenizer_table", (1 << 20,), "uint8"),     # 1 MB
    ("infra/runtime_config", (256 << 10,), "uint8"),    # 256 KB
    ("infra/grpc_channel_state", (2 << 20,), "uint8"),  # 2 MB
    ("infra/executable_cache_index", (1 << 20,), "uint8"),
    ("infra/kernel_pages", (4 << 20,), "uint8"),        # guest-kernel analogue
)


def _frontend_tensors(cfg: ModelConfig) -> list[tuple[str, tuple, str, str]]:
    """Modality frontend stub weight banks (sized like a small ViT/w2v)."""
    out = []
    if cfg.family == "vlm":
        out.append(("vision/vit_stub", (24, cfg.d_model, 1024), "bfloat16", "serve"))
    if cfg.family == "encdec":
        out.append(("audio/frontend_stub", (12, cfg.d_model, 512), "bfloat16", "serve"))
    return out


def instance_tensor_list(cfg: ModelConfig, *, include_boot: bool = True):
    """(path, shape, dtype, region) list in arena layout order."""
    fam = get_family(cfg)
    specs = fam.param_specs(cfg)
    tensors: list[tuple[str, tuple, str, str]] = [
        (p, s, d, "infra") for (p, s, d) in INFRA_TENSORS]
    tensors += _frontend_tensors(cfg)
    for path, s in nnspec.tree_paths(specs):
        tensors.append((f"params/{path}", s.shape, str(np.dtype(s.dtype)), "serve"))
    if include_boot:
        for path, s in nnspec.tree_paths(specs):
            tensors.append((f"boot/master/{path}", s.shape, "float32", "boot"))
            tensors.append((f"boot/adam_mu/{path}", s.shape, "float32", "boot"))
            tensors.append((f"boot/adam_nu/{path}", s.shape, "float32", "boot"))
    return tensors


def build_instance_snapshot(cfg: ModelConfig, base: str, *, seed: int = 0,
                            include_boot: bool = True) -> GuestMemoryFile:
    """Create <base>.mem/.manifest.json for a booted instance of ``cfg``."""
    fam = get_family(cfg)
    specs = fam.param_specs(cfg)
    tensors = instance_tensor_list(cfg, include_boot=include_boot)
    layout = ArenaLayout.build(tensors)

    host = nnspec.host_initialize(specs, seed=seed)
    arrays: dict[str, np.ndarray] = {}
    rng = np.random.default_rng(seed)
    for path, shape, dtype, _region in tensors:
        if path.startswith("params/"):
            arrays[path] = host[path[len("params/"):]]
        elif path.startswith("boot/master/"):
            arrays[path] = host[path[len("boot/master/"):]].astype(np.float32)
        elif path.startswith("boot/"):
            sub = path.split("/", 2)[2]
            arrays[path] = np.zeros(host[sub].shape, np.float32)
        else:  # infra / frontend banks: deterministic filler
            if dtype == "uint8":
                arrays[path] = rng.integers(0, 255, shape, dtype=np.uint8)
            else:
                arrays[path] = (rng.standard_normal(shape).astype(np.float32)
                                * 0.02).astype(np.dtype(dtype))
    return GuestMemoryFile.create(base, layout, arrays)


def booted_footprint_bytes(cfg: ModelConfig, include_boot: bool = True) -> int:
    """Footprint of a freshly-booted instance (everything in the image)."""
    layout = ArenaLayout.build(instance_tensor_list(cfg, include_boot=include_boot))
    return layout.total_bytes
