"""SPES-style adaptive prewarming control plane (the provisioning policy).

PR 1's data plane reacts *after* an arrival: a burst pays a cold start for
every instance the warm pool is short.  This module closes the loop the way
SPES (Lee et al.) and "How Low Can You Go?" (Tan et al.) argue for — predict
arrivals from per-function history and pre-spawn instances *off* the
invocation critical path:

  * **Demand model** (:class:`FunctionDemand`) — per-function inter-arrival
    EWMA plus a sliding-window arrival rate, fed from the router's arrival
    timestamps (``Router.drain_arrivals``).  The window catches bursts; the
    EWMA smooths them into a keepalive horizon.
  * **Target sizing** — Little's-law concurrency demand: predicted rate x
    estimated (warm) service time x a headroom factor, clamped to
    ``max_warm``.  The target becomes the function's ``min_warm`` floor (the
    keepalive reaper never shrinks below it) and its per-function
    ``warm_limit`` (replacing the static global knob).
  * **Prewarming** — when the target exceeds instances that exist or are
    being spawned, :meth:`Orchestrator.prewarm` cold-starts the difference
    on pool threads; arrivals then find IDLE instances and never pay
    ``load_vmm_s``/``prefetch_s`` (their reports carry ``prewarmed=True``).
  * **Adaptive keepalive** — per-function keepalive tracks the expected
    inter-arrival gap (a few EWMA horizons), so hot functions stay resident
    and cold ones scale to zero quickly (paper §2's keepalive/memory
    tradeoff).

Two demand signals beyond the reactive model feed the same actuators:

  * **Periodicity forecasts** — ``PolicyConfig(forecast=True)`` swaps the
    demand model for :class:`~repro.serving.forecast.ForecastDemand`,
    which folds arrival history into a phase-binned rate profile and
    raises targets *ahead* of a learned ramp (forecast.py).
  * **Fleet hints** — a cluster-level aggregator (cluster/demand.py) may
    :meth:`PrewarmPolicy.push_forecast` a TTL'd rate share for functions
    whose traffic lands on *other* nodes; the step actuates
    ``max(local target, fleet target)``, so owner-shard replicas are warm
    before spillover placements arrive.

The loop runs on a daemon thread (:meth:`PrewarmPolicy.start`) but every
decision is a pure function of ingested timestamps, so tests drive
:meth:`ingest` + :meth:`step` directly — with ``clock=`` injecting a fake
monotonic clock (tests/fakeclock.py) they run in milliseconds.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
from collections import deque

from .orchestrator import FunctionRecord, Orchestrator
from .router import Router


@dataclasses.dataclass
class PolicyConfig:
    interval_s: float = 0.1          # control-loop period
    window_s: float = 5.0            # sliding window for the arrival rate
    ewma_alpha: float = 0.3          # inter-arrival EWMA smoothing factor
    headroom: float = 2.0            # safety factor over Little's-law demand
    max_warm: int = 8                # per-function warm-target ceiling
    default_service_s: float = 0.05  # service-time prior (no samples yet)
    service_samples: int = 32        # recent invocations in the estimate
    keepalive_horizons: float = 8.0  # keepalive = this many EWMA inter-arrivals
    min_keepalive_s: float = 0.5
    max_keepalive_s: float = 60.0
    max_prewarms_per_step: int = 2   # actuation rate limit per function/step
    sweep: bool = True               # run the keepalive reaper each step
    forecast: bool = False           # periodicity-aware demand (forecast.py)
    forecast_cfg: object | None = None  # ForecastConfig when forecast=True


class FunctionDemand:
    """Arrival model for one function: windowed rate + inter-arrival EWMA.

    ``clock`` supplies "now" whenever a caller omits it (tests inject a
    fake monotonic clock so timing assertions never sleep).
    """

    def __init__(self, cfg: PolicyConfig, *, clock=time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self.window: deque[float] = deque()
        self.last_arrival: float | None = None
        self.ewma_interarrival: float | None = None
        self.n_arrivals = 0

    def observe(self, timestamps: list[float]) -> None:
        for t in sorted(timestamps):
            if self.last_arrival is not None:
                gap = max(t - self.last_arrival, 1e-9)
                a = self.cfg.ewma_alpha
                self.ewma_interarrival = (
                    gap if self.ewma_interarrival is None
                    else a * gap + (1 - a) * self.ewma_interarrival)
            self.last_arrival = t
            self.window.append(t)
            self.n_arrivals += 1

    def _trim(self, now: float) -> None:
        horizon = now - self.cfg.window_s
        while self.window and self.window[0] < horizon:
            self.window.popleft()

    def rate(self, now: float | None = None) -> float:
        """Predicted arrival rate (rps): max of the windowed empirical rate
        and the EWMA rate — the window reacts to bursts, the EWMA keeps a
        just-ended burst from zeroing the forecast instantly."""
        now = self.clock() if now is None else now
        self._trim(now)
        windowed = len(self.window) / self.cfg.window_s
        ewma = (1.0 / self.ewma_interarrival
                if self.ewma_interarrival else 0.0)
        return max(windowed, ewma if self.active(now) else 0.0)

    def peak_concurrency(self, service_s: float,
                         now: float | None = None) -> int:
        """Max arrivals landing within one service time anywhere in the
        window — the instantaneous concurrency a burst demands.  Little's
        law alone misses this: an 8-wide simultaneous burst needs 8 warm
        instances no matter how low the average rate is."""
        now = self.clock() if now is None else now
        self._trim(now)
        ts = list(self.window)
        peak = 0
        lo = 0
        for hi in range(len(ts)):
            while ts[hi] - ts[lo] > max(service_s, 1e-9):
                lo += 1
            peak = max(peak, hi - lo + 1)
        return peak

    def active(self, now: float | None = None) -> bool:
        """Demand is live while the gap since the last arrival is within the
        adaptive keepalive horizon."""
        now = self.clock() if now is None else now
        return (self.last_arrival is not None
                and now - self.last_arrival <= self.keepalive(now))

    def forgettable(self, now: float | None = None) -> bool:
        """May the policy drop this demand entry once its target hits zero?
        The reactive model holds no state worth keeping past its keepalive;
        the forecasting subclass overrides this to preserve a learned
        period through traffic troughs."""
        now = self.clock() if now is None else now
        return not self.active(now)

    def gap_estimate(self, now: float | None = None) -> float | None:
        """Expected inter-arrival gap, robust to bursts: the raw EWMA is
        dominated by tiny intra-burst gaps (a burst of 8 back-to-back
        arrivals drives it to ~0), which would collapse the keepalive right
        before the *next* burst.  Taking the max with the windowed mean gap
        keeps the horizon tied to how often traffic actually recurs.

        None when there is no recurrence evidence at all (a single stray
        arrival whose window has expired): such functions must scale down
        *fast*, not be pinned at the maximum keepalive.
        """
        now = self.clock() if now is None else now
        self._trim(now)
        cands = []
        if self.ewma_interarrival is not None:
            cands.append(self.ewma_interarrival)
        if self.window:
            cands.append(self.cfg.window_s / len(self.window))
        return max(cands) if cands else None

    def keepalive(self, now: float | None = None) -> float:
        now = self.clock() if now is None else now
        gap = self.gap_estimate(now)
        if gap is None:
            return self.cfg.min_keepalive_s
        return min(self.cfg.max_keepalive_s,
                   max(self.cfg.min_keepalive_s,
                       self.cfg.keepalive_horizons * gap))


class PrewarmPolicy:
    """Background control loop: router arrivals in, provisioning out.

    Actuators per function (all on the orchestrator):

      * ``set_policy(warm_limit=, keepalive_s=, min_warm=)``
      * ``prewarm(name, n)`` for the warm-pool deficit
      * ``reap_idle()`` each step so adaptive keepalive takes effect
    """

    def __init__(self, orch: Orchestrator, router: Router | None = None,
                 cfg: PolicyConfig | None = None, *, clock=time.monotonic):
        self.orch = orch
        self.router = router
        self.cfg = cfg or PolicyConfig()
        self.clock = clock
        self.demand: dict[str, FunctionDemand] = {}
        self.targets: dict[str, int] = {}
        # fleet-pushed forecast rates: name -> (rate_rps, expires_at).  The
        # cluster demand plane (cluster/demand.py) pushes these to the
        # owner-shard nodes so replicas prewarm before spillover lands.
        self.fleet: dict[str, tuple[float, float]] = {}
        self.n_steps = 0
        self.n_prewarms = 0
        self.n_errors = 0
        self.last_error: BaseException | None = None
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        # guards demand/targets against callers (ingest/stats) racing the
        # loop thread; reentrant because step() ingests internally
        self._mu = threading.RLock()

    # -- demand ingestion ----------------------------------------------

    def _new_demand(self) -> FunctionDemand:
        if self.cfg.forecast:
            from .forecast import ForecastDemand
            return ForecastDemand(self.cfg, self.cfg.forecast_cfg,
                                  clock=self.clock)
        return FunctionDemand(self.cfg, clock=self.clock)

    def ingest(self, arrivals: dict[str, list[float]]) -> None:
        """Feed per-function arrival timestamps (``time.monotonic``)."""
        with self._mu:
            for name, ts in arrivals.items():
                d = self.demand.get(name)
                if d is None:
                    d = self.demand[name] = self._new_demand()
                d.observe(ts)

    def push_forecast(self, name: str, rate_rps: float,
                      expires_at: float) -> None:
        """Accept a fleet-wide demand forecast for ``name`` (rate share
        this node should be warm for).  Hints expire at ``expires_at`` so
        a dead aggregator can never pin warm pools forever."""
        with self._mu:
            self.fleet[name] = (rate_rps, expires_at)

    def clear_forecast(self, name: str) -> None:
        with self._mu:
            self.fleet.pop(name, None)

    def _fleet_target(self, name: str, rec: FunctionRecord,
                      now: float) -> int:
        """Warm instances the fleet forecast asks this node to hold.

        The pushed rate already carries the aggregator's safety factor
        (DemandConfig.headroom) — applying ``self.cfg.headroom`` on top
        would square the margin, so Little's law runs on the rate as-is.
        """
        hint = self.fleet.get(name)
        if hint is None:
            return 0
        rate, expires = hint
        if now >= expires or rate <= 0:
            return 0
        demand = rate * self._service_estimate(rec)
        return min(self.cfg.max_warm, max(1, math.ceil(demand)))

    def _service_estimate(self, rec: FunctionRecord) -> float:
        with rec.lock:
            recent = rec.stats[-self.cfg.service_samples:]
            samples = [r.processing_s for r in recent if r.processing_s > 0]
        if not samples:
            return self.cfg.default_service_s
        return sum(samples) / len(samples)

    def _restore_estimate(self, rec: FunctionRecord) -> float:
        """Mean observed cold-restore cost (load VMM + connection + WS
        prefetch) — what an under-provisioned arrival would pay."""
        with rec.lock:
            recent = rec.stats[-self.cfg.service_samples:]
            samples = [r.load_vmm_s + r.connection_s + r.prefetch_s
                       for r in recent if r.load_vmm_s > 0]
        if not samples:
            return self.cfg.default_service_s
        return sum(samples) / len(samples)

    def target_for(self, name: str, now: float | None = None) -> int:
        """Warm-pool target: Little's-law concurrency demand with headroom,
        floored by the burst width the window has actually seen.

        The burst horizon is service + restore time: two arrivals landing
        within one cold-restore duration need two warm instances — the
        second can't wait for a reactive spawn without paying cold.
        """
        now = self.clock() if now is None else now
        d = self.demand.get(name)
        rec = self.orch.functions.get(name)
        if d is None or rec is None or not d.active(now):
            return 0
        svc = self._service_estimate(rec)
        little = d.rate(now) * svc * self.cfg.headroom
        burst = d.peak_concurrency(svc + self._restore_estimate(rec), now)
        return min(self.cfg.max_warm, max(1, math.ceil(max(little, burst))))

    # -- control loop ---------------------------------------------------

    def step(self, now: float | None = None) -> dict[str, int]:
        """One control iteration; returns the per-function targets applied."""
        with self._mu:
            return self._step_locked(now)

    def _step_locked(self, now: float | None) -> dict[str, int]:
        if self.router is not None:
            self.ingest(self.router.drain_arrivals())
        now = self.clock() if now is None else now
        inflight: dict[str, int] = {}
        if self.router is not None:
            inflight = self.router.stats()["inflight"]
        applied: dict[str, int] = {}
        stale: list[str] = []
        for name, (_, expires) in list(self.fleet.items()):
            if now >= expires:
                del self.fleet[name]
        # visit every name with live demand or a hint, plus any actuated
        # last step — an expired/withdrawn hint must still get one pass
        # through the target-0 branch to drop its min_warm floor
        names = (set(self.demand) | set(self.fleet)
                 | {n for n, t in self.targets.items() if t > 0})
        for name in names:
            d = self.demand.get(name)
            rec = self.orch.functions.get(name)
            if rec is None:
                stale.append(name)
                continue
            # the local reactive/forecast target and the fleet-pushed
            # forecast are independent demand signals; warm for the larger
            target = max(self.target_for(name, now),
                         self._fleet_target(name, rec, now))
            applied[name] = target
            if target > 0:
                # The limit is a capacity cap, the target a residency floor.
                # Only ever *raise* the cap above the orchestrator default —
                # shrinking it below would reclaim instances the reactive
                # path could have parked; memory is recovered through the
                # adaptive keepalive sweep instead.
                # a fleet-hint-only function has no local arrival history;
                # its residency is carried by the min_warm floor, so the
                # keepalive just needs to be sane, not adaptive
                keepalive = (d.keepalive(now) if d is not None
                             else self.cfg.min_keepalive_s)
                self.orch.set_policy(
                    name,
                    warm_limit=max(target, self.orch.warm_limit),
                    keepalive_s=keepalive,
                    min_warm=target)
                with rec.lock:
                    have = len(rec.idle) + rec.n_prewarming
                have += inflight.get(name, 0)  # busy instances rejoin the pool
                # rate-limit actuation so a burst can't trigger a prewarm
                # storm that steals cycles from in-flight invocations
                deficit = min(target - have, self.cfg.max_prewarms_per_step)
                if deficit > 0:
                    self.n_prewarms += self.orch.prewarm(name, deficit)
            else:
                # demand went stale: drop the floor and leave a *short*
                # keepalive so residual instances scale to zero fast (the
                # static default may be a minute).  The reactive model is
                # then forgotten — fresh traffic rebuilds its history —
                # but a forecasting model that still holds a learned
                # period (forgettable() False) is kept through the trough.
                self.orch.set_policy(name, warm_limit=None,
                                     keepalive_s=self.cfg.min_keepalive_s,
                                     min_warm=0)
                if d is None or d.forgettable(now):
                    stale.append(name)
        for name in stale:
            self.demand.pop(name, None)
            self.fleet.pop(name, None)
        self.targets = applied
        if self.cfg.sweep:
            self.orch.reap_idle()
        self.n_steps += 1
        return applied

    # -- lifecycle ------------------------------------------------------

    def start(self) -> "PrewarmPolicy":
        if self._thread is not None:
            return self
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="prewarm-policy", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    def _loop(self) -> None:
        while not self._stop.wait(self.cfg.interval_s):
            try:
                self.step()
            except Exception as e:
                # a policy hiccup (e.g. a function being deregistered
                # mid-step) must never kill the control loop — but a loop
                # that errors every step must be observable via stats()
                self.n_errors += 1
                self.last_error = e
                continue

    def __enter__(self) -> "PrewarmPolicy":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def stats(self) -> dict:
        with self._mu:
            now = self.clock()
            return {
                "steps": self.n_steps,
                "prewarms_scheduled": self.n_prewarms,
                "errors": self.n_errors,
                "last_error": (repr(self.last_error)
                               if self.last_error else None),
                "targets": dict(self.targets),
                "fleet_hints": {n: rate for n, (rate, exp) in
                                self.fleet.items() if now < exp},
                "keepalives": {n: d.keepalive(now)
                               for n, d in self.demand.items()},
            }
