"""Concurrent serving data plane: per-function queues + a worker pool.

The paper's scalability experiment (Fig. 9) drives many *concurrent*
cold-starts; this router is the data plane that makes such load runnable
in-process.  Architecture:

  * **Per-function FIFO queues** — invocations of one function are ordered;
    functions are dispatched round-robin for fairness.
  * **Worker pool** — ``max_concurrency`` threads execute invocations
    against the orchestrator.  Page-fault and WS-read I/O release the GIL,
    so cold-start I/O genuinely overlaps across workers.
  * **Admission control** — the AWS-Lambda one-invocation-per-instance
    model (orchestrator.py): a function with fewer than
    ``max_instances_per_function`` in-flight invocations may *spawn* (or
    reuse) an instance; beyond that, arrivals *queue*.  A queue longer than
    ``queue_depth`` rejects the submit (the 429/throttle analogue).

Every accepted invocation resolves to an :class:`Invocation` future whose
report carries the queueing delay (``report.queue_s``) as a first-class
timing segment next to the paper's load/connect/prefetch/processing split.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any

from ..core.reap import ColdStartReport
from ..telemetry import TELEMETRY
from .orchestrator import Orchestrator


class AdmissionError(RuntimeError):
    """Submit rejected: the per-function queue is at ``queue_depth``."""


class RouterClosedError(RuntimeError):
    """The router was closed while this invocation was still queued."""


@dataclasses.dataclass
class RouterConfig:
    max_concurrency: int = 8            # worker-pool size (global)
    max_instances_per_function: int = 8  # queue-or-spawn threshold
    queue_depth: int = 1024             # per-function backlog bound
    # Group-restore ceiling: a worker dispatching a cold invocation counts
    # the same-function waiters still queued behind it and the orchestrator
    # restores the whole group as ONE batch (one WS fetch, one fused
    # install pass — core/restore.py).  1 disables batching (every cold
    # start runs its own pipeline, pre-PR-5 behaviour).
    batch_restore_limit: int = 8


class Invocation:
    """Future for one accepted invocation."""

    def __init__(self, name: str, batch: dict, force_cold: bool,
                 *, clock=time.perf_counter):
        self.name = name
        self.batch = batch
        self.force_cold = force_cold
        self.t_submit = clock()
        self.queue_s = 0.0
        self.group_hint = 1              # set at dispatch: cold-group size
        self._done = threading.Event()
        self._output: Any = None
        self._report: ColdStartReport | None = None
        self._error: BaseException | None = None

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: float | None = None) -> tuple[Any, ColdStartReport]:
        if not self._done.wait(timeout):
            raise TimeoutError(f"invocation of {self.name!r} still pending")
        if self._error is not None:
            raise self._error
        return self._output, self._report

    @property
    def report(self) -> ColdStartReport:
        return self.result()[1]

    def _resolve(self, output: Any, report: ColdStartReport) -> None:
        self._output, self._report = output, report
        self._done.set()

    def _fail(self, err: BaseException) -> None:
        self._error = err
        self._done.set()


class Router:
    """Dispatches queued invocations onto a bounded worker pool.

    ``start=False`` builds the router paused (submits enqueue only) — used
    by tests and by the load generator to stage a burst, then ``start()``.
    """

    def __init__(self, orch: Orchestrator, cfg: RouterConfig | None = None,
                 *, start: bool = True, clock=time.perf_counter,
                 arrival_clock=time.monotonic, registry=None):
        self.orch = orch
        self.cfg = cfg or RouterConfig()
        self.registry = TELEMETRY if registry is None else registry
        # queue/drain deltas use ``clock``; arrival taps use
        # ``arrival_clock`` because the policy/demand consumers compare
        # those stamps against their own monotonic clocks
        self.clock = clock
        self.arrival_clock = arrival_clock
        self._cv = threading.Condition()
        self._queues: dict[str, deque[Invocation]] = {}
        self._rr: deque[str] = deque()     # round-robin function order
        self._inflight: dict[str, int] = {}
        # per-function arrival timestamps (time.monotonic) fanned out to
        # one deque per *tap*: the default tap feeds the node's prewarming
        # policy loop; the cluster demand plane opens its own tap so both
        # consumers see every arrival (a single queue would let whichever
        # drains first starve the other).  Bounded so an idle consumer
        # can't leak memory.
        self._taps: dict[str, dict[str, deque[float]]] = {
            self.DEFAULT_TAP: {}}
        self.max_arrival_history = 4096
        self._closed = False
        self._started = False
        self._workers: list[threading.Thread] = []
        self.completed = 0
        self.rejected = 0
        if start:
            self.start()

    # -- client API ----------------------------------------------------

    def submit(self, name: str, batch: dict, *,
               force_cold: bool = False) -> Invocation:
        """Enqueue one invocation; returns its future.

        Raises :class:`AdmissionError` when the function's backlog is full.
        """
        inv = Invocation(name, batch, force_cold, clock=self.clock)
        with self._cv:
            if self._closed:
                raise RouterClosedError("router is closed")
            q = self._queues.get(name)
            if q is None:
                q = self._queues[name] = deque()
                self._rr.append(name)
                self._inflight.setdefault(name, 0)
            # demand signal for the policy loop(s): every arrival counts,
            # including ones the admission controller is about to throttle
            t_arr = self.arrival_clock()
            for tap in self._taps.values():
                arr = tap.get(name)
                if arr is None:
                    arr = tap[name] = deque(
                        maxlen=self.max_arrival_history)
                arr.append(t_arr)
            if len(q) >= self.cfg.queue_depth:
                self.rejected += 1
                self.registry.inc("router.rejected")
                raise AdmissionError(
                    f"{name}: queue depth {self.cfg.queue_depth} exceeded")
            q.append(inv)
            self._cv.notify()
        self.registry.inc("router.submitted")
        return inv

    def invoke(self, name: str, batch: dict, *, force_cold: bool = False,
               timeout: float | None = None) -> tuple[Any, ColdStartReport]:
        """Blocking convenience wrapper around :meth:`submit`."""
        return self.submit(name, batch, force_cold=force_cold).result(timeout)

    def map(self, items: list[tuple[str, dict]],
            *, force_cold: bool = False) -> list[tuple[Any, ColdStartReport]]:
        """Submit a batch of (function, request) pairs; wait for all."""
        invs = [self.submit(n, b, force_cold=force_cold) for n, b in items]
        return [inv.result() for inv in invs]

    def start(self) -> None:
        with self._cv:
            if self._started or self._closed:
                return
            self._started = True
            for i in range(self.cfg.max_concurrency):
                t = threading.Thread(target=self._worker_loop,
                                     name=f"router-worker-{i}", daemon=True)
                self._workers.append(t)
                t.start()

    def drain(self, timeout: float | None = None) -> None:
        """Block until every accepted invocation has resolved."""
        deadline = None if timeout is None else self.clock() + timeout
        with self._cv:
            while (any(self._queues.values())
                   or any(self._inflight.values())):
                left = None if deadline is None else deadline - self.clock()
                if left is not None and left <= 0:
                    raise TimeoutError("router drain timed out")
                self._cv.wait(timeout=left)

    def close(self, *, drain: bool = True) -> None:
        """Shut the router down.

        ``drain=True`` waits for every accepted invocation first.  With
        ``drain=False`` (or on a never-started router) still-queued
        invocations are failed with :class:`RouterClosedError` — a waiter
        blocked in ``result()`` must never hang forever on a closed router.
        """
        if drain and self._started:
            self.drain()
        with self._cv:
            self._closed = True
            abandoned = [inv for q in self._queues.values() for inv in q]
            for q in self._queues.values():
                q.clear()
            self._cv.notify_all()
        for inv in abandoned:
            inv._fail(RouterClosedError(
                f"router closed with {inv.name!r} still queued"))
        for t in self._workers:
            t.join(timeout=5.0)

    DEFAULT_TAP = "policy"

    def open_tap(self, tap: str) -> str:
        """Create an independent arrival stream named ``tap`` (idempotent).
        Every subsequent submit is recorded into it; drain it with
        ``drain_arrivals(tap=...)``."""
        with self._cv:
            self._taps.setdefault(tap, {})
        return tap

    def drain_arrivals(self, tap: str = DEFAULT_TAP) -> dict[str, list[float]]:
        """Pop and return per-function arrival timestamps accumulated in
        ``tap`` since its previous drain (``time.monotonic`` values, submit
        order).  Draining one tap never disturbs another's backlog."""
        with self._cv:
            arrivals = self._taps.get(tap, {})
            out = {n: list(d) for n, d in arrivals.items() if d}
            for d in arrivals.values():
                d.clear()
        return out

    def stats(self) -> dict:
        with self._cv:
            return {
                "queued": {n: len(q) for n, q in self._queues.items() if q},
                "inflight": {n: c for n, c in self._inflight.items() if c},
                "completed": self.completed,
                "rejected": self.rejected,
            }

    def __enter__(self) -> "Router":
        return self

    def __exit__(self, *exc) -> None:
        self.close(drain=exc == (None, None, None))

    # -- worker pool ---------------------------------------------------

    def _next_locked(self) -> Invocation | None:
        """Pick the next dispatchable invocation (round-robin across
        functions); called with ``_cv`` held."""
        for _ in range(len(self._rr)):
            name = self._rr[0]
            self._rr.rotate(-1)
            q = self._queues[name]
            if q and self._inflight[name] < self.cfg.max_instances_per_function:
                self._inflight[name] += 1
                inv = q.popleft()
                # group-restore hint: same-function waiters still queued
                # behind this invocation that the instance budget will let
                # dispatch concurrently — if this dispatch goes cold, the
                # orchestrator restores the whole group as one batch
                budget = (self.cfg.max_instances_per_function
                          - self._inflight[name])
                inv.group_hint = 1 + min(
                    len(q), budget, max(self.cfg.batch_restore_limit - 1, 0))
                return inv
        return None

    def _worker_loop(self) -> None:
        while True:
            with self._cv:
                inv = self._next_locked()
                while inv is None and not self._closed:
                    self._cv.wait()
                    inv = self._next_locked()
                if inv is None:      # closed and nothing dispatchable
                    return
            inv.queue_s = self.clock() - inv.t_submit
            self.registry.observe("router.queue_s", inv.queue_s)
            try:
                out, rep = self.orch.invoke(inv.name, inv.batch,
                                            force_cold=inv.force_cold,
                                            group_hint=inv.group_hint)
                rep = dataclasses.replace(rep, queue_s=inv.queue_s)
                inv._resolve(out, rep)
            except BaseException as e:  # propagate to the waiter, keep serving
                inv._fail(e)
            finally:
                with self._cv:
                    self._inflight[inv.name] -= 1
                    self.completed += 1
                    self._cv.notify_all()
                self.registry.inc("router.completed")


def percentile(xs: list[float], q: float) -> float:
    """Nearest-rank percentile of ``xs`` (q in [0, 100])."""
    if not xs:
        return 0.0
    s = sorted(xs)
    k = min(len(s) - 1, max(0, int(round(q / 100.0 * (len(s) - 1)))))
    return s[k]


def summarize(reports: list[ColdStartReport]) -> dict:
    """Latency summary of a batch of per-invocation reports.

    ``stage_seconds`` is the canonical mean per-stage schema
    (:class:`~repro.core.reap.StageTimings` keys) — the same dict shape
    ``WorkerNode.stats`` and the benchmark artifacts emit, with the
    overlapped-restore tail-wait time attributed separately.
    """
    from ..core.reap import StageTimings
    n = max(len(reports), 1)
    e2e = [r.e2e_s for r in reports]
    # an invocation is "cold" when restore cost landed on its critical path
    cold = sum(1 for r in reports if r.load_vmm_s > 0)
    stage = {k: 0.0 for k in StageTimings().as_dict()}
    for r in reports:
        for k, v in r.stages.as_dict().items():
            stage[k] += v
    return {
        "n": len(reports),
        "queue_mean_s": sum(r.queue_s for r in reports) / n,
        "queue_p95_s": percentile([r.queue_s for r in reports], 95),
        "total_mean_s": sum(r.total_s for r in reports) / n,
        "e2e_p50_s": percentile(e2e, 50),
        "e2e_p95_s": percentile(e2e, 95),
        "ws_cache_hits": sum(1 for r in reports if r.ws_cache_hit),
        "cold": cold,
        "cold_fraction": cold / n,
        "prewarmed": sum(1 for r in reports if r.prewarmed),
        # group-restore attribution (restore.py): invocations whose cold
        # instance was restored in a batch, and the install-stage cost
        "batched": sum(1 for r in reports
                       if r.load_vmm_s > 0 and r.batch_size > 1),
        "install_mean_s": sum(r.install_s for r in reports) / n,
        "stage_seconds": {k: v / n for k, v in stage.items()},
        # overlapped restore: faults that blocked on a background tail
        "tail_waits": sum(r.tail_waits for r in reports),
        "tail_wait_mean_s": stage["tail_wait_s"] / n,
    }
