"""Function-instance lifecycle: the MicroVM analogue.

Cold-start latency is split exactly as the paper measures it (§4.2):

  * **Load VMM**            -- open the guest memory file (manifest parse),
                               map the arena, restore the executable handle
                               (jit-cache lookup = Firecracker's device state
                               restore analogue).
  * **Connection restore**  -- re-bind the instance to the orchestrator's
                               data plane over a real socketpair handshake
                               (the persistent-gRPC analogue).
  * **(REAP) prefetch**     -- single large O_DIRECT read of the WS file +
                               eager install (only in prefetch mode); split
                               into ``ws_fetch`` and ``install`` stages, the
                               install fused across a restore group.
  * **Function processing** -- actual invocation, demand-faulting any page
                               not yet resident.

The restore itself lives in :mod:`repro.core.restore`: a
:class:`FunctionInstance` is a thin shell — its constructor does **no I/O**
— that adopts the result of a :class:`~repro.core.restore.RestorePipeline`.
:func:`restore_group` restores N instances of one function as a single
staged batch (one manifest parse, one WS fetch, one fused gather pass, N
vectorized installs).
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
import threading
import time

from ..configs.base import ModelConfig
from ..core import ReapConfig, run_invocation
from ..core.reap import ColdStartReport, StageTimings
from ..core.restore import RestoreBatch, RestorePipeline
from ..models import get_family
from ..nn import spec as nnspec


class State(enum.Enum):
    LOADING = "loading"
    IDLE = "idle"
    BUSY = "busy"
    RECLAIMED = "reclaimed"


class ExecutableCache:
    """Process-wide jit executable cache (the snapshot's 'emulated devices'
    restore is a lookup here, not a recompile).  Executables are compiled at
    function *deploy* time via :func:`warm`."""

    @classmethod
    def get(cls, cfg: ModelConfig):
        from ..core.executor import _jit_forward
        import functools
        return functools.partial(_jit_forward, cfg)

    @classmethod
    def warm(cls, cfg: ModelConfig, example_batch: dict) -> None:
        from ..core.executor import warm_executables
        warm_executables(cfg, example_batch)


class FunctionInstance:
    """One sandboxed instance of a function (cfg), restored from snapshot.

    The constructor only records identity — all restore I/O (manifest,
    handshake, WS fetch, install) runs in :meth:`restore` /
    :func:`restore_group` through the staged pipeline, so instances can be
    built in bulk and restored as one batch.

    State transitions are lock-guarded so the router's worker pool, the
    keepalive reaper, and scale-to-zero can race safely: an instance is
    dispatched only via :meth:`try_acquire` (IDLE -> BUSY) and reclaimed
    only via :meth:`try_reclaim`, which refuses BUSY instances.
    """

    _ids = itertools.count()
    # class-level defaults so state-machine methods work on instances built
    # without __init__ (tests construct bare instances via __new__)
    clock = staticmethod(time.monotonic)
    perf_clock = staticmethod(time.perf_counter)

    def __init__(self, name: str, cfg: ModelConfig, base: str,
                 reap: ReapConfig, *, mode: str = "auto",
                 prewarmed: bool = False, ws_cache=None,
                 clock=time.monotonic, perf_clock=time.perf_counter):
        """``prewarmed=True`` marks an instance spawned by the control plane
        *off* the invocation path: its load/connect/prefetch costs were paid
        by a pool thread, so no invocation report ever charges them.
        ``ws_cache`` selects the WS page cache for the REAP prefetch (None
        => the process-wide default; cluster nodes pass their own).
        ``clock`` stamps ``last_used`` (compared against the reaper's
        monotonic clock); ``perf_clock`` times invocation processing."""
        self.name = name
        self.cfg = cfg
        self.base = base
        self.reap = reap
        self.mode = mode
        self.prewarmed = prewarmed
        self.ws_cache = ws_cache
        self.clock = clock
        self.perf_clock = perf_clock
        self.instance_id = next(FunctionInstance._ids)
        self._state_lock = threading.Lock()
        self.state = State.LOADING
        self.report = ColdStartReport()
        self.last_used = clock()
        self.gm = None
        self.monitor = None
        self._warm_params = None
        self._n_invocations = 0
        #: live background tail install (overlapped restore), else None —
        #: a MATERIALIZED instance with a live tail is NOT fully resident;
        #: faults on tail pages wait on the install (arena.py)
        self._tail = None

    # -- restore (thin shell over core/restore.py) ---------------------

    def _pipeline(self) -> RestorePipeline:
        mode = "vanilla" if self.mode == "vanilla" else None
        return RestorePipeline(
            self.base, self.reap, mode=mode, cache=self.ws_cache,
            exec_restore=lambda: ExecutableCache.get(self.cfg))

    def _adopt(self, pipe: RestorePipeline, batch_size: int = 1) -> None:
        """Take ownership of a completed pipeline's state and map its stage
        timings onto the §4.2 report split."""
        self.gm = pipe.gm
        self.monitor = pipe.monitor
        self._tail = pipe.tail
        self.report = dataclasses.replace(
            self.report,
            stages=dataclasses.replace(pipe.timings),
            n_prefetched_pages=pipe.monitor.prefetched,
            ws_cache_hit=pipe.monitor.ws_cache_hit,
            prewarmed=self.prewarmed,
            batch_size=batch_size)
        self.last_used = self.clock()
        self.state = State.IDLE

    def restore(self) -> "FunctionInstance":
        """Run the full staged restore for this instance alone."""
        restore_group([self])
        return self

    # -- state machine -------------------------------------------------

    def try_acquire(self) -> bool:
        """IDLE -> BUSY; False if the instance is not dispatchable."""
        with self._state_lock:
            if self.state is not State.IDLE:
                return False
            self.state = State.BUSY
            return True

    def release(self) -> None:
        """BUSY -> IDLE (after an invocation completes)."""
        with self._state_lock:
            if self.state is State.BUSY:
                self.state = State.IDLE
            self.last_used = self.clock()

    def try_reclaim(self) -> bool:
        """IDLE -> RECLAIMED; never tears down a BUSY instance, and never
        one whose background tail is still installing (the tail worker
        writes into the arena mmap — a keepalive sweep must not close it
        under the worker; forced paths use :meth:`cancel_tail` first)."""
        with self._state_lock:
            if self.state is not State.IDLE:
                return False
            if self._tail is not None and not self._tail.done():
                return False
            self.state = State.RECLAIMED
        self.monitor.arena.close()
        self._warm_params = None
        return True

    def cancel_tail(self, join: bool = True) -> None:
        """Stop a live background tail install (no-op without one)."""
        if self._tail is not None:
            self._tail.cancel(join=join)

    # ------------------------------------------------------------------

    def invoke(self, batch: dict, *, parallel_faults: int = 0):
        """Process one invocation; first call is cold, later calls warm."""
        stats = self.monitor.arena.stats
        f0, fs0 = stats.n_faults, stats.fault_seconds
        tw0, tws0 = stats.tail_waits, stats.tail_wait_seconds
        t0 = self.perf_clock()
        if self._warm_params is not None:
            logits = ExecutableCache.get(self.cfg)(self._warm_params, batch)
            logits.block_until_ready()
        else:
            logits, _ = run_invocation(self.cfg, self.monitor.arena, batch,
                                       parallel=parallel_faults)
            logits.block_until_ready()
        dt = self.perf_clock() - t0
        first = self._n_invocations == 0
        self._n_invocations += 1
        # fresh per-invocation report; load/connect/prefetch costs belong to
        # the first (cold) invocation only — and never to an invocation on a
        # prewarmed instance, whose restore ran off the critical path
        on_path = first and not self.prewarmed
        prev = self.report.stages
        tail = self._tail
        stages = StageTimings(
            load_vmm_s=prev.load_vmm_s if on_path else 0.0,
            connection_s=prev.connection_s if on_path else 0.0,
            ws_fetch_s=prev.ws_fetch_s if on_path else 0.0,
            install_s=prev.install_s if on_path else 0.0,
            materialize_s=prev.materialize_s if on_path else 0.0,
            # overlap window: restore-return → fully resident (known only
            # once the background tail finished; 0.0 while still live)
            materialize_to_resident_s=(
                tail.done_at - tail.t0
                if on_path and tail is not None and tail.done_at is not None
                else 0.0),
            # tail-wait time is attributed to whichever invocation's faults
            # actually blocked on the pending install — including warm
            # invocations racing a still-live tail
            tail_wait_s=stats.tail_wait_seconds - tws0,
        )
        self.report = dataclasses.replace(
            self.report,
            stages=stages,
            n_prefetched_pages=self.report.n_prefetched_pages if on_path else 0,
            ws_cache_hit=self.report.ws_cache_hit if on_path else False,
            prewarmed=self.prewarmed,
            processing_s=dt,
            fault_s=stats.fault_seconds - fs0,
            n_faults=stats.n_faults - f0,
            tail_waits=stats.tail_waits - tw0,
        )
        self.last_used = self.clock()
        return logits, dt

    def make_warm(self):
        """Promote to a memory-resident (warm) instance: materialize params
        as device arrays so later invocations skip the arena entirely."""
        import jax.numpy as jnp
        fam = get_family(self.cfg)
        specs = fam.param_specs(self.cfg)
        self.monitor.arena.touch_pages(
            sorted(set().union(*[set(self.monitor.arena.layout.pages_of(f"params/{p}"))
                                 for p, _ in nnspec.tree_paths(specs)])))
        self._warm_params = nnspec.map_leaves(
            lambda p, s: jnp.asarray(
                self.monitor.arena.tensor(f"params/{p}", fault=False)), specs)

    def finish_cold(self) -> dict:
        if self.monitor.mode == "vanilla":
            stats = self.monitor.arena.stats
            return {"mode": "vanilla", "n_faults": stats.n_faults,
                    "fault_s": stats.fault_seconds,
                    "resident_bytes": self.monitor.arena.resident_bytes}
        return self.monitor.finish()

    def reclaim(self):
        """Unconditional teardown (caller must know the instance is not
        mid-invocation); prefer :meth:`try_reclaim` on shared paths.  A
        live background tail is cancelled and joined first so the arena
        never closes under the tail worker's writes."""
        with self._state_lock:
            self.state = State.RECLAIMED
        self.cancel_tail(join=True)
        if self.monitor is not None:
            self.monitor.arena.close()
        self._warm_params = None


def restore_group(instances: list[FunctionInstance], *,
                  materialize: bool = False) -> list[FunctionInstance]:
    """Restore N instances of ONE function as a single staged batch.

    The batch performs one manifest parse, one WS fetch and one fused
    page-gather pass for the whole group, then one vectorized install per
    arena — instead of N full pipelines with N single-flight cache waits
    and N per-page install loops.  ``materialize=True`` additionally makes
    every instance warm (param residency) inside the timed ``materialize``
    stage (the prewarm path).
    """
    pipes = [inst._pipeline() for inst in instances]
    RestoreBatch(pipes).run()
    k = len(instances)
    for inst, pipe in zip(instances, pipes):
        inst._adopt(pipe, batch_size=k)
    if materialize:
        try:
            for inst, pipe in zip(instances, pipes):
                pipe.materialize(inst.make_warm)
        except BaseException:
            # a failed materialization (e.g. records dropped mid-spawn)
            # must not leak the group's already-adopted arenas
            for inst in instances:
                inst.reclaim()
            raise
    return instances
