"""Periodicity-aware demand forecasting (the anticipatory half of SPES).

policy.py's :class:`FunctionDemand` is reactive-statistical: an EWMA and a
sliding window both *trail* the arrival process, so a diurnal ramp is only
provisioned for after its first arrivals land cold.  SPES (Lee et al.) and
"How Low Can You Go?" (Tan et al.) both observe that production serverless
traffic is strongly periodic per function — the remaining cold-start floor
is exactly this anticipation gap.  This module closes it:

  * :class:`PeriodicityDetector` — keeps a bounded per-function arrival
    history, bins it at ``bin_s`` resolution over the ``history_s`` window,
    and scans normalized autocorrelation over candidate lags (the diurnal
    window ``[min_period_s, max_period_s]``).  A confident peak becomes the
    function's period; the history is then *folded* modulo the period into
    a phase-binned rate profile (arrivals/s per phase bin, averaged over
    the cycles each phase bin was observed).  A ``period_hint_s`` (e.g.
    from the trace generator, or an operator who knows traffic is daily)
    skips the search: the profile is trusted as soon as one full cycle of
    history exists, instead of the >= ``min_cycles`` the blind search needs.
  * :class:`ForecastDemand` — drop-in :class:`FunctionDemand` subclass that
    blends the profile with the reactive model:
    ``rate(now) = max(reactive, confidence * profile peak over
    [now, now + lookahead_s])`` — so the warm target rises *before* the
    ramp's arrivals do, and never falls below what the reactive model would
    have provisioned (the forecast can only add instances, not starve).
    During a trough the profile goes to ~0 and the function scales down as
    usual, but the demand entry is *not* forgotten (``forgettable``) while
    history remains — forgetting it would discard the learned period right
    before the next ramp needs it.

Everything is a pure function of ingested timestamps; ``clock=`` injects a
fake clock (tests/fakeclock.py) so tests run in milliseconds.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import numpy as np

from .policy import FunctionDemand, PolicyConfig


@dataclasses.dataclass
class ForecastConfig:
    bin_s: float = 0.25            # arrival-count bin width
    history_s: float = 120.0       # how much history the detector folds
    max_arrivals: int = 16384      # bound on stored timestamps
    min_period_s: float = 1.0      # candidate-period search window
    max_period_s: float = 60.0
    min_cycles: float = 2.0        # blind search needs >= this many folds
    min_confidence: float = 0.35   # autocorrelation acceptance threshold
    lookahead_s: float = 0.5       # provision for the profile this far ahead
    period_hint_s: float | None = None  # known period (trace metadata)


class PeriodicityDetector:
    """Detects a per-function arrival period and folds history into a
    phase-binned rate profile.

    ``detect`` returns ``(period_s, confidence)`` or ``None``;
    ``forecast_rate(now, window_s)`` returns the profile's peak rate over
    ``[now, now + window_s)`` (None when no confident period exists) —
    peak, not mean, because provisioning must cover the ramp's front edge.
    """

    def __init__(self, cfg: ForecastConfig | None = None, *,
                 clock=time.monotonic):
        self.cfg = cfg or ForecastConfig()
        self.clock = clock
        self.arrivals: deque[float] = deque(maxlen=self.cfg.max_arrivals)
        self._cache_key: tuple | None = None
        self._cache: tuple[float, float] | None = None
        # persisted prior (seed): a profile exported by a previous run.
        # It answers detect()/profile() until fresh history can — a
        # restarted fleet prewarms day-one ramps instead of re-learning.
        self._seed: dict | None = None

    def observe(self, timestamps: list[float]) -> None:
        self.arrivals.extend(timestamps)

    # -- persistence (ROADMAP: cross-restart forecast profiles) ---------

    def to_state(self, now: float | None = None) -> dict | None:
        """Serializable profile state, or None when nothing confident is
        known.  Phase is anchored at t=0 of the detector's clock domain
        (exactly the convention ``profile``/``forecast_rate`` fold with),
        so reloading is valid whenever the restarted process shares the
        clock epoch — ``time.monotonic`` on the same boot, or a fake clock
        continuing the same timeline in tests.  Falls back to carrying an
        unreplaced seed forward, so back-to-back restarts don't lose it."""
        now = self.clock() if now is None else now
        det = self.detect(now)
        if det is not None:
            period_s, conf = det
            prof = self.profile(now, period_s)
            if prof is not None and len(prof) and float(prof.max()) > 0:
                return {"period_s": float(period_s),
                        "confidence": float(conf),
                        "bin_s": float(self.cfg.bin_s),
                        "rates": [float(r) for r in prof]}
        return dict(self._seed) if self._seed is not None else None

    def seed(self, state: dict | None) -> bool:
        """Install a persisted profile as the prior; returns False (and
        ignores it) when the state is empty or was folded at a different
        bin width than this detector's (phase indices wouldn't line up)."""
        if (not state or not state.get("rates")
                or "period_s" not in state
                or abs(float(state.get("bin_s", self.cfg.bin_s))
                       - self.cfg.bin_s) > 1e-9):
            return False
        self._seed = dict(state)
        return True

    @property
    def seeded(self) -> bool:
        return self._seed is not None

    def _seed_detect(self) -> tuple[float, float] | None:
        s = self._seed
        if s is None:
            return None
        return float(s["period_s"]), float(s["confidence"])

    def span(self) -> float:
        """Seconds of history currently held."""
        if len(self.arrivals) < 2:
            return 0.0
        return max(self.arrivals) - min(self.arrivals)

    # -- period detection ----------------------------------------------

    def _counts(self, now: float) -> tuple[np.ndarray, float]:
        """Arrival counts binned at ``bin_s`` over the history window;
        returns (counts, t0) with ``t0`` the absolute time of bin 0."""
        c = self.cfg
        t0 = now - c.history_s
        ts = np.asarray([t for t in self.arrivals if t0 <= t <= now])
        n_bins = max(int(np.ceil(c.history_s / c.bin_s)), 1)
        counts = np.zeros(n_bins)
        if ts.size:
            idx = np.clip(((ts - t0) / c.bin_s).astype(int), 0, n_bins - 1)
            np.add.at(counts, idx, 1.0)
        return counts, t0

    def _autocorr(self, x: np.ndarray, lag: int) -> float:
        """Normalized autocorrelation of ``x`` at ``lag`` (mean-removed)."""
        if lag <= 0 or lag >= len(x):
            return 0.0
        d = x - x.mean()
        var = float(np.dot(d, d))
        if var <= 0:
            return 0.0
        return float(np.dot(d[:-lag], d[lag:])) / var

    def detect(self, now: float | None = None) -> tuple[float, float] | None:
        """(period_s, confidence in [0, 1]) or None.

        With a ``period_hint_s`` the hint is trusted (confidence 1.0) once
        one full cycle of history exists — the search and its >=
        ``min_cycles`` requirement are skipped.  Without a hint, candidate
        lags are scanned and the *smallest* lag within 10% of the best
        correlation wins (a signal with period P also correlates at 2P;
        preferring the fundamental keeps the fold dense).
        """
        now = self.clock() if now is None else now
        c = self.cfg
        if c.period_hint_s is not None:
            if (len(self.arrivals) >= 4
                    and self.span() >= c.period_hint_s):
                return c.period_hint_s, 1.0
            return self._seed_detect()
        key = (len(self.arrivals), int(now / c.bin_s))
        if key != self._cache_key:
            self._cache_key = key
            self._cache = self._detect(now)
        if self._cache is not None:
            return self._cache
        # fresh history can't answer yet: fall back to the persisted prior
        return self._seed_detect()

    def _detect(self, now: float) -> tuple[float, float] | None:
        c = self.cfg
        if len(self.arrivals) < 8:
            return None
        counts, _ = self._counts(now)
        # only bins the history actually covers participate
        covered = min(int(np.ceil(self.span() / c.bin_s)) + 1, len(counts))
        x = counts[-covered:]
        lo = max(int(round(c.min_period_s / c.bin_s)), 1)
        hi = min(int(round(c.max_period_s / c.bin_s)),
                 int(len(x) / c.min_cycles))
        if hi < lo:
            return None
        corr = np.asarray([self._autocorr(x, lag)
                           for lag in range(lo, hi + 1)])
        best = float(corr.max(initial=0.0))
        if best < c.min_confidence:
            return None
        # smallest lag within 10% of the best: prefer the fundamental
        for i, r in enumerate(corr):
            if r >= 0.9 * best:
                return (lo + i) * c.bin_s, float(r)
        return None                  # unreachable; keeps type-checkers calm

    # -- phase-binned rate profile -------------------------------------

    def profile(self, now: float | None = None,
                period_s: float | None = None) -> np.ndarray | None:
        """Arrivals/s per phase bin, folded modulo the period.

        Each phase bin's count is divided by the number of times that
        phase was actually observed in the history window, so a partially
        covered final cycle does not dilute the profile.
        """
        now = self.clock() if now is None else now
        if period_s is None:
            det = self.detect(now)
            if det is None:
                return None
            period_s, _ = det
        c = self.cfg
        s = self._seed
        if (s is not None and self.span() < period_s
                and abs(float(s["period_s"]) - period_s) <= c.bin_s):
            # under one full cycle of fresh history: the persisted fold is
            # still the better estimate of the phase profile
            return np.asarray(s["rates"], dtype=float)
        n_phase = max(int(round(period_s / c.bin_s)), 1)
        counts, t0 = self._counts(now)
        n_bins = len(counts)
        phases = (np.arange(n_bins) + int(round(t0 / c.bin_s))) % n_phase
        folded = np.zeros(n_phase)
        occurrences = np.zeros(n_phase)
        # restrict the fold to covered history so empty pre-history bins
        # don't register as observed-zero phases
        covered = min(int(np.ceil(self.span() / c.bin_s)) + 1, n_bins)
        np.add.at(folded, phases[-covered:], counts[-covered:])
        np.add.at(occurrences, phases[-covered:], 1.0)
        with np.errstate(invalid="ignore"):
            rates = np.where(occurrences > 0,
                             folded / np.maximum(occurrences, 1) / c.bin_s,
                             0.0)
        return rates

    def forecast_rate(self, at: float, window_s: float = 0.0, *,
                      now: float | None = None) -> float | None:
        """Profile's peak rate over ``[at, at + window_s)``; None when no
        confident period exists."""
        now = self.clock() if now is None else now
        det = self.detect(now)
        if det is None:
            return None
        period_s, conf = det
        prof = self.profile(now, period_s)
        if prof is None or not len(prof):
            return None
        c = self.cfg
        first = int((at % period_s) / c.bin_s)
        n = max(int(np.ceil(window_s / c.bin_s)), 1)
        idx = (first + np.arange(n)) % len(prof)
        return float(prof[idx].max()) * conf


class ForecastDemand(FunctionDemand):
    """FunctionDemand + a periodicity forecast: provisions for the profile
    ``lookahead_s`` ahead, never below what the reactive model asks for."""

    def __init__(self, cfg: PolicyConfig, fcfg: ForecastConfig | None = None,
                 *, clock=time.monotonic):
        super().__init__(cfg, clock=clock)
        self.fcfg = fcfg or ForecastConfig()
        self.detector = PeriodicityDetector(self.fcfg, clock=clock)

    def observe(self, timestamps: list[float]) -> None:
        super().observe(timestamps)
        self.detector.observe(timestamps)

    def _upcoming(self, now: float) -> float | None:
        """Forecast peak rate over the lookahead horizon (None: no period)."""
        return self.detector.forecast_rate(
            now, self.fcfg.lookahead_s + self.fcfg.bin_s, now=now)

    def rate(self, now: float | None = None) -> float:
        now = self.clock() if now is None else now
        reactive = super().rate(now)
        f = self._upcoming(now)
        return reactive if f is None else max(reactive, f)

    def active(self, now: float | None = None) -> bool:
        """Live while the reactive model says so, *or* while the profile
        predicts arrivals inside the lookahead — the prewarm-ahead path."""
        now = self.clock() if now is None else now
        if super().active(now):
            return True
        f = self._upcoming(now)
        # "predicts arrivals": at least ~one arrival expected in the horizon
        horizon = self.fcfg.lookahead_s + self.fcfg.bin_s
        return f is not None and f * horizon >= 0.5

    # -- persistence ----------------------------------------------------

    def export_state(self, now: float | None = None) -> dict | None:
        """Serializable periodicity profile (detector state), or None."""
        return self.detector.to_state(now)

    def seed_state(self, state: dict | None) -> bool:
        """Install a persisted profile as this demand's prior."""
        return self.detector.seed(state)

    def forgettable(self, now: float | None = None) -> bool:
        """Keep the learned period through troughs: only forget once the
        entire history window has gone quiet.  A seeded entry that has not
        yet seen traffic is kept — forgetting it would discard the
        persisted profile before the ramp it predicts arrives."""
        now = self.clock() if now is None else now
        if self.last_arrival is None:
            return not self.detector.seeded
        return now - self.last_arrival > self.fcfg.history_s
