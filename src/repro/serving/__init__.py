from .instance import ExecutableCache, FunctionInstance, State
from .orchestrator import Orchestrator
