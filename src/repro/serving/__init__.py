from .config import ServeConfig
from .forecast import ForecastConfig, ForecastDemand, PeriodicityDetector
from .instance import (ExecutableCache, FunctionInstance, State,
                       restore_group)
from .loadgen import (ClosedLoopGenerator, OpenLoopGenerator, Trace,
                      TraceEvent, azure_trace, diurnal_trace, poisson_trace,
                      uniform_trace)
from .orchestrator import FunctionRecord, Orchestrator
from .policy import FunctionDemand, PolicyConfig, PrewarmPolicy
from .router import (AdmissionError, Invocation, Router, RouterClosedError,
                     RouterConfig, percentile, summarize)
