from .instance import ExecutableCache, FunctionInstance, State
from .loadgen import (ClosedLoopGenerator, OpenLoopGenerator, Trace,
                      TraceEvent, poisson_trace, uniform_trace)
from .orchestrator import FunctionRecord, Orchestrator
from .router import (AdmissionError, Invocation, Router, RouterConfig,
                     percentile, summarize)
