"""Trace-driven load generation for the concurrent data plane.

Workload model (SPES-style: provisioning policy must react to arrival
patterns, so arrivals must be *replayable*):

  * A :class:`Trace` is an ordered list of :class:`TraceEvent`s — arrival
    offset, function name, modality, per-event seed.  Traces serialize to
    JSON so a workload can be saved, diffed, and replayed bit-identically.
  * :func:`poisson_trace` synthesizes an **open-loop** arrival process
    (exponential inter-arrivals at ``rate_rps``) over a weighted function
    mix and modality mix, from a seed.
  * :func:`uniform_trace` synthesizes a deterministic fixed-interval trace
    (``interval_s=0`` => an N-wide concurrent burst, the Fig. 9 shape).
  * :class:`OpenLoopGenerator` replays a trace against a router at wall
    pace: submits happen at each event's offset whether or not earlier
    invocations finished (queueing delay is *measured*, not avoided).
  * :class:`ClosedLoopGenerator` runs N client loops (submit, wait, think)
    — the throughput-oriented counterpart.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
from typing import Callable

import numpy as np

from ..core.reap import ColdStartReport
from .router import AdmissionError, Router

MODALITIES = ("text", "vision", "audio")


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    t: float                 # arrival offset from trace start, seconds
    function: str
    modality: str = "text"
    seed: int = 0


@dataclasses.dataclass
class Trace:
    events: list[TraceEvent]

    @property
    def duration_s(self) -> float:
        return self.events[-1].t if self.events else 0.0

    @property
    def functions(self) -> list[str]:
        return sorted({e.function for e in self.events})

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"version": 1,
                       "events": [dataclasses.asdict(e) for e in self.events]},
                      f, indent=None)

    @classmethod
    def load(cls, path: str) -> "Trace":
        with open(path) as f:
            d = json.load(f)
        return cls([TraceEvent(**e) for e in d["events"]])


def _normalize_mix(names: list[str], mix: dict[str, float] | None) -> np.ndarray:
    w = np.asarray([1.0 if mix is None else float(mix.get(n, 0.0))
                    for n in names])
    if w.sum() <= 0:
        raise ValueError("function mix has no mass")
    return w / w.sum()


def poisson_trace(rate_rps: float, duration_s: float, functions: list[str], *,
                  mix: dict[str, float] | None = None,
                  modality_mix: dict[str, float] | None = None,
                  seed: int = 0) -> Trace:
    """Open-loop Poisson arrivals over a weighted multi-function mix."""
    rng = np.random.default_rng(seed)
    probs = _normalize_mix(functions, mix)
    mod_names = list(MODALITIES)
    mod_probs = _normalize_mix(mod_names, modality_mix or {"text": 1.0})
    events: list[TraceEvent] = []
    t = 0.0
    while True:
        t += float(rng.exponential(1.0 / rate_rps))
        if t > duration_s:
            break
        events.append(TraceEvent(
            t=t,
            function=functions[int(rng.choice(len(functions), p=probs))],
            modality=mod_names[int(rng.choice(len(mod_names), p=mod_probs))],
            seed=int(rng.integers(0, 2**31)),
        ))
    return Trace(events)


def uniform_trace(n: int, interval_s: float, functions: list[str], *,
                  seed: int = 0) -> Trace:
    """Deterministic arrivals every ``interval_s``; ``interval_s=0`` is an
    N-wide concurrent burst round-robined over ``functions``."""
    return Trace([TraceEvent(t=i * interval_s,
                             function=functions[i % len(functions)],
                             seed=seed + i)
                  for i in range(n)])


def diurnal_trace(base_rps: float, peak_rps: float, period_s: float,
                  duration_s: float, functions: list[str], *,
                  mix: dict[str, float] | None = None,
                  burst_rps: float = 0.0, burst_every_s: float = 0.0,
                  burst_len_s: float = 0.1, seed: int = 0) -> Trace:
    """Non-homogeneous Poisson arrivals with a diurnal (sinusoidal) rate,
    optionally overlaid with periodic bursts — the Azure-Functions-style
    shape an adaptive prewarming policy must track (troughs scale to zero,
    ramps are predicted, bursts stress the warm-pool target).

    Rate profile (requests/s at offset ``t``)::

        rate(t) = base + (peak - base) * (1 - cos(2*pi*t/period)) / 2
                  [+ burst_rps while t mod burst_every_s < burst_len_s]

    Synthesized by Lewis-Shedler thinning of a homogeneous process at the
    peak rate, so the trace is exact and replayable from ``seed``.
    """
    if peak_rps < base_rps:
        raise ValueError("peak_rps must be >= base_rps")
    rng = np.random.default_rng(seed)
    probs = _normalize_mix(functions, mix)

    def rate(t: float) -> float:
        r = base_rps + (peak_rps - base_rps) * (
            1.0 - np.cos(2.0 * np.pi * t / period_s)) / 2.0
        if burst_rps > 0 and burst_every_s > 0 \
                and (t % burst_every_s) < burst_len_s:
            r += burst_rps
        return r

    rate_max = peak_rps + (burst_rps if burst_every_s > 0 else 0.0)
    if rate_max <= 0:
        return Trace([])
    events: list[TraceEvent] = []
    t = 0.0
    while True:
        t += float(rng.exponential(1.0 / rate_max))
        if t > duration_s:
            break
        if rng.uniform() * rate_max > rate(t):   # thinning: reject
            continue
        events.append(TraceEvent(
            t=t,
            function=functions[int(rng.choice(len(functions), p=probs))],
            seed=int(rng.integers(0, 2**31)),
        ))
    return Trace(events)


#: Maps one trace event to a request payload for its function.
BatchFactory = Callable[[TraceEvent], dict]


class OpenLoopGenerator:
    """Replay a trace against a router at wall-clock pace.

    ``speedup`` compresses the timeline (2.0 => replay twice as fast);
    submits are never delayed by completions — that is the point of
    open-loop load (queueing delay shows up in ``report.queue_s``).
    """

    def __init__(self, router: Router, trace: Trace,
                 make_batch: BatchFactory, *, speedup: float = 1.0):
        self.router = router
        self.trace = trace
        self.make_batch = make_batch
        self.speedup = speedup

    def run(self) -> list[tuple[TraceEvent, ColdStartReport | None]]:
        """Returns (event, report) per event; report None when rejected."""
        pending: list[tuple[TraceEvent, object]] = []
        rejected: list[TraceEvent] = []
        t0 = time.perf_counter()
        for ev in self.trace.events:
            target = ev.t / self.speedup
            delay = target - (time.perf_counter() - t0)
            if delay > 0:
                time.sleep(delay)
            try:
                pending.append(
                    (ev, self.router.submit(ev.function, self.make_batch(ev))))
            except AdmissionError:
                rejected.append(ev)
        out: list[tuple[TraceEvent, ColdStartReport | None]] = []
        for ev, inv in pending:
            out.append((ev, inv.result()[1]))
        out.extend((ev, None) for ev in rejected)
        return out


class ClosedLoopGenerator:
    """N concurrent clients, each looping submit -> wait -> think."""

    def __init__(self, router: Router, trace: Trace, make_batch: BatchFactory,
                 *, n_clients: int = 4, think_time_s: float = 0.0):
        self.router = router
        self.trace = trace
        self.make_batch = make_batch
        self.n_clients = n_clients
        self.think_time_s = think_time_s

    def run(self) -> list[tuple[TraceEvent, ColdStartReport | None]]:
        """Returns (event, report) per event; report None when the submit
        was throttled (:class:`AdmissionError`) — parity with
        :class:`OpenLoopGenerator`.  Only *real* invocation failures abort
        the run; a throttle is a measured outcome, not an error.
        """
        events = list(self.trace.events)
        out: list[tuple[TraceEvent, ColdStartReport | None]] = []
        errors: list[BaseException] = []
        out_lock = threading.Lock()
        it_lock = threading.Lock()
        idx = [0]

        def client() -> None:
            while True:
                with it_lock:
                    if idx[0] >= len(events):
                        return
                    ev = events[idx[0]]
                    idx[0] += 1
                try:
                    _, rep = self.router.invoke(ev.function,
                                                self.make_batch(ev))
                except AdmissionError:
                    with out_lock:
                        out.append((ev, None))   # throttled, not failed
                    continue
                except BaseException as e:
                    with out_lock:
                        errors.append(e)
                    continue
                with out_lock:
                    out.append((ev, rep))
                if self.think_time_s:
                    time.sleep(self.think_time_s)

        threads = [threading.Thread(target=client, name=f"client-{i}",
                                    daemon=True)
                   for i in range(self.n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise errors[0]  # partial results must not masquerade as a run
        return out
