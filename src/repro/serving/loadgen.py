"""Trace-driven load generation for the concurrent data plane.

Workload model (SPES-style: provisioning policy must react to arrival
patterns, so arrivals must be *replayable*):

  * A :class:`Trace` is an ordered list of :class:`TraceEvent`s — arrival
    offset, function name, modality, per-event seed.  Traces serialize to
    JSON so a workload can be saved, diffed, and replayed bit-identically.
  * :func:`poisson_trace` synthesizes an **open-loop** arrival process
    (exponential inter-arrivals at ``rate_rps``) over a weighted function
    mix and modality mix, from a seed.
  * :func:`uniform_trace` synthesizes a deterministic fixed-interval trace
    (``interval_s=0`` => an N-wide concurrent burst, the Fig. 9 shape).
  * :func:`azure_trace` ingests the Azure Functions 2019
    invocations-per-minute CSV, mapping the busiest production functions
    onto registered names (real arrival shapes, compressed in time).
  * :class:`OpenLoopGenerator` replays a trace against a router at wall
    pace: submits happen at each event's offset whether or not earlier
    invocations finished (queueing delay is *measured*, not avoided).
  * :class:`ClosedLoopGenerator` runs N client loops (submit, wait, think)
    — the throughput-oriented counterpart.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
from typing import Callable

import numpy as np

from ..core.reap import ColdStartReport
from .router import AdmissionError, Router

MODALITIES = ("text", "vision", "audio")


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    t: float                 # arrival offset from trace start, seconds
    function: str
    modality: str = "text"
    seed: int = 0


@dataclasses.dataclass
class Trace:
    events: list[TraceEvent]
    #: Known fundamental period of the arrival process (seconds), when the
    #: generator has one (diurnal_trace's sinusoid period; azure_trace's
    #: compressed day).  A forecasting policy may take it as a hint
    #: (ForecastConfig.period_hint_s) instead of detecting the period
    #: blind.  None: no periodicity is claimed.
    period_hint_s: float | None = None

    @property
    def duration_s(self) -> float:
        return self.events[-1].t if self.events else 0.0

    @property
    def functions(self) -> list[str]:
        return sorted({e.function for e in self.events})

    def save(self, path: str) -> None:
        doc = {"version": 1,
               "events": [dataclasses.asdict(e) for e in self.events]}
        if self.period_hint_s is not None:
            doc["period_hint_s"] = self.period_hint_s
        with open(path, "w") as f:
            json.dump(doc, f, indent=None)

    @classmethod
    def load(cls, path: str) -> "Trace":
        with open(path) as f:
            d = json.load(f)
        return cls([TraceEvent(**e) for e in d["events"]],
                   period_hint_s=d.get("period_hint_s"))


def _normalize_mix(names: list[str], mix: dict[str, float] | None) -> np.ndarray:
    w = np.asarray([1.0 if mix is None else float(mix.get(n, 0.0))
                    for n in names])
    if w.sum() <= 0:
        raise ValueError("function mix has no mass")
    return w / w.sum()


def poisson_trace(rate_rps: float, duration_s: float, functions: list[str], *,
                  mix: dict[str, float] | None = None,
                  modality_mix: dict[str, float] | None = None,
                  seed: int = 0) -> Trace:
    """Open-loop Poisson arrivals over a weighted multi-function mix."""
    rng = np.random.default_rng(seed)
    probs = _normalize_mix(functions, mix)
    mod_names = list(MODALITIES)
    mod_probs = _normalize_mix(mod_names, modality_mix or {"text": 1.0})
    events: list[TraceEvent] = []
    t = 0.0
    while True:
        t += float(rng.exponential(1.0 / rate_rps))
        if t > duration_s:
            break
        events.append(TraceEvent(
            t=t,
            function=functions[int(rng.choice(len(functions), p=probs))],
            modality=mod_names[int(rng.choice(len(mod_names), p=mod_probs))],
            seed=int(rng.integers(0, 2**31)),
        ))
    return Trace(events)


def uniform_trace(n: int, interval_s: float, functions: list[str], *,
                  seed: int = 0) -> Trace:
    """Deterministic arrivals every ``interval_s``; ``interval_s=0`` is an
    N-wide concurrent burst round-robined over ``functions``."""
    return Trace([TraceEvent(t=i * interval_s,
                             function=functions[i % len(functions)],
                             seed=seed + i)
                  for i in range(n)])


def diurnal_trace(base_rps: float, peak_rps: float, period_s: float,
                  duration_s: float, functions: list[str], *,
                  mix: dict[str, float] | None = None,
                  burst_rps: float = 0.0, burst_every_s: float = 0.0,
                  burst_len_s: float = 0.1, seed: int = 0) -> Trace:
    """Non-homogeneous Poisson arrivals with a diurnal (sinusoidal) rate,
    optionally overlaid with periodic bursts — the Azure-Functions-style
    shape an adaptive prewarming policy must track (troughs scale to zero,
    ramps are predicted, bursts stress the warm-pool target).

    Rate profile (requests/s at offset ``t``)::

        rate(t) = base + (peak - base) * (1 - cos(2*pi*t/period)) / 2
                  [+ burst_rps while t mod burst_every_s < burst_len_s]

    Synthesized by Lewis-Shedler thinning of a homogeneous process at the
    peak rate, so the trace is exact and replayable from ``seed``.
    """
    if peak_rps < base_rps:
        raise ValueError("peak_rps must be >= base_rps")
    rng = np.random.default_rng(seed)
    probs = _normalize_mix(functions, mix)

    def rate(t: float) -> float:
        r = base_rps + (peak_rps - base_rps) * (
            1.0 - np.cos(2.0 * np.pi * t / period_s)) / 2.0
        if burst_rps > 0 and burst_every_s > 0 \
                and (t % burst_every_s) < burst_len_s:
            r += burst_rps
        return r

    rate_max = peak_rps + (burst_rps if burst_every_s > 0 else 0.0)
    if rate_max <= 0:
        return Trace([], period_hint_s=period_s)
    events: list[TraceEvent] = []
    t = 0.0
    while True:
        t += float(rng.exponential(1.0 / rate_max))
        if t > duration_s:
            break
        if rng.uniform() * rate_max > rate(t):   # thinning: reject
            continue
        events.append(TraceEvent(
            t=t,
            function=functions[int(rng.choice(len(functions), p=probs))],
            seed=int(rng.integers(0, 2**31)),
        ))
    return Trace(events, period_hint_s=period_s)


def azure_trace(path: str, functions: list[str] | None = None, *,
                duration_s: float | None = None,
                max_minutes: int | None = None,
                top_k: int | None = None, seed: int = 0) -> Trace:
    """Ingest the Azure Functions 2019 invocations-per-minute CSV format.

    Each row is one function: hash-id columns (``HashOwner``, ``HashApp``,
    ``HashFunction``, ``Trigger``, ...) followed by numeric minute columns
    ``1..1440`` holding the invocation count in that minute of the day.
    Parsing is header-driven — any non-numeric leading columns are treated
    as identity, any numeric header as a minute index — so the 2021 format
    variants parse too.  Rows with garbled count cells are skipped, not
    fatal; a file yielding *no* valid rows raises ``ValueError``.

    Synthesis: rows are ranked by total invocations and the busiest
    ``top_k`` kept (default: ``len(functions)`` when a mapping is given,
    else all rows).  With ``functions`` given, rank *i* maps onto
    ``functions[i % len(functions)]`` — the production arrival *shape*
    replayed over this repo's registered function names.  A count of *c*
    in minute *m* becomes *c* arrivals uniformly placed inside
    ``[60*m, 60*(m+1))`` by a seeded RNG, so the trace is exact and
    replayable.  ``duration_s`` rescales the whole timeline (1440 minutes
    of production traffic compressed into a benchmark window);
    ``max_minutes`` truncates to the first N minute columns first.
    """
    rows: list[tuple[str, list[int]]] = []   # (function id, per-minute counts)
    with open(path) as f:
        header = f.readline().rstrip("\n").split(",")
        minute_cols = [i for i, h in enumerate(header)
                       if h.strip().lstrip("-").isdigit()]
        if not minute_cols:
            raise ValueError(f"{path}: no numeric minute columns in header")
        if max_minutes is not None:
            minute_cols = minute_cols[:max_minutes]
        # identity = every column before the first minute column (columns
        # interleaved after that point are not supported and would parse
        # as counts)
        id_cols = list(range(minute_cols[0]))
        n_skipped = 0
        for line in f:
            cells = line.rstrip("\n").split(",")
            if len(cells) <= minute_cols[0]:
                continue                     # blank/short line
            fid = "/".join(cells[i] for i in id_cols) or f"row{len(rows)}"
            try:
                counts = [int(float(cells[i])) if i < len(cells) and cells[i]
                          else 0 for i in minute_cols]
            except ValueError:
                # a garbled count cell poisons only its own row: real trace
                # dumps carry the occasional truncated/corrupt line, and
                # one of them must not abort a whole replay
                n_skipped += 1
                continue
            rows.append((fid, counts))
    if not rows:
        raise ValueError(
            f"{path}: no function rows"
            + (f" ({n_skipped} malformed rows skipped)" if n_skipped else ""))
    rows.sort(key=lambda r: (-sum(r[1]), r[0]))  # busiest first, stable
    k = top_k if top_k is not None else (len(functions) if functions
                                         else len(rows))
    rows = rows[:max(k, 1)]

    span_s = 60.0 * max(len(c) for _, c in rows)
    scale = 1.0 if duration_s is None else duration_s / span_s
    rng = np.random.default_rng(seed)
    events: list[TraceEvent] = []
    for rank, (fid, counts) in enumerate(rows):
        name = (functions[rank % len(functions)] if functions else fid)
        for m, c in enumerate(counts):
            if c <= 0:
                continue
            for t in rng.uniform(60.0 * m, 60.0 * (m + 1), size=c):
                events.append(TraceEvent(t=float(t) * scale, function=name,
                                         seed=int(rng.integers(0, 2**31))))
    events.sort(key=lambda e: e.t)
    # production traffic's fundamental period is the day; in the compressed
    # timeline that is the full span (one cycle of history per replay)
    return Trace(events, period_hint_s=span_s * scale)


#: Maps one trace event to a request payload for its function.
BatchFactory = Callable[[TraceEvent], dict]


class OpenLoopGenerator:
    """Replay a trace against a router at wall-clock pace.

    ``speedup`` compresses the timeline (2.0 => replay twice as fast);
    submits are never delayed by completions — that is the point of
    open-loop load (queueing delay shows up in ``report.queue_s``).
    """

    def __init__(self, router: Router, trace: Trace,
                 make_batch: BatchFactory, *, speedup: float = 1.0,
                 clock=time.perf_counter, sleep=time.sleep):
        self.router = router
        self.trace = trace
        self.make_batch = make_batch
        self.speedup = speedup
        self.clock = clock
        self.sleep = sleep

    def run(self) -> list[tuple[TraceEvent, ColdStartReport | None]]:
        """Returns (event, report) per event; report None when throttled.

        A throttle is a measured outcome, never an abort — whether it
        happens at submit time or later (a cluster rerouting a failed
        node's queue may find every survivor full and fail the future
        with :class:`AdmissionError` at result time).
        """
        pending: list[tuple[TraceEvent, object]] = []
        rejected: list[TraceEvent] = []
        t0 = self.clock()
        for ev in self.trace.events:
            target = ev.t / self.speedup
            delay = target - (self.clock() - t0)
            if delay > 0:
                self.sleep(delay)
            try:
                pending.append(
                    (ev, self.router.submit(ev.function, self.make_batch(ev))))
            except AdmissionError:
                rejected.append(ev)
        out: list[tuple[TraceEvent, ColdStartReport | None]] = []
        for ev, inv in pending:
            try:
                out.append((ev, inv.result()[1]))
            except AdmissionError:
                rejected.append(ev)
        out.extend((ev, None) for ev in rejected)
        return out


class ClosedLoopGenerator:
    """N concurrent clients, each looping submit -> wait -> think."""

    def __init__(self, router: Router, trace: Trace, make_batch: BatchFactory,
                 *, n_clients: int = 4, think_time_s: float = 0.0,
                 sleep=time.sleep):
        self.router = router
        self.trace = trace
        self.make_batch = make_batch
        self.n_clients = n_clients
        self.think_time_s = think_time_s
        self.sleep = sleep

    def run(self) -> list[tuple[TraceEvent, ColdStartReport | None]]:
        """Returns (event, report) per event; report None when the submit
        was throttled (:class:`AdmissionError`) — parity with
        :class:`OpenLoopGenerator`.  Only *real* invocation failures abort
        the run; a throttle is a measured outcome, not an error.
        """
        events = list(self.trace.events)
        out: list[tuple[TraceEvent, ColdStartReport | None]] = []
        errors: list[BaseException] = []
        out_lock = threading.Lock()
        it_lock = threading.Lock()
        idx = [0]

        def client() -> None:
            while True:
                with it_lock:
                    if idx[0] >= len(events):
                        return
                    ev = events[idx[0]]
                    idx[0] += 1
                try:
                    _, rep = self.router.invoke(ev.function,
                                                self.make_batch(ev))
                except AdmissionError:
                    with out_lock:
                        out.append((ev, None))   # throttled, not failed
                    continue
                except BaseException as e:
                    with out_lock:
                        errors.append(e)
                    continue
                with out_lock:
                    out.append((ev, rep))
                if self.think_time_s:
                    self.sleep(self.think_time_s)

        threads = [threading.Thread(target=client, name=f"client-{i}",
                                    daemon=True)
                   for i in range(self.n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise errors[0]  # partial results must not masquerade as a run
        return out
