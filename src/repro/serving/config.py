"""ServeConfig: the one composed entrypoint for the serving stack.

The knobs used to be scattered — ``ReapConfig`` on the orchestrator,
``RouterConfig.batch_restore_limit`` on the router, ``PolicyConfig`` /
``forecast_cfg`` on the policy loop, ``DemandConfig`` and per-node
``TransferModel`` args on the cluster layer.  :class:`ServeConfig` composes
them behind a single dataclass consumed by
:class:`~repro.serving.Orchestrator`, :class:`~repro.cluster.WorkerNode`
and :func:`~repro.cluster.build_fleet`; the overlapped-restore knobs
(``overlap_install``, ``hot_prefix_frac``, ``tail_workers``,
``tail_deadline_s``) live here first and are folded into the effective
:class:`~repro.core.ReapConfig` by :meth:`ServeConfig.resolved_reap`.

The old loose-kwarg constructors keep working through deprecation shims.
Note the default flips ``overlap_install`` ON: constructing through
ServeConfig opts into serving from the hot prefix while the working-set
tail installs in the background (a MATERIALIZED instance is then *not*
necessarily fully resident — the arena's pending-fault path covers the
gap).  Legacy constructors keep the old fully-resident behaviour.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

from ..core import ReapConfig


@dataclasses.dataclass
class ServeConfig:
    # -- restore / REAP -------------------------------------------------
    reap: ReapConfig = dataclasses.field(default_factory=ReapConfig)
    mode: str = "reap"               # 'reap' | 'vanilla'
    # -- overlapped restore (authoritative here; folded into ``reap``) --
    overlap_install: bool = True
    hot_prefix_frac: float = 0.125
    tail_workers: int = 2
    tail_deadline_s: float = 5.0
    # -- instance pools -------------------------------------------------
    keepalive_s: float = 60.0
    warm_limit: int = 8
    prewarm_concurrency: int = 4
    # -- data plane (None => RouterConfig()'s defaults) ----------------
    # typed Any to keep this module import-cycle-free (router.py imports
    # orchestrator.py which imports this module)
    router: Optional[Any] = None     # serving.RouterConfig
    # -- optional control/cluster planes -------------------------------
    policy: Optional[Any] = None     # serving.PolicyConfig (prewarm loop)
    demand: Optional[Any] = None     # cluster.DemandConfig (fleet forecasts)
    transfer: Optional[Any] = None   # cluster.TransferModel (shard network)
    # -- observability ---------------------------------------------------
    # telemetry.TelemetryConfig: enables the periodic StatsSnapshotter
    # (fleet-wide via build_fleet; per-node too with ``per_node=True``)
    telemetry: Optional[Any] = None
    # -- page transport (cluster fleets; repro.transport) ----------------
    # "inproc": threads in one heap, TransferModel-modeled copies (the
    # deprecation seam).  "socket": process-per-node fleet moving chunks
    # over Unix-domain sockets / shared memory (build_fleet dispatches).
    transport: str = "inproc"
    transport_compress: bool = False   # per-chunk wire compression (codec)
    transport_shm: bool = True         # shm data plane when available
    transport_inline_max: int = 64 << 10  # <= this many bytes ride inline

    def resolved_reap(self) -> ReapConfig:
        """The effective ReapConfig: ``reap`` with the overlap knobs
        (authoritative on this config) folded in."""
        return dataclasses.replace(
            self.reap,
            overlap_install=self.overlap_install,
            hot_prefix_frac=self.hot_prefix_frac,
            tail_workers=self.tail_workers,
            tail_deadline_s=self.tail_deadline_s)

    def router_config(self):
        from .router import RouterConfig
        return self.router if self.router is not None else RouterConfig()
