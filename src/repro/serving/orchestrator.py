"""vHive-CRI orchestrator analogue: function registry, instance pool,
autoscaler-lite with keepalive + scale-to-zero.

The orchestrator owns the snapshot store and the per-function REAP records.
Per the paper's AWS-Lambda model, one instance processes one invocation at
a time; concurrent invocations of the same function spawn additional
instances (Fig. 9's scalability experiment drives exactly this path).

Every public method is thread-safe: the router's worker pool (router.py)
calls :meth:`invoke` from many threads while the keepalive reaper runs
concurrently.  Instances move IDLE -> BUSY only via
``FunctionInstance.try_acquire`` and are torn down only via
``try_reclaim``, which refuses BUSY instances — so a reaper racing an
invocation can never pull the arena out from under it.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Any

from ..configs.base import ModelConfig
from ..core import ReapConfig, build_instance_snapshot
from ..core.reap import ColdStartReport, drop_record
from .instance import FunctionInstance


class FunctionRecord:
    """Per-function state: snapshot base, warm pool, invocation stats.

    ``lock`` guards ``idle`` and ``stats``; ``n_spawned`` / ``n_invocations``
    are monotone counters updated under the same lock.
    """

    def __init__(self, name: str, cfg: ModelConfig, base: str):
        self.name = name
        self.cfg = cfg
        self.base = base
        self.lock = threading.Lock()
        self.idle: list[FunctionInstance] = []
        self.stats: list[ColdStartReport] = []
        self.n_spawned = 0
        self.n_invocations = 0


class Orchestrator:
    def __init__(self, store_dir: str, *, reap: ReapConfig | None = None,
                 mode: str = "reap", keepalive_s: float = 60.0,
                 warm_limit: int = 8):
        """mode: 'reap' (record+prefetch) | 'vanilla' (baseline snapshots)."""
        self.store_dir = store_dir
        self.reap = reap or ReapConfig()
        self.mode = mode
        self.keepalive_s = keepalive_s
        self.warm_limit = warm_limit
        self.functions: dict[str, FunctionRecord] = {}
        self._lock = threading.Lock()
        os.makedirs(store_dir, exist_ok=True)

    # -- control plane -------------------------------------------------

    def register(self, name: str, cfg: ModelConfig, *, seed: int = 0,
                 rebuild: bool = False,
                 warmup_batch: dict | None = None) -> FunctionRecord:
        base = os.path.join(self.store_dir, name)
        if rebuild or not os.path.exists(base + ".mem"):
            build_instance_snapshot(cfg, base, seed=seed)
            drop_record(base)
        if warmup_batch is not None:
            # deploy-time compile of all invocation executables (the paper's
            # analogue: booting/initialization happens once, off the
            # invocation critical path)
            from .instance import ExecutableCache
            ExecutableCache.warm(cfg, warmup_batch)
        with self._lock:
            rec = self.functions.get(name)
            if rec is None:
                rec = FunctionRecord(name, cfg, base)
                self.functions[name] = rec
        return rec

    def reset_records(self, name: str) -> None:
        drop_record(self.functions[name].base)

    def scale_to_zero(self, name: str) -> None:
        rec = self.functions[name]
        with rec.lock:
            keep = [i for i in rec.idle if not i.try_reclaim()]
            rec.idle = keep

    def reap_idle(self) -> int:
        """Keepalive sweep: reclaim instances idle past the deadline.

        Safe to run concurrently with ``invoke``: an instance that a worker
        just acquired is BUSY and ``try_reclaim`` refuses it.
        """
        now = time.monotonic()
        n = 0
        with self._lock:
            records = list(self.functions.values())
        for rec in records:
            with rec.lock:
                keep = []
                for inst in rec.idle:
                    if (now - inst.last_used > self.keepalive_s
                            and inst.try_reclaim()):
                        n += 1
                    else:
                        keep.append(inst)
                rec.idle = keep
        return n

    # -- data plane ------------------------------------------------------

    def _acquire_instance(self, rec: FunctionRecord,
                          force_cold: bool) -> tuple[FunctionInstance, bool]:
        """Pop a warm instance (atomically marking it BUSY) or cold-start a
        new one.  Returns (instance, was_cold)."""
        if not force_cold:
            with rec.lock:
                while rec.idle:
                    inst = rec.idle.pop()
                    if inst.try_acquire():
                        return inst, False
                    # lost a race with a reaper; instance is already dead
        mode = "vanilla" if self.mode == "vanilla" else "auto"
        inst = FunctionInstance(rec.name, rec.cfg, rec.base, self.reap,
                                mode=mode)
        inst.try_acquire()
        with rec.lock:
            rec.n_spawned += 1
        return inst, True

    def _release_instance(self, rec: FunctionRecord, inst: FunctionInstance,
                          report: ColdStartReport) -> None:
        inst.release()
        with rec.lock:
            rec.stats.append(report)
            rec.n_invocations += 1
            if len(rec.idle) < self.warm_limit:
                rec.idle.append(inst)
                return
        inst.try_reclaim()

    def invoke(self, name: str, batch: dict,
               *, force_cold: bool = False) -> tuple[Any, ColdStartReport]:
        """Route one invocation; cold-starts a new instance if needed."""
        rec = self.functions[name]
        inst, cold = self._acquire_instance(rec, force_cold)
        try:
            logits, _ = inst.invoke(
                batch, parallel_faults=self.reap.parallel_faults)
            if cold:
                inst.finish_cold()
                inst.make_warm()  # stays memory-resident until reclaimed
        except BaseException:
            # failed invocation: never return the instance to the warm pool,
            # and never leak its arena mmap
            inst.release()
            inst.try_reclaim()
            raise
        report = inst.report
        self._release_instance(rec, inst, report)
        return logits, report
