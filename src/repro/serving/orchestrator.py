"""vHive-CRI orchestrator analogue: function registry, instance pool,
router/data-plane, autoscaler-lite with keepalive + scale-to-zero.

The orchestrator owns the snapshot store and the per-function REAP records.
Per the paper's AWS-Lambda model, one instance processes one invocation at
a time; concurrent invocations of the same function spawn additional
instances (Fig. 9's scalability experiment drives exactly this path).
"""
from __future__ import annotations

import os
import threading
import time
from typing import Any

from ..configs.base import ModelConfig
from ..core import ReapConfig, build_instance_snapshot
from ..core.reap import ColdStartReport, drop_record, has_record
from .instance import FunctionInstance, State


class FunctionRecord:
    def __init__(self, name: str, cfg: ModelConfig, base: str):
        self.name = name
        self.cfg = cfg
        self.base = base
        self.lock = threading.Lock()
        self.idle: list[FunctionInstance] = []
        self.stats: list[ColdStartReport] = []


class Orchestrator:
    def __init__(self, store_dir: str, *, reap: ReapConfig | None = None,
                 mode: str = "reap", keepalive_s: float = 60.0,
                 warm_limit: int = 8):
        """mode: 'reap' (record+prefetch) | 'vanilla' (baseline snapshots)."""
        self.store_dir = store_dir
        self.reap = reap or ReapConfig()
        self.mode = mode
        self.keepalive_s = keepalive_s
        self.warm_limit = warm_limit
        self.functions: dict[str, FunctionRecord] = {}
        self._lock = threading.Lock()
        os.makedirs(store_dir, exist_ok=True)

    # -- control plane -------------------------------------------------

    def register(self, name: str, cfg: ModelConfig, *, seed: int = 0,
                 rebuild: bool = False,
                 warmup_batch: dict | None = None) -> FunctionRecord:
        base = os.path.join(self.store_dir, name)
        if rebuild or not os.path.exists(base + ".mem"):
            build_instance_snapshot(cfg, base, seed=seed)
            drop_record(base)
        if warmup_batch is not None:
            # deploy-time compile of all invocation executables (the paper's
            # analogue: booting/initialization happens once, off the
            # invocation critical path)
            from .instance import ExecutableCache
            ExecutableCache.warm(cfg, warmup_batch)
        with self._lock:
            rec = self.functions.get(name)
            if rec is None:
                rec = FunctionRecord(name, cfg, base)
                self.functions[name] = rec
        return rec

    def reset_records(self, name: str) -> None:
        drop_record(self.functions[name].base)

    def scale_to_zero(self, name: str) -> None:
        rec = self.functions[name]
        with rec.lock:
            for inst in rec.idle:
                inst.reclaim()
            rec.idle.clear()

    def reap_idle(self) -> int:
        """Keepalive sweep: reclaim instances idle past the deadline."""
        now = time.monotonic()
        n = 0
        for rec in self.functions.values():
            with rec.lock:
                keep = []
                for inst in rec.idle:
                    if now - inst.last_used > self.keepalive_s:
                        inst.reclaim()
                        n += 1
                    else:
                        keep.append(inst)
                rec.idle = keep
        return n

    # -- data plane ------------------------------------------------------

    def invoke(self, name: str, batch: dict,
               *, force_cold: bool = False) -> tuple[Any, ColdStartReport]:
        """Route one invocation; cold-starts a new instance if needed."""
        rec = self.functions[name]
        inst: FunctionInstance | None = None
        if not force_cold:
            with rec.lock:
                if rec.idle:
                    inst = rec.idle.pop()
        cold = inst is None
        if cold:
            mode = "vanilla" if self.mode == "vanilla" else "auto"
            inst = FunctionInstance(name, rec.cfg, rec.base, self.reap,
                                    mode=mode)
        logits, _ = inst.invoke(
            batch, parallel_faults=self.reap.parallel_faults)
        if cold:
            inst.finish_cold()
            inst.make_warm()  # instance stays memory-resident until reclaimed
        report = inst.report
        with rec.lock:
            rec.stats.append(report)
            if len(rec.idle) < self.warm_limit:
                rec.idle.append(inst)
            else:
                inst.reclaim()
        return logits, report
