"""vHive-CRI orchestrator analogue: function registry, instance pool,
autoscaler-lite with keepalive + scale-to-zero.

The orchestrator owns the snapshot store and the per-function REAP records.
Per the paper's AWS-Lambda model, one instance processes one invocation at
a time; concurrent invocations of the same function spawn additional
instances (Fig. 9's scalability experiment drives exactly this path).

Cold starts run through the staged restore pipeline (core/restore.py) and
are **batched**: when the router reports a queue of same-function cold
waiters (``group_hint``), :meth:`Orchestrator.invoke` restores the whole
group through :meth:`spawn_batch` — one WS fetch and one fused install pass
for N instances — parking the extras in the function's *fresh pool* for the
waiters to claim.  Prewarm bursts take the same path (one group restore per
``prewarm`` call instead of n single-instance pipelines).

Every public method is thread-safe: the router's worker pool (router.py)
calls :meth:`invoke` from many threads while the keepalive reaper runs
concurrently.  Instances move IDLE -> BUSY only via
``FunctionInstance.try_acquire`` and are torn down only via
``try_reclaim``, which refuses BUSY instances — so a reaper racing an
invocation can never pull the arena out from under it.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
import warnings
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any

from ..configs.base import ModelConfig
from ..core import ReapConfig, build_instance_snapshot
from ..core.reap import ColdStartReport, StageTimings, drop_record
from .config import ServeConfig
from .instance import FunctionInstance, restore_group


class FunctionRecord:
    """Per-function state: snapshot base, warm pool, invocation stats.

    ``lock`` is a condition variable guarding ``idle``, ``fresh`` and
    ``stats``; ``n_spawned`` / ``n_invocations`` / ``n_prewarmed`` are
    monotone counters updated under the same lock.  ``fresh`` holds
    batch-restored instances that have never served an invocation: a cold
    arrival that claims one still pays (reports) the group's restore split.
    ``batch_pending`` counts fresh instances an in-flight group restore
    will deliver — cold arrivals wait on it instead of spawning duplicates.

    ``warm_limit`` / ``keepalive_s`` are per-function overrides (None =>
    inherit the orchestrator-wide default); ``min_warm`` is the adaptive
    policy's floor — the keepalive reaper never shrinks the idle pool below
    it (policy.py owns all three).
    """

    def __init__(self, name: str, cfg: ModelConfig, base: str):
        self.name = name
        self.cfg = cfg
        self.base = base
        self.lock = threading.Condition()
        self.idle: list[FunctionInstance] = []
        self.fresh: list[FunctionInstance] = []
        self.batch_pending = 0
        self.stats: list[ColdStartReport] = []
        self.n_spawned = 0
        self.n_batched = 0               # instances restored in groups > 1
        self.n_invocations = 0
        self.n_prewarmed = 0
        self.n_prewarming = 0            # prewarms currently on pool threads
        self.n_prewarm_failures = 0
        self.last_prewarm_error: BaseException | None = None
        self.warm_limit: int | None = None
        self.keepalive_s: float | None = None
        self.min_warm = 0


class Orchestrator:
    def __init__(self, store_dir: str, config: ServeConfig | None = None, *,
                 reap: ReapConfig | None = None, mode: str | None = None,
                 keepalive_s: float | None = None, warm_limit: int | None = None,
                 prewarm_concurrency: int | None = None, ws_cache=None,
                 clock=time.monotonic):
        """``config`` (a :class:`~repro.serving.ServeConfig`) is the
        recommended construction path; it also enables overlapped restore
        by default.  The loose keyword knobs (``reap``, ``mode``,
        ``keepalive_s``, ``warm_limit``, ``prewarm_concurrency``) are the
        pre-ServeConfig API, kept working as a deprecation shim — they
        override the matching ``config`` field and keep the legacy
        fully-resident restore behaviour when no config is given.
        ``ws_cache``: WS page cache every instance prefetches through (None
        => process-wide default; a cluster WorkerNode passes its own
        two-tier cache so restores resolve local-hit / remote-fetch /
        origin-disk)."""
        legacy = {"reap": reap, "mode": mode, "keepalive_s": keepalive_s,
                  "warm_limit": warm_limit,
                  "prewarm_concurrency": prewarm_concurrency}
        legacy = {k: v for k, v in legacy.items() if v is not None}
        if config is None:
            # legacy construction keeps PR-5 behaviour: overlap off unless
            # the passed ReapConfig itself opted in
            config = ServeConfig(overlap_install=False)
        if legacy:
            warnings.warn(
                "Orchestrator(store_dir, reap=..., mode=..., ...) keyword "
                "knobs are deprecated; pass a ServeConfig instead",
                DeprecationWarning, stacklevel=2)
            r = legacy.pop("reap", None)
            if r is not None:
                # the loose ReapConfig is authoritative, overlap knobs
                # included (it predates their ServeConfig home)
                config = dataclasses.replace(
                    config, reap=r,
                    overlap_install=r.overlap_install,
                    hot_prefix_frac=r.hot_prefix_frac,
                    tail_workers=r.tail_workers,
                    tail_deadline_s=r.tail_deadline_s)
            config = dataclasses.replace(config, **legacy)
        self.config = config
        self.clock = clock   # monotonic seconds: keepalive/quiesce deadlines
        self.store_dir = store_dir
        self.reap = config.resolved_reap()
        self.mode = config.mode
        self.ws_cache = ws_cache
        self.keepalive_s = config.keepalive_s
        self.warm_limit = config.warm_limit
        self.prewarm_concurrency = config.prewarm_concurrency
        self.functions: dict[str, FunctionRecord] = {}
        self._lock = threading.Lock()
        self._prewarm_pool: ThreadPoolExecutor | None = None
        self._prewarm_futures: list[Future] = []
        # live background tail installs spawned by this orchestrator's
        # group restores (bounded; drained by tail_quiesce / tail_stats)
        self._tails: deque = deque(maxlen=512)
        self._closed = False
        os.makedirs(store_dir, exist_ok=True)

    def _effective_warm_limit(self, rec: FunctionRecord) -> int:
        return self.warm_limit if rec.warm_limit is None else rec.warm_limit

    def _effective_keepalive(self, rec: FunctionRecord) -> float:
        return self.keepalive_s if rec.keepalive_s is None else rec.keepalive_s

    # -- control plane -------------------------------------------------

    def register(self, name: str, cfg: ModelConfig, *, seed: int = 0,
                 rebuild: bool = False,
                 warmup_batch: dict | None = None) -> FunctionRecord:
        base = os.path.join(self.store_dir, name)
        if rebuild or not os.path.exists(base + ".mem"):
            build_instance_snapshot(cfg, base, seed=seed)
            drop_record(base)
        if warmup_batch is not None:
            # deploy-time compile of all invocation executables (the paper's
            # analogue: booting/initialization happens once, off the
            # invocation critical path)
            from .instance import ExecutableCache
            ExecutableCache.warm(cfg, warmup_batch)
        with self._lock:
            rec = self.functions.get(name)
            if rec is None:
                rec = FunctionRecord(name, cfg, base)
                self.functions[name] = rec
        return rec

    def reset_records(self, name: str) -> None:
        drop_record(self.functions[name].base)

    @staticmethod
    def _force_reclaim(inst: FunctionInstance) -> bool:
        """Reclaim an instance that may carry a live tail install: cancel
        the tail (join) first, then reclaim.  Returns False only when the
        instance is BUSY."""
        if inst.try_reclaim():
            return True
        inst.cancel_tail(join=True)
        return inst.try_reclaim()

    def scale_to_zero(self, name: str) -> None:
        """Reclaim every idle/fresh instance of ``name``.  Unlike the
        keepalive reaper this is a *forced* path: live background tail
        installs are cancelled (and joined) so the arenas actually close.

        The pools are snapshotted (and emptied) under ``rec.lock`` but the
        reclaims run *outside* it: cancelling a live tail joins its worker
        future (up to seconds), and holding the record condvar across that
        join would stall every invoke/release on this function — and order
        ``rec.lock`` under the tail worker's own blocking.  Instances the
        reclaim must keep (a BUSY straggler) are re-parked afterwards.
        """
        rec = self.functions[name]
        with rec.lock:
            idle, rec.idle = rec.idle, []
            fresh, rec.fresh = rec.fresh, []
        keep_idle = [i for i in idle if not self._force_reclaim(i)]
        keep_fresh = [i for i in fresh if not self._force_reclaim(i)]
        if keep_idle or keep_fresh:
            with rec.lock:
                rec.idle.extend(keep_idle)
                rec.fresh.extend(keep_fresh)

    def set_policy(self, name: str, *, warm_limit: int | None = None,
                   keepalive_s: float | None = None,
                   min_warm: int | None = None) -> None:
        """Per-function provisioning knobs (the policy loop's actuators).

        ``warm_limit``/``keepalive_s`` of None restore the orchestrator-wide
        defaults; ``min_warm`` is the reaper floor (always explicit).
        """
        rec = self.functions[name]
        with rec.lock:
            rec.warm_limit = warm_limit
            rec.keepalive_s = keepalive_s
            if min_warm is not None:
                rec.min_warm = min_warm

    def idle_count(self, name: str) -> int:
        """Warm instances currently parked for ``name`` (0 if unknown) —
        the cluster scheduler's warm-availability signal."""
        rec = self.functions.get(name)
        if rec is None:
            return 0
        with rec.lock:
            return len(rec.idle)

    def warm_counts(self) -> dict[str, int]:
        """Idle warm instances per registered function (the canonical
        ``warm_instances`` stat — telemetry/schema.py)."""
        with self._lock:
            records = dict(self.functions)
        out = {}
        for name, rec in records.items():
            with rec.lock:
                out[name] = len(rec.idle)
        return out

    def prewarm(self, name: str, n: int, *, wait: bool = False) -> int:
        """Pre-spawn up to ``n`` warm instances of ``name`` on a pool thread.

        The cold-start cost (load VMM, connection restore, WS prefetch,
        param materialization) is paid here — *off* every invocation's
        critical path — and the whole burst restores as **one** group
        (one WS fetch, one fused install pass) instead of n single-flight
        pipelines.  Spawns are capped so the idle pool never exceeds the
        function's warm limit, counting prewarms already in flight.
        Returns the number of spawns actually scheduled.
        """
        rec = self.functions[name]
        with self._lock:
            if self._closed:             # never resurrect the pool after close
                return 0
            if self._prewarm_pool is None:
                self._prewarm_pool = ThreadPoolExecutor(
                    max_workers=self.prewarm_concurrency,
                    thread_name_prefix="prewarm")
            pool = self._prewarm_pool
        with rec.lock:
            limit = self._effective_warm_limit(rec)
            allowed = min(n, limit - len(rec.idle) - rec.n_prewarming)
            if allowed <= 0:
                scheduled = 0
            else:
                rec.n_prewarming += allowed
                scheduled = allowed
        if scheduled:
            try:
                fut = pool.submit(self._prewarm_group, rec, scheduled)
            except RuntimeError:        # pool shut down by a concurrent close
                with rec.lock:
                    rec.n_prewarming -= scheduled
                return 0
            with self._lock:
                self._prewarm_futures = (
                    [f for f in self._prewarm_futures if not f.done()] + [fut])
        if wait:
            self.prewarm_quiesce()
        return scheduled

    def prewarm_quiesce(self, timeout: float | None = None) -> None:
        """Block until every scheduled prewarm has finished (test/bench aid).

        ``timeout`` bounds the *total* wait, not the wait per prewarm.
        """
        deadline = None if timeout is None else self.clock() + timeout
        with self._lock:
            futs = list(self._prewarm_futures)
        for f in futs:
            left = None if deadline is None else deadline - self.clock()
            f.result(left)

    def _prewarm_group(self, rec: FunctionRecord, n: int) -> None:
        insts: list[FunctionInstance] = []
        try:
            insts = self.spawn_batch(rec.name, n, prewarmed=True,
                                     materialize=True)
            if insts[0].monitor.mode == "record":
                # No WS record existed yet (function was never cold-invoked):
                # persist one from the pages make_warm just faulted, so REAP
                # prefetch engages on the next true cold start instead of the
                # function staying permanently recordless behind warm pools.
                # A mispredicted record self-corrects via the §7.2 re-record
                # fallback.
                for inst in insts:
                    inst.finish_cold()
            leftover: list[FunctionInstance] = []
            with rec.lock:
                rec.n_prewarmed += len(insts)
                for inst in insts:
                    if len(rec.idle) < self._effective_warm_limit(rec):
                        rec.idle.append(inst)
                    else:
                        leftover.append(inst)  # limit shrank mid-spawn
            for inst in leftover:
                self._force_reclaim(inst)
        except BaseException as e:
            # a failed prewarm (e.g. records dropped mid-spawn) must neither
            # leak half-built instances nor detonate later out of a Future
            # in prewarm_quiesce — record it and move on
            with rec.lock:
                rec.n_prewarm_failures += 1
                rec.last_prewarm_error = e
            for inst in insts:
                inst.reclaim()
        finally:
            with rec.lock:
                rec.n_prewarming -= n

    def tail_quiesce(self, timeout: float | None = None) -> int:
        """Block until every tracked background tail install has finished
        (installed, demoted, or cancelled); returns how many were waited
        on.  ``timeout`` bounds the total wait."""
        deadline = None if timeout is None else self.clock() + timeout
        with self._lock:
            tails = list(self._tails)
        n = 0
        for t in tails:
            left = None if deadline is None else max(
                deadline - self.clock(), 0.001)
            try:
                t.wait(left)
            except BaseException:
                pass
            n += 1
        return n

    def tail_stats(self) -> dict:
        """Counters over tracked background tail installs + per-arena
        fault-wait totals (live = still installing)."""
        with self._lock:
            tails = list(self._tails)
        out = {"tracked": len(tails),
               "live": sum(1 for t in tails if not t.done()),
               "demoted": sum(1 for t in tails if t.demoted)}
        waits = wait_s = 0
        with self._lock:
            records = list(self.functions.values())
        for rec in records:
            with rec.lock:
                for r in rec.stats:
                    waits += r.tail_waits
                    wait_s += r.stages.tail_wait_s
        out["tail_waits"] = waits
        out["tail_wait_seconds"] = wait_s
        return out

    def stage_seconds(self) -> dict:
        """Mean per-stage seconds across every recorded invocation report
        (the same ``stage_seconds`` schema Router.summarize emits)."""
        totals = {k: 0.0 for k in StageTimings().as_dict()}
        n = 0
        with self._lock:
            records = list(self.functions.values())
        for rec in records:
            with rec.lock:
                reports = list(rec.stats)
            for r in reports:
                n += 1
                for k, v in r.stages.as_dict().items():
                    totals[k] += v
        return {k: v / max(n, 1) for k, v in totals.items()}

    def reap_idle(self) -> int:
        """Keepalive sweep: reclaim instances idle past the deadline.

        Safe to run concurrently with ``invoke``: an instance that a worker
        just acquired is BUSY and ``try_reclaim`` refuses it.  Never shrinks
        a function's idle pool below its policy floor (``min_warm``), so an
        adaptive target survives keepalive expiry.  Fresh (batch-restored,
        never-invoked) instances expire on the same deadline but are not
        protected by the floor — they are surplus from an over-sized group.
        """
        now = self.clock()
        n = 0
        with self._lock:
            records = list(self.functions.values())
        for rec in records:
            with rec.lock:
                keepalive = self._effective_keepalive(rec)
                # oldest-first so the floor keeps the most recently used
                candidates = sorted(rec.idle, key=lambda i: i.last_used)
                keep = []
                n_idle = len(candidates)
                for inst in candidates:
                    if (n_idle > rec.min_warm
                            and now - inst.last_used > keepalive
                            and inst.try_reclaim()):
                        n += 1
                        n_idle -= 1
                    else:
                        keep.append(inst)
                rec.idle = keep
                stale = [i for i in rec.fresh
                         if now - i.last_used > keepalive]
                if stale:
                    rec.fresh = [i for i in rec.fresh if i not in stale]
            for inst in stale:
                if inst.try_reclaim():
                    n += 1
        return n

    def close(self) -> None:
        """Tear down the prewarm pool and reclaim every idle instance.

        Permanent: later ``prewarm`` calls become no-ops (a policy loop
        still winding down must not resurrect the pool).
        """
        with self._lock:
            self._closed = True
            pool, self._prewarm_pool = self._prewarm_pool, None
            self._prewarm_futures = []
        if pool is not None:
            pool.shutdown(wait=True)
        for name in list(self.functions):
            self.scale_to_zero(name)

    # -- data plane ------------------------------------------------------

    def spawn_batch(self, name: str, n: int, *, prewarmed: bool = False,
                    materialize: bool = False) -> list[FunctionInstance]:
        """Restore ``n`` instances of ``name`` as ONE staged group.

        The group shares a single manifest parse, a single WS fetch and a
        single fused page-gather pass (core/restore.py); each instance then
        installs the shared block with one vectorized scatter.  Returns the
        instances (IDLE, not parked anywhere).
        """
        rec = self.functions[name]
        n = max(1, n)
        mode = "vanilla" if self.mode == "vanilla" else "auto"
        insts = [FunctionInstance(rec.name, rec.cfg, rec.base, self.reap,
                                  mode=mode, prewarmed=prewarmed,
                                  ws_cache=self.ws_cache, clock=self.clock)
                 for _ in range(n)]
        restore_group(insts, materialize=materialize)
        tails = [i._tail for i in insts if i._tail is not None]
        if tails:
            with self._lock:
                self._tails.extend(tails)
        with rec.lock:
            rec.n_spawned += n
            if n > 1:
                rec.n_batched += n
        return insts

    def _pop_fresh_locked(self, rec: FunctionRecord):
        while rec.fresh:
            inst = rec.fresh.pop()
            if inst.try_acquire():
                return inst
            # lost a race with a reaper; instance is already dead
        return None

    def _acquire_instance(self, rec: FunctionRecord, force_cold: bool,
                          group_hint: int = 1) -> tuple[FunctionInstance, bool]:
        """Pop a warm instance (atomically marking it BUSY) or cold-start.

        The cold path is group-aware: a fresh (batch-restored) instance is
        claimed first; else, while a group restore is in flight
        (``batch_pending``), the caller waits for its delivery instead of
        spawning a duplicate; else it becomes the spawner for a group of up
        to ``group_hint`` (1 + the same-function cold waiters the router
        saw queued behind this invocation).  Returns (instance, was_cold).
        """
        if not force_cold:
            with rec.lock:
                while rec.idle:
                    inst = rec.idle.pop()
                    if inst.try_acquire():
                        return inst, False
                    # lost a race with a reaper; instance is already dead
        extra = 0
        with rec.lock:
            while True:
                inst = self._pop_fresh_locked(rec)
                if inst is not None:
                    return inst, True
                if rec.batch_pending > 0:
                    # a group restore in flight will deliver fresh
                    # instances; joining it beats spawning a duplicate.
                    # The timeout is a liveness backstop (a delivery
                    # notify can never be missed under the condvar).
                    rec.lock.wait(timeout=60.0)
                    continue
                # become the spawner; cover waiters the router saw queued,
                # minus restores already in flight for them
                extra = max(0, group_hint - 1)
                rec.batch_pending += extra
                break
        try:
            insts = self.spawn_batch(rec.name, 1 + extra)
        except BaseException:
            with rec.lock:
                rec.batch_pending -= extra
                rec.lock.notify_all()    # waiters fall through to self-spawn
            raise
        insts[0].try_acquire()
        with rec.lock:
            rec.fresh.extend(insts[1:])
            rec.batch_pending -= extra
            rec.lock.notify_all()
        return insts[0], True

    def _release_instance(self, rec: FunctionRecord, inst: FunctionInstance,
                          report: ColdStartReport) -> None:
        inst.release()
        with rec.lock:
            rec.stats.append(report)
            rec.n_invocations += 1
            # never re-park after close(): the teardown sweep already ran
            # and nothing would ever reclaim a late-parked arena
            if not self._closed and len(rec.idle) < self._effective_warm_limit(rec):
                rec.idle.append(inst)
                return
        self._force_reclaim(inst)

    def invoke(self, name: str, batch: dict, *, force_cold: bool = False,
               group_hint: int = 1) -> tuple[Any, ColdStartReport]:
        """Route one invocation; cold-starts a new instance if needed.

        ``group_hint`` (from the router) is the number of same-function
        invocations — this one included — believed to need cold instances
        right now; a cold start restores that many as one batch.
        """
        rec = self.functions[name]
        inst, cold = self._acquire_instance(rec, force_cold, group_hint)
        try:
            logits, _ = inst.invoke(
                batch, parallel_faults=self.reap.parallel_faults)
            if cold:
                inst.finish_cold()
                inst.make_warm()  # stays memory-resident until reclaimed
        except BaseException:
            # failed invocation: never return the instance to the warm pool,
            # and never leak its arena mmap (a live tail is cancelled first)
            inst.release()
            self._force_reclaim(inst)
            raise
        report = inst.report
        self._release_instance(rec, inst, report)
        return logits, report
