"""vHive-CRI orchestrator analogue: function registry, instance pool,
autoscaler-lite with keepalive + scale-to-zero.

The orchestrator owns the snapshot store and the per-function REAP records.
Per the paper's AWS-Lambda model, one instance processes one invocation at
a time; concurrent invocations of the same function spawn additional
instances (Fig. 9's scalability experiment drives exactly this path).

Every public method is thread-safe: the router's worker pool (router.py)
calls :meth:`invoke` from many threads while the keepalive reaper runs
concurrently.  Instances move IDLE -> BUSY only via
``FunctionInstance.try_acquire`` and are torn down only via
``try_reclaim``, which refuses BUSY instances — so a reaper racing an
invocation can never pull the arena out from under it.
"""
from __future__ import annotations

import os
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any

from ..configs.base import ModelConfig
from ..core import ReapConfig, build_instance_snapshot
from ..core.reap import ColdStartReport, drop_record
from .instance import FunctionInstance


class FunctionRecord:
    """Per-function state: snapshot base, warm pool, invocation stats.

    ``lock`` guards ``idle`` and ``stats``; ``n_spawned`` / ``n_invocations``
    / ``n_prewarmed`` are monotone counters updated under the same lock.

    ``warm_limit`` / ``keepalive_s`` are per-function overrides (None =>
    inherit the orchestrator-wide default); ``min_warm`` is the adaptive
    policy's floor — the keepalive reaper never shrinks the idle pool below
    it (policy.py owns all three).
    """

    def __init__(self, name: str, cfg: ModelConfig, base: str):
        self.name = name
        self.cfg = cfg
        self.base = base
        self.lock = threading.Lock()
        self.idle: list[FunctionInstance] = []
        self.stats: list[ColdStartReport] = []
        self.n_spawned = 0
        self.n_invocations = 0
        self.n_prewarmed = 0
        self.n_prewarming = 0            # prewarms currently on pool threads
        self.n_prewarm_failures = 0
        self.last_prewarm_error: BaseException | None = None
        self.warm_limit: int | None = None
        self.keepalive_s: float | None = None
        self.min_warm = 0


class Orchestrator:
    def __init__(self, store_dir: str, *, reap: ReapConfig | None = None,
                 mode: str = "reap", keepalive_s: float = 60.0,
                 warm_limit: int = 8, prewarm_concurrency: int = 4,
                 ws_cache=None):
        """mode: 'reap' (record+prefetch) | 'vanilla' (baseline snapshots).
        ``ws_cache``: WS page cache every instance prefetches through (None
        => process-wide default; a cluster WorkerNode passes its own
        two-tier cache so restores resolve local-hit / remote-fetch /
        origin-disk)."""
        self.store_dir = store_dir
        self.reap = reap or ReapConfig()
        self.mode = mode
        self.ws_cache = ws_cache
        self.keepalive_s = keepalive_s
        self.warm_limit = warm_limit
        self.prewarm_concurrency = prewarm_concurrency
        self.functions: dict[str, FunctionRecord] = {}
        self._lock = threading.Lock()
        self._prewarm_pool: ThreadPoolExecutor | None = None
        self._prewarm_futures: list[Future] = []
        self._closed = False
        os.makedirs(store_dir, exist_ok=True)

    def _effective_warm_limit(self, rec: FunctionRecord) -> int:
        return self.warm_limit if rec.warm_limit is None else rec.warm_limit

    def _effective_keepalive(self, rec: FunctionRecord) -> float:
        return self.keepalive_s if rec.keepalive_s is None else rec.keepalive_s

    # -- control plane -------------------------------------------------

    def register(self, name: str, cfg: ModelConfig, *, seed: int = 0,
                 rebuild: bool = False,
                 warmup_batch: dict | None = None) -> FunctionRecord:
        base = os.path.join(self.store_dir, name)
        if rebuild or not os.path.exists(base + ".mem"):
            build_instance_snapshot(cfg, base, seed=seed)
            drop_record(base)
        if warmup_batch is not None:
            # deploy-time compile of all invocation executables (the paper's
            # analogue: booting/initialization happens once, off the
            # invocation critical path)
            from .instance import ExecutableCache
            ExecutableCache.warm(cfg, warmup_batch)
        with self._lock:
            rec = self.functions.get(name)
            if rec is None:
                rec = FunctionRecord(name, cfg, base)
                self.functions[name] = rec
        return rec

    def reset_records(self, name: str) -> None:
        drop_record(self.functions[name].base)

    def scale_to_zero(self, name: str) -> None:
        rec = self.functions[name]
        with rec.lock:
            keep = [i for i in rec.idle if not i.try_reclaim()]
            rec.idle = keep

    def set_policy(self, name: str, *, warm_limit: int | None = None,
                   keepalive_s: float | None = None,
                   min_warm: int | None = None) -> None:
        """Per-function provisioning knobs (the policy loop's actuators).

        ``warm_limit``/``keepalive_s`` of None restore the orchestrator-wide
        defaults; ``min_warm`` is the reaper floor (always explicit).
        """
        rec = self.functions[name]
        with rec.lock:
            rec.warm_limit = warm_limit
            rec.keepalive_s = keepalive_s
            if min_warm is not None:
                rec.min_warm = min_warm

    def idle_count(self, name: str) -> int:
        """Warm instances currently parked for ``name`` (0 if unknown) —
        the cluster scheduler's warm-availability signal."""
        rec = self.functions.get(name)
        if rec is None:
            return 0
        with rec.lock:
            return len(rec.idle)

    def prewarm(self, name: str, n: int, *, wait: bool = False) -> int:
        """Pre-spawn up to ``n`` warm instances of ``name`` on pool threads.

        The cold-start cost (load VMM, connection restore, WS prefetch,
        param materialization) is paid here — *off* every invocation's
        critical path.  Spawns are capped so the idle pool never exceeds the
        function's warm limit, counting prewarms already in flight.
        Returns the number of spawns actually scheduled.
        """
        rec = self.functions[name]
        scheduled = 0
        with self._lock:
            if self._closed:             # never resurrect the pool after close
                return 0
            if self._prewarm_pool is None:
                self._prewarm_pool = ThreadPoolExecutor(
                    max_workers=self.prewarm_concurrency,
                    thread_name_prefix="prewarm")
            pool = self._prewarm_pool
        for _ in range(n):
            with rec.lock:
                limit = self._effective_warm_limit(rec)
                if len(rec.idle) + rec.n_prewarming >= limit:
                    break
                rec.n_prewarming += 1
            try:
                fut = pool.submit(self._prewarm_one, rec)
            except RuntimeError:        # pool shut down by a concurrent close
                with rec.lock:
                    rec.n_prewarming -= 1
                break
            scheduled += 1
            with self._lock:
                self._prewarm_futures = (
                    [f for f in self._prewarm_futures if not f.done()] + [fut])
        if wait:
            self.prewarm_quiesce()
        return scheduled

    def prewarm_quiesce(self, timeout: float | None = None) -> None:
        """Block until every scheduled prewarm has finished (test/bench aid).

        ``timeout`` bounds the *total* wait, not the wait per prewarm.
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            futs = list(self._prewarm_futures)
        for f in futs:
            left = None if deadline is None else deadline - time.monotonic()
            f.result(left)

    def _prewarm_one(self, rec: FunctionRecord) -> None:
        inst = None
        try:
            mode = "vanilla" if self.mode == "vanilla" else "auto"
            inst = FunctionInstance(rec.name, rec.cfg, rec.base, self.reap,
                                    mode=mode, prewarmed=True,
                                    ws_cache=self.ws_cache)
            inst.make_warm()         # params memory-resident before any arrival
            if inst.monitor.mode == "record":
                # No WS record existed yet (function was never cold-invoked):
                # persist one from the pages make_warm just faulted, so REAP
                # prefetch engages on the next true cold start instead of the
                # function staying permanently recordless behind warm pools.
                # A mispredicted record self-corrects via the §7.2 re-record
                # fallback.
                inst.finish_cold()
            with rec.lock:
                rec.n_spawned += 1
                rec.n_prewarmed += 1
                if len(rec.idle) < self._effective_warm_limit(rec):
                    rec.idle.append(inst)
                    return
            inst.try_reclaim()       # limit shrank while we were spawning
        except BaseException as e:
            # a failed prewarm (e.g. records dropped mid-spawn) must neither
            # leak the half-built instance nor detonate later out of a
            # Future in prewarm_quiesce — record it and move on
            with rec.lock:
                rec.n_prewarm_failures += 1
                rec.last_prewarm_error = e
            if inst is not None:
                inst.reclaim()
        finally:
            with rec.lock:
                rec.n_prewarming -= 1

    def reap_idle(self) -> int:
        """Keepalive sweep: reclaim instances idle past the deadline.

        Safe to run concurrently with ``invoke``: an instance that a worker
        just acquired is BUSY and ``try_reclaim`` refuses it.  Never shrinks
        a function's idle pool below its policy floor (``min_warm``), so an
        adaptive target survives keepalive expiry.
        """
        now = time.monotonic()
        n = 0
        with self._lock:
            records = list(self.functions.values())
        for rec in records:
            with rec.lock:
                keepalive = self._effective_keepalive(rec)
                # oldest-first so the floor keeps the most recently used
                candidates = sorted(rec.idle, key=lambda i: i.last_used)
                keep = []
                n_idle = len(candidates)
                for inst in candidates:
                    if (n_idle > rec.min_warm
                            and now - inst.last_used > keepalive
                            and inst.try_reclaim()):
                        n += 1
                        n_idle -= 1
                    else:
                        keep.append(inst)
                rec.idle = keep
        return n

    def close(self) -> None:
        """Tear down the prewarm pool and reclaim every idle instance.

        Permanent: later ``prewarm`` calls become no-ops (a policy loop
        still winding down must not resurrect the pool).
        """
        with self._lock:
            self._closed = True
            pool, self._prewarm_pool = self._prewarm_pool, None
            self._prewarm_futures = []
        if pool is not None:
            pool.shutdown(wait=True)
        for name in list(self.functions):
            self.scale_to_zero(name)

    # -- data plane ------------------------------------------------------

    def _acquire_instance(self, rec: FunctionRecord,
                          force_cold: bool) -> tuple[FunctionInstance, bool]:
        """Pop a warm instance (atomically marking it BUSY) or cold-start a
        new one.  Returns (instance, was_cold)."""
        if not force_cold:
            with rec.lock:
                while rec.idle:
                    inst = rec.idle.pop()
                    if inst.try_acquire():
                        return inst, False
                    # lost a race with a reaper; instance is already dead
        mode = "vanilla" if self.mode == "vanilla" else "auto"
        inst = FunctionInstance(rec.name, rec.cfg, rec.base, self.reap,
                                mode=mode, ws_cache=self.ws_cache)
        inst.try_acquire()
        with rec.lock:
            rec.n_spawned += 1
        return inst, True

    def _release_instance(self, rec: FunctionRecord, inst: FunctionInstance,
                          report: ColdStartReport) -> None:
        inst.release()
        with rec.lock:
            rec.stats.append(report)
            rec.n_invocations += 1
            # never re-park after close(): the teardown sweep already ran
            # and nothing would ever reclaim a late-parked arena
            if not self._closed and len(rec.idle) < self._effective_warm_limit(rec):
                rec.idle.append(inst)
                return
        inst.try_reclaim()

    def invoke(self, name: str, batch: dict,
               *, force_cold: bool = False) -> tuple[Any, ColdStartReport]:
        """Route one invocation; cold-starts a new instance if needed."""
        rec = self.functions[name]
        inst, cold = self._acquire_instance(rec, force_cold)
        try:
            logits, _ = inst.invoke(
                batch, parallel_faults=self.reap.parallel_faults)
            if cold:
                inst.finish_cold()
                inst.make_warm()  # stays memory-resident until reclaimed
        except BaseException:
            # failed invocation: never return the instance to the warm pool,
            # and never leak its arena mmap
            inst.release()
            inst.try_reclaim()
            raise
        report = inst.report
        self._release_instance(rec, inst, report)
        return logits, report
