"""seamless-m4t-medium [audio] — enc-dec 12L+12L d_model=1024 16H (kv=16)
d_ff=4096 vocab=256206; modality frontend is a stub (precomputed frame
embeddings).  [arXiv:2308.11596; hf]"""
from .base import ModelConfig, reduce_for_smoke

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, n_enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206, frame_stride=8,
)
SMOKE = reduce_for_smoke(CONFIG)
