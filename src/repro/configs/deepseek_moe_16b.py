"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (kv=16) d_ff=1408(expert)
vocab=102400; fine-grained MoE: 2 shared + 64 routed top-6, 1 leading dense
layer (d_ff 10944).  [arXiv:2401.06066; hf]"""
from .base import ModelConfig, reduce_for_smoke

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=102400, head_dim=128,
    n_experts=64, top_k=6, n_shared_experts=2, d_ff_expert=1408,
    first_dense=1, d_ff_dense=10944, moe_every=1,
)
SMOKE = reduce_for_smoke(CONFIG)
