"""pixtral-12b [vlm] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072; pixtral-ViT frontend is a stub (precomputed patch embeddings)
feeding a mistral-nemo backbone.  [hf:mistralai/Pixtral-12B-2409; unverified]"""
from .base import ModelConfig, reduce_for_smoke

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=131072, head_dim=128, rope_theta=1_000_000.0, n_patches=1024,
)
SMOKE = reduce_for_smoke(CONFIG)
