"""--arch <id> registry for the 10 assigned architectures."""
from __future__ import annotations

from . import (deepseek_moe_16b, llama4_maverick_400b_a17b, mistral_nemo_12b,
               olmo_1b, pixtral_12b, qwen1_5_110b, qwen2_7b, rwkv6_7b,
               seamless_m4t_medium, zamba2_1_2b)
from .base import SHAPES, ModelConfig, ShapeConfig, shape_applicable

_MODULES = [
    qwen1_5_110b, qwen2_7b, mistral_nemo_12b, olmo_1b, zamba2_1_2b,
    deepseek_moe_16b, llama4_maverick_400b_a17b, seamless_m4t_medium,
    pixtral_12b, rwkv6_7b,
]

ARCHS: dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}
SMOKES: dict[str, ModelConfig] = {m.CONFIG.name: m.SMOKE for m in _MODULES}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_smoke(name: str) -> ModelConfig:
    return SMOKES[name]


def list_archs() -> list[str]:
    return list(ARCHS)
