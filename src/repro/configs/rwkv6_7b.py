"""rwkv6-7b [ssm] — Finch: 32L d_model=4096 (attention-free) d_ff=14336
vocab=65536; data-dependent per-channel decay.  [arXiv:2404.05892; hf]"""
from .base import ModelConfig, reduce_for_smoke

CONFIG = ModelConfig(
    name="rwkv6-7b", family="rwkv",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, d_ff=14336,
    vocab=65536, rwkv_head_dim=64, decay_lora=64, sub_quadratic=True,
)
SMOKE = reduce_for_smoke(CONFIG)
