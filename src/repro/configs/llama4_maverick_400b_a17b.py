"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192(expert) vocab=202048; MoE every 2nd layer, 128 routed experts
top-1 + 1 shared expert; dense interleave d_ff 16384.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from .base import ModelConfig, reduce_for_smoke

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab=202048, head_dim=128, rope_theta=500_000.0,
    n_experts=128, top_k=1, n_shared_experts=1, d_ff_expert=8192,
    first_dense=0, d_ff_dense=16384, moe_every=2,
)
SMOKE = reduce_for_smoke(CONFIG)
