"""olmo-1b [dense] — 16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304,
non-parametric LN, tied embeddings.  [arXiv:2402.00838; hf]"""
from .base import ModelConfig, reduce_for_smoke

CONFIG = ModelConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab=50304, norm="nonparam_ln", tied_embeddings=True,
)
SMOKE = reduce_for_smoke(CONFIG)
