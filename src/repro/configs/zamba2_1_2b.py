"""zamba2-1.2b [hybrid] — 38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000,
ssm_state=64; Mamba2 backbone + weight-shared attention block applied every
2 Mamba layers (19 applications).  [arXiv:2411.15242; hf]"""
from .base import ModelConfig, reduce_for_smoke

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=32000, ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    attn_every=2, sub_quadratic=True,
)
SMOKE = reduce_for_smoke(CONFIG)
