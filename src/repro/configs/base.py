"""Architecture / shape / run configuration.

Every assigned architecture lives in its own ``configs/<id>.py`` exporting
``CONFIG`` (exact published shape) and ``SMOKE`` (reduced same-family config
for CPU smoke tests).  ``repro.configs.registry`` resolves ``--arch <id>``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | rwkv | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"  # rmsnorm | nonparam_ln
    rope_theta: float = 10000.0
    tied_embeddings: bool = False
    dtype: Any = jnp.bfloat16
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    moe_every: int = 1          # MoE layer every k-th layer (llama4 interleave)
    first_dense: int = 0        # leading dense layers (deepseek-moe)
    d_ff_dense: int = 0         # d_ff of the dense layers in an MoE stack
    capacity_factor: float = 1.25
    # --- hybrid (zamba2-style) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_kernel: int = 4
    attn_every: int = 0         # shared attention block every k mamba layers
    # --- rwkv6 ---
    rwkv_head_dim: int = 64
    decay_lora: int = 64
    # --- encoder-decoder ---
    n_enc_layers: int = 0
    frame_stride: int = 8       # audio frames = seq // frame_stride
    # --- vlm ---
    n_patches: int = 1024       # precomputed patch embeddings (frontend stub)
    # --- serving/runtime knobs ---
    kv_cache_dtype: str = "bfloat16"   # "int8" halves the decode working set
    ce_chunk: int = 1024               # tokens per memory-efficient-CE chunk
    attn_chunk: int = 1024
    sub_quadratic: bool = False  # True => long_500k shape is runnable
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


# The four assigned LM shapes (identical for all 10 archs).
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch x shape) cell runs, per DESIGN.md §5."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: O(L^2) at 524288 skipped (DESIGN.md §5)"
    return True, ""


def reduce_for_bench(cfg: ModelConfig) -> ModelConfig:
    """Mid-size same-family config for the REAP serving benchmarks:
    arena working sets land in the paper's 8-99MB range (Fig. 4)."""
    return dataclasses.replace(
        reduce_for_smoke(cfg),
        name=cfg.name + "-bench",
        n_layers=max(4, min(6, cfg.n_layers)),
        d_model=256,
        n_heads=8,
        n_kv_heads=4 if cfg.n_kv_heads < cfg.n_heads else 8,
        head_dim=32,
        d_ff=1024,
        vocab=8192,
        n_experts=min(cfg.n_experts, 16),
        top_k=min(cfg.top_k, 2),
        d_ff_expert=256 if cfg.d_ff_expert else 0,
        d_ff_dense=1024 if cfg.d_ff_dense else 0,
        ssm_state=min(cfg.ssm_state, 32) if cfg.ssm_state else 0,
        n_patches=32,
    )


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Same-family reduced config: tiny layers/width/vocab/experts."""
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=max(2, min(4, cfg.n_layers)),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(4, max(1, cfg.n_kv_heads * 4 // max(cfg.n_heads, 1))),
        head_dim=32,
        d_ff=256,
        vocab=512,
        n_experts=min(cfg.n_experts, 8),
        top_k=min(cfg.top_k, 2),
        n_shared_experts=min(cfg.n_shared_experts, 1),
        d_ff_expert=64 if cfg.d_ff_expert else 0,
        d_ff_dense=256 if cfg.d_ff_dense else 0,
        first_dense=min(cfg.first_dense, 1),
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=32 if cfg.ssm_state else cfg.ssm_head_dim,
        attn_every=min(cfg.attn_every, 2) if cfg.attn_every else 0,
        rwkv_head_dim=32,
        decay_lora=16,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        n_patches=16,
        attn_chunk=64,
    )
