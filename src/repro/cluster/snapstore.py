"""Sharded snapshot store: fleet-wide two-tier WS record serving.

Within one host, :data:`repro.core.reap.WS_CACHE` already collapses N
concurrent cold-starts into one WS-file read.  Across a fleet the same
redundancy reappears one level up: every host that cold-starts function
*f* re-reads *f*'s working set from the origin (shared) disk.  "How Low
Can You Go?" (Tan et al., 2021) measures exactly this — cold-start floors
dominated by state-loading I/O that a shared tier can amortize.

This module shards that tier by the consistent-hash ring (shardmap.py):

  * every node gets its own bounded :class:`~repro.core.reap.WSCache`
    (**L1**, attached via :meth:`ShardedSnapshotStore.attach`);
  * each function name hashes to 1..R **owner** shards; a node's L1 miss
    **peeks** the alive peer replicas' caches (an owner consults its
    co-owners too before paying the origin read): a resident WS is
    transferred over a modeled network (:class:`TransferModel`, latency +
    bandwidth cost paid as real sleep time so benchmarks observe it) and
    installed locally — restores resolve **local hit -> remote fetch ->
    origin disk**;
  * the wire ships only the chunks the requester's L1 doesn't already
    hold *from any function* (the caches' content-hash index,
    pagestore.py): ``transfer_bytes`` charges actual-missing bytes and
    ``dedup_bytes_saved`` the cross-function overlap;
  * a *cold* owner does not serve (counted ``remote_misses``) — the
    requester reads origin itself.  Owner caches are populated by their
    own cold starts and by :meth:`warm_owners` (the scheduler's
    ``rebalance()`` runs it after every ring change);
  * when no owner that was ever alive remains alive (node failure), the
    non-owner falls back to the origin disk and the event is counted
    (``dead_owner_fallbacks``); ring entries that never came up are
    ordinary ``remote_misses`` — nothing "died".

Deadlock-freedom by construction: the remote tier uses
:meth:`~repro.core.reap.WSCache.peek`, which serves only *completed*
entries and never joins another cache's in-flight single-flight read — so
no thread ever blocks on another cache's event, and ring changes mid-fetch
(which can flip ownership between two nodes that are simultaneously
fetching) cannot create a cross-cache wait cycle.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time

from ..core.reap import (PAGE, ReapConfig, WSCache, _read_ws, has_record,
                         register_invalidation_listener,
                         unregister_invalidation_listener)
from .shardmap import ConsistentHashRing


@dataclasses.dataclass
class TransferModel:
    """Cost model for moving WS pages between hosts.

    ``cost_s = latency_s + n_bytes / bytes_per_s`` — a one-way RPC plus a
    bandwidth term per page.  Defaults model a ~10 GbE fabric with sub-ms
    RPC latency; benchmarks lower ``gbps`` to make tier placement visible
    at smoke-config WS sizes.

    .. deprecated:: PR 10
        This modeled sleep is the *inproc* fleet's stand-in for a copy
        that never happens (every node shares one heap).  The
        ``transport="socket"`` fleet (:mod:`repro.transport`) moves
        chunks between real processes and pays real wire/shm time; it
        does not consult this model.  Kept as the ``inproc`` seam for
        A/B baselines.
    """
    latency_s: float = 5e-4
    gbps: float = 10.0

    def cost_s(self, n_bytes: int) -> float:
        return self.latency_s + n_bytes * 8.0 / (self.gbps * 1e9)

    def cost_pages(self, n_pages: int) -> float:
        return self.cost_s(n_pages * PAGE)


class ShardedSnapshotStore:
    """Fleet-wide WS-record store sharded over a consistent-hash ring.

    One instance spans the whole (simulated) fleet.  Per-node caches are
    created by :meth:`attach`; ownership queries and node liveness live
    here so a node's miss path can route around dead owners.
    """

    def __init__(self, ring: ConsistentHashRing, *,
                 transfer: TransferModel | None = None,
                 replication: int = 1,
                 cache_capacity_bytes: int = 256 << 20,
                 reap: ReapConfig | None = None,
                 sleep=time.sleep):
        if replication < 1:
            raise ValueError("replication must be >= 1")
        self.ring = ring
        self.transfer = transfer or TransferModel()
        self.replication = replication
        self.reap = reap or ReapConfig()     # read config for warm passes
        self.cache_capacity_bytes = cache_capacity_bytes
        self.caches: dict[str, WSCache] = {}
        self._alive: dict[str, bool] = {}
        self._ever_alive: set[str] = set()   # dead vs never-up accounting
        self._hot: dict[str, int] = {}       # per-function replication override
        self._mu = threading.Lock()
        self._sleep = sleep                  # injectable for tests
        self.remote_fetches = 0
        self.remote_misses = 0               # owner alive but cache cold
        self.origin_reads = 0
        self.dead_owner_fallbacks = 0
        self.transfer_bytes = 0              # actual-missing chunk bytes shipped
        self.dedup_bytes_saved = 0           # WS bytes the requester already held
        self.transfer_s = 0.0
        self.group_fetches = 0               # shard fetches serving a batch
        self.group_instances = 0             # instances amortized over those
        self.pushed_invalidations = 0        # stale peer-L1 entries dropped
        # Push invalidation (the eager path): a re-record or record drop
        # broadcasts through core.reap's listener hook; every attached L1
        # drops its stale entry *now* instead of on its next mtime-checked
        # fetch.  The listener holds only a weakref so a store that is
        # never close()d (and its caches) can still be collected; close()
        # and GC both unregister it.
        import weakref
        self_ref = weakref.ref(self)

        def _listener(base):
            store = self_ref()
            if store is not None:
                store._push_invalidation(base)

        self._listener = _listener
        register_invalidation_listener(_listener)
        weakref.finalize(self, unregister_invalidation_listener, _listener)

    # -- membership -----------------------------------------------------

    def attach(self, node_id: str, *,
               capacity_bytes: int | None = None) -> WSCache:
        """Create (or return) ``node_id``'s L1 cache, wired so its misses
        resolve through the shard tier.  Also joins the node to the ring
        if absent."""
        with self._mu:
            cache = self.caches.get(node_id)
            if cache is None:
                cap = (self.cache_capacity_bytes if capacity_bytes is None
                       else capacity_bytes)
                cache = WSCache(
                    cap,
                    source=lambda base, cfg, group=1, _n=node_id:
                        self._shard_fetch(_n, base, cfg, group=group))
                self.caches[node_id] = cache
            self._alive[node_id] = True
            self._ever_alive.add(node_id)
        self.ring.add(node_id)
        return cache

    def set_alive(self, node_id: str, alive: bool) -> None:
        """Mark a node up/down for the fetch path.  A down node also leaves
        the ring, so new placements/ownership exclude it (minimal remap)."""
        with self._mu:
            self._alive[node_id] = alive
            if alive:
                self._ever_alive.add(node_id)
        if alive:
            self.ring.add(node_id)
        else:
            self.ring.remove(node_id)

    def is_alive(self, node_id: str) -> bool:
        with self._mu:
            return self._alive.get(node_id, False)

    # -- ownership ------------------------------------------------------

    def set_replication(self, name: str, n: int) -> None:
        """Raise (or lower) one function's replica count — the hot-function
        knob: a popular WS served from R shards instead of one."""
        if n < 1:
            raise ValueError("replication must be >= 1")
        with self._mu:
            self._hot[name] = n

    def replication_of(self, name: str) -> int:
        with self._mu:
            return self._hot.get(name, self.replication)

    def owners(self, name: str) -> list[str]:
        """Owner shards for ``name`` in preference order (primary first)."""
        return self.ring.lookup(name, self.replication_of(name))

    # -- fetch path (per-node WSCache source hook) ----------------------

    def _shard_fetch(self, node_id: str, base: str, cfg: ReapConfig,
                     group: int = 1):
        """L1-miss resolution for ``node_id``: peek an alive peer
        replica's cache over the modeled network, else origin disk.  An
        owner consults its co-owner replicas too — a cold owner paying an
        origin read while an alive peer holds the WS wastes exactly the
        I/O this tier exists to amortize.  Runs outside any cache lock
        (the WSCache leader pattern), so the transfer sleep never blocks
        other functions' fetches; ``peek`` never blocks at all, so no
        cross-cache wait cycle can form.

        The transfer is charged at **actual-missing bytes**: the serving
        peer's chunk hashes are diffed against the requester L1's
        cross-function chunk index, and only absent chunks ship (the rest
        is ``dedup_bytes_saved``).

        ``group`` is the restore-batch size this fetch feeds (restore.py
        threads it through the node's L1): a k-instance group restore
        reaches the shard tier at most once, so the transfer cost is paid
        once per group instead of once per instance."""
        if group > 1:
            with self._mu:
                self.group_fetches += 1
                self.group_instances += group
        name = os.path.basename(base)
        owners = self.owners(name)
        is_owner = node_id in owners
        any_alive = False
        any_ever_alive = False
        for owner in owners:
            if owner == node_id:
                continue                 # own L1 already missed
            with self._mu:
                cache = self.caches.get(owner)
                up = self._alive.get(owner, False)
                ever = owner in self._ever_alive
                requester = self.caches.get(node_id)
            any_ever_alive = any_ever_alive or ever
            if cache is None or not up:
                continue
            any_alive = True
            served = cache.peek_chunks(base)
            if served is None:
                continue                 # owner is cold: try next replica
            pages, data, hashes = served
            missing = (requester.missing_chunks(hashes)
                       if requester is not None else set(hashes))
            wire_bytes = len(missing) * PAGE
            # A fully-deduped fetch ships nothing: charging the modeled
            # per-transfer latency for zero wire bytes would bill a
            # network round-trip that never happens (the chunk diff is
            # an in-memory index lookup).
            cost = self.transfer.cost_s(wire_bytes) if wire_bytes else 0.0
            self._sleep(cost)
            with self._mu:
                self.remote_fetches += 1
                self.transfer_bytes += wire_bytes
                self.dedup_bytes_saved += max(len(data) - wire_bytes, 0)
                self.transfer_s += cost
            return pages, data
        if not is_owner and owners:
            with self._mu:
                if any_alive:
                    self.remote_misses += 1      # cold owners only
                elif any_ever_alive:
                    self.dead_owner_fallbacks += 1
                else:
                    # ring entries that never came up: nothing "died", the
                    # owner tier simply has no replica yet
                    self.remote_misses += 1
        pages, data = _read_ws(base, cfg)
        with self._mu:
            self.origin_reads += 1
        return pages, data

    # -- maintenance ----------------------------------------------------

    def _push_invalidation(self, base: str) -> None:
        """Re-record/drop broadcast: eagerly drop ``base`` from every
        attached L1 so no node can serve (or remote-peek) the stale WS
        while waiting for its next mtime check.  Counted per entry
        actually dropped (``pushed_invalidations``)."""
        with self._mu:
            caches = list(self.caches.values())
        dropped = 0
        for cache in caches:
            if cache.invalidate(base):
                dropped += 1
        if dropped:
            with self._mu:
                self.pushed_invalidations += dropped

    def close(self) -> None:
        """Detach from the record-invalidation broadcast (a store used per
        benchmark arm must not keep invalidating caches it no longer
        owns).  GC of an unclosed store detaches it too (weakref.finalize
        in ``__init__``)."""
        unregister_invalidation_listener(self._listener)

    def resident(self, node_id: str, base: str) -> bool:
        """Scheduler locality probe: does ``node_id``'s L1 hold ``base``?"""
        cache = self.caches.get(node_id)
        return cache is not None and cache.contains(base)

    def warm_owners(self, base: str) -> int:
        """Pull ``base``'s WS into every alive owner's L1 (rebalance /
        post-join warm-up).  Returns the number of owner caches now
        holding it; no-op when no record exists yet."""
        if not has_record(base):
            return 0
        name = os.path.basename(base)
        warmed = 0
        cfg = self.reap                      # the fleet's configured reads
        for owner in self.owners(name):
            with self._mu:
                cache = self.caches.get(owner)
                up = self._alive.get(owner, False)
            if cache is None or not up:
                continue
            try:
                cache.fetch(base, cfg)
                warmed += 1
            except OSError:
                continue                 # record dropped mid-warm: skip
        return warmed

    def reset_stats(self) -> None:
        """Zero the store's counters and every attached cache's (cache
        *contents* survive — use each cache's ``clear`` for that)."""
        with self._mu:
            self.remote_fetches = self.remote_misses = 0
            self.origin_reads = self.dead_owner_fallbacks = 0
            self.transfer_bytes = 0
            self.dedup_bytes_saved = 0
            self.transfer_s = 0.0
            self.group_fetches = self.group_instances = 0
            self.pushed_invalidations = 0
            caches = list(self.caches.values())
        for c in caches:
            c.reset_stats()

    def stats(self) -> dict:
        with self._mu:
            out = {
                "remote_fetches": self.remote_fetches,
                "remote_misses": self.remote_misses,
                "origin_reads": self.origin_reads,
                "dead_owner_fallbacks": self.dead_owner_fallbacks,
                "transfer_bytes": self.transfer_bytes,
                "dedup_bytes_saved": self.dedup_bytes_saved,
                "transfer_s": self.transfer_s,
                "group_fetches": self.group_fetches,
                "group_instances": self.group_instances,
                "pushed_invalidations": self.pushed_invalidations,
                "alive": sorted(n for n, up in self._alive.items() if up),
            }
            caches = dict(self.caches)
        out["nodes"] = {n: c.stats() for n, c in sorted(caches.items())}
        local = sum(c["hits"] for c in out["nodes"].values())
        lookups = local + sum(c["misses"] for c in out["nodes"].values())
        out["local_hit_rate"] = local / lookups if lookups else 0.0
        return out
