"""Multi-host cluster layer: sharded snapshot store + locality scheduling.

  shardmap.py  -- consistent-hash ring (virtual nodes, replication)
  snapstore.py -- two-tier sharded WS store (local / remote shard / origin)
  node.py      -- WorkerNode: Orchestrator + Router + policy + L1 cache
  scheduler.py -- ClusterRouter: fleet admission, locality placement,
                  node-failure rerouting, ring rebalance
  demand.py    -- DemandAggregator: fleet-wide demand forecasts pushed to
                  the owner shards ahead of spillover
"""
from .demand import DemandAggregator, DemandConfig
from .node import NodeDownError, WorkerNode
from .scheduler import (ClusterInvocation, ClusterRouter, NoAliveNodeError,
                        ScheduleConfig, build_fleet)
from .shardmap import ConsistentHashRing, stable_hash
from .snapstore import ShardedSnapshotStore, TransferModel
