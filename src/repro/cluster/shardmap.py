"""Consistent-hash shard map: function name -> owner host(s).

The fleet's sharded snapshot store (snapstore.py) and the locality-aware
scheduler (scheduler.py) both need a stable answer to "which node owns
function *f*'s working set?" that

  * spreads functions evenly across hosts (virtual nodes smooth out the
    variance a bare one-point-per-host ring would have),
  * moves only ~1/N of the keyspace when a host joins or leaves (minimal
    remap — a full rehash would invalidate every node's cache residency at
    once), and
  * supports a **replication factor**: hot functions list their first R
    distinct hosts clockwise from the key's hash, so a popular WS is served
    from several shards instead of hot-spotting one.

Hashing is :mod:`hashlib`-based (blake2b), never Python's randomized
``hash()``, so the mapping is stable across processes and runs — traces,
benchmarks, and a restarted fleet all agree on ownership.
"""
from __future__ import annotations

import bisect
import hashlib
import threading


def stable_hash(key: str) -> int:
    """64-bit stable hash (process-independent, unlike built-in hash())."""
    return int.from_bytes(
        hashlib.blake2b(key.encode("utf-8"), digest_size=8).digest(), "big")


class ConsistentHashRing:
    """Ring of ``vnodes`` virtual points per host; thread-safe.

    ``lookup(key, n)`` walks clockwise from ``hash(key)`` and returns the
    first ``n`` *distinct* hosts — position 0 is the primary owner, the
    rest are replicas in preference order.
    """

    def __init__(self, nodes: tuple[str, ...] | list[str] = (), *,
                 vnodes: int = 64):
        if vnodes < 1:
            raise ValueError("vnodes must be >= 1")
        self.vnodes = vnodes
        self._mu = threading.Lock()
        self._nodes: set[str] = set()
        self._points: list[int] = []     # sorted vnode hashes
        self._owners: list[str] = []     # owner of _points[i]
        for n in nodes:
            self.add(n)

    def add(self, node_id: str) -> None:
        with self._mu:
            if node_id in self._nodes:
                return
            self._nodes.add(node_id)
            for v in range(self.vnodes):
                h = stable_hash(f"{node_id}#{v}")
                i = bisect.bisect_left(self._points, h)
                # tie-break vnode-hash collisions by node id so insertion
                # order can't change the mapping
                while (i < len(self._points) and self._points[i] == h
                       and self._owners[i] < node_id):
                    i += 1
                self._points.insert(i, h)
                self._owners.insert(i, node_id)

    def remove(self, node_id: str) -> None:
        with self._mu:
            if node_id not in self._nodes:
                return
            self._nodes.discard(node_id)
            kept = [(p, o) for p, o in zip(self._points, self._owners)
                    if o != node_id]
            self._points = [p for p, _ in kept]
            self._owners = [o for _, o in kept]

    def lookup(self, key: str, n: int = 1) -> list[str]:
        """First ``n`` distinct owners clockwise from ``hash(key)``.

        Returns fewer than ``n`` when the ring has fewer hosts; empty when
        the ring is empty.
        """
        with self._mu:
            if not self._points:
                return []
            n = min(n, len(self._nodes))
            out: list[str] = []
            seen: set[str] = set()
            start = bisect.bisect_right(self._points, stable_hash(key))
            for step in range(len(self._points)):
                owner = self._owners[(start + step) % len(self._points)]
                if owner not in seen:
                    seen.add(owner)
                    out.append(owner)
                    if len(out) >= n:
                        break
            return out

    def owner(self, key: str) -> str | None:
        """Primary owner of ``key`` (None on an empty ring)."""
        owners = self.lookup(key, 1)
        return owners[0] if owners else None

    @property
    def nodes(self) -> list[str]:
        with self._mu:
            return sorted(self._nodes)

    def __len__(self) -> int:
        with self._mu:
            return len(self._nodes)

    def __contains__(self, node_id: str) -> bool:
        with self._mu:
            return node_id in self._nodes
