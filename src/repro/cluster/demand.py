"""Fleet-wide demand plane: merge every node's arrivals, forecast, and
push warm targets to the *owner shards* ahead of spillover.

PR 2's :class:`~repro.serving.PrewarmPolicy` is per-node: each instance of
it sees only the arrivals its own router admitted.  Under a diurnal ramp
that is exactly wrong — the warm node saturates, the scheduler spills the
overflow onto other hosts, and those hosts' policies have *no history* for
the function, so every spillover placement lands cold.  The
:class:`DemandAggregator` closes the loop at the fleet level:

  1. **Merge** — each step drains a dedicated arrival tap on every alive
     node's router (``Router.open_tap``: the node's local policy keeps its
     own tap, so neither consumer starves the other) and folds the union
     into one :class:`~repro.serving.ForecastDemand` per function —
     fleet-wide rate, fleet-wide periodicity.
  2. **Forecast** — the blended model (phase-binned periodicity profile
     over EWMA, forecast.py) predicts each function's fleet arrival rate
     over the lookahead horizon, i.e. *ahead* of the ramp.
  3. **Route to owners** — the predicted rate is split across the
     function's alive owner shards (the :class:`ConsistentHashRing` lookup
     the sharded store already uses) and pushed as a hinted rate share
     (:meth:`PrewarmPolicy.push_forecast`).  Owners are where spillover
     wants to land anyway (``w_owner`` in the placement score, and their
     L1 caches hold the WS), so prewarming them turns the ramp's spillover
     placements into ``prewarmed=True`` serves.

Hints carry a TTL: a wedged aggregator can never pin warm pools.  Ring
membership changes (kill_node / rebalance / join) call :meth:`retarget`,
which drops every outstanding hint so the next step re-pushes against the
new ownership map — replicas of a dead owner start prewarming within one
control interval.
"""
from __future__ import annotations

import dataclasses
import threading
import time

from ..serving.forecast import ForecastConfig, ForecastDemand
from ..serving.policy import PolicyConfig

_NO_NODES: frozenset[str] = frozenset()

FLEET_TAP = "fleet-demand"


@dataclasses.dataclass
class DemandConfig:
    interval_s: float = 0.1          # aggregator loop period
    hint_ttl_s: float = 2.0          # pushed hints expire after this
    # The *single* safety factor on the fleet rate split (the receiving
    # policy converts the pushed rate to a warm target without adding its
    # own headroom — see PrewarmPolicy._fleet_target).
    headroom: float = 1.5
    min_push_rate: float = 0.1       # rps below which no hint is pushed
    owners_per_function: int | None = None  # None => store replication
    # demand-model knobs (window/EWMA) and the periodicity detector's
    policy: PolicyConfig | None = None
    forecast: ForecastConfig | None = None


class DemandAggregator:
    """Fleet-level control loop over a :class:`ClusterRouter`.

    Runs on a daemon thread like the per-node policy, but every decision
    is a pure function of ingested timestamps + the ring, so tests drive
    :meth:`ingest` + :meth:`step` with a fake clock.
    """

    def __init__(self, cluster, cfg: DemandConfig | None = None, *,
                 clock=time.monotonic):
        self.cluster = cluster
        self.cfg = cfg or DemandConfig()
        self.clock = clock
        pcfg = self.cfg.policy or PolicyConfig()
        self._pcfg = pcfg
        self._fcfg = self.cfg.forecast or ForecastConfig()
        self.demand: dict[str, ForecastDemand] = {}
        self.pushed: dict[str, set[str]] = {}   # function -> hinted node ids
        self.n_steps = 0
        self.n_pushes = 0
        self.n_errors = 0
        self.last_error: BaseException | None = None
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._mu = threading.RLock()

    # -- demand ingestion ----------------------------------------------

    def attach_node(self, node) -> None:
        """Open this aggregator's arrival tap on a node's router."""
        node.router.open_tap(FLEET_TAP)

    def ingest(self, arrivals: dict[str, list[float]]) -> None:
        with self._mu:
            for name, ts in arrivals.items():
                d = self.demand.get(name)
                if d is None:
                    d = self.demand[name] = ForecastDemand(
                        self._pcfg, self._fcfg, clock=self.clock)
                d.observe(ts)

    def _drain_nodes(self) -> None:
        for node in self.cluster.alive_nodes():
            self.ingest(node.router.drain_arrivals(tap=FLEET_TAP))

    # -- forecast routing ----------------------------------------------

    def _owner_nodes(self, name: str) -> list:
        """Alive owner-shard nodes for ``name`` in ring preference order
        (falls back to the whole alive fleet when the store is absent or
        every owner is dead)."""
        store = self.cluster.store
        alive = {n.node_id: n for n in self.cluster.alive_nodes()}
        if store is not None:
            n_owners = self.cfg.owners_per_function
            if n_owners is None:
                ids = store.owners(name)
            else:
                ids = store.ring.lookup(name, n_owners)
            owners = [alive[i] for i in ids if i in alive]
            if owners:
                return owners
        return list(alive.values())

    def _clear(self, name: str, keep: set[str] = _NO_NODES) -> None:
        """Withdraw ``name``'s hints from every node not in ``keep``."""
        for node_id in self.pushed.get(name, set()) - set(keep):
            node = self.cluster.nodes.get(node_id)
            if node is not None and node.alive:
                node.clear_forecast(name)
        if keep:
            self.pushed[name] = set(keep)
        else:
            self.pushed.pop(name, None)

    def step(self, now: float | None = None) -> dict[str, float]:
        """One control iteration; returns per-function fleet rates pushed."""
        with self._mu:
            return self._step_locked(now)

    def _step_locked(self, now: float | None) -> dict[str, float]:
        self._drain_nodes()
        now = self.clock() if now is None else now
        pushed_rates: dict[str, float] = {}
        forgotten: list[str] = []
        for name, d in self.demand.items():
            if d.forgettable(now):
                self._clear(name)
                forgotten.append(name)
                continue
            rate = d.rate(now) * self.cfg.headroom
            if not d.active(now) or rate < self.cfg.min_push_rate:
                self._clear(name)
                continue
            owners = self._owner_nodes(name)
            if not owners:
                self._clear(name)
                continue
            share = rate / len(owners)
            expires = now + self.cfg.hint_ttl_s
            for node in owners:
                node.push_forecast(name, share, expires)
                self.n_pushes += 1
            self._clear(name, keep={n.node_id for n in owners})
            pushed_rates[name] = rate
        for name in forgotten:
            del self.demand[name]
        self.n_steps += 1
        return pushed_rates

    def retarget(self) -> None:
        """Drop every outstanding hint (ring membership changed); the next
        step re-pushes against the current ownership map."""
        with self._mu:
            for name in list(self.pushed):
                self._clear(name)

    # -- forecast persistence -------------------------------------------

    def export_profiles(self, now: float | None = None) -> dict[str, dict]:
        """Serializable periodicity profiles for every function whose
        detector has (or inherited) a confident period — what
        ``ClusterRouter.close`` writes alongside the snapshot store."""
        with self._mu:
            now = self.clock() if now is None else now
            out = {}
            for name, d in self.demand.items():
                state = d.export_state(now)
                if state is not None:
                    out[name] = state
            return out

    def seed_profiles(self, profiles: dict[str, dict]) -> int:
        """Install persisted profiles (``build_fleet`` reload path):
        creates a pre-seeded :class:`ForecastDemand` per function so the
        next control step prewarms day-one ramps before any arrival.
        Returns how many profiles were accepted."""
        n = 0
        with self._mu:
            for name, state in profiles.items():
                d = self.demand.get(name)
                if d is None:
                    d = self.demand[name] = ForecastDemand(
                        self._pcfg, self._fcfg, clock=self.clock)
                if d.seed_state(state):
                    n += 1
        return n

    # -- lifecycle ------------------------------------------------------

    def start(self) -> "DemandAggregator":
        if self._thread is not None:
            return self
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="demand-aggregator", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    def _loop(self) -> None:
        while not self._stop.wait(self.cfg.interval_s):
            try:
                self.step()
            except Exception as e:
                # a racing node death mid-step must not kill the fleet's
                # control loop; persistent failure is observable via stats
                self.n_errors += 1
                self.last_error = e
                continue

    def stats(self) -> dict:
        with self._mu:
            now = self.clock()
            return {
                "steps": self.n_steps,
                "pushes": self.n_pushes,
                "errors": self.n_errors,
                "last_error": (repr(self.last_error)
                               if self.last_error else None),
                "functions": {n: {"rate": d.rate(now),
                                  "active": d.active(now),
                                  "period": (d.detector.detect(now) or
                                             (None,))[0]}
                              for n, d in self.demand.items()},
                "pushed": {n: sorted(ids) for n, ids in self.pushed.items()},
            }
