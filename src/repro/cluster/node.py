"""WorkerNode: one host of the simulated fleet.

A node bundles the single-host serving stack PR 1/PR 2 built — an
:class:`~repro.serving.Orchestrator` (instance pool + keepalive), a
:class:`~repro.serving.Router` (queues + worker pool + admission), and
optionally a per-node :class:`~repro.serving.PrewarmPolicy` control loop —
behind one id, one capacity figure, and one liveness flag, plus the
node-local L1 WS cache the sharded store attached (snapstore.py).

The cluster scheduler reads three signals off a node when scoring a
placement: ``warm_count(name)`` (an idle instance => zero-restore serve),
``ws_resident(name)`` (L1 WS hit => cheap cold start), and ``load()``
(queued + in-flight vs capacity).  ``kill()`` simulates host failure:
queued invocations fail fast (RouterClosedError) so the cluster layer can
reroute them — they are never left hanging — while invocations already
executing run to completion (their results are kept; the "connection"
outlives the control plane in this simulation).
"""
from __future__ import annotations

import os
import threading

from ..configs.base import ModelConfig
from ..core import ReapConfig
from ..core.reap import WSCache
from ..serving import (Orchestrator, PolicyConfig, PrewarmPolicy, Router,
                       RouterConfig)


class NodeDownError(RuntimeError):
    """The target node was killed (or closed) before accepting the work."""


class WorkerNode:
    def __init__(self, node_id: str, store_dir: str, *,
                 ws_cache: WSCache | None = None,
                 reap: ReapConfig | None = None, mode: str = "reap",
                 max_concurrency: int = 4,
                 max_instances_per_function: int = 4,
                 queue_depth: int = 256,
                 batch_restore_limit: int = 8,
                 keepalive_s: float = 60.0, warm_limit: int = 8,
                 policy: PolicyConfig | None = None):
        """``ws_cache``: this node's L1 (usually ``store.attach(node_id)``);
        ``policy``: when given, an adaptive prewarming loop runs per node.
        ``batch_restore_limit`` caps the node's group restores: a queue of
        same-function cold starts restores as one batch whose single L1
        fetch makes any remote shard fetch happen once per group too.
        """
        self.node_id = node_id
        self.ws_cache = ws_cache
        self.capacity = max_concurrency
        self.orch = Orchestrator(store_dir, reap=reap, mode=mode,
                                 keepalive_s=keepalive_s,
                                 warm_limit=warm_limit, ws_cache=ws_cache)
        self.router = Router(self.orch, RouterConfig(
            max_concurrency=max_concurrency,
            max_instances_per_function=max_instances_per_function,
            queue_depth=queue_depth,
            batch_restore_limit=batch_restore_limit))
        self.policy = (PrewarmPolicy(self.orch, self.router, policy).start()
                       if policy is not None else None)
        self._mu = threading.Lock()
        self.alive = True

    # -- control plane --------------------------------------------------

    def register(self, name: str, cfg: ModelConfig, *, seed: int = 0,
                 warmup_batch: dict | None = None):
        """Register a function on this node.  All nodes share one origin
        store_dir, so the snapshot is built by whichever node registers
        first and reused read-only by the rest."""
        return self.orch.register(name, cfg, seed=seed,
                                  warmup_batch=warmup_batch)

    def kill(self) -> None:
        """Simulated host failure.  Fails every queued invocation fast
        (their waiters see RouterClosedError and the cluster reroutes);
        in-flight invocations finish and keep their results.  The router
        dies *first* — stopping the policy loop first would join a thread
        mid-sleep and hand the workers tens of milliseconds to drain the
        queue a crash should have stranded."""
        with self._mu:
            if not self.alive:
                return
            self.alive = False
        self.router.close(drain=False)
        if self.policy is not None:
            self.policy.stop()
        self.orch.close()

    def close(self) -> None:
        """Graceful shutdown: drain accepted work, then tear down."""
        with self._mu:
            if not self.alive:
                return
            self.alive = False
        if self.policy is not None:
            self.policy.stop()
        self.router.close(drain=True)
        self.orch.close()

    # -- fleet demand plane ----------------------------------------------

    def push_forecast(self, name: str, rate_rps: float,
                      expires_at: float) -> None:
        """Accept a fleet-wide forecast rate share for ``name`` (pushed by
        the cluster DemandAggregator to owner-shard nodes).  A node built
        without a policy loop has no prewarming actuator — the hint is
        dropped, matching its purely reactive behaviour."""
        if self.policy is not None:
            self.policy.push_forecast(name, rate_rps, expires_at)

    def clear_forecast(self, name: str) -> None:
        if self.policy is not None:
            self.policy.clear_forecast(name)

    # -- data plane ------------------------------------------------------

    def submit(self, name: str, batch: dict, *, force_cold: bool = False):
        """Enqueue one invocation; raises :class:`NodeDownError` if the
        node is dead (the scheduler treats it like any placement failure
        and tries the next candidate)."""
        if not self.alive:
            raise NodeDownError(f"node {self.node_id} is down")
        return self.router.submit(name, batch, force_cold=force_cold)

    # -- scheduler signals -----------------------------------------------

    def load(self) -> int:
        """Queued + in-flight invocations on this node."""
        s = self.router.stats()
        return sum(s["queued"].values()) + sum(s["inflight"].values())

    def warm_count(self, name: str) -> int:
        """Idle warm instances of ``name`` parked on this node."""
        return self.orch.idle_count(name)

    def ws_resident(self, name: str) -> bool:
        """Is ``name``'s working set resident in this node's L1 cache?"""
        if self.ws_cache is None:
            return False
        return self.ws_cache.contains(os.path.join(self.orch.store_dir, name))

    def stats(self) -> dict:
        out = {
            "node": self.node_id,
            "alive": self.alive,
            "capacity": self.capacity,
            "load": self.load() if self.alive else 0,
            "router": self.router.stats(),
        }
        if self.ws_cache is not None:
            out["ws_cache"] = self.ws_cache.stats()
        if self.policy is not None:
            out["policy"] = self.policy.stats()
        return out

    def __repr__(self) -> str:
        return (f"WorkerNode({self.node_id!r}, alive={self.alive}, "
                f"load={self.load() if self.alive else '-'}/{self.capacity})")
