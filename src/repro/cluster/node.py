"""WorkerNode: one host of the simulated fleet.

A node bundles the single-host serving stack PR 1/PR 2 built — an
:class:`~repro.serving.Orchestrator` (instance pool + keepalive), a
:class:`~repro.serving.Router` (queues + worker pool + admission), and
optionally a per-node :class:`~repro.serving.PrewarmPolicy` control loop —
behind one id, one capacity figure, and one liveness flag, plus the
node-local L1 WS cache the sharded store attached (snapstore.py).

The cluster scheduler reads three signals off a node when scoring a
placement: ``warm_count(name)`` (an idle instance => zero-restore serve),
``ws_resident(name)`` (L1 WS hit => cheap cold start), and ``load()``
(queued + in-flight vs capacity).  ``kill()`` simulates host failure:
queued invocations fail fast (RouterClosedError) so the cluster layer can
reroute them — they are never left hanging — while invocations already
executing run to completion (their results are kept; the "connection"
outlives the control plane in this simulation).
"""
from __future__ import annotations

import dataclasses
import os
import threading
import warnings

from ..configs.base import ModelConfig
from ..core.reap import WSCache
from ..serving import (Orchestrator, PrewarmPolicy, Router, RouterConfig,
                       ServeConfig)
from ..telemetry import StatsSnapshotter

#: Node-flavoured data-plane defaults (smaller than the single-host
#: RouterConfig: a fleet host shares the machine with its peers).
NODE_ROUTER = RouterConfig(max_concurrency=4, max_instances_per_function=4,
                           queue_depth=256, batch_restore_limit=8)


class NodeDownError(RuntimeError):
    """The target node was killed (or closed) before accepting the work."""


class WorkerNode:
    def __init__(self, node_id: str, store_dir: str,
                 config: ServeConfig | None = None, *,
                 ws_cache: WSCache | None = None, **legacy):
        """``config`` (a :class:`~repro.serving.ServeConfig`) is the
        recommended construction path; its ``router`` field defaults to
        :data:`NODE_ROUTER` and its ``policy`` field enables the per-node
        adaptive prewarming loop.  ``ws_cache``: this node's L1 (usually
        ``store.attach(node_id)``).  The pre-ServeConfig loose kwargs
        (``reap``, ``mode``, ``max_concurrency``,
        ``max_instances_per_function``, ``queue_depth``,
        ``batch_restore_limit``, ``keepalive_s``, ``warm_limit``,
        ``policy``) keep working as a deprecation shim.
        """
        if legacy:
            known = {"reap", "mode", "max_concurrency",
                     "max_instances_per_function", "queue_depth",
                     "batch_restore_limit", "keepalive_s", "warm_limit",
                     "policy"}
            unknown = set(legacy) - known
            if unknown:
                raise TypeError(
                    f"WorkerNode got unexpected kwargs {sorted(unknown)}")
            warnings.warn(
                "WorkerNode(..., reap=..., max_concurrency=..., ...) loose "
                "kwargs are deprecated; pass a ServeConfig instead",
                DeprecationWarning, stacklevel=2)
            config = self._fold_legacy(config, legacy)
        if config is None:
            config = ServeConfig(overlap_install=False, router=NODE_ROUTER)
        if config.router is None:
            config = dataclasses.replace(config, router=NODE_ROUTER)
        self.node_id = node_id
        self.config = config
        self.ws_cache = ws_cache
        self.capacity = config.router.max_concurrency
        self.orch = Orchestrator(store_dir, config, ws_cache=ws_cache)
        self.router = Router(self.orch, config.router)
        self.policy = (PrewarmPolicy(self.orch, self.router,
                                     config.policy).start()
                       if config.policy is not None else None)
        # optional per-node time series (the fleet-level snapshotter in
        # build_fleet already nests every node's stats; this one is for
        # standalone nodes or per-node files)
        tcfg = config.telemetry
        self.snapshotter = None
        if tcfg is not None and getattr(tcfg, "per_node", False):
            path = (os.path.join(tcfg.out_dir, f"{node_id}.jsonl")
                    if tcfg.out_dir else None)
            self.snapshotter = StatsSnapshotter(
                interval_s=tcfg.interval_s, path=path, ring=tcfg.ring)
            self.snapshotter.add_source("node", self.stats)
            self.snapshotter.start()
        self._mu = threading.Lock()
        self.alive = True

    @staticmethod
    def _fold_legacy(config: ServeConfig | None, legacy: dict) -> ServeConfig:
        """Fold pre-ServeConfig loose kwargs into a ServeConfig (the shim
        keeps PR-5 behaviour: overlap off unless the ReapConfig opted in)."""
        if config is None:
            config = ServeConfig(overlap_install=False)
        router = config.router or NODE_ROUTER
        router = dataclasses.replace(router, **{
            k: legacy[k] for k in ("max_concurrency",
                                   "max_instances_per_function",
                                   "queue_depth", "batch_restore_limit")
            if k in legacy})
        fields = {k: legacy[k] for k in ("mode", "keepalive_s", "warm_limit",
                                         "policy") if k in legacy}
        r = legacy.get("reap")
        if r is not None:
            fields.update(reap=r, overlap_install=r.overlap_install,
                          hot_prefix_frac=r.hot_prefix_frac,
                          tail_workers=r.tail_workers,
                          tail_deadline_s=r.tail_deadline_s)
        return dataclasses.replace(config, router=router, **fields)

    # -- control plane --------------------------------------------------

    def register(self, name: str, cfg: ModelConfig, *, seed: int = 0,
                 warmup_batch: dict | None = None):
        """Register a function on this node.  All nodes share one origin
        store_dir, so the snapshot is built by whichever node registers
        first and reused read-only by the rest."""
        return self.orch.register(name, cfg, seed=seed,
                                  warmup_batch=warmup_batch)

    def kill(self) -> None:
        """Simulated host failure.  Fails every queued invocation fast
        (their waiters see RouterClosedError and the cluster reroutes);
        in-flight invocations finish and keep their results.  The router
        dies *first* — stopping the policy loop first would join a thread
        mid-sleep and hand the workers tens of milliseconds to drain the
        queue a crash should have stranded."""
        with self._mu:
            if not self.alive:
                return
            self.alive = False
        if self.snapshotter is not None:
            self.snapshotter.stop()   # crash: no final sample, no drain
        self.router.close(drain=False)
        if self.policy is not None:
            self.policy.stop()
        self.orch.close()

    def close(self) -> None:
        """Graceful shutdown: drain accepted work, then tear down."""
        with self._mu:
            if not self.alive:
                return
            self.alive = False
        if self.policy is not None:
            self.policy.stop()
        self.router.close(drain=True)
        if self.snapshotter is not None:
            self.snapshotter.close()  # final sample while stats still live
        self.orch.close()

    # -- fleet demand plane ----------------------------------------------

    def push_forecast(self, name: str, rate_rps: float,
                      expires_at: float) -> None:
        """Accept a fleet-wide forecast rate share for ``name`` (pushed by
        the cluster DemandAggregator to owner-shard nodes).  A node built
        without a policy loop has no prewarming actuator — the hint is
        dropped, matching its purely reactive behaviour."""
        if self.policy is not None:
            self.policy.push_forecast(name, rate_rps, expires_at)

    def clear_forecast(self, name: str) -> None:
        if self.policy is not None:
            self.policy.clear_forecast(name)

    # -- data plane ------------------------------------------------------

    def submit(self, name: str, batch: dict, *, force_cold: bool = False):
        """Enqueue one invocation; raises :class:`NodeDownError` if the
        node is dead (the scheduler treats it like any placement failure
        and tries the next candidate)."""
        if not self.alive:
            raise NodeDownError(f"node {self.node_id} is down")
        return self.router.submit(name, batch, force_cold=force_cold)

    # -- scheduler signals -----------------------------------------------

    def load(self) -> int:
        """Queued + in-flight invocations on this node."""
        s = self.router.stats()
        return sum(s["queued"].values()) + sum(s["inflight"].values())

    def warm_count(self, name: str) -> int:
        """Idle warm instances of ``name`` parked on this node."""
        return self.orch.idle_count(name)

    def ws_resident(self, name: str) -> bool:
        """Is ``name``'s working set resident in this node's L1 cache?"""
        if self.ws_cache is None:
            return False
        return self.ws_cache.contains(os.path.join(self.orch.store_dir, name))

    def stats(self) -> dict:
        out = {
            "node": self.node_id,
            "alive": self.alive,
            "capacity": self.capacity,
            "load": self.load() if self.alive else 0,
            "warm_instances": self.orch.warm_counts(),
            "router": self.router.stats(),
        }
        out["stage_seconds"] = self.orch.stage_seconds()
        out["tails"] = self.orch.tail_stats()
        if self.ws_cache is not None:
            out["ws_cache"] = self.ws_cache.stats()
        if self.policy is not None:
            out["policy"] = self.policy.stats()
        return out

    def __repr__(self) -> str:
        return (f"WorkerNode({self.node_id!r}, alive={self.alive}, "
                f"load={self.load() if self.alive else '-'}/{self.capacity})")
