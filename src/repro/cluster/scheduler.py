"""ClusterRouter: fleet-wide admission + locality-aware placement.

The single-host :class:`~repro.serving.Router` dispatches onto a worker
pool; this layer sits above a fleet of :class:`~repro.cluster.WorkerNode`s
and decides *which host* serves each invocation.  Placement scores
locality against load:

  * ``w_warm``  — an idle warm instance of the function (zero restore cost)
  * ``w_ws``    — the function's working set resident in the node's L1
    cache (cold start avoids both the origin read and the shard transfer)
  * ``w_owner`` — the node is an owner shard for the function (its origin
    reads double as shard-tier population, and it likely keeps the WS hot)
  * ``w_load``  — penalty proportional to (queued + in-flight) / capacity

``placement="random"`` is the ablation arm benchmarks compare against.

Failure handling: every accepted invocation is a :class:`ClusterInvocation`
future that outlives its placement.  When a node is killed its queued
invocations fail fast with ``RouterClosedError``; the cluster reroutes them
to surviving nodes — proactively at :meth:`ClusterRouter.kill_node` time
and again lazily in ``result()`` for any raced stragglers — so no waiter
ever hangs on a dead host.  Admission is fleet-wide: a node whose queue is
full simply loses the placement to the next-ranked node, and
``AdmissionError`` surfaces only when *every* alive node refuses.

When ring membership changes (join/leave/kill), :meth:`rebalance` pulls
each function's WS into its (possibly new) owner shards' caches, so the
shard tier is warm before traffic hits the new mapping.
"""
from __future__ import annotations

import dataclasses
import json
import os
import random
import threading
import time

from ..configs.base import ModelConfig
from ..serving import AdmissionError, RouterClosedError
from ..telemetry import TELEMETRY, StatsSnapshotter
from .demand import DemandAggregator, DemandConfig
from .node import NodeDownError, WorkerNode
from .snapstore import ShardedSnapshotStore


class NoAliveNodeError(RuntimeError):
    """Every node in the fleet is dead; nothing can place the invocation."""


@dataclasses.dataclass
class ScheduleConfig:
    placement: str = "locality"      # "locality" | "random"
    w_warm: float = 4.0              # idle warm instance available
    w_ws: float = 2.0                # WS resident in node L1 cache
    w_owner: float = 1.0             # node is an owner shard
    w_load: float = 3.0              # x utilization (load / capacity)
    max_reroutes: int = 3            # per-invocation node-failure retries
    seed: int = 0                    # random-placement RNG seed


class ClusterInvocation:
    """Future for one fleet-admitted invocation; survives node failure by
    rebinding to a replacement placement (`node_ids` records the path)."""

    def __init__(self, cluster: "ClusterRouter", name: str, batch: dict,
                 force_cold: bool):
        self._cluster = cluster
        self.name = name
        self.batch = batch
        self.force_cold = force_cold
        self._mu = threading.Lock()
        self._inv = None                   # current serving.Invocation
        self._terminal: BaseException | None = None
        self.node_ids: list[str] = []      # placement history
        self.reroutes = 0

    def _bind_locked(self, node_id: str, inv) -> None:
        self._inv = inv
        self.node_ids.append(node_id)

    @property
    def node_id(self) -> str | None:
        with self._mu:
            return self.node_ids[-1] if self.node_ids else None

    def done(self) -> bool:
        """True once the invocation has truly finished.  A placement that
        failed with a *rerouteable* error (its node died) is not done —
        ``result()`` will rebind and re-execute it on a survivor."""
        with self._mu:
            if self._terminal is not None:
                return True
            inv = self._inv
        if inv is None or not inv.done():
            return False
        return not isinstance(inv._error, (RouterClosedError, NodeDownError))

    def result(self, timeout: float | None = None):
        """Block for (output, report).  A placement that died reroutes
        transparently; raises only terminal errors (admission exhaustion,
        reroute budget, a real invocation failure, or timeout)."""
        clock = self._cluster.clock
        deadline = None if timeout is None else clock() + timeout
        while True:
            with self._mu:
                if self._terminal is not None:
                    err = self._terminal
                else:
                    err = None
                inv = self._inv
            if err is not None:
                self._cluster._forget(self)
                raise err
            left = (None if deadline is None
                    else max(deadline - clock(), 0.0))
            try:
                out = inv.result(left)
            except (RouterClosedError, NodeDownError):
                # the placement died under us; rebind (idempotent vs the
                # proactive reroute in kill_node) and wait again
                self._cluster._reroute(self, inv)
                continue
            except TimeoutError:
                raise                      # still pending: stay registered
            except BaseException:
                self._cluster._forget(self)
                raise                      # terminal: unregister, propagate
            self._cluster._forget(self)
            return out

    @property
    def report(self):
        return self.result()[1]


class ClusterRouter:
    """Admits invocations fleet-wide and places them on worker nodes."""

    def __init__(self, nodes: list[WorkerNode] | tuple[WorkerNode, ...] = (),
                 *, store: ShardedSnapshotStore | None = None,
                 cfg: ScheduleConfig | None = None,
                 demand: DemandConfig | None = None,
                 clock=time.perf_counter):
        """``demand``: when given, a fleet-wide :class:`DemandAggregator`
        runs (demand.py) — every node's arrivals merge into per-function
        forecasts pushed to the owner-shard nodes' prewarm policies.
        ``clock`` times result/drain deadlines (injectable for tests)."""
        self.cfg = cfg or ScheduleConfig()
        self.clock = clock
        if self.cfg.placement not in ("locality", "random"):
            raise ValueError(f"unknown placement {self.cfg.placement!r}")
        self.store = store
        self.nodes: dict[str, WorkerNode] = {}
        self._functions: dict[str, tuple[ModelConfig, int]] = {}
        self._pending: dict[str, set[ClusterInvocation]] = {}
        self._mu = threading.Lock()
        self._rng = random.Random(self.cfg.seed)
        self.n_placed = 0
        self.n_rerouted = 0
        self.n_rejected = 0
        self.placements: dict[str, int] = {}
        self.demand_plane = (DemandAggregator(self, demand)
                             if demand is not None else None)
        #: fleet-level StatsSnapshotter (wired by build_fleet when the
        #: ServeConfig carries a TelemetryConfig); closed first in close()
        self.telemetry = None
        for n in nodes:
            self.add_node(n, rebalance=False)
        if self.demand_plane is not None:
            self.demand_plane.start()

    # -- membership -----------------------------------------------------

    def add_node(self, node: WorkerNode, *, rebalance: bool = True) -> None:
        """Join a node: attach its L1 cache to the store (wiring it into
        the node if it was built without one — a joined-but-unattached
        owner would silently degrade the shard tier), register the known
        function set on it, and optionally warm the new ring mapping."""
        if self.store is not None:
            cache = self.store.attach(node.node_id)  # alive + on the ring
            if node.ws_cache is None:
                node.ws_cache = cache
                node.orch.ws_cache = cache
            elif node.ws_cache is not cache:
                raise ValueError(
                    f"{node.node_id}: node was built with a ws_cache that "
                    f"is not the store's attached cache for it")
        with self._mu:
            self.nodes[node.node_id] = node
            self._pending.setdefault(node.node_id, set())
            self.placements.setdefault(node.node_id, 0)
            functions = list(self._functions.items())
        if self.demand_plane is not None:
            self.demand_plane.attach_node(node)
        for name, (cfg, seed) in functions:
            node.register(name, cfg, seed=seed)
        if rebalance:
            self.rebalance()

    def kill_node(self, node_id: str) -> int:
        """Simulated host failure: drop the node from the ring, fail its
        queue, and proactively reroute every queued invocation onto
        survivors.  Returns the number rerouted here (stragglers that race
        this pass reroute lazily in ``result()``)."""
        node = self.nodes[node_id]
        if self.store is not None:
            self.store.set_alive(node_id, False)
        node.kill()                        # queued invocations now failed
        if self.demand_plane is not None:
            # ownership moved: drop stale hints so the victim's replicas
            # start prewarming on the next aggregator step
            self.demand_plane.retarget()
        with self._mu:
            pending = list(self._pending.pop(node_id, ()))
            self._pending[node_id] = set()
        rerouted = 0
        for cinv in pending:
            with cinv._mu:
                inv = cinv._inv
            if inv is None or not inv.done():
                continue                   # in-flight: will finish normally
            try:
                inv.result(0)
            except (RouterClosedError, NodeDownError):
                if self._reroute(cinv, inv):
                    rerouted += 1
            except BaseException:
                pass                       # real failure/timeout: the waiter's
        return rerouted

    def alive_nodes(self) -> list[WorkerNode]:
        with self._mu:
            return [n for n in self.nodes.values() if n.alive]

    # -- control plane ---------------------------------------------------

    def register(self, name: str, cfg: ModelConfig, *, seed: int = 0,
                 warmup_batch: dict | None = None,
                 replication: int | None = None) -> None:
        """Register a function fleet-wide.  The snapshot builds once in the
        shared origin store (first node wins); the deploy-time executable
        warm-up runs once (the jit cache is process-wide).  ``replication``
        raises the function's owner-shard count (hot functions)."""
        with self._mu:
            self._functions[name] = (cfg, seed)
            nodes = list(self.nodes.values())
        if replication is not None and self.store is not None:
            self.store.set_replication(name, replication)
        for i, node in enumerate(nodes):
            node.register(name, cfg, seed=seed,
                          warmup_batch=warmup_batch if i == 0 else None)

    def rebalance(self) -> dict[str, int]:
        """Warm each function's WS into its current owner shards' caches —
        run after ring membership changes so the shard tier serves the new
        mapping immediately.  Returns per-function owner caches warmed."""
        if self.demand_plane is not None:
            self.demand_plane.retarget()   # hints follow the new ring
        if self.store is None:
            return {}
        with self._mu:
            names = list(self._functions)
            store_dirs = {n.orch.store_dir for n in self.nodes.values()}
        warmed = {}
        for name in names:
            warmed[name] = sum(
                self.store.warm_owners(os.path.join(d, name))
                for d in store_dirs)
        return warmed

    def drain(self, timeout: float | None = None) -> None:
        deadline = None if timeout is None else self.clock() + timeout
        for node in self.alive_nodes():
            left = (None if deadline is None
                    else max(deadline - self.clock(), 0.001))
            node.router.drain(left)

    def close(self) -> None:
        if self.telemetry is not None:
            self.telemetry.close()       # final sample while nodes are live
        self._save_forecasts()           # persist before the plane stops
        if self.demand_plane is not None:
            self.demand_plane.stop()
        for node in self.alive_nodes():
            node.close()
        if self.store is not None:
            self.store.close()           # detach the invalidation broadcast

    FORECAST_STATE = "forecast_profiles.json"

    def _save_forecasts(self) -> None:
        """Serialize every confident periodicity profile alongside the
        snapshot store so the next fleet build prewarms day-one ramps
        (:func:`build_fleet` reloads the file into its demand plane)."""
        if self.demand_plane is None:
            return
        profiles = self.demand_plane.export_profiles()
        if not profiles:
            return
        dirs = {n.orch.store_dir for n in self.nodes.values()}
        payload = json.dumps({"version": 1, "profiles": profiles},
                             sort_keys=True)
        for d in sorted(dirs):
            try:
                with open(os.path.join(d, self.FORECAST_STATE), "w",
                          encoding="utf-8") as fh:
                    fh.write(payload)
            except OSError:
                continue                 # store dir gone: nothing to persist

    def __enter__(self) -> "ClusterRouter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- placement -------------------------------------------------------

    def score(self, node: WorkerNode, name: str, load: int | None = None,
              owners: set[str] | None = None) -> float:
        """Placement score; ``load``/``owners`` accept precomputed values
        so the submit hot path pays one router-stats pass per node and one
        ring lookup per placement instead of per (node, placement)."""
        c = self.cfg
        load = node.load() if load is None else load
        if owners is None:
            owners = (set(self.store.owners(name))
                      if self.store is not None else set())
        s = 0.0
        if node.warm_count(name) > 0:
            s += c.w_warm
        if node.ws_resident(name):
            s += c.w_ws
        if node.node_id in owners:
            s += c.w_owner
        return s - c.w_load * load / max(node.capacity, 1)

    def rank(self, name: str) -> list[WorkerNode]:
        """Alive nodes in placement-preference order."""
        alive = self.alive_nodes()
        if not alive:
            return []
        if self.cfg.placement == "random":
            with self._mu:
                return self._rng.sample(alive, len(alive))
        # deterministic locality order: score desc, then least loaded,
        # then node id (stable across equal-score fresh fleets)
        owners = (set(self.store.owners(name))
                  if self.store is not None else set())
        scored = []
        for n in alive:
            load = n.load()
            scored.append((-self.score(n, name, load, owners), load,
                           n.node_id, n))
        scored.sort(key=lambda t: (t[0], t[1], t[2]))
        return [t[3] for t in scored]

    def _submit_once(self, name: str, batch: dict, force_cold: bool):
        """Place on the best node that accepts; falls through ranked
        candidates on full queues and dead nodes.

        Exhaustion surfaces as exactly two errors: AdmissionError when at
        least one alive node refused on a full queue (a throttle, which
        load generators record as a rejection), else NoAliveNodeError
        (every candidate was dead or died racing us) — a raced node's
        NodeDownError/RouterClosedError never leaks to the caller as if
        it were this submit's failure.
        """
        admission: AdmissionError | None = None
        for node in self.rank(name):
            try:
                inv = node.submit(name, batch, force_cold=force_cold)
            except AdmissionError as e:
                admission = e
                continue
            except (NodeDownError, RouterClosedError):
                continue                   # died racing us: next candidate
            with self._mu:
                self.n_placed += 1
                self.placements[node.node_id] = (
                    self.placements.get(node.node_id, 0) + 1)
            return node, inv
        if admission is not None:
            with self._mu:
                self.n_rejected += 1
            raise admission
        raise NoAliveNodeError("no alive nodes in the fleet")

    # -- client API -------------------------------------------------------

    def submit(self, name: str, batch: dict, *,
               force_cold: bool = False) -> ClusterInvocation:
        """Admit one invocation fleet-wide; returns its future.  Raises
        AdmissionError only when every alive node's queue is full."""
        cinv = ClusterInvocation(self, name, batch, force_cold)
        node, inv = self._submit_once(name, batch, force_cold)
        with cinv._mu:
            cinv._bind_locked(node.node_id, inv)
        with self._mu:
            self._pending.setdefault(node.node_id, set()).add(cinv)
        return cinv

    def invoke(self, name: str, batch: dict, *, force_cold: bool = False,
               timeout: float | None = None):
        return self.submit(name, batch, force_cold=force_cold).result(timeout)

    def map(self, items: list[tuple[str, dict]], *,
            force_cold: bool = False) -> list:
        invs = [self.submit(n, b, force_cold=force_cold) for n, b in items]
        return [inv.result() for inv in invs]

    # -- failure handling -------------------------------------------------

    def _reroute(self, cinv: ClusterInvocation, failed_inv) -> bool:
        """Rebind ``cinv`` after its placement died; True when this call
        actually rebound it.  Idempotent: the kill-time proactive pass and
        a concurrent ``result()`` waiter may both observe the same failed
        placement; only one rebinds."""
        with cinv._mu:
            if cinv._terminal is not None or cinv._inv is not failed_inv:
                return False               # someone else already rebound it
            cinv.reroutes += 1
            if cinv.reroutes > self.cfg.max_reroutes:
                cinv._terminal = NoAliveNodeError(
                    f"{cinv.name}: reroute budget exhausted "
                    f"(tried {cinv.node_ids})")
                return False
            old = cinv.node_ids[-1] if cinv.node_ids else None
            try:
                node, inv = self._submit_once(cinv.name, cinv.batch,
                                              cinv.force_cold)
            except BaseException as e:
                cinv._terminal = e
                return False
            cinv._bind_locked(node.node_id, inv)
        with self._mu:
            self.n_rerouted += 1
            if old is not None:
                self._pending.get(old, set()).discard(cinv)
            self._pending.setdefault(node.node_id, set()).add(cinv)
        return True

    def _forget(self, cinv: ClusterInvocation) -> None:
        """Drop a resolved invocation from the pending registry."""
        node_id = cinv.node_id
        if node_id is None:
            return
        with self._mu:
            self._pending.get(node_id, set()).discard(cinv)

    # -- observability ----------------------------------------------------

    def reset_stats(self) -> None:
        """Zero placement/reroute counters (and the store's, if any) —
        benchmark arms reset between replays without touching state."""
        with self._mu:
            self.n_placed = self.n_rerouted = self.n_rejected = 0
            self.placements = {n: 0 for n in self.nodes}
        if self.store is not None:
            self.store.reset_stats()

    def stats(self) -> dict:
        with self._mu:
            out = {
                "placement": self.cfg.placement,
                "placed": self.n_placed,
                "rerouted": self.n_rerouted,
                "rejected": self.n_rejected,
                "placements": dict(self.placements),
                "pending": {n: len(s) for n, s in self._pending.items() if s},
            }
            nodes = list(self.nodes.values())
        out["nodes"] = {n.node_id: n.stats() for n in nodes}
        if self.store is not None:
            out["store"] = self.store.stats()
        if self.demand_plane is not None:
            out["demand"] = self.demand_plane.stats()
        return out


def build_fleet(n_nodes: int, store_dir: str, *,
                config=None,
                cfg: ScheduleConfig | None = None,
                demand: DemandConfig | None = None,
                replication: int = 1, vnodes: int = 64,
                transfer=None, cache_capacity_bytes: int = 256 << 20,
                transport: str | None = None,
                **node_kw):
    """Assemble ring + sharded store + N worker nodes into a ClusterRouter.

    ``config`` (a :class:`~repro.serving.ServeConfig`) is the recommended
    construction path: it configures every node's serving stack and its
    ``demand``/``transfer`` fields supply the fleet demand plane and shard
    network model unless overridden by the explicit kwargs.  ``node_kw``
    is the pre-ServeConfig per-node kwarg form (concurrency, keepalive,
    per-node policy, ...), kept working via WorkerNode's deprecation shim.
    Nodes share ``store_dir`` as the origin snapshot store.

    ``transport`` (defaults to ``config.transport``, else ``"inproc"``):
    ``"inproc"`` builds this thread-fleet ClusterRouter with the modeled
    :class:`~repro.cluster.snapstore.TransferModel` network;
    ``"socket"`` builds a :class:`~repro.transport.procnode.ProcessFleet`
    — one child process per node, WS chunks moving over Unix-domain
    sockets / shared memory (repro.transport) — speaking the same
    scheduling interface, so the two fleets A/B on identical traces.
    """
    if transport is None:
        transport = getattr(config, "transport", None) or "inproc"
    if transport == "socket":
        if node_kw:
            raise TypeError(
                "transport='socket' takes configuration via ServeConfig, "
                f"not loose node kwargs {sorted(node_kw)}")
        from ..transport.procnode import build_process_fleet
        return build_process_fleet(
            n_nodes, store_dir, config=config, cfg=cfg,
            replication=replication, vnodes=vnodes,
            cache_capacity_bytes=cache_capacity_bytes)
    if transport != "inproc":
        raise ValueError(f"unknown transport {transport!r}")
    from .shardmap import ConsistentHashRing
    ring = ConsistentHashRing(vnodes=vnodes)
    if config is not None:
        demand = demand if demand is not None else config.demand
        transfer = transfer if transfer is not None else config.transfer
        reap = config.resolved_reap()
    else:
        reap = node_kw.get("reap")
    store = ShardedSnapshotStore(ring, transfer=transfer,
                                 replication=replication,
                                 cache_capacity_bytes=cache_capacity_bytes,
                                 reap=reap)
    nodes = [WorkerNode(f"node-{i}", store_dir, config,
                        ws_cache=store.attach(f"node-{i}"), **node_kw)
             for i in range(n_nodes)]
    cluster = ClusterRouter(nodes, store=store, cfg=cfg, demand=demand)
    # restart path: reload persisted periodicity profiles so the demand
    # plane prewarms known ramps before re-learning them from arrivals
    if cluster.demand_plane is not None:
        state = os.path.join(store_dir, ClusterRouter.FORECAST_STATE)
        try:
            with open(state, encoding="utf-8") as fh:
                payload = json.load(fh)
            cluster.demand_plane.seed_profiles(payload.get("profiles", {}))
        except (OSError, ValueError):
            pass                         # no prior state (or unreadable)
    # fleet-level time series: one snapshotter over the nested cluster
    # stats (per-node warm counts / cache tiers / stage breakdowns / demand
    # forecasts) plus the process registry's counters and histograms
    tcfg = config.telemetry if config is not None else None
    if tcfg is not None:
        path = (os.path.join(tcfg.out_dir, "fleet.jsonl")
                if tcfg.out_dir else None)
        snap = StatsSnapshotter(interval_s=tcfg.interval_s, path=path,
                                ring=tcfg.ring)
        snap.add_source("cluster", cluster.stats)
        snap.add_source("registry", TELEMETRY.collect)
        cluster.telemetry = snap.start()
    return cluster
