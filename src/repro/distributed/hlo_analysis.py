"""Compiled-HLO analysis: loop-corrected FLOPs / bytes / collective traffic.

``compiled.cost_analysis()`` on the CPU backend counts ``while`` bodies
ONCE, so a scanned 80-layer model reports ~1/80th of its FLOPs and none of
its per-layer collectives x trip count.  We therefore parse the optimized
(post-SPMD) HLO text ourselves and walk the computation call graph:

  * every ``while`` carries ``backend_config={"known_trip_count":{"n": K}}``
    -- its body's costs are multiplied by K (nested loops multiply),
  * ``dot`` FLOPs = 2 x |result| x prod(contracting dims)  (the MXU term),
  * collective bytes = operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (async -start counted
    once),
  * HBM-byte proxy = bytes written by every buffer-producing op (dots,
    fusions, reduces, copies, ...), x2 for the read side -- a documented
    approximation (EXPERIMENTS.md §Roofline).

All numbers are PER DEVICE (the HLO is the per-device SPMD program).
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_TYPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply|condition)=%([\w.\-]+)")

_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "partition-id", "replica-id"}


def _shape_dims(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _type_bytes(text: str) -> int:
    """Total bytes of all array types mentioned in `text` (handles tuples)."""
    total = 0
    for m in _TYPE_RE.finditer(text):
        total += _shape_dims(m.group(2)) * _DTYPE_BYTES[m.group(1)]
    return total


def analyze_hlo(text: str) -> dict:
    """Loop-corrected per-device totals from optimized HLO text."""
    # ---- pass 1: per-computation symbol dims ----
    comp_syms: dict[str, dict[str, tuple[str, str]]] = {}
    cur_name = None
    for line in text.splitlines():
        mh = _COMP_RE.match(line)
        if mh and "=" not in line.split("(")[0]:
            cur_name = mh.group(2)
            comp_syms[cur_name] = {}
            continue
        if cur_name is None:
            continue
        md = _DEF_RE.match(line)
        if md:
            mt = _TYPE_RE.search(md.group(2))
            if mt:
                comp_syms[cur_name][md.group(1)] = (mt.group(1), mt.group(2))

    # parameters: "%p = f32[..] parameter(0)" matched above; also tuple types
    # are skipped by taking the first array type (sufficient for dot/coll).

    # ---- pass 2: per-computation costs ----
    comps: dict[str, dict] = {}
    entry = None
    cur = None
    for line in text.splitlines():
        mh = _COMP_RE.match(line)
        if mh and "=" not in line.split("(")[0]:
            cur_name = mh.group(2)
            cur = {"flops": 0.0, "write": 0.0,
                   "coll": {c: [0.0, 0] for c in _COLLECTIVES},
                   "whiles": [], "calls": []}
            comps[cur_name] = cur
            if mh.group(1):
                entry = cur_name
            continue
        if cur is None:
            continue
        md = _DEF_RE.match(line)
        if not md:
            continue
        name, rtype, op = md.group(1), md.group(2), md.group(3)
        rbytes = _type_bytes(rtype)

        if op == "while":
            mt = _TRIP_RE.search(line)
            mb = _BODY_RE.search(line)
            if mb:
                cur["whiles"].append((mb.group(1), int(mt.group(1)) if mt else 1))
            continue

        base = op[:-6] if op.endswith("-start") else op
        if base in _COLLECTIVES:
            if op.endswith("-done"):
                continue
            paren = line[line.index(op + "(") + len(op) + 1:]
            paren = paren.split("), ")[0]
            syms = comp_syms.get(cur_name, {})
            ob = 0
            for o in _OPERAND_RE.findall(paren):
                if o in syms:
                    dt, dims = syms[o]
                    ob += _shape_dims(dims) * _DTYPE_BYTES[dt]
            if ob == 0:
                ob = _type_bytes(paren)
            cur["coll"][base][0] += ob
            cur["coll"][base][1] += 1
            cur["write"] += rbytes
            continue

        for mc in _CALL_RE.finditer(line):
            cur["calls"].append(mc.group(1))

        if op in ("dynamic-update-slice", "scatter", "select-and-scatter"):
            # HBM traffic is the updated slice, not the whole buffer: count
            # the smallest operand (the update) instead of the result
            paren = line[line.index(op + "(") + len(op) + 1:]
            syms = comp_syms.get(cur_name, {})
            sizes = []
            for o in _OPERAND_RE.findall(paren.split(")")[0]):
                if o in syms:
                    dt, dims = syms[o]
                    sizes.append(_shape_dims(dims) * _DTYPE_BYTES[dt])
            upd = min(sizes) if sizes else rbytes
            cur["write"] += min(upd * 2, rbytes)  # update write + read-mod
            continue

        if op == "dot":
            mres = _TYPE_RE.search(rtype)
            res_elems = _shape_dims(mres.group(2)) if mres else 0
            mlhs = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
            paren = line[line.index("dot(") + 4:]
            opnames = _OPERAND_RE.findall(paren.split(")")[0])
            contract = 1
            syms = comp_syms.get(cur_name, {})
            if mlhs and opnames and opnames[0] in syms:
                dims = syms[opnames[0]][1]
                dl = [int(d) for d in dims.split(",")] if dims else []
                for ci in (mlhs.group(1).split(",") if mlhs.group(1) else []):
                    idx = int(ci)
                    if idx < len(dl):
                        contract *= dl[idx]
            cur["flops"] += 2.0 * res_elems * contract

        if op not in _SKIP_OPS:
            cur["write"] += rbytes

    # ---- pass 3: weighted walk from entry ----
    memo: dict[str, tuple] = {}

    def walk(name: str) -> tuple:
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None:
            return (0.0, 0.0, {k: [0.0, 0] for k in _COLLECTIVES})
        memo[name] = (0.0, 0.0, {k: [0.0, 0] for k in _COLLECTIVES})  # cycle guard
        fl, wr = c["flops"], c["write"]
        coll = {k: list(v) for k, v in c["coll"].items()}
        for callee in c["calls"]:
            cf, cw, cc = walk(callee)
            fl += cf
            wr += cw
            for k in coll:
                coll[k][0] += cc[k][0]
                coll[k][1] += cc[k][1]
        for body, trip in c["whiles"]:
            cf, cw, cc = walk(body)
            fl += cf * trip
            wr += cw * trip
            for k in coll:
                coll[k][0] += cc[k][0] * trip
                coll[k][1] += cc[k][1] * trip
        memo[name] = (fl, wr, coll)
        return memo[name]

    fl, wr, coll = walk(entry) if entry else (0.0, 0.0, {})
    return {
        "dot_flops_per_device": fl,
        "hbm_bytes_per_device": 2.0 * wr,  # write + read proxy
        "collective_bytes_per_device": {k: v[0] for k, v in coll.items()},
        "collective_count": {k: v[1] for k, v in coll.items()},
        "entry": entry,
    }


# --- TPU v5e hardware model (per brief) ------------------------------------
PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link


@dataclasses.dataclass
class Roofline:
    """Three-term roofline for one compiled step.

    flops / hbm_bytes / coll_bytes are PER-DEVICE (from analyze_hlo), so the
    terms are per-chip seconds directly.
    """
    flops: float
    hbm_bytes: float
    coll_bytes: float
    n_chips: int
    model_flops: float = 0.0   # global (all chips)

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        per_dev_model = self.model_flops / self.n_chips
        return per_dev_model / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """model-FLOPs utilization implied by the dominant term (an MFU
        upper bound: ideal_time(model_flops) / roofline_step_time)."""
        if not self.model_flops or not self.step_s:
            return 0.0
        ideal = self.model_flops / (self.n_chips * PEAK_FLOPS)
        return ideal / self.step_s

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "coll_bytes_per_device": self.coll_bytes,
            "n_chips": self.n_chips, "model_flops": self.model_flops,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck, "step_s": self.step_s,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(cfg, shape, n_params_total: int, n_params_active: int) -> float:
    """6·N·D (train) / 2·N·D (inference) with MoE active-param counting."""
    n = n_params_active or n_params_total
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch


# Backwards-compatible simple interface used by tests
@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: dict[str, int]
    count_by_op: dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    r = analyze_hlo(hlo_text)
    return CollectiveStats(
        {k: int(v) for k, v in r["collective_bytes_per_device"].items()},
        dict(r["collective_count"]))
