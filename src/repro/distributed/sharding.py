"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Parameters carry logical axis names in their TensorSpec; these rules decide
the physical layout:

  * TP axes   ("heads", "kv_heads", "mlp", "vocab", "expert", "state")
              -> "model"
  * FSDP axis ("embed" on weight matrices) -> ("pod", "data") -- every weight
              is additionally sharded across the data-parallel axes so that
              400B-param archs fit 16 GB/chip HBM; XLA all-gathers per layer
              inside the scan (ZeRO-3 semantics).
  * batch     -> ("pod", "data") when divisible, else replicated (the
              long_500k batch=1 cell).
"""
from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_size(mesh: Mesh) -> int:
    return math.prod(mesh.shape[a] for a in data_axes(mesh))


def batch_axes(mesh: Mesh, batch: int) -> tuple[str, ...] | None:
    """Largest prefix of (pod, data) whose size divides ``batch``."""
    axes = data_axes(mesh)
    while axes:
        if batch % math.prod(mesh.shape[a] for a in axes) == 0:
            return axes
        axes = axes[1:]
    return None


def make_rules(mesh: Mesh, *, batch: int | None = None,
               fsdp: bool = True, tp: bool = True) -> dict[str, Any]:
    model = "model" if (tp and "model" in mesh.axis_names) else None
    b_axes = batch_axes(mesh, batch) if batch is not None else data_axes(mesh)
    rules: dict[str, Any] = {
        "heads": model,
        "kv_heads": model,
        "mlp": model,
        "vocab": model,
        "expert": model,
        "seq": model,       # KV-cache sequence sharding (decode/prefill)
        "state": None,
        "head_dim": None,
        "layers": None,
        "embed": data_axes(mesh) if fsdp else None,
        "batch": b_axes,
    }
    return rules


# --- activation sharding constraints ---------------------------------------
#
# XLA's sharding propagation alone replicates activations once FSDP weight
# shardings conflict with batch sharding (both want the "data" axis).  Like
# MaxText, we pin activations explicitly.  The launcher installs the rules
# (mesh + axis map) before tracing; when unset (smoke tests, 1 device) every
# constraint is a no-op, keeping models mesh-agnostic.

_ACT: dict | None = None


def set_activation_rules(mesh: Mesh | None, batch: int | None = None) -> None:
    global _ACT
    if mesh is None:
        _ACT = None
        return
    b_axes = batch_axes(mesh, batch) if batch is not None else data_axes(mesh)
    _ACT = {"mesh": mesh, "batch": b_axes,
            "model": "model" if "model" in mesh.axis_names else None}


def _apply(x, entries):
    if _ACT is None:
        return x
    spec = PartitionSpec(*entries)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_ACT["mesh"], spec))


def act_batch(x):
    """Shard dim0 by the data axes, replicate the rest (B, S, d) etc."""
    if _ACT is None or _ACT["batch"] is None:
        return x
    b = _ACT["batch"]
    return _apply(x, (b if len(b) > 1 else b[0],) + (None,) * (x.ndim - 1))


def act_logits(x):
    """(B, S, V): batch on data axes, vocab on model."""
    if _ACT is None:
        return x
    b = _ACT["batch"]
    lead = (b if b and len(b) > 1 else (b[0] if b else None))
    return _apply(x, (lead,) + (None,) * (x.ndim - 2) + (_ACT["model"],))


def act_heads(x):
    """(B, S, H, D): heads on model (when divisible), batch on data axes."""
    if _ACT is None or _ACT["model"] is None:
        return x
    h = x.shape[2]
    msize = _ACT["mesh"].shape[_ACT["model"]]
    if h % msize:
        return x
    b = _ACT["batch"]
    lead = (b if b and len(b) > 1 else (b[0] if b else None))
    return _apply(x, (lead, None, _ACT["model"], None))


def act_expert(x):
    """(E, C, d): expert dim on model (expert parallelism).

    NOTE (§Perf, refuted hypothesis): additionally sharding the capacity
    dim on the data axes looked like a free 16-32x on the dispatch buffers,
    but the token-indexed scatter/gather then forces XLA to replicate the
    whole buffer per shard (peak 25.6GB -> 113GB on deepseek prefill/multi).
    Expert-major sharding only.
    """
    if _ACT is None:
        return x
    return _apply(x, (_ACT["model"],) + (None,) * (x.ndim - 1))


def batch_pspec(mesh: Mesh, batch: int, ndim: int = 2) -> PartitionSpec:
    axes = batch_axes(mesh, batch)
    lead = axes if axes and len(axes) > 1 else (axes[0] if axes else None)
    return PartitionSpec(lead, *([None] * (ndim - 1)))


def batch_sharding(mesh: Mesh, batch: int, ndim: int = 2) -> NamedSharding:
    return NamedSharding(mesh, batch_pspec(mesh, batch, ndim))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, PartitionSpec())
