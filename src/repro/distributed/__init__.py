from . import compress, hlo_analysis, sharding
