"""Gradient compression with error feedback (distributed-optimization trick).

Two mechanisms:

* **bf16 reduction** -- ``build_train_step(grad_dtype=jnp.bfloat16)`` makes
  the gradient reduce-scatter/all-reduce operands bf16 instead of f32; the
  collective-bytes reduction is directly visible in the dry-run HLO and in
  the §Roofline collective term.

* **int8 error-feedback quantization** -- classic EF-SGD compressor: the
  residual of each quantization step is carried in an f32 buffer and added
  to the next gradient before quantizing, so the *long-run* update is
  unbiased.  ``ef_psum`` wires it through an explicit ``shard_map`` psum for
  the data axes (the operand of the collective is int8 => 4x fewer bytes on
  the wire than f32).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress(g: jax.Array, err: jax.Array):
    """Returns (q, scale, new_err). g, err f32."""
    c = g + err
    q, scale = quantize_int8(c)
    return q, scale, c - dequantize_int8(q, scale)


def ef_compress_tree(grads, errors):
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errors)
    qs, scales, new_e = [], [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = ef_compress(g.astype(jnp.float32), e)
        qs.append(q)
        scales.append(s)
        new_e.append(ne)
    unf = lambda leaves: jax.tree.unflatten(treedef, leaves)
    return unf(qs), unf(scales), unf(new_e)


def decompress_tree(qs, scales):
    return jax.tree.map(dequantize_int8, qs, scales)


def ef_psum(grads, errors, mesh, axes: tuple[str, ...]):
    """Explicit int8-on-the-wire gradient mean over ``axes``.

    Each rank quantizes (grad + error), psums the int8 payload (the HLO
    all-reduce operand is int8), dequantizes with the max scale, and keeps
    its local residual.  Returns (mean_grads, new_errors).
    """
    def local(g, e):
        q, s, ne = ef_compress(g.astype(jnp.float32), e)
        acc = jax.lax.psum(q.astype(jnp.int32), axes)   # int payload on the wire
        smax = jax.lax.pmax(s, axes)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        return (acc.astype(jnp.float32) * smax / n), ne

    fn = jax.shard_map(
        lambda g, e: jax.tree.map(local, g, e),
        mesh=mesh,
        in_specs=(P(), P()),
        out_specs=P(),
        check_vma=False,
    )
    out = fn(grads, errors)
    mean = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    errs = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return mean, errs
