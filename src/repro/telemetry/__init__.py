"""Fleet control room: process-wide metrics registry, cold-start trace
spans, and a periodic stats snapshotter.

Three tiers (see README "Control room"):

  emitters -> MetricsRegistry -> StatsSnapshotter -> results/telemetry/*.jsonl
                                                       -> scripts/control_room.py (dashboard)
                                                       -> scripts/bench_compare.py --history (CI gate)

* :class:`MetricsRegistry` — lock-light counters / gauges / fixed-bucket
  histograms plus a :class:`Trace`/:class:`Span` API for per-invocation
  cold-start traces.  A process-wide default lives at
  :data:`repro.telemetry.TELEMETRY`; emitters take ``registry=None`` and
  fall back to it, and :meth:`MetricsRegistry.disable` turns every
  emission into a no-op (the overhead A/B in the scalability benchmark).
* :class:`StatsSnapshotter` — samples every registered ``stats()``
  surface on a configurable interval into a JSON-lines time series.
  The clock is injected, so tests drive :meth:`StatsSnapshotter.sample`
  sleep-free; the background thread follows the REP004 convention
  (daemon + stop event + joined in :meth:`StatsSnapshotter.stop`).
* :mod:`repro.telemetry.schema` — the one documented stat-key schema
  (canonical names, legacy aliases, per-sample invariants).
"""
from .registry import (  # noqa: F401
    TELEMETRY,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Span,
    Trace,
)
from .schema import LEGACY_ALIASES, SAMPLE_KEYS, canonicalize  # noqa: F401
from .snapshot import StatsSnapshotter, TelemetryConfig  # noqa: F401

__all__ = [
    "TELEMETRY",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Span",
    "Trace",
    "StatsSnapshotter",
    "TelemetryConfig",
    "LEGACY_ALIASES",
    "SAMPLE_KEYS",
    "canonicalize",
]
