"""The one documented stat-key schema.

Historically the serving and benchmark layers drifted: ``Router.summarize``
said ``ws_cache_hits`` while the scalability CSV's derived column said
``ws_hits`` and one benchmark metric block said ``ws_cache_hit_rate``.
This module pins the canonical names; readers that still hold artifacts
written with the old keys go through :func:`canonicalize`.

Canonical keys
==============

Summary blocks (``Router.summarize`` and per-arm benchmark metrics)::

    n                  invocations summarized
    queue_mean_s       mean router queue wait (seconds)
    queue_p95_s        p95 router queue wait
    total_mean_s       mean restore+execute time
    e2e_p50_s          median end-to-end latency
    e2e_p95_s          p95 end-to-end latency
    ws_cache_hits      cold starts served from the shared WS page cache
    ws_cache_hit_rate  hits / (hits + misses) over the run's cache lookups
    cold               cold starts
    cold_fraction      cold / n  — lives at the TOP LEVEL of each summary
                       or per-arm metrics block, never nested
    prewarmed          serves that hit a policy-prewarmed instance
    batched            cold starts restored as part of a fused group
    install_mean_s     mean eager-install seconds
    stage_seconds      per-stage mean seconds (StageTimings field names)
    tail_waits         arena faults that blocked on an in-flight tail
    tail_wait_mean_s   mean seconds spent in those waits

Node stats (``WorkerNode.stats``)::

    node, alive, capacity, load
    warm_instances     {function: idle warm instances} (per-node warm counts)
    router             Router.stats()
    stage_seconds      Orchestrator.stage_seconds()
    tails              Orchestrator.tail_stats()
    ws_cache           WSCache.stats() (when the node owns a private cache)
    policy             PrewarmPolicy.stats() (when a policy is attached)

Content-addressed page store (``PageStore.stats`` — core/pagestore.py,
and the shard tier's ``ShardedSnapshotStore.stats``)::

    store_bytes        live unique-chunk bytes held by the chunk store
    data_bytes         chunks.data file bytes (live + dead, pre-compaction)
    logical_bytes      flat-file-equivalent WS bytes across live manifests
    dedup_ratio        logical_bytes / store_bytes (1.0 for an empty store);
                       >1 means cross-function/intra-WS page sharing
    delta_chunks       chunks a re-record actually appended (delta writes);
                       unchanged pages show up as dedup_hits instead
    dedup_hits         manifest chunks already present at write time
    transfer_bytes     shard-tier bytes shipped — ONLY chunks the
                       requester's L1 was missing (actual-missing charge)
    dedup_bytes_saved  WS bytes a remote fetch did NOT ship because the
                       requester already held the chunks (any function)

Node transport stats (``nodes.<id>.transport`` in a socket-fleet
``ProcessFleet.stats`` — repro.transport; absent on inproc fleets, and
readers like scripts/control_room.py must render a placeholder then)::

    wire_tx_bytes      socket bytes this node put on the wire (frames,
                       client + server side)
    wire_rx_bytes      socket bytes received (frames, both sides)
    shm_bytes          chunk bytes that rode shared-memory segments
    inline_bytes       encoded chunk bytes that rode the socket inline
    raw_chunks         inline chunks shipped unencoded (server codec)
    compressed_chunks  inline chunks shipped compressed
    compress_ratio     logical / wire bytes over the codec'd stream
                       (1.0 for an all-raw or idle stream)
    fetch_rtt_s        {count, sum, p50, p95} of this node's WS-fetch
                       round-trips (negotiate + ship + verify)
    remote_fetches     L1 misses served by a peer's PageServer
    remote_misses      owner dialed but cold (no WS entry to serve)
    origin_reads       fetches that fell through to the origin disk
    dead_owner_fallbacks  fetches where a dead peer (connection refused/
                       reset) forced the origin fallback
    chunks_served      chunks this node's PageServer shipped to peers
    shm_responses / inline_responses  server responses by data plane

Snapshotter samples (one JSON object per line, see
:class:`repro.telemetry.StatsSnapshotter`)::

    t        sample timestamp in the snapshotter's injected-clock domain
    seq      monotonically increasing sample index
    sources  {source_name: that source's stats() dict, or
              {"error": repr} when the source raised}
    errors   cumulative count of source failures so far
"""
from __future__ import annotations

__all__ = ["SAMPLE_KEYS", "LEGACY_ALIASES", "canonicalize"]

#: Keys present in *every* snapshotter sample (schema-stability contract).
SAMPLE_KEYS = ("t", "seq", "sources", "errors")

#: legacy key -> canonical key.  Readers of old artifacts map through
#: :func:`canonicalize`; writers must only emit canonical names.
LEGACY_ALIASES = {
    "ws_hits": "ws_cache_hits",
    "ws_cache_hit": "ws_cache_hits",
    "ws_hit_rate": "ws_cache_hit_rate",
    "warm_counts": "warm_instances",
}


def canonicalize(obj):
    """Recursively rename legacy stat keys to their canonical names.

    Canonical keys win on collision (an artifact carrying both spellings
    keeps the canonical value).  Lists are mapped element-wise; scalars
    pass through untouched.
    """
    if isinstance(obj, dict):
        out = {}
        for k, v in obj.items():
            ck = LEGACY_ALIASES.get(k, k)
            if ck in out and ck != k:
                continue  # canonical spelling already present
            out[ck] = canonicalize(v)
        return out
    if isinstance(obj, list):
        return [canonicalize(v) for v in obj]
    return obj
