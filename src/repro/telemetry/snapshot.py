"""Periodic stats snapshotter: every ``stats()`` surface -> JSONL time
series.

The snapshotter owns *no* statistics of its own — it polls callables
(``Router.stats``, ``Orchestrator.tail_stats``, ``PrewarmPolicy.stats``,
``ShardedSnapshotStore.stats``, ``ClusterRouter.stats``,
``DemandAggregator.stats``, ``MetricsRegistry.collect``) and appends one
JSON object per interval to a bounded in-memory ring and, optionally, a
``.jsonl`` file under ``results/telemetry/``.

Clock and pacing are injected: the background thread (REP004: daemon +
stop event + joined in :meth:`StatsSnapshotter.stop`) paces itself off a
wall ``threading.Event.wait``, but every *sample timestamp* comes from
``self.clock``, and tests bypass the thread entirely by driving
:meth:`sample` / :meth:`maybe_sample` with a fake clock — no sleeps.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from collections import deque
from typing import Any, Callable, Optional

from .registry import TELEMETRY, MetricsRegistry
from .schema import SAMPLE_KEYS  # noqa: F401  (re-exported contract)

__all__ = ["TelemetryConfig", "StatsSnapshotter"]


@dataclasses.dataclass
class TelemetryConfig:
    """Knob block carried on ``ServeConfig.telemetry``.

    ``out_dir=None`` keeps samples in memory only (tests); otherwise each
    snapshotter writes ``<out_dir>/<stream>.jsonl``.
    """

    interval_s: float = 0.25
    out_dir: Optional[str] = "results/telemetry"
    ring: int = 512
    per_node: bool = False     # also run one snapshotter per WorkerNode


class StatsSnapshotter:
    """Samples registered stats sources into a ring + JSONL stream."""

    def __init__(self, *, interval_s: float = 0.25,
                 path: Optional[str] = None, ring: int = 512,
                 clock: Callable[[], float] = time.monotonic,
                 registry: Optional[MetricsRegistry] = None) -> None:
        self.interval_s = float(interval_s)
        self.path = path
        self.clock = clock
        self.registry = TELEMETRY if registry is None else registry
        self.sources: dict[str, Callable[[], Any]] = {}
        self.n_samples = 0
        self.n_errors = 0
        self._ring: deque[dict] = deque(maxlen=int(ring))
        self._last_t: Optional[float] = None
        self._fh = None
        self._mu = threading.Lock()      # leaf: guards ring + seq only
        self._io = threading.Lock()      # leaf: guards the jsonl file
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- sources --------------------------------------------------------

    def add_source(self, name: str, fn: Callable[[], Any]) -> "StatsSnapshotter":
        """Register ``fn`` to be polled as ``sources[name]`` each sample."""
        self.sources[name] = fn
        return self

    # -- sampling -------------------------------------------------------

    def sample(self, now: Optional[float] = None) -> dict:
        """Take one sample immediately.  A raising source is recorded as
        ``{"error": repr(exc)}`` under its name — one dying node must not
        take the time series down with it."""
        now = self.clock() if now is None else now
        polled: dict[str, Any] = {}
        errors = 0
        for name, fn in list(self.sources.items()):
            try:
                polled[name] = fn()
            except Exception as e:
                polled[name] = {"error": repr(e)}
                errors += 1
        with self._mu:
            self.n_errors += errors
            rec = {"t": now, "seq": self.n_samples, "sources": polled,
                   "errors": self.n_errors}
            self.n_samples += 1
            self._last_t = now
            self._ring.append(rec)
        self._write(rec)                 # file I/O outside the ring lock
        return rec

    def maybe_sample(self, now: Optional[float] = None) -> Optional[dict]:
        """Sample only if ``interval_s`` has elapsed since the last sample
        (fake-clock cadence driver); returns the sample or ``None``."""
        now = self.clock() if now is None else now
        last = self._last_t
        if last is not None and now - last < self.interval_s:
            return None
        return self.sample(now)

    def samples(self) -> list[dict]:
        with self._mu:
            return list(self._ring)

    # -- persistence ----------------------------------------------------

    def _write(self, rec: dict) -> None:
        if self.path is None:
            return
        line = json.dumps(rec, default=_json_default) + "\n"
        if self._fh is None:
            # open outside the lock (never hold a lock across file open);
            # a racing opener loses and closes its handle
            d = os.path.dirname(self.path)
            if d:
                os.makedirs(d, exist_ok=True)
            fh = open(self.path, "a", encoding="utf-8")
            keep = False
            with self._io:
                if self._fh is None:
                    self._fh = fh
                    keep = True
            if not keep:
                fh.close()
        with self._io:
            fh = self._fh
            if fh is None:
                return                   # closed concurrently: drop the line
            fh.write(line)
            fh.flush()

    # -- lifecycle (REP004) --------------------------------------------

    def start(self) -> "StatsSnapshotter":
        if self._thread is not None:
            return self
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="stats-snapshotter", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    def close(self) -> None:
        """Stop the thread, take one final sample, and close the file."""
        self.stop()
        if self.sources:
            self.sample()
        with self._io:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            self.sample()


def _json_default(obj):
    """Stats dicts occasionally carry numpy scalars; degrade gracefully."""
    for attr in ("item",):
        f = getattr(obj, attr, None)
        if callable(f):
            try:
                return f()
            except Exception:
                break
    return repr(obj)
