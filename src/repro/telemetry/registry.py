"""Lock-light metrics registry + cold-start trace spans.

Design constraints, in order:

1. **Cheap on the hot path.**  Counters/gauges take one tiny leaf lock
   for the update only; histograms bisect fixed bucket edges under their
   own leaf lock.  No registry lock is ever held while calling out, so
   the static lock-graph analysis sees pure leaves (no ordering edges).
2. **Disable == no-op.**  :meth:`MetricsRegistry.disable` flips one
   boolean checked before any work; the scalability benchmark's
   telemetry-overhead A/B toggles it.
3. **StageTimings stays the stage-seconds sink (REP005).**  Restore
   spans *read* their durations from the just-written ``StageTimings``
   fields — the registry never computes a stage duration itself.
4. **No direct ``time.*`` reads.**  Emitters pass their own injected
   clock's timestamps in; the registry only stores what it is handed.
"""
from __future__ import annotations

import bisect
import math
import dataclasses
import threading
from collections import deque

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Span",
    "Trace",
    "MetricsRegistry",
    "TELEMETRY",
]

# Default histogram edges (seconds): 100us .. ~26s, x2 per bucket.
DEFAULT_EDGES = tuple(1e-4 * 2.0 ** i for i in range(19))


class Counter:
    """Monotonic counter."""

    __slots__ = ("_mu", "_n")

    def __init__(self) -> None:
        self._mu = threading.Lock()
        self._n = 0

    def inc(self, n: int = 1) -> None:
        with self._mu:
            self._n += n

    @property
    def value(self) -> int:
        with self._mu:
            return self._n

    def snapshot(self):
        return self.value


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("_mu", "_v")

    def __init__(self) -> None:
        self._mu = threading.Lock()
        self._v = 0.0

    def set(self, v: float) -> None:
        with self._mu:
            self._v = float(v)

    @property
    def value(self) -> float:
        with self._mu:
            return self._v

    def snapshot(self):
        return self.value


class Histogram:
    """Fixed-bucket histogram; ``edges[i]`` is the inclusive upper bound
    of bucket ``i``, with one implicit overflow bucket at the end."""

    __slots__ = ("edges", "_mu", "_buckets", "_count", "_sum", "_min", "_max")

    def __init__(self, edges=DEFAULT_EDGES) -> None:
        self.edges = tuple(float(e) for e in edges)
        if list(self.edges) != sorted(self.edges):
            raise ValueError("histogram edges must be sorted ascending")
        self._mu = threading.Lock()
        self._buckets = [0] * (len(self.edges) + 1)
        self._count = 0
        self._sum = 0.0
        self._min = None
        self._max = None

    def observe(self, v: float) -> None:
        v = float(v)
        i = bisect.bisect_left(self.edges, v)
        with self._mu:
            self._buckets[i] += 1
            self._count += 1
            self._sum += v
            if self._min is None or v < self._min:
                self._min = v
            if self._max is None or v > self._max:
                self._max = v

    @property
    def count(self) -> int:
        with self._mu:
            return self._count

    def percentile(self, q: float) -> float | None:
        """Bucket-resolution percentile (upper edge of the bucket holding
        the ``q``-th percentile, ``q`` in [0, 100]); None when empty."""
        with self._mu:
            if self._count == 0:
                return None
            rank = min(self._count,
                       max(1, math.ceil(q / 100.0 * self._count)))
            seen = 0
            for i, n in enumerate(self._buckets):
                seen += n
                if seen >= rank:
                    if i < len(self.edges):
                        return self.edges[i]
                    return self._max
            return self._max

    def snapshot(self) -> dict:
        with self._mu:
            return {
                "count": self._count,
                "sum": self._sum,
                "min": self._min,
                "max": self._max,
                "buckets": list(self._buckets),
                "edges": list(self.edges),
            }


@dataclasses.dataclass
class Span:
    """One timed stage inside a :class:`Trace`.  ``start_s`` is in the
    emitting component's clock domain; ``duration_s`` is read from the
    component's own timing sink (StageTimings for restore stages)."""

    name: str
    start_s: float
    duration_s: float
    attrs: dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        d = {"name": self.name, "start_s": self.start_s,
             "duration_s": self.duration_s}
        if self.attrs:
            d["attrs"] = dict(self.attrs)
        return d


class Trace:
    """A per-invocation span list (e.g. one cold start).  Built by one
    thread; the registry keeps a bounded ring of finished traces."""

    __slots__ = ("kind", "attrs", "spans", "_registry")

    def __init__(self, kind: str, attrs: dict | None = None,
                 registry: "MetricsRegistry | None" = None) -> None:
        self.kind = kind
        self.attrs = dict(attrs or {})
        self.spans: list[Span] = []
        self._registry = registry

    def add(self, name: str, start_s: float, duration_s: float,
            **attrs) -> Span:
        span = Span(name, float(start_s), float(duration_s), attrs)
        self.spans.append(span)
        return span

    def finish(self) -> None:
        """Hand the completed trace to the owning registry's ring."""
        if self._registry is not None:
            self._registry._record_trace(self)

    def to_dict(self) -> dict:
        return {"kind": self.kind, "attrs": dict(self.attrs),
                "spans": [s.to_dict() for s in self.spans]}


class _Noop:
    """Stand-in returned by a disabled registry; swallows everything."""

    __slots__ = ()

    def inc(self, n: int = 1) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def add(self, name, start_s, duration_s, **attrs) -> None:
        pass

    def finish(self) -> None:
        pass


_NOOP = _Noop()


class MetricsRegistry:
    """Process-wide named metrics + trace ring.

    The creation lock (``_mu``) guards only the name->metric maps and the
    trace ring; per-metric updates take the metric's own leaf lock.  All
    public methods are safe from any thread.
    """

    def __init__(self, *, trace_ring: int = 256, enabled: bool = True) -> None:
        self._mu = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}
        self._traces: deque[Trace] = deque(maxlen=trace_ring)
        self.enabled = bool(enabled)

    # -- toggles --------------------------------------------------------

    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    # -- metric accessors ----------------------------------------------

    def counter(self, name: str) -> Counter:
        if not self.enabled:
            return _NOOP  # type: ignore[return-value]
        c = self._counters.get(name)
        if c is None:
            with self._mu:
                c = self._counters.setdefault(name, Counter())
        return c

    def gauge(self, name: str) -> Gauge:
        if not self.enabled:
            return _NOOP  # type: ignore[return-value]
        g = self._gauges.get(name)
        if g is None:
            with self._mu:
                g = self._gauges.setdefault(name, Gauge())
        return g

    def histogram(self, name: str, edges=DEFAULT_EDGES) -> Histogram:
        if not self.enabled:
            return _NOOP  # type: ignore[return-value]
        h = self._histograms.get(name)
        if h is None:
            with self._mu:
                h = self._histograms.setdefault(name, Histogram(edges))
        return h

    # -- convenience emitters ------------------------------------------

    def inc(self, name: str, n: int = 1) -> None:
        self.counter(name).inc(n)

    def set_gauge(self, name: str, v: float) -> None:
        self.gauge(name).set(v)

    def observe(self, name: str, v: float) -> None:
        self.histogram(name).observe(v)

    # -- traces ---------------------------------------------------------

    def trace(self, kind: str, **attrs) -> Trace:
        if not self.enabled:
            return _NOOP  # type: ignore[return-value]
        return Trace(kind, attrs, registry=self)

    def _record_trace(self, trace: Trace) -> None:
        with self._mu:
            self._traces.append(trace)

    def traces(self, kind: str | None = None) -> list[Trace]:
        with self._mu:
            ts = list(self._traces)
        if kind is None:
            return ts
        return [t for t in ts if t.kind == kind]

    # -- export ---------------------------------------------------------

    def collect(self) -> dict:
        """Stable-keyed snapshot of every metric (no traces: those are
        bounded-ring debugging payloads, exported separately)."""
        with self._mu:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = dict(self._histograms)
        return {
            "enabled": self.enabled,
            "counters": {k: counters[k].snapshot() for k in sorted(counters)},
            "gauges": {k: gauges[k].snapshot() for k in sorted(gauges)},
            "histograms": {k: hists[k].snapshot() for k in sorted(hists)},
        }

    def reset(self) -> None:
        """Drop every metric and trace (benchmark arm isolation)."""
        with self._mu:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()
            self._traces.clear()


#: Process-wide default registry.  Emitters take ``registry=None`` and
#: fall back to this, mirroring the module-level WS_CACHE convention.
TELEMETRY = MetricsRegistry()
